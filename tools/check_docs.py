"""Docs hygiene checks, run by CI (and locally: `python tools/check_docs.py`).

1. Link check — every RELATIVE markdown link in README.md and docs/*.md
   must resolve to a file or directory in the tree (http(s) and #anchors
   are skipped; `path#anchor` checks only the path part).
2. ISSUE file check — every tree-path-looking backtick reference in
   ISSUE.md (e.g. `docs/ARCHITECTURE.md`, `benchmarks/consensus_bench.py`)
   must exist, so the issue's deliverables cannot silently drop out of the
   tree.

Exits non-zero with a per-problem report on failure.
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# backtick refs in ISSUE.md that look like tree paths (contain a slash and
# one of the repo's top-level dirs); `pkg/mod.py::sym` checks the file part
ISSUE_PATH = re.compile(
    r"`((?:src|docs|tests|benchmarks|examples|tools|\.github)/[^`\s]+)`")


def check_markdown_links(md_path: str) -> list[str]:
    problems = []
    base = os.path.dirname(md_path)
    with open(md_path, encoding="utf-8") as f:
        text = f.read()
    for m in MD_LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = os.path.normpath(os.path.join(base, path))
        if not os.path.exists(resolved):
            problems.append(f"{os.path.relpath(md_path, ROOT)}: "
                            f"broken relative link -> {target}")
    return problems


def check_issue_files(issue_path: str) -> list[str]:
    problems = []
    with open(issue_path, encoding="utf-8") as f:
        text = f.read()
    for m in ISSUE_PATH.finditer(text):
        ref = m.group(1).split("::", 1)[0].rstrip("/")
        if not os.path.exists(os.path.join(ROOT, ref)):
            problems.append(f"ISSUE.md references missing file: {ref}")
    return problems


def main() -> int:
    targets = [os.path.join(ROOT, "README.md")]
    docs_dir = os.path.join(ROOT, "docs")
    if os.path.isdir(docs_dir):
        targets += [os.path.join(docs_dir, n)
                    for n in sorted(os.listdir(docs_dir))
                    if n.endswith(".md")]
    else:
        print("FAIL: docs/ directory missing")
        return 1
    problems = []
    for t in targets:
        problems += check_markdown_links(t)
    issue = os.path.join(ROOT, "ISSUE.md")
    if os.path.exists(issue):
        problems += check_issue_files(issue)
    if problems:
        print(f"FAIL: {len(problems)} docs problem(s)")
        for p in problems:
            print("  -", p)
        return 1
    print(f"OK: {len(targets)} markdown file(s) link-checked, "
          "ISSUE.md file references all present")
    return 0


if __name__ == "__main__":
    sys.exit(main())
