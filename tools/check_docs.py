"""Docs hygiene checks, run by CI (and locally: `python tools/check_docs.py`).

1. Link check — every RELATIVE markdown link in README.md and docs/*.md
   must resolve to a file or directory in the tree (http(s) and #anchors
   are skipped; `path#anchor` checks only the path part).
2. ISSUE file check — every tree-path-looking backtick reference in
   ISSUE.md (e.g. `docs/ARCHITECTURE.md`, `benchmarks/consensus_bench.py`)
   must exist, so the issue's deliverables cannot silently drop out of the
   tree.
3. Eq→code map symbol check — every dotted code reference named in
   docs/ARCHITECTURE.md's "Equation → code map" section (e.g.
   `engine.FusionCenter.combine`, `optim.consensus.adapt_rho`) must still
   import/resolve, so engine refactors cannot silently strand the map
   (rename drift).

Exits non-zero with a per-problem report on failure.
"""
from __future__ import annotations

import importlib
import inspect
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# backtick refs in ISSUE.md that look like tree paths (contain a slash and
# one of the repo's top-level dirs); `pkg/mod.py::sym` checks the file part
ISSUE_PATH = re.compile(
    r"`((?:src|docs|tests|benchmarks|examples|tools|\.github)/[^`\s]+)`")

# backticked pure dotted identifiers (`engine.run_vb`, `model.GMMModel
# .local_optimum`) inside the eq→code map section
DOTTED_SYM = re.compile(r"`([A-Za-z_][A-Za-z0-9_]*(?:\.[A-Za-z0-9_]+)+)`")

# head alias -> import path it abbreviates in the docs.  Heads not listed
# here are skipped (file paths, field names etc. have their own checks).
SYM_ALIASES = {
    "engine": "repro.core.engine",
    "model": "repro.core.model",
    "expfam": "repro.core.expfam",
    "linreg": "repro.core.linreg",
    "gmm": "repro.core.gmm",
    "network": "repro.core.network",
    "stream": "repro.data.stream",
    "algorithms": "repro.core.algorithms",
    "distributed": "repro.core.distributed",
    "backends": "repro.core.backends",
    "optim": "repro.optim",
    "ckpt": "repro.checkpoint.ckpt",
    "vb_service": "repro.serving.vb_service",
    "driver": "repro.serving.driver",
    "admission": "repro.serving.admission",
    "blocks": "repro.core.blocks",
    "hmm": "repro.models.hmm",
    "ppca": "repro.models.ppca",
    "GMMModel": "repro.core.model.GMMModel",
    "LinRegModel": "repro.core.model.LinRegModel",
    "HMMModel": "repro.models.hmm.HMMModel",
    "PPCAModel": "repro.models.ppca.PPCAModel",
    "Backend": "repro.core.backends.Backend",
    "ConsensusDiagnostics": "repro.core.engine.ConsensusDiagnostics",
    "MinibatchSpec": "repro.data.stream.MinibatchSpec",
    "StreamState": "repro.data.stream.StreamState",
    "VBState": "repro.core.engine.VBState",
    "VBService": "repro.serving.vb_service.VBService",
    "VBRequest": "repro.serving.vb_service.VBRequest",
}


def _resolve_symbol(full: str) -> bool:
    """True iff the dotted path resolves: the longest importable module
    prefix, then getattr down; a final attribute that lives on a CLASS in
    the module (protocol/instance methods written `model.take_minibatch`)
    also counts."""
    parts = full.split(".")
    obj, consumed = None, 0
    for i in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:i]))
            consumed = i
            break
        except ImportError:
            continue
    if obj is None:
        return False
    rest = parts[consumed:]
    for j, name in enumerate(rest):
        if hasattr(obj, name):
            obj = getattr(obj, name)
            continue
        if inspect.ismodule(obj) and j == len(rest) - 1:
            # `model.take_minibatch`-style: a method of some class in
            # the module
            return any(hasattr(cls, name)
                       for _, cls in inspect.getmembers(obj, inspect.isclass))
        return False
    return True


def check_eq_code_map(arch_path: str) -> list[str]:
    """Every dotted symbol in the eq→code map section must resolve."""
    if not os.path.exists(arch_path):
        return ["docs/ARCHITECTURE.md missing (eq→code map check)"]
    with open(arch_path, encoding="utf-8") as f:
        text = f.read()
    m = re.search(r"^## Equation → code map$(.*?)(?=^## )", text,
                  re.M | re.S)
    if not m:
        return ["docs/ARCHITECTURE.md: no '## Equation → code map' section"]
    problems, seen = [], set()
    for tok in DOTTED_SYM.findall(m.group(1)):
        if tok in seen:
            continue
        seen.add(tok)
        head = tok.split(".", 1)[0]
        if head not in SYM_ALIASES:
            continue                       # not a code alias we vouch for
        full = SYM_ALIASES[head] + tok[len(head):]
        if not _resolve_symbol(full):
            problems.append(
                f"ARCHITECTURE.md eq→code map: `{tok}` does not resolve "
                f"(tried {full}) — rename drift?")
    if not seen:
        problems.append("ARCHITECTURE.md eq→code map: no symbols found "
                        "(check the table formatting)")
    return problems


def check_markdown_links(md_path: str) -> list[str]:
    problems = []
    base = os.path.dirname(md_path)
    with open(md_path, encoding="utf-8") as f:
        text = f.read()
    for m in MD_LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = os.path.normpath(os.path.join(base, path))
        if not os.path.exists(resolved):
            problems.append(f"{os.path.relpath(md_path, ROOT)}: "
                            f"broken relative link -> {target}")
    return problems


def check_issue_files(issue_path: str) -> list[str]:
    problems = []
    with open(issue_path, encoding="utf-8") as f:
        text = f.read()
    for m in ISSUE_PATH.finditer(text):
        ref = m.group(1).split("::", 1)[0].rstrip("/")
        # `path.py:107`-style line anchors reference the file
        ref = re.sub(r":\d+(?:-\d+)?$", "", ref)
        if not os.path.exists(os.path.join(ROOT, ref)):
            problems.append(f"ISSUE.md references missing file: {ref}")
    return problems


def main() -> int:
    targets = [os.path.join(ROOT, "README.md")]
    docs_dir = os.path.join(ROOT, "docs")
    if os.path.isdir(docs_dir):
        targets += [os.path.join(docs_dir, n)
                    for n in sorted(os.listdir(docs_dir))
                    if n.endswith(".md")]
    else:
        print("FAIL: docs/ directory missing")
        return 1
    problems = []
    for t in targets:
        problems += check_markdown_links(t)
    issue = os.path.join(ROOT, "ISSUE.md")
    if os.path.exists(issue):
        problems += check_issue_files(issue)
    problems += check_eq_code_map(os.path.join(docs_dir, "ARCHITECTURE.md"))
    if problems:
        print(f"FAIL: {len(problems)} docs problem(s)")
        for p in problems:
            print("  -", p)
        return 1
    print(f"OK: {len(targets)} markdown file(s) link-checked, "
          "ISSUE.md file references all present, eq→code map symbols "
          "resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
