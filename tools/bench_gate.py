"""Perf-regression gate over the committed bench trajectory.

`benchmarks/run.py --json` emits `{name: {us_per_call, derived}}` rows;
`BENCH_engine.json` at the repo root is the committed baseline that
accumulates across PRs.  Until now nothing CHECKED those rows — a PR
could silently double `vb_driver_poisson`'s per-slice cost or break the
fused-backend speedup and CI would still be green.  This gate closes
that loop; CI runs it against a fresh snapshot on every push
(.github/workflows/ci.yml, plus a negative test that degrades a row and
asserts the gate fails).

Two kinds of checks, tuned for very different noise profiles:

1. **Timing ratios** — `fresh.us_per_call <= baseline * max_ratio +
   ABS_SLACK_US`.  CI machines differ wildly from the machine that
   committed the baseline (container CPU vs laptop, thermal throttling,
   noisy neighbors), so the default ratio is deliberately generous
   (4.0x): it catches complexity-class regressions (an accidental
   O(N^2) materialization, a lost jit cache causing per-tick retraces),
   not 10% drifts.  Per-row overrides in `MAX_RATIO` tighten or loosen
   individual rows; the absolute slack keeps sub-millisecond rows from
   flapping on scheduler jitter.
2. **Derived-metric rules** — machine-INDEPENDENT assertions parsed
   from the `key=value` tokens each bench packs into its `derived`
   string (speedups, KL ratios, compile counts, bit-exactness flags).
   These are exact semantics, so the bounds are tight: e.g. the driver
   must keep `compiles=1` and `speedup_vs_sync>=2`, SVRG must keep its
   variance win, kernels must stay within oracle tolerance.  A rule is
   skipped when its row is absent from the fresh snapshot (partial
   `--only` runs) or its key does not parse — `--strict` turns those
   skips into failures.

Run `python tools/bench_gate.py` with no arguments to self-check the
committed baseline (fresh defaults to baseline: ratios are 1.0 and the
derived rules validate the committed values themselves).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Absolute slack added to every timing bound: sub-millisecond rows can
# double on scheduler jitter alone without meaning anything.
ABS_SLACK_US = 500.0

# Default and per-row fresh/baseline wall-time ratio ceilings.
DEFAULT_MAX_RATIO = 4.0
MAX_RATIO = {
    # the telemetry acceptance row: disabled-by-default overhead must be
    # unmeasurable, so this row gets no extra headroom beyond the
    # cross-machine guard
    "vb_driver_poisson": 4.0,
    # interpret-mode Pallas kernels: python-loop dominated, very stable
    "kernel_flash_attention": 3.0,
    "kernel_ssd_scan": 3.0,
    "kernel_gmm_estep": 3.0,
    # large-N sparse rows are long enough to be timing-stable
    "topology_scale_sparse_diffusion_n10000": 3.0,
    "topology_scale_gossip_n10000": 3.0,
    "topology_scale_hierarchical_n10000": 3.0,
}

# Machine-independent rules: name -> [(derived key, op, bound)].
# ops: "<=", ">=", "==" (== compares bools/strings verbatim).
DERIVED_RULES = {
    "vb_driver_poisson": [("speedup_vs_sync", ">=", 2.0),
                          ("compiles", "<=", 1)],
    "vb_service_throughput": [("speedup_vs_sequential", ">=", 2.0)],
    "vb_service_mixed": [("ratio_vs_same_shape", ">=", 0.5),
                         ("groups", "<=", 1),
                         ("compiles", "<=", 1)],
    "svrg_vb": [("kl_ratio_equal_iters", "<=", 0.5),
                ("degen_bitexact", "==", True)],
    "minibatch_vb": [("kl_ratio_equal_flops", "<=", 0.5)],
    "kernel_flash_attention": [("max_err_vs_oracle", "<=", 1e-4)],
    "kernel_ssd_scan": [("max_err_vs_oracle", "<=", 1e-4)],
    "kernel_gmm_estep": [("max_err_vs_oracle", "<=", 1e-4)],
    "backend_speedup": [("max_rel_phi_err", "<=", 1e-5)],
    "consensus_lm_training": [("resid_diff", "<=", 1e-6)],
    "topology_scale_sparse_diffusion_n10000": [("no_nxn_hlo", "==", True)],
    "topology_scale_gossip_n10000": [("no_nxn_hlo", "==", True)],
    "topology_scale_hierarchical_n10000": [("no_nxn_hlo", "==", True)],
}


def parse_derived(derived: str) -> dict:
    """`key=value` tokens of a bench row's derived string, typed.

    >>> d = parse_derived("speedup_vs_sync=2.4x compiles=1 ok=True x y=")
    >>> d["speedup_vs_sync"], d["compiles"], d["ok"]
    (2.4, 1.0, True)
    >>> "x" in d or "y" in d
    False
    """
    out = {}
    for tok in str(derived).split():
        if "=" not in tok:
            continue
        key, _, val = tok.partition("=")
        if not key or not val:
            continue
        if val in ("True", "False"):
            out[key] = val == "True"
            continue
        if val.endswith("x"):
            val = val[:-1]
        try:
            out[key] = float(val)
        except ValueError:
            out[key] = tok.partition("=")[2]        # keep the raw string
    return out


def _check_rule(value, op: str, bound):
    if op == "<=":
        return float(value) <= float(bound)
    if op == ">=":
        return float(value) >= float(bound)
    if op == "==":
        return value == bound
    raise ValueError(f"unknown op {op!r}")


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def gate(baseline: dict, fresh: dict, *, max_ratio: float,
         only: tuple = (), strict: bool = False) -> tuple:
    """Returns (failures, checks) — lists of human-readable lines.  An
    empty failure list is a pass."""
    failures, checks = [], []
    base_rows = baseline.get("results", {})
    fresh_rows = fresh.get("results", {})
    if only:
        fresh_rows = {n: r for n, r in fresh_rows.items()
                      if n.startswith(only)}

    for name in fresh.get("failed", []):
        failures.append(f"{name}: bench FAILED in fresh snapshot")

    for name, row in sorted(fresh_rows.items()):
        us = float(row.get("us_per_call") or 0.0)
        base = base_rows.get(name)
        if base is not None and base.get("us_per_call"):
            base_us = float(base["us_per_call"])
            if base_us > 0 and us == us:            # NaN-safe
                ratio = MAX_RATIO.get(name, max_ratio)
                bound = base_us * ratio + ABS_SLACK_US
                line = (f"{name}: {us:.1f}us vs baseline "
                        f"{base_us:.1f}us (<= {ratio}x + "
                        f"{ABS_SLACK_US:.0f}us)")
                if us > bound:
                    failures.append("TIMING " + line)
                else:
                    checks.append("timing  ok  " + line)
        for key, op, ref in DERIVED_RULES.get(name, ()):
            vals = parse_derived(row.get("derived", ""))
            if key not in vals:
                msg = f"{name}: derived key {key!r} missing"
                (failures if strict else checks).append(
                    ("MISSING " if strict else "derived skip ") + msg)
                continue
            line = f"{name}: {key}={vals[key]} ({op} {ref})"
            if _check_rule(vals[key], op, ref):
                checks.append("derived ok  " + line)
            else:
                failures.append("DERIVED " + line)
    if not fresh_rows:
        failures.append("no fresh rows matched — nothing was gated")
    return failures, checks


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline",
                    default=os.path.join(_ROOT, "BENCH_engine.json"),
                    help="committed snapshot (default: BENCH_engine.json)")
    ap.add_argument("--fresh", default=None,
                    help="fresh benchmarks/run.py --json output "
                         "(default: the baseline itself — a self-check "
                         "of the committed values)")
    ap.add_argument("--max-ratio", type=float, default=DEFAULT_MAX_RATIO,
                    help="default fresh/baseline wall-time ceiling "
                         f"(default {DEFAULT_MAX_RATIO}; per-row "
                         "overrides in MAX_RATIO)")
    ap.add_argument("--only", default=None,
                    help="comma-separated row-name prefixes to gate")
    ap.add_argument("--strict", action="store_true",
                    help="fail when a DERIVED_RULES key is missing "
                         "instead of skipping it")
    ap.add_argument("--quiet", action="store_true",
                    help="print failures only")
    args = ap.parse_args(argv)

    baseline = load(args.baseline)
    fresh = load(args.fresh) if args.fresh else baseline
    failures, checks = gate(
        baseline, fresh, max_ratio=args.max_ratio,
        only=tuple(args.only.split(",")) if args.only else (),
        strict=args.strict)
    if not args.quiet:
        for line in checks:
            print(line)
    for line in failures:
        print("FAIL " + line, file=sys.stderr)
    n_rows = len(fresh.get("results", {}))
    if failures:
        print(f"bench gate: {len(failures)} failure(s) over {n_rows} "
              f"rows", file=sys.stderr)
        return 1
    print(f"bench gate: PASS ({len(checks)} checks over {n_rows} rows)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
