"""AdamW on arbitrary pytrees (optax is not available offline).

Optimizer moments are kept float32 regardless of parameter dtype (mixed
precision: bf16 params, f32 state, f32 master update path).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    mu: dict
    nu: dict
    count: jnp.ndarray


def init(params) -> AdamState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamState(mu=jax.tree.map(zeros, params),
                     nu=jax.tree.map(zeros, params),
                     count=jnp.zeros((), jnp.int32))


def update(grads, state: AdamState, params, *, lr, b1: float = 0.9,
           b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1):
    """Returns (new_params, new_state).  lr may be a traced scalar."""
    count = state.count + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** cf
    bc2 = 1.0 - b2 ** cf

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * gf
        v = b2 * v + (1.0 - b2) * gf * gf
        step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, m, v

    flat = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamState(mu=new_mu, nu=new_nu, count=count)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm
