from repro.optim import adamw, consensus, schedules  # noqa: F401
