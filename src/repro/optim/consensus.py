"""The paper's technique as a data-parallel consensus layer for training.

Classical data parallelism computes the exact average of per-replica updates
every step — an all-reduce, the direct analogue of the fusion-centre VBM
solution Eq. 20 (cVB).  The paper replaces the fusion centre with one-hop
neighbour exchanges; lifted to training on a TPU mesh, the "sensor graph"
becomes the ICI/DCI ring along a mesh axis and the natural parameters become
the model parameters (Gaussian mean-field natural parameter with fixed
covariance == the weight itself; see DESIGN.md §2):

* `dp_mode="diffusion"` (dSVB, Eqs. 27a/27b): each replica takes its local
  optimiser step (the stochastic natural-gradient step — the lr schedule
  plays eta_t's Robbins-Monro role) and then combines parameters with its
  ring neighbours using nearest-neighbour weights (Eq. 47, w = 1/3 each).
* `dp_mode="admm"` (dVB-ADMM, Eqs. 38a/39/40): consensus-ADMM on the
  parameters with per-replica aggregate duals lambda_i and the kappa_t ramp.
  The primal step treats the locally-updated parameters as phi*_i; the
  projection (38b) is a no-op here because the parameter space of a weight
  is all of R^n (Omega = R^n) — noted in DESIGN.md.

Both run INSIDE a shard_map whose manual axis is the consensus axis
("data" single-pod, "pod" multi-pod); everything uses lax.ppermute — the
cheapest collective on a torus — instead of all-reduce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine import ring_combine, ring_neighbors
from repro.dist import compat

_ring_neighbors = ring_neighbors   # backward-compatible alias


def ring_size(axis: str) -> int:
    return compat.axis_size(axis)


# ---------------------------------------------------------------------------
# dSVB-style diffusion (Eq. 27b with nearest-neighbour weights on a ring)
# — per-tensor form of the engine's RingDiffusion primitive
# ---------------------------------------------------------------------------
def diffusion_combine(params, axis: str, w_self: float = 1.0 / 3.0):
    def comb(p):
        out = ring_combine(p, axis, w_self, compute_dtype=jnp.float32)
        return out.astype(p.dtype)

    return jax.tree.map(comb, params)


# ---------------------------------------------------------------------------
# dVB-ADMM consensus (Eqs. 38a / 39 on a ring; deg_i = 2)
# ---------------------------------------------------------------------------
def admm_init_duals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def admm_step(params_star, params_prev, duals, axis: str, *, rho: float,
              kappa):
    """One primal+dual ADMM consensus round.

    params_star: locally-optimised parameters (phi*_i of Eq. 18 — here the
    post-AdamW parameters).  params_prev: last round's consensus iterate.
    Returns (new_params, new_duals).
    """
    deg = 2.0

    def primal(p_star, p_prev, lam):
        left, right = _ring_neighbors(p_prev.astype(jnp.float32), axis)
        num = (p_star.astype(jnp.float32) - 2.0 * lam
               + rho * (deg * p_prev.astype(jnp.float32) + left + right))
        return (num / (1.0 + 2.0 * rho * deg)).astype(p_star.dtype)

    new_params = jax.tree.map(primal, params_star, params_prev, duals)

    def dual(lam, p_new):
        left, right = _ring_neighbors(p_new.astype(jnp.float32), axis)
        resid = deg * p_new.astype(jnp.float32) - left - right
        return lam + kappa * rho / 2.0 * resid

    new_duals = jax.tree.map(dual, duals, new_params)
    return new_params, new_duals


# ---------------------------------------------------------------------------
# Disagreement diagnostic (how far replicas are from consensus)
# ---------------------------------------------------------------------------
def consensus_residual(params, axis: str) -> jnp.ndarray:
    """mean over tensors of ||phi_i - mean_j phi_j||^2 (cheap: psum)."""
    def res(p):
        pf = p.astype(jnp.float32)
        mean = jax.lax.pmean(pf, axis)
        return jnp.mean((pf - mean) ** 2)

    leaves = jax.tree.leaves(jax.tree.map(res, params))
    return jnp.mean(jnp.stack(leaves))
