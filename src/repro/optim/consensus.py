"""The paper's technique as a data-parallel consensus layer for training.

Classical data parallelism computes the exact average of per-replica updates
every step — an all-reduce, the direct analogue of the fusion-centre VBM
solution Eq. 20 (cVB).  The paper replaces the fusion centre with one-hop
neighbour exchanges; lifted to training on a TPU mesh, the "sensor graph"
becomes the ICI/DCI ring along a mesh axis and the natural parameters become
the model parameters (Gaussian mean-field natural parameter with fixed
covariance == the weight itself; see DESIGN.md §2):

* `dp_mode="diffusion"` (dSVB, Eqs. 27a/27b): each replica takes its local
  optimiser step (the stochastic natural-gradient step — the lr schedule
  plays eta_t's Robbins-Monro role) and then combines parameters with its
  ring neighbours using nearest-neighbour weights (Eq. 47, w = 1/3 each).
* `dp_mode="admm"` (dVB-ADMM, Eqs. 38a/39/40): consensus-ADMM on the
  parameters with per-replica aggregate duals lambda_i and the kappa_t ramp.
  The primal step treats the locally-updated parameters as phi*_i; the
  projection (38b) is a no-op here because the parameter space of a weight
  is all of R^n (Omega = R^n) — noted in DESIGN.md.

Both run INSIDE a shard_map whose manual axis is the consensus axis
("data" single-pod, "pod" multi-pod); everything uses lax.ppermute — the
cheapest collective on a torus — instead of all-reduce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine import (residual_balanced_rho, ring_combine,
                               ring_neighbors)
from repro.dist import compat

_ring_neighbors = ring_neighbors   # backward-compatible alias


def ring_size(axis: str) -> int:
    return compat.axis_size(axis)


# ---------------------------------------------------------------------------
# dSVB-style diffusion (Eq. 27b with nearest-neighbour weights on a ring)
# — per-tensor form of the engine's RingDiffusion primitive
# ---------------------------------------------------------------------------
def diffusion_combine(params, axis: str, w_self: float = 1.0 / 3.0):
    def comb(p):
        out = ring_combine(p, axis, w_self, compute_dtype=jnp.float32)
        return out.astype(p.dtype)

    return jax.tree.map(comb, params)


# ---------------------------------------------------------------------------
# dVB-ADMM consensus (Eqs. 38a / 39 on a ring; deg_i = 2)
# ---------------------------------------------------------------------------
def admm_init_duals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def admm_step(params_star, params_prev, duals, axis: str, *, rho: float,
              kappa, return_residuals: bool = False):
    """One primal+dual ADMM consensus round.

    params_star: locally-optimised parameters (phi*_i of Eq. 18 — here the
    post-AdamW parameters).  params_prev: last round's consensus iterate.
    Returns (new_params, new_duals), plus the global (||r||, ||s||) RMS
    residual norms when `return_residuals` — computed from the SAME ring
    exchange the dual ascent already performs, so the observability is
    communication-free.
    """
    deg = 2.0

    def primal(p_star, p_prev, lam):
        left, right = _ring_neighbors(p_prev.astype(jnp.float32), axis)
        num = (p_star.astype(jnp.float32) - 2.0 * lam
               + rho * (deg * p_prev.astype(jnp.float32) + left + right))
        return (num / (1.0 + 2.0 * rho * deg)).astype(p_star.dtype)

    new_params = jax.tree.map(primal, params_star, params_prev, duals)

    def ring_resid(p_new):                    # Eq. 39: 2 p_i - p_{i-1} - p_{i+1}
        pf = p_new.astype(jnp.float32)
        left, right = _ring_neighbors(pf, axis)
        return deg * pf - left - right

    resid = jax.tree.map(ring_resid, new_params)
    new_duals = jax.tree.map(lambda lam, r: lam + kappa * rho / 2.0 * r,
                             duals, resid)
    if not return_residuals:
        return new_params, new_duals
    return new_params, new_duals, _rms_norms(
        jax.tree.leaves(resid),
        [rho * (pn.astype(jnp.float32) - pp.astype(jnp.float32))
         for pn, pp in zip(jax.tree.leaves(new_params),
                           jax.tree.leaves(params_prev))], axis)


# ---------------------------------------------------------------------------
# Adaptive penalty for the training-layer ADMM mode — the VB engine's
# residual-balancing rule (engine.residual_balanced_rho) on ring residuals
# ---------------------------------------------------------------------------
def _rms_norms(r_leaves, s_leaves, axis: str):
    """Global RMS norms of two residual leaf-lists (psum over `axis`)."""
    r_sq = sum(jnp.sum(r * r) for r in r_leaves)
    s_sq = sum(jnp.sum(s * s) for s in s_leaves)
    n = sum(r.size for r in r_leaves)
    r_sq = jax.lax.psum(r_sq, axis)
    s_sq = jax.lax.psum(s_sq, axis)
    n = jax.lax.psum(jnp.asarray(n, jnp.float32), axis)
    return jnp.sqrt(r_sq / n), jnp.sqrt(s_sq / n)


def admm_residual_norms(params_new, params_prev, axis: str, *, rho):
    """(||r||, ||s||) of one ADMM consensus round on the ring, as global
    RMS norms over all tensors and replicas (psum over `axis`).

    r is the Eq. 39 disagreement 2 p_i - p_{i-1} - p_{i+1}; s is Boyd's
    dual residual rho (p^t - p^{t-1}).  Feed them to `adapt_rho` between
    training steps to residual-balance `rho` exactly like the VB engine's
    `ADMMConsensus(adaptive_rho=True)` does per VB iteration.  (Inside
    `admm_step(return_residuals=True)` the same norms ride along on the
    dual update's own ring exchange — prefer that form on a hot path.)
    """
    r_leaves, s_leaves = [], []
    for p_new, p_prev in zip(jax.tree.leaves(params_new),
                             jax.tree.leaves(params_prev)):
        pf = p_new.astype(jnp.float32)
        left, right = _ring_neighbors(pf, axis)
        r_leaves.append(2.0 * pf - left - right)
        s_leaves.append(rho * (pf - p_prev.astype(jnp.float32)))
    return _rms_norms(r_leaves, s_leaves, axis)


def adapt_rho(rho, r_norm, s_norm, *, mu: float = 10.0,
              tau_incr: float = 2.0, tau_decr: float = 2.0,
              rho_min: float = 1e-3, rho_max: float = 1e3):
    """Residual-balance the training-layer ADMM penalty (Boyd Sec. 3.4.1);
    thin alias of the engine rule so both layers share one implementation."""
    return residual_balanced_rho(rho, r_norm, s_norm, mu=mu,
                                 tau_incr=tau_incr, tau_decr=tau_decr,
                                 rho_min=rho_min, rho_max=rho_max)


# ---------------------------------------------------------------------------
# Disagreement diagnostic (how far replicas are from consensus)
# ---------------------------------------------------------------------------
def consensus_residual(params, axis: str) -> jnp.ndarray:
    """mean over tensors of ||phi_i - mean_j phi_j||^2 (cheap: psum)."""
    def res(p):
        pf = p.astype(jnp.float32)
        mean = jax.lax.pmean(pf, axis)
        return jnp.mean((pf - mean) ** 2)

    leaves = jax.tree.leaves(jax.tree.map(res, params))
    return jnp.mean(jnp.stack(leaves))
