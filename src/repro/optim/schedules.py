"""Learning-rate and consensus-step schedules.

`eta` / `kappa` are the paper's Eq. 29 / Eq. 40 — reused verbatim by the
consensus optimiser wrappers (repro.optim.consensus) so the framework layer
runs the same schedules the faithful layer validated.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.algorithms import eta_schedule as eta      # noqa: F401  Eq. 29
from repro.core.algorithms import kappa_schedule as kappa  # noqa: F401  Eq. 40


def cosine_warmup(step, *, peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    s = step.astype(jnp.float32)
    warm = s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1.0 - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return peak_lr * jnp.where(s < warmup, warm, cos)
