"""Granite-8B (code) — llama-architecture dense, GQA kv=8 [arXiv:2405.04324]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", arch_type="dense", n_layers=36, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=49152, head_dim=128,
    citation="arXiv:2405.04324",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        head_dim=32, vocab_size=512,
        param_dtype="float32", compute_dtype="float32")
