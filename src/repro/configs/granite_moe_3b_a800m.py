"""Granite-3.0 MoE 3B-A800M [hf:ibm-granite/granite-3.0-1b-a400m-base family].

32 layers, d_model 1536, GQA kv=8 (head_dim 64), MoE with 40 experts top-8,
per-expert d_ff = 512 (task-header spec; the bracket note "32 experts" is
superseded — see DESIGN.md §7).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", arch_type="moe", n_layers=32, d_model=1536,
    n_heads=24, n_kv_heads=8, d_ff=512, vocab_size=49155, head_dim=64,
    n_experts=40, experts_per_token=8,
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=64,
        head_dim=32, vocab_size=512, n_experts=4, experts_per_token=2,
        param_dtype="float32", compute_dtype="float32")
