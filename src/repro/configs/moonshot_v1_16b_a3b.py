"""Moonlight-16B-A3B (moonshot) [hf:moonshotai/Moonlight-16B-A3B].

Task header tags it [dense] but specifies MoE 64 experts top-6 with
per-expert d_ff 1408 — implemented as MoE (matches the model card; see
DESIGN.md §7).  48 layers, d_model 2048, kv=16.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", arch_type="moe", n_layers=48, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab_size=163840, head_dim=128,
    n_experts=64, experts_per_token=6,
    citation="hf:moonshotai/Moonlight-16B-A3B",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=64,
        head_dim=32, vocab_size=512, n_experts=4, experts_per_token=2,
        param_dtype="float32", compute_dtype="float32")
