"""Config system: architecture + run configuration.

Every assigned architecture gets a module `src/repro/configs/<id>.py`
exporting `CONFIG: ModelConfig` (the exact published shape) and
`smoke_config()` (a reduced same-family variant for CPU tests).  The registry
resolves `--arch <id>` names for the launcher, dry-run and benchmarks.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None      # defaults to d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4
    # --- hybrid (recurrentgemma) ---
    # layer pattern, tiled to n_layers: "attn" | "rec" | "ssm"
    block_pattern: Tuple[str, ...] = ("attn",)
    lru_width: Optional[int] = None
    # --- attention flavour ---
    window: int = 0                     # >0: sliding-window ("local") attention
    rope_theta: float = 10000.0
    rope_style: str = "full"            # full | half (chatglm 2d) | mrope (qwen2-vl)
    mrope_sections: Tuple[int, ...] = ()
    # --- modality frontend (stub per task carve-out) ---
    frontend: str = "none"              # none | vision_stub | audio_stub
    frontend_len: int = 0               # positions consumed by stub embeddings
    # --- misc ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    citation: str = ""
    # --- numerics / partitioning knobs (run-level, overridable) ---
    # flat-head attention: broadcast KV to all query heads so the (fused)
    # head axis shards cleanly over "model" even when n_kv_heads doesn't
    # divide it (kills GSPMD resharding thrash; §Perf hillclimb knob)
    attn_flat_heads: bool = False
    # bound each query chunk's keys to [chunk_end - window, chunk_end) via
    # dynamic_slice instead of masking the full row (§Perf hillclimb knob)
    windowed_kv: bool = False
    # MoE: route/scatter per data shard (shard_map, per-shard capacity —
    # the Switch-Transformer "per-core" semantics) instead of one global
    # dispatch buffer whose scatter crosses every shard (§Perf knob).
    # Requires expert weights replicated over "data" (no fsdp on them).
    moe_local_dispatch: bool = False
    # pad embedding/unembedding tables to this size so the vocab axis
    # shards over "model" (0 = no padding).  Padded logit columns are
    # masked to -1e30 (§Perf knob; granite-moe's 49155 is indivisible).
    vocab_pad: int = 0
    # query-chunk length of the blocked attention (peak logits memory
    # scales linearly with it; §Perf memory knob)
    attn_q_chunk: int = 1024
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    fsdp: bool = True                   # shard fsdp dim of weights over "data"
    remat: bool = True                  # activation-checkpoint each layer
    scan_layers: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Resolved per-layer block kinds of length n_layers."""
        pat = self.block_pattern
        reps = (self.n_layers + len(pat) - 1) // len(pat)
        return tuple((pat * reps)[: self.n_layers])

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def n_params(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs in roofline)."""
        from repro.models.model import param_count
        return param_count(self)

    def n_active_params(self) -> int:
        from repro.models.model import param_count
        return param_count(self, active_only=True)


@dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                           # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = (
    "musicgen_large",
    "mamba2_370m",
    "recurrentgemma_2b",
    "yi_6b",
    "granite_moe_3b_a800m",
    "granite_8b",
    "moonshot_v1_16b_a3b",
    "qwen2_vl_2b",
    "grok_1_314b",
    "chatglm3_6b",
)


def canonical(arch: str) -> str:
    return arch.replace("-", "_")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.smoke_config()


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
