"""RecurrentGemma-2B — RG-LRU + local attention, 2:1 [arXiv:2402.19427].

26 layers, pattern (rec, rec, attn); local sliding-window attention
(window 2048) with MQA (kv=1, head_dim 256).  lru_width = d_model = 2560.
`long_500k` runs natively (bounded window + recurrent state).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", arch_type="hybrid", n_layers=26, d_model=2560,
    n_heads=10, n_kv_heads=1, d_ff=7680, vocab_size=256000, head_dim=256,
    block_pattern=("rec", "rec", "attn"), lru_width=2560, window=2048,
    windowed_kv=True,   # O(S*window) local attention (PerfLog: -71% Tc)
    scan_layers=False, tie_embeddings=True,
    citation="arXiv:2402.19427",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=128, n_heads=4, n_kv_heads=1, d_ff=256,
        head_dim=32, vocab_size=512, lru_width=128, window=16,
        param_dtype="float32", compute_dtype="float32")
