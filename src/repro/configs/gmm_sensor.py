"""The paper's own experiment configuration (Sec. V-A): Bayesian GMM over a
50-node random geometric sensor network."""
from dataclasses import dataclass


@dataclass(frozen=True)
class GMMSensorConfig:
    n_nodes: int = 50
    n_per_node: int = 100
    K: int = 3
    D: int = 2
    comm_radius: float = 0.8
    tau: float = 0.2          # dSVB forgetting rate (Fig. 3 optimum)
    d0: float = 1.0
    rho: float = 0.5          # ADMM penalty (Fig. 7 choice)
    xi: float = 0.05          # kappa ramp (Eq. 40)
    n_iters: int = 2000
    alpha0: float = 1.0
    beta0: float = 0.1
    w0_scale: float = 10.0


CONFIG = GMMSensorConfig()
