"""Qwen2-VL-2B language backbone [arXiv:2409.12191].

M-RoPE: the hd/2 = 64 rotary frequency slots are split into (t, h, w)
sections (16, 24, 24), each driven by its own position-id stream.  The
vision tower (ViT + merger) is a stub per the task carve-out: input_specs
supplies `frontend_len` precomputed patch embeddings (dynamic-resolution
token counts are represented by the fixed stub length in the dry-run).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", arch_type="vlm", n_layers=28, d_model=1536,
    n_heads=12, n_kv_heads=2, d_ff=8960, vocab_size=151936, head_dim=128,
    rope_style="mrope", mrope_sections=(16, 24, 24), rope_theta=1e6,
    frontend="vision_stub", frontend_len=256, tie_embeddings=True,
    citation="arXiv:2409.12191",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        head_dim=32, mrope_sections=(8, 4, 4), vocab_size=512,
        frontend_len=8,
        param_dtype="float32", compute_dtype="float32")
