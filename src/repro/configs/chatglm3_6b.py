"""ChatGLM3-6B [arXiv:2406.12793] — dense, GQA kv=2, 2-d RoPE.

GLM applies rotary embeddings to only the first half of each head's dims
("RoPE 2d"); implemented as rope_style="half".
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", arch_type="dense", n_layers=28, d_model=4096,
    n_heads=32, n_kv_heads=2, d_ff=13696, vocab_size=65024, head_dim=128,
    rope_style="half",
    citation="arXiv:2406.12793",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        head_dim=32, vocab_size=512,
        param_dtype="float32", compute_dtype="float32")
