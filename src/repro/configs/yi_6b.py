"""Yi-6B — llama-architecture dense decoder with GQA kv=4 [arXiv:2403.04652]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", arch_type="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=4, d_ff=11008, vocab_size=64000, head_dim=128,
    rope_theta=5e6,
    citation="arXiv:2403.04652",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        head_dim=32, vocab_size=512,
        param_dtype="float32", compute_dtype="float32")
