"""Mamba-2 370M — SSD (state-space duality) [arXiv:2405.21060].

Attention-free; d_ff=0 (no MLP — the Mamba block is the whole layer).
d_inner = 2*1024 = 2048, head_dim 64 -> 32 SSD heads, state N=128.
`long_500k` runs natively (recurrent state, O(1) per decoded token).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", arch_type="ssm", n_layers=48, d_model=1024,
    n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    block_pattern=("ssm",), tie_embeddings=True,
    citation="arXiv:2405.21060",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, vocab_size=512, ssm_state=16,
        ssm_head_dim=32, ssm_chunk=16,
        param_dtype="float32", compute_dtype="float32")
