"""Grok-1 314B [hf:xai-org/grok-1] — MoE 8 experts top-2.

64 layers, d_model 6144, 48 heads GQA kv=8, per-expert d_ff 32768.  The
largest assigned config — exercises fsdp weight sharding and expert-ff
model-parallel sharding in the dry-run.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", arch_type="moe", n_layers=64, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=32768, vocab_size=131072, head_dim=128,
    n_experts=8, experts_per_token=2,
    citation="hf:xai-org/grok-1",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=128,
        head_dim=32, vocab_size=512, n_experts=4, experts_per_token=2,
        param_dtype="float32", compute_dtype="float32")
