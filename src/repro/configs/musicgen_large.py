"""MusicGen-Large language-model backbone [arXiv:2306.05284].

Decoder-only transformer over EnCodec audio tokens (vocab 2048).  The audio
frontend (EnCodec codec / text conditioner) is a stub per the task carve-out:
input_specs supplies `frontend_len` precomputed conditioning embeddings.
kv = 32 == n_heads (no GQA grouping — MHA, as in the released model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", arch_type="audio", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=2048, head_dim=64,
    frontend="audio_stub", frontend_len=256,
    citation="arXiv:2306.05284",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        head_dim=32, vocab_size=512, frontend_len=8,
        param_dtype="float32", compute_dtype="float32")
