"""Training loop driver: sharded state, host data pipeline, metrics, ckpt."""
from __future__ import annotations

import time
from typing import Optional

import jax
import numpy as np

from repro import checkpoint
from repro.configs.base import ModelConfig
from repro.dist import compat
from repro.data.tokens import Batcher
from repro.training import train_step as ts


class Trainer:
    def __init__(self, cfg: ModelConfig, mesh, *, dp_mode: str = "allreduce",
                 consensus_axis: Optional[str] = None,
                 hyper: ts.TrainHyper = ts.TrainHyper(),
                 global_batch: int = 8, seq_len: int = 256, seed: int = 0,
                 ckpt_dir: Optional[str] = None, use_kernels: bool = False):
        self.cfg, self.mesh = cfg, mesh
        self.dp_mode, self.axis = dp_mode, consensus_axis
        self.ckpt_dir = ckpt_dir
        n_rep = (dict(zip(mesh.axis_names, mesh.devices.shape))
                 .get(consensus_axis, 1)) if consensus_axis else 1
        key = jax.random.PRNGKey(seed)
        state = ts.init_state(cfg, key, dp_mode=dp_mode, n_replicas=n_rep,
                              hyper=hyper)
        self.shardings = ts.state_shardings(state, cfg, mesh, dp_mode=dp_mode,
                                            consensus_axis=consensus_axis)
        self.state = jax.device_put(state, self.shardings)
        self.batch_shd = ts.batch_sharding(mesh)
        self.batcher = Batcher(cfg.vocab_size, global_batch, seq_len,
                               seed=seed, frontend_len=cfg.frontend_len,
                               d_model=cfg.d_model)
        step_fn = ts.make_train_step(cfg, mesh, dp_mode=dp_mode,
                                     consensus_axis=consensus_axis,
                                     hyper=hyper, use_kernels=use_kernels)
        self.step_fn = jax.jit(step_fn, donate_argnums=0)
        self.history: list[dict] = []

    def run(self, n_steps: int, log_every: int = 10) -> list[dict]:
        with compat.use_mesh(self.mesh):
            t0 = time.time()
            for i in range(n_steps):
                batch = jax.device_put(self.batcher.next_batch(),
                                       self.batch_shd)
                self.state, metrics = self.step_fn(self.state, batch)
                if (i + 1) % log_every == 0 or i == 0:
                    m = {k: float(v) for k, v in metrics.items()}
                    m["step"] = i + 1
                    m["wall_s"] = time.time() - t0
                    self.history.append(m)
                    print(f"step {i+1:5d} loss {m['loss']:.4f} "
                          f"lr {m['lr']:.2e} |g| {m['grad_norm']:.3f}"
                          + (f" resid {m['consensus_residual']:.2e}"
                             if "consensus_residual" in m else ""))
        return self.history

    def save(self, step: int) -> Optional[str]:
        if self.ckpt_dir is None:
            return None
        return checkpoint.save(self.ckpt_dir, jax.device_get(self.state),
                               step=step)

    def restore(self, step: int):
        restored = checkpoint.restore(self.ckpt_dir, self.state, step=step)
        self.state = jax.device_put(restored, self.shardings)
