"""Training step factory — classical and consensus (paper-technique) modes.

dp_mode:
  "allreduce" — baseline (cVB analogue): one global parameter set, batch
      sharded over data/pod axes, XLA inserts the gradient all-reduce.
  "diffusion" — dSVB analogue (Eq. 27): per-replica parameters along the
      consensus axis; local AdamW step then nearest-neighbour ring combine
      via ppermute.  No all-reduce over the consensus axis.
  "admm" — dVB-ADMM analogue (Eqs. 38a/39/40): per-replica parameters plus
      aggregate duals; primal/dual consensus round per step.

The consensus axis is "data" on the single-pod mesh and "pod" on the
multi-pod mesh (diffusion across the slow inter-pod links, exact all-reduce
inside a pod — hierarchical, the WSN-faithful deployment).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist import compat, sharding
from repro.models import model as model_lib
from repro.optim import adamw, consensus, schedules


class TrainState(NamedTuple):
    params: dict
    opt: adamw.AdamState
    duals: Optional[dict]     # ADMM only
    step: jnp.ndarray
    rho: Optional[jnp.ndarray] = None   # ADMM penalty as DYNAMIC state
    # (residual-balanced across steps when TrainHyper.adaptive_rho; None
    #  for non-ADMM modes)


class TrainHyper(NamedTuple):
    peak_lr: float = 3e-4
    warmup: int = 200
    total_steps: int = 10000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # consensus knobs (paper defaults)
    w_self: float = 1.0 / 3.0   # Eq. 47 nearest-neighbour on a ring
    rho: float = 0.5            # ADMM penalty (Remark 3); initial value —
    #                             the live value is TrainState.rho
    xi: float = 0.05            # kappa ramp (Eq. 40)
    # residual balancing of rho across training steps (Boyd Sec. 3.4.1,
    # the VB engine's rule via optim.consensus.adapt_rho)
    adaptive_rho: bool = False
    rho_mu: float = 10.0        # grow when ||r|| > mu ||s||, shrink flipped


def loss_fn(cfg: ModelConfig, params, batch, *, use_kernels: bool = False):
    out = model_lib.forward(cfg, params, batch["tokens"],
                            batch.get("frontend"), use_kernels=use_kernels)
    logits = out["logits"][:, :-1, :]
    labels = batch["tokens"][:, 1:]
    mask = jnp.arange(labels.shape[1])[None, :] >= cfg.frontend_len
    mask = jnp.broadcast_to(mask, labels.shape).astype(jnp.float32)
    # Sharding-friendly CE: both terms reduce over the (model-sharded) vocab
    # axis, so XLA emits small (B,S) all-reduces instead of all-gathering
    # the full logits (take_along_axis would gather ~16 GiB for yi-6b).
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = (labels[..., None] ==
              jnp.arange(logits.shape[-1])[None, None, :])
    ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    ce = jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = ce + cfg.router_aux_weight * out["aux_loss"]
    return loss, {"ce": ce, "aux": out["aux_loss"]}


def init_state(cfg: ModelConfig, key, *, dp_mode: str = "allreduce",
               n_replicas: int = 1,
               hyper: "TrainHyper" = None) -> TrainState:
    """Pass the SAME `hyper` here and to `make_train_step`: the dynamic
    ADMM penalty `TrainState.rho` is seeded from `hyper.rho` (the live
    value is the state, not the hyper — residual balancing moves it when
    `hyper.adaptive_rho`)."""
    hyper = hyper if hyper is not None else TrainHyper()
    params = model_lib.init_params(cfg, key)
    if dp_mode != "allreduce":
        params = jax.tree.map(
            lambda p: jnp.broadcast_to(p[None], (n_replicas,) + p.shape),
            params)
    opt = adamw.init(params)
    duals = consensus.admm_init_duals(params) if dp_mode == "admm" else None
    rho_state = (jnp.asarray(hyper.rho, jnp.float32) if dp_mode == "admm"
                 else None)
    return TrainState(params=params, opt=opt, duals=duals,
                      step=jnp.zeros((), jnp.int32), rho=rho_state)


# ---------------------------------------------------------------------------
# Sharding trees
# ---------------------------------------------------------------------------
def state_shardings(state_like, cfg: ModelConfig, mesh: Mesh, *,
                    dp_mode: str, consensus_axis: Optional[str]):
    replica = consensus_axis if dp_mode != "allreduce" else None
    scanned = model_lib._homogeneous(cfg)
    # consensus modes: per-replica parameters shard over "model" only.
    # (fsdp inside a replica trips an XLA SPMD-partitioner CHECK on the
    # embedding gather under partial-manual shard_map; and with
    # replica=data the data axis is consumed by replication anyway.)
    fsdp = cfg.fsdp and replica is None

    no_fsdp = ("moe",) if cfg.moe_local_dispatch else ()

    def spec_params(tree):
        return sharding.param_shardings(tree, mesh, fsdp=fsdp,
                                        scanned=scanned, replica_axis=replica,
                                        no_fsdp_keys=no_fsdp)

    rep0 = NamedSharding(mesh, P())
    rep_r = NamedSharding(mesh, P(replica)) if replica else rep0
    return TrainState(
        params=spec_params(state_like.params),
        opt=adamw.AdamState(mu=spec_params(state_like.opt.mu),
                            nu=spec_params(state_like.opt.nu),
                            count=rep0),
        duals=(spec_params(state_like.duals)
               if state_like.duals is not None else None),
        step=rep0,
        rho=rep0 if state_like.rho is not None else None,
    )


def batch_sharding(mesh: Mesh):
    return NamedSharding(mesh, sharding.batch_spec(mesh))


# ---------------------------------------------------------------------------
# Step factories
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, mesh: Mesh, *, dp_mode: str = "allreduce",
                    consensus_axis: Optional[str] = None,
                    hyper: TrainHyper = TrainHyper(),
                    use_kernels: bool = False):
    """Returns a (state, batch) -> (state, metrics) function (not yet jitted;
    launch/dryrun wraps it with jit + shardings)."""
    if dp_mode == "allreduce":
        return _allreduce_step(cfg, hyper, use_kernels)
    assert consensus_axis is not None
    return _consensus_step(cfg, mesh, dp_mode, consensus_axis, hyper,
                           use_kernels)


def _local_update(cfg, hyper, use_kernels, params, opt, batch, step):
    lr = schedules.cosine_warmup(step, peak_lr=hyper.peak_lr,
                                 warmup=hyper.warmup,
                                 total=hyper.total_steps)
    (loss, aux), grads = jax.value_and_grad(
        functools.partial(loss_fn, cfg, use_kernels=use_kernels),
        has_aux=True)(params, batch)
    grads, gnorm = adamw.clip_by_global_norm(grads, hyper.clip_norm)
    new_params, new_opt = adamw.update(
        grads, opt, params, lr=lr, weight_decay=hyper.weight_decay)
    metrics = {"loss": loss, "ce": aux["ce"], "grad_norm": gnorm, "lr": lr}
    return new_params, new_opt, metrics


def _allreduce_step(cfg, hyper, use_kernels):
    def step_fn(state: TrainState, batch):
        new_params, new_opt, metrics = _local_update(
            cfg, hyper, use_kernels, state.params, state.opt, batch,
            state.step)
        return TrainState(new_params, new_opt, None, state.step + 1), metrics

    return step_fn


def _consensus_step(cfg, mesh: Mesh, dp_mode: str, axis: str, hyper,
                    use_kernels):
    is_admm = dp_mode == "admm"

    def inner(params, opt, duals, step, rho, batch):
        # strip the per-replica leading axis (size 1 in this shard)
        params_l = jax.tree.map(lambda p: p[0], params)
        opt_l = adamw.AdamState(mu=jax.tree.map(lambda p: p[0], opt.mu),
                                nu=jax.tree.map(lambda p: p[0], opt.nu),
                                count=opt.count)
        # local stochastic step on local data (no consensus-axis psum!)
        p_star, new_opt, metrics = _local_update(
            cfg, hyper, use_kernels, params_l, opt_l, batch, step)
        if dp_mode == "diffusion":
            p_new = consensus.diffusion_combine(p_star, axis, hyper.w_self)
            d_new = None
            rho_new = rho
            r_norm = s_norm = jnp.zeros((), jnp.float32)
        else:
            kap = schedules.kappa(step.astype(jnp.float32) + 1.0, hyper.xi)
            duals_l = jax.tree.map(lambda p: p[0], duals)
            # residual norms ride along on the dual update's own ring
            # exchange — the same primal/dual residuals the VB engine
            # records in ConsensusDiagnostics; with `adaptive_rho` they
            # residual-balance the DYNAMIC TrainState.rho between steps
            # (the engine's Boyd Sec. 3.4.1 rule via consensus.adapt_rho)
            p_new, d_new, (r_norm, s_norm) = consensus.admm_step(
                p_star, params_l, duals_l, axis, rho=rho, kappa=kap,
                return_residuals=True)
            d_new = jax.tree.map(lambda p: p[None], d_new)
            if hyper.adaptive_rho:
                rho_new = consensus.adapt_rho(rho, r_norm, s_norm,
                                              mu=hyper.rho_mu)
            else:
                rho_new = rho
        metrics = {k: jax.lax.pmean(v, axis) for k, v in metrics.items()}
        metrics["consensus_residual"] = consensus.consensus_residual(
            p_new, axis)
        metrics["admm_primal_resid"] = r_norm
        metrics["admm_dual_resid"] = s_norm
        metrics["admm_rho"] = (rho_new if is_admm
                               else jnp.zeros((), jnp.float32))
        p_new = jax.tree.map(lambda p: p[None], p_new)
        new_opt = adamw.AdamState(
            mu=jax.tree.map(lambda p: p[None], new_opt.mu),
            nu=jax.tree.map(lambda p: p[None], new_opt.nu),
            count=new_opt.count)
        return p_new, new_opt, d_new, rho_new, metrics

    def step_fn(state: TrainState, batch):
        lead = P(axis)
        rep = P()

        def leaf_specs(tree, spec):
            return jax.tree.map(lambda _: spec, tree)

        rho_in = (state.rho if state.rho is not None
                  else jnp.zeros((), jnp.float32))
        in_specs = (
            leaf_specs(state.params, lead),
            adamw.AdamState(mu=leaf_specs(state.opt.mu, lead),
                            nu=leaf_specs(state.opt.nu, lead), count=rep),
            (leaf_specs(state.duals, lead)
             if state.duals is not None else None),
            rep,
            rep,
            leaf_specs(batch, lead),
        )
        out_specs = (in_specs[0], in_specs[1], in_specs[2], rep,
                     leaf_specs({"loss": 0, "ce": 0, "grad_norm": 0, "lr": 0,
                                 "consensus_residual": 0,
                                 "admm_primal_resid": 0,
                                 "admm_dual_resid": 0,
                                 "admm_rho": 0}, rep))
        # Partial-manual (auto "model" axis) where supported; otherwise run
        # fully manual — params replicate over "model" inside the body,
        # which is numerically identical (redundant compute per model
        # shard) and avoids the old-XLA partitioner CHECK.
        names = {axis} if compat.PARTIAL_MANUAL_OK else None
        fn = compat.shard_map(inner, mesh=mesh, axis_names=names,
                              in_specs=in_specs, out_specs=out_specs,
                              check_vma=False)
        p, o, d, rho_new, metrics = fn(state.params, state.opt, state.duals,
                                       state.step, rho_in, batch)
        return TrainState(p, o, d, state.step + 1,
                          rho_new if state.rho is not None else None), \
            metrics

    return step_fn
