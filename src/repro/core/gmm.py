"""Bayesian Gaussian-mixture model — the paper's application (Sec. IV + App. A).

Each node i holds data x_i of shape (Ni, D).  The local generative model uses
the *replicated* likelihood P({x_i}_N | ...) = prod_j prod_k N(x | mu, L)^(N y),
so every local count is scaled by the network size N (Appendix A: R_ik =
N * sum_j r_ijk, etc.).

`local_vbm_optimum` computes responsibilities given the current global
posterior and returns the *local optimum* natural parameters phi*_{theta,i}
(Eq. 18) — i.e. the hyperparameter update of Appendix A packed via
expfam.pack_natural.  The five algorithms in core/algorithms.py differ only
in what they do with the stack {phi*_i}.

This module is the REFERENCE implementation of the hot path (naive
three-pass einsums over the data).  The engine's production compute layer
is `core/backends.py`: the fused single-pass Pallas kernel
(`kernels/gmm_estep.py`) is parity-tested against the functions here
(tests/test_backends.py, tests/test_kernels.py) and selected via
`GMMModel(..., backend="fused")` / `run_vb(..., backend="fused")`.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import expfam
from repro.core.expfam import GMMPosterior


class SuffStats(NamedTuple):
    """Replicated sufficient statistics of Appendix A (per component)."""

    R: jnp.ndarray       # (K,)        R_k   = N * sum_j r_jk
    sum_x: jnp.ndarray   # (K, D)      N * sum_j r_jk x_j       (= R_k xbar_k)
    sum_xx: jnp.ndarray  # (K, D, D)   N * sum_j r_jk x_j x_j^T


def responsibilities(x: jnp.ndarray, q: GMMPosterior,
                     mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """r_jk (Bishop 10.46 / Appendix A), shape (Ni, K).

    ln rho_jk = E[ln pi_k] + 1/2 E[ln|L_k|] - D/2 ln 2pi
                - 1/2 E[(x_j - mu_k)^T L_k (x_j - mu_k)]
    """
    D = x.shape[-1]
    e_logpi = expfam.dirichlet_expected_log(q.alpha)              # (K,)
    e_logdet = expfam.wishart_expected_logdet(q.W, q.nu)          # (K,)
    diff = x[:, None, :] - q.m[None, :, :]                        # (Ni, K, D)
    maha = jnp.einsum("jki,kil,jkl->jk", diff, q.W, diff)         # (Ni, K)
    e_quad = D / q.beta[None, :] + q.nu[None, :] * maha
    log_rho = (e_logpi[None, :] + 0.5 * e_logdet[None, :]
               - 0.5 * D * jnp.log(2.0 * jnp.pi) - 0.5 * e_quad)
    r = jax.nn.softmax(log_rho, axis=-1)
    if mask is not None:
        r = r * mask[:, None]
    return r


def estep_terms(q: GMMPosterior, dtype=None):
    """Per-component terms consumed by the fused VBE kernel
    (kernels/gmm_estep.py) — the expanded form of the Appendix-A
    log-responsibility:

      log_prior (K,)   = E[ln pi] + 1/2 E[ln|L|] - D/2 ln 2pi
      Wn (K, D, D)     = nu W          (E[Lambda])
      b  (K, D)        = nu W m        (E[Lambda mu])
      c  (K,)          = D/beta + nu m^T W m   (E[mu^T Lambda mu])

    so that ln rho_jk = log_prior_k - (x^T Wn x - 2 x^T b + c) / 2,
    identical (up to f.p. reassociation) to `responsibilities`.
    """
    D = q.D
    e_logpi = expfam.dirichlet_expected_log(q.alpha)
    e_logdet = expfam.wishart_expected_logdet(q.W, q.nu)
    log_prior = e_logpi + 0.5 * e_logdet - 0.5 * D * jnp.log(2.0 * jnp.pi)
    Wn = q.nu[:, None, None] * q.W
    b = jnp.einsum("kde,ke->kd", Wn, q.m)
    c = D / q.beta + jnp.einsum("kd,kd->k", q.m, b)
    if dtype is not None:
        log_prior, Wn, b, c = (a.astype(dtype) for a in (log_prior, Wn, b, c))
    return log_prior, Wn, b, c


def sufficient_stats(x: jnp.ndarray, r: jnp.ndarray,
                     replication: float) -> SuffStats:
    """Replicated stats (Appendix A).  `replication` is the network size N.

    The data-axis reductions go through `expfam.ordered_sum` (multiply
    then fixed-chunk sequential sum) rather than einsum contractions:
    XLA re-tiles a dot_general (and even a plain reduce) when the axis
    length changes, so mask-zero padding slots appended by the serving
    layer's bucketed admission (serving/admission.py) would perturb the
    last ulp.  `ordered_sum` pins the association order, keeping padded
    statistics BIT-equal to the unpadded computation.
    """
    R = replication * expfam.ordered_sum(r)                       # (K,)
    rx = r[:, :, None] * x[:, None, :]                            # (j, K, D)
    sum_x = replication * expfam.ordered_sum(rx)                  # (K, D)
    sum_xx = replication * expfam.ordered_sum(
        rx[:, :, :, None] * x[:, None, None, :])                  # (K, D, D)
    return SuffStats(R=R, sum_x=sum_x, sum_xx=sum_xx)


def posterior_from_stats(stats: SuffStats, prior: GMMPosterior,
                         eps: float = 1e-12) -> GMMPosterior:
    """Hyperparameter updates of Appendix A given (replicated) stats."""
    R = stats.R
    alpha = prior.alpha + R
    beta = prior.beta + R
    nu = prior.nu + R
    xbar = stats.sum_x / (R[:, None] + eps)                       # (K, D)
    m = (prior.beta[:, None] * prior.m + stats.sum_x) / beta[:, None]
    # R*S = sum_xx - R xbar xbar^T ;  prior cross term beta0 R/(beta0+R)(..)
    RS = stats.sum_xx - R[:, None, None] * (xbar[:, :, None] * xbar[:, None, :])
    diff = xbar - prior.m
    cross = (prior.beta * R / (prior.beta + R))[:, None, None] * (
        diff[:, :, None] * diff[:, None, :])
    W0_inv = jnp.linalg.inv(prior.W)
    W_inv = W0_inv + RS + cross
    W_inv = 0.5 * (W_inv + jnp.swapaxes(W_inv, -1, -2))
    W = jnp.linalg.inv(W_inv)
    return GMMPosterior(alpha=alpha, m=m, beta=beta, W=W, nu=nu)


def local_vbm_optimum(x: jnp.ndarray, q_global: GMMPosterior,
                      prior: GMMPosterior, replication: float,
                      mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """One VBE step + local VBM optimum -> phi*_{theta,i}  (Eqs. 17a, 18).

    Returns the flat natural-parameter message of Eq. 45.
    """
    r = responsibilities(x, q_global, mask)
    stats = sufficient_stats(x, r, replication)
    q_star = posterior_from_stats(stats, prior)
    return expfam.pack_natural(q_star)


# vmapped over a leading node axis: x (Nnodes, Ni, D), phi (Nnodes, P)
def local_vbm_optimum_nodes(x: jnp.ndarray, phi: jnp.ndarray,
                            prior: GMMPosterior, replication: float,
                            K: int, D: int,
                            mask: jnp.ndarray | None = None) -> jnp.ndarray:
    def one(xi, phii, mi):
        q = expfam.unpack_natural(phii, K, D)
        return local_vbm_optimum(xi, q, prior, replication, mi)

    if mask is None:
        mask = jnp.ones(x.shape[:2], x.dtype)
    return jax.vmap(one)(x, phi, mask)


def elbo(x: jnp.ndarray, q: GMMPosterior, prior: GMMPosterior,
         replication: float = 1.0) -> jnp.ndarray:
    """Local variational lower bound L_i (Eq. 15) up to y-entropy terms.

    Used for monitoring / tests (monotonicity of centralised VB), not inside
    the algorithms themselves.
    """
    r = responsibilities(x, q)
    D = x.shape[-1]
    e_logpi = expfam.dirichlet_expected_log(q.alpha)
    e_logdet = expfam.wishart_expected_logdet(q.W, q.nu)
    diff = x[:, None, :] - q.m[None, :, :]
    maha = jnp.einsum("jki,kil,jkl->jk", diff, q.W, diff)
    e_quad = D / q.beta[None, :] + q.nu[None, :] * maha
    log_rho = (e_logpi[None, :] + 0.5 * e_logdet[None, :]
               - 0.5 * D * jnp.log(2.0 * jnp.pi) - 0.5 * e_quad)
    e_loglik = replication * jnp.sum(r * log_rho)
    ent_y = -replication * jnp.sum(r * jnp.log(r + 1e-30))
    kl_theta = expfam.gmm_kl(q, prior)
    return e_loglik + ent_y - kl_theta


def ground_truth_posterior(x_all: jnp.ndarray, labels: jnp.ndarray,
                           prior: GMMPosterior, K: int) -> GMMPosterior:
    """Closed-form conjugate posterior given the *true* component labels
    (Sec. V-A: available for synthetic data) — the reference of Eq. 46."""
    r = jax.nn.one_hot(labels, K, dtype=x_all.dtype)              # (Ntot, K)
    stats = sufficient_stats(x_all, r, replication=1.0)
    return posterior_from_stats(stats, prior)


def predict_labels(x: jnp.ndarray, q: GMMPosterior) -> jnp.ndarray:
    """Hard cluster assignment under the variational posterior."""
    return jnp.argmax(responsibilities(x, q), axis=-1)
