"""Sensor-network topologies and combination-weight rules (Sec. II, Eq. 47).

Graph generation is host-side numpy (it happens once, outside jit); the
returned adjacency / weight matrices are plain jnp arrays consumed by the
algorithms.  The paper's reference topology is a random geometric graph:
50 nodes in a 3.5 x 3.5 square, communication radius 0.8, 144 edges.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def random_geometric_graph(n_nodes: int, *, side: float | None = None,
                           radius: float = 0.8, seed: int = 0,
                           max_tries: int = 200):
    """Connected random geometric graph.

    `side` defaults to the paper's density: 3.5 for N=50, scaled with
    sqrt(N/50) otherwise (Sec. V-C2 keeps density constant by zooming the
    square).  Returns (adjacency (N,N) float, positions (N,2)).
    """
    if side is None:
        side = 3.5 * float(np.sqrt(n_nodes / 50.0))
    rng = np.random.default_rng(seed)
    for _ in range(max_tries):
        pos = rng.uniform(0.0, side, size=(n_nodes, 2))
        d2 = np.sum((pos[:, None, :] - pos[None, :, :]) ** 2, axis=-1)
        adj = (d2 <= radius * radius).astype(np.float64)
        np.fill_diagonal(adj, 0.0)
        if _is_connected(adj):
            return jnp.asarray(adj), jnp.asarray(pos)
    raise RuntimeError(
        f"could not sample a connected geometric graph (N={n_nodes}, "
        f"side={side}, radius={radius})")


def _is_connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    seen = np.zeros(n, bool)
    stack = [0]
    seen[0] = True
    while stack:
        i = stack.pop()
        for j in np.nonzero(adj[i])[0]:
            if not seen[j]:
                seen[j] = True
                stack.append(int(j))
    return bool(seen.all())


def ring_graph(n_nodes: int) -> jnp.ndarray:
    """1-D ring — the topology the TPU-adapted framework layer uses (each
    data-parallel replica talks to its +/-1 ICI neighbours)."""
    adj = np.zeros((n_nodes, n_nodes))
    for i in range(n_nodes):
        adj[i, (i + 1) % n_nodes] = 1.0
        adj[i, (i - 1) % n_nodes] = 1.0
    return jnp.asarray(adj)


def degrees(adj: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(adj, axis=1)


def nearest_neighbor_weights(adj: jnp.ndarray) -> jnp.ndarray:
    """Eq. 47: w_ij = 1/(|N_i|+1) for j in N_i u {i}, else 0 (row-stochastic)."""
    n = adj.shape[0]
    a_self = adj + jnp.eye(n, dtype=adj.dtype)
    return a_self / jnp.sum(a_self, axis=1, keepdims=True)


def metropolis_weights(adj: jnp.ndarray) -> jnp.ndarray:
    """Metropolis-Hastings rule — doubly stochastic, used in robustness tests."""
    deg = degrees(adj)
    off = adj / (1.0 + jnp.maximum(deg[:, None], deg[None, :]))
    diag = 1.0 - jnp.sum(off, axis=1)
    return off + jnp.diag(diag)


# ---------------------------------------------------------------------------
# Time-varying links: per-iteration Bernoulli link failures (jit-side; the
# keep masks are drawn from a replicated key + the iteration index, so every
# executor layout sees the identical failure pattern at iteration t)
# ---------------------------------------------------------------------------
def link_keep_matrix(key, t, n: int, drop_prob: float,
                     dtype=jnp.float32) -> jnp.ndarray:
    """Symmetric (N, N) 0/1 keep mask for iteration t: each *undirected*
    link (i, j) survives with probability 1 - drop_prob (both directions
    share one coin — a failed link is failed both ways); the diagonal is
    always 1 (a node never loses itself).  Deterministic in (key, t)."""
    kt = jax.random.fold_in(key, t)
    u = jnp.triu(jax.random.uniform(kt, (n, n)), 1)
    u = u + u.T                                       # one coin per pair
    keep = (u >= drop_prob).astype(dtype)
    return jnp.maximum(keep, jnp.eye(n, dtype=dtype))


def ring_link_keep(key, t, n: int, drop_prob: float,
                   dtype=jnp.float32) -> jnp.ndarray:
    """(N,) keep mask of the ring edges for iteration t: entry i gates the
    undirected link (i, i+1 mod N).  Deterministic in (key, t)."""
    kt = jax.random.fold_in(key, t)
    return (jax.random.uniform(kt, (n,)) >= drop_prob).astype(dtype)


def algebraic_connectivity(adj: jnp.ndarray) -> float:
    """Second-smallest Laplacian eigenvalue (reported for the real-data nets)."""
    lap = jnp.diag(degrees(adj)) - adj
    eig = jnp.linalg.eigvalsh(lap)
    return float(eig[1])
