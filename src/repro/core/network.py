"""Sensor-network topologies and combination-weight rules (Sec. II, Eq. 47).

Graph generation is host-side numpy (it happens once, outside jit); the
returned adjacency / weight matrices are plain jnp arrays consumed by the
algorithms.  The paper's reference topology is a random geometric graph:
50 nodes in a 3.5 x 3.5 square, communication radius 0.8, 144 edges.

Two graph representations live here:

* **dense** — an (N, N) 0/1 adjacency (and (N, N) weight matrices built
  from it).  The paper's scale; stays the golden-parity oracle.
* **sparse** — `SparseGraph`: directed edge lists + per-node degrees,
  built by `random_geometric_edges` / `SparseGraph.ring` without ever
  materialising an N x N array, consumed by the engine's
  `segment_sum`-based combines (docs/sparse-topologies.md).  This is
  what scales the network axis to 10k+ nodes.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp


def connectivity_radius(n_nodes: int, side: float) -> float:
    """The random-geometric-graph connectivity threshold
    r_c = side * sqrt(ln n / (pi n)) (Penrose; Gupta-Kumar): below it the
    graph is disconnected w.h.p., above it isolated nodes vanish as
    n^(1 - (r/r_c)^2)."""
    n = max(int(n_nodes), 2)
    return side * math.sqrt(math.log(n) / (math.pi * n))


def _resolve_radius(n_nodes: int, side: float,
                    radius: float | None) -> float:
    """Default communication radius: the paper's 0.8 (1.45x the threshold
    at N=50, and constant-density via the sqrt(N/50) side scaling) — but
    never below 1.3x the connectivity threshold, which the constant-0.8
    rule crosses at N ~ 6k and which made the rejection-sampling loop
    stall at N=10k.  1.3x leaves ~n^-0.69 expected isolated nodes, so a
    connected sample lands in a couple of tries at any N.  An explicit
    `radius` always wins."""
    if radius is not None:
        return float(radius)
    return max(0.8, 1.3 * connectivity_radius(n_nodes, side))


def _paper_side(n_nodes: int, side: float | None) -> float:
    """3.5 for N=50, scaled with sqrt(N/50) otherwise (Sec. V-C2 keeps
    density constant by zooming the square)."""
    if side is None:
        return 3.5 * float(np.sqrt(n_nodes / 50.0))
    return float(side)


def random_geometric_graph(n_nodes: int, *, side: float | None = None,
                           radius: float | None = None, seed: int = 0,
                           max_tries: int = 200):
    """Connected random geometric graph (dense form).

    `side` defaults to the paper's density (see `_paper_side`); `radius`
    defaults to the paper's 0.8, floored at 1.3x the connectivity
    threshold for large N (see `_resolve_radius` — every N <= ~128 call
    is bit-identical to the historical constant-0.8 default).  Returns
    (adjacency (N,N) float, positions (N,2)).
    """
    side = _paper_side(n_nodes, side)
    radius = _resolve_radius(n_nodes, side, radius)
    rng = np.random.default_rng(seed)
    for _ in range(max_tries):
        pos = rng.uniform(0.0, side, size=(n_nodes, 2))
        d2 = np.sum((pos[:, None, :] - pos[None, :, :]) ** 2, axis=-1)
        adj = (d2 <= radius * radius).astype(np.float64)
        np.fill_diagonal(adj, 0.0)
        if _is_connected(adj):
            return jnp.asarray(adj), jnp.asarray(pos)
    raise RuntimeError(
        f"could not sample a connected geometric graph (N={n_nodes}, "
        f"side={side}, radius={radius})")


def _is_connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    seen = np.zeros(n, bool)
    stack = [0]
    seen[0] = True
    while stack:
        i = stack.pop()
        for j in np.nonzero(adj[i])[0]:
            if not seen[j]:
                seen[j] = True
                stack.append(int(j))
    return bool(seen.all())


def ring_graph(n_nodes: int) -> jnp.ndarray:
    """1-D ring — the topology the TPU-adapted framework layer uses (each
    data-parallel replica talks to its +/-1 ICI neighbours)."""
    adj = np.zeros((n_nodes, n_nodes))
    for i in range(n_nodes):
        adj[i, (i + 1) % n_nodes] = 1.0
        adj[i, (i - 1) % n_nodes] = 1.0
    return jnp.asarray(adj)


def degrees(adj: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(adj, axis=1)


def nearest_neighbor_weights(adj: jnp.ndarray) -> jnp.ndarray:
    """Eq. 47: w_ij = 1/(|N_i|+1) for j in N_i u {i}, else 0 (row-stochastic)."""
    n = adj.shape[0]
    a_self = adj + jnp.eye(n, dtype=adj.dtype)
    return a_self / jnp.sum(a_self, axis=1, keepdims=True)


def metropolis_weights(adj: jnp.ndarray) -> jnp.ndarray:
    """Metropolis-Hastings rule — doubly stochastic, used in robustness tests."""
    deg = degrees(adj)
    off = adj / (1.0 + jnp.maximum(deg[:, None], deg[None, :]))
    diag = 1.0 - jnp.sum(off, axis=1)
    return off + jnp.diag(diag)


# ---------------------------------------------------------------------------
# Time-varying links: per-iteration Bernoulli link failures (jit-side; the
# keep masks are drawn from a replicated key + the iteration index, so every
# executor layout sees the identical failure pattern at iteration t)
# ---------------------------------------------------------------------------
def link_keep_matrix(key, t, n: int, drop_prob: float,
                     dtype=jnp.float32) -> jnp.ndarray:
    """Symmetric (N, N) 0/1 keep mask for iteration t: each *undirected*
    link (i, j) survives with probability 1 - drop_prob (both directions
    share one coin — a failed link is failed both ways); the diagonal is
    always 1 (a node never loses itself).  Deterministic in (key, t)."""
    kt = jax.random.fold_in(key, t)
    u = jnp.triu(jax.random.uniform(kt, (n, n)), 1)
    u = u + u.T                                       # one coin per pair
    keep = (u >= drop_prob).astype(dtype)
    return jnp.maximum(keep, jnp.eye(n, dtype=dtype))


def ring_link_keep(key, t, n: int, drop_prob: float,
                   dtype=jnp.float32) -> jnp.ndarray:
    """(N,) keep mask of the ring edges for iteration t: entry i gates the
    undirected link (i, i+1 mod N).  Deterministic in (key, t)."""
    kt = jax.random.fold_in(key, t)
    return (jax.random.uniform(kt, (n,)) >= drop_prob).astype(dtype)


def algebraic_connectivity(adj: jnp.ndarray) -> float:
    """Second-smallest Laplacian eigenvalue (reported for the real-data nets)."""
    lap = jnp.diag(degrees(adj)) - adj
    eig = jnp.linalg.eigvalsh(lap)
    return float(eig[1])


# ---------------------------------------------------------------------------
# Sparse representation: edge lists + per-node degrees, never an N x N array
# ---------------------------------------------------------------------------
class SparseGraph:
    """Edge-list sensor graph for the engine's sparse combines.

    Stores every undirected link twice as a DIRECTED message edge
    (sender -> receiver), sorted by receiver so `jax.ops.segment_sum`
    over `receivers` runs on sorted segments.  `edge_id` maps each
    directed edge back to its undirected link, so both directions of a
    link share one Bernoulli coin under `sparse_link_keep` / gossip
    activation — the same one-coin-per-pair contract as the dense
    `link_keep_matrix`.

    Memory is O(E + N); nothing here (or in the combines consuming it)
    ever materialises an (N, N) array.

    >>> g = SparseGraph.ring(4)
    >>> (g.n_nodes, g.n_undirected, int(g.senders.shape[0]))
    (4, 4, 8)
    >>> g.deg.tolist()                        # every ring node has 2 links
    [2, 2, 2, 2]
    """

    __slots__ = ("senders", "receivers", "edge_id", "deg", "n_nodes",
                 "n_undirected")

    def __init__(self, senders, receivers, edge_id, deg, n_nodes: int,
                 n_undirected: int):
        self.senders = senders            # (E,) int32, E = 2 * n_undirected
        self.receivers = receivers        # (E,) int32, sorted ascending
        self.edge_id = edge_id            # (E,) int32 -> undirected link id
        self.deg = deg                    # (N,) int32 neighbour counts
        self.n_nodes = int(n_nodes)
        self.n_undirected = int(n_undirected)

    @classmethod
    def from_undirected(cls, u, v, n_nodes: int) -> "SparseGraph":
        """Build from undirected link lists: link k connects (u[k], v[k]).
        The link ORDER is the coin order of `sparse_link_keep` — e.g.
        `ring`'s link k = (k, k+1 mod N) matches `ring_link_keep`'s e[k]
        exactly.  No self-loops or duplicate links."""
        u = np.asarray(u, np.int64)
        v = np.asarray(v, np.int64)
        if u.shape != v.shape or u.ndim != 1:
            raise ValueError("u/v must be equal-length 1-D link lists")
        if np.any(u == v):
            raise ValueError("self-loops are not links")
        if np.any(u < 0) or np.any(v < 0) or np.any(u >= n_nodes) \
                or np.any(v >= n_nodes):
            raise ValueError(f"node ids must be in [0, {n_nodes})")
        key = np.minimum(u, v) * n_nodes + np.maximum(u, v)
        if np.unique(key).size != key.size:
            raise ValueError("duplicate undirected links")
        m = u.shape[0]
        s = np.concatenate([u, v])
        r = np.concatenate([v, u])
        eid = np.concatenate([np.arange(m), np.arange(m)])
        order = np.argsort(r, kind="stable")
        deg = np.bincount(r, minlength=n_nodes)
        return cls(jnp.asarray(s[order], jnp.int32),
                   jnp.asarray(r[order], jnp.int32),
                   jnp.asarray(eid[order], jnp.int32),
                   jnp.asarray(deg, jnp.int32), n_nodes, m)

    @classmethod
    def from_dense(cls, adj) -> "SparseGraph":
        """From a dense 0/1 adjacency (must be symmetric, zero diagonal)."""
        a = np.asarray(adj)
        if not np.array_equal(a, a.T):
            raise ValueError("adjacency must be symmetric")
        u, v = np.nonzero(np.triu(a, 1))
        return cls.from_undirected(u, v, a.shape[0])

    @classmethod
    def ring(cls, n_nodes: int) -> "SparseGraph":
        """Edge-list form of `ring_graph`: link k = (k, k+1 mod N), the
        ordering under which `sparse_link_keep` draws the IDENTICAL
        per-link coins as `ring_link_keep`."""
        if n_nodes < 3:
            raise ValueError(f"a ring needs >= 3 nodes: {n_nodes}")
        i = np.arange(n_nodes)
        return cls.from_undirected(i, (i + 1) % n_nodes, n_nodes)

    def to_dense(self, dtype=np.float64) -> np.ndarray:
        """(N, N) adjacency — the parity oracle's view of this graph.
        Host-side numpy on purpose: the dense path is the small-N oracle,
        and returning numpy keeps the default f64 exact under jax f32."""
        a = np.zeros((self.n_nodes, self.n_nodes), dtype)
        a[np.asarray(self.senders), np.asarray(self.receivers)] = 1.0
        return a

    def __repr__(self):
        return (f"SparseGraph(n_nodes={self.n_nodes}, "
                f"n_undirected={self.n_undirected})")


class SparseWeights(NamedTuple):
    """Combination weights over a `SparseGraph`: w_edge[e] weights the
    directed message edge e (sender -> receiver) and w_self[i] weights
    node i's own iterate — together one row-stochastic combine
    phi_i <- w_self_i varphi_i + sum_e w_e varphi_send(e) without ever
    forming the (N, N) matrix."""

    graph: SparseGraph
    w_edge: np.ndarray                # (E,) f64 host constants; cast to the
    w_self: np.ndarray                # (N,) iterate dtype inside the combine


def sparse_nearest_neighbor_weights(graph: SparseGraph) -> SparseWeights:
    """Eq. 47 in edge-list form: receiver i takes 1/(|N_i|+1) from itself
    and from each neighbour — exactly `nearest_neighbor_weights`' rows.

    >>> g = SparseGraph.ring(3)
    >>> sw = sparse_nearest_neighbor_weights(g)
    >>> sw.w_self.tolist()
    [0.3333333333333333, 0.3333333333333333, 0.3333333333333333]
    """
    # host-side numpy f64 on purpose: these are static per-run constants
    # (closure-embedded under jit) and the combine casts them to the
    # iterate dtype at use, so full precision survives x64 runs without
    # depending on whether x64 was enabled at CONSTRUCTION time
    inv = 1.0 / (np.asarray(graph.deg, np.float64) + 1.0)
    return SparseWeights(graph, inv[np.asarray(graph.receivers)], inv)


def sparse_metropolis_weights(graph: SparseGraph) -> SparseWeights:
    """Metropolis-Hastings rule in edge-list form — symmetric doubly
    stochastic, matching `metropolis_weights` entrywise."""
    deg = np.asarray(graph.deg, np.float64)
    s = np.asarray(graph.senders)
    r = np.asarray(graph.receivers)
    w_e = 1.0 / (1.0 + np.maximum(deg[s], deg[r]))
    w_self = 1.0 - np.bincount(r, weights=w_e, minlength=graph.n_nodes)
    return SparseWeights(graph, w_e, w_self)


def sparse_link_keep(key, t, n_undirected: int, drop_prob: float,
                     dtype=jnp.float32) -> jnp.ndarray:
    """(E_undirected,) 0/1 keep mask for iteration t: undirected link k
    survives with probability 1 - drop_prob; both directed edges of a
    link read coin `edge_id[e]`, so a failed link is failed both ways.
    Deterministic in (key, t), and — by the coin-order contract of
    `SparseGraph.ring` — bit-identical to `ring_link_keep` on rings."""
    kt = jax.random.fold_in(key, t)
    return (jax.random.uniform(kt, (n_undirected,)) >= drop_prob) \
        .astype(dtype)


def random_geometric_edges(n_nodes: int, *, side: float | None = None,
                           radius: float | None = None, seed: int = 0,
                           max_tries: int = 200, chunk: int = 1024):
    """Connected random geometric graph as a `SparseGraph` + positions —
    the large-N constructor: distances are computed in (chunk, N) row
    blocks and connectivity is checked by edge-list label propagation,
    so nothing ever allocates an (N, N) array.

    Same distribution as `random_geometric_graph` (same rng stream, same
    default side/radius rules): at equal (n_nodes, side, radius, seed)
    the first connected sample's edge set equals the dense adjacency's.
    With the default threshold-derived radius a connected sample lands
    in a handful of tries at any N (regression-tested at N=10k).
    """
    side = _paper_side(n_nodes, side)
    radius = _resolve_radius(n_nodes, side, radius)
    rng = np.random.default_rng(seed)
    for _ in range(max_tries):
        pos = rng.uniform(0.0, side, size=(n_nodes, 2))
        u, v = _radius_edges(pos, radius, chunk=chunk)
        if _edges_connected(u, v, n_nodes):
            return SparseGraph.from_undirected(u, v, n_nodes), \
                jnp.asarray(pos)
    raise RuntimeError(
        f"could not sample a connected geometric graph (N={n_nodes}, "
        f"side={side}, radius={radius})")


def _radius_edges(pos: np.ndarray, radius: float, *, chunk: int = 1024):
    """Undirected links (u, v) with u < v and ||pos_u - pos_v|| <= radius,
    via (chunk, N) distance blocks — O(N * chunk) peak memory."""
    n = pos.shape[0]
    us, vs = [], []
    r2 = radius * radius
    for lo in range(0, n, chunk):
        hi = min(lo + chunk, n)
        d2 = np.sum((pos[lo:hi, None, :] - pos[None, :, :]) ** 2, axis=-1)
        bu, bv = np.nonzero(d2 <= r2)
        bu = bu + lo
        keep = bu < bv                   # upper triangle only, no loops
        us.append(bu[keep])
        vs.append(bv[keep])
    return np.concatenate(us), np.concatenate(vs)


def _edges_connected(u: np.ndarray, v: np.ndarray, n: int) -> bool:
    """Connectivity from an undirected link list: vectorised min-label
    propagation with pointer jumping — O(E) per sweep, ~diameter sweeps,
    no adjacency matrix."""
    if n <= 1:
        return True
    if u.size == 0:
        return False
    lbl = np.arange(n)
    for _ in range(n):
        new = lbl.copy()
        np.minimum.at(new, u, lbl[v])
        np.minimum.at(new, v, lbl[u])
        new = new[new]                   # pointer jumping
        if np.array_equal(new, lbl):
            break
        lbl = new
    return bool((lbl == 0).all())


def two_level_partition(n_nodes: int, n_gateways: int, n_regions: int):
    """Balanced contiguous sensor -> gateway -> region assignment for
    `engine.HierarchicalFusion`: (gateway_of (N,), region_of (G,)).

    >>> g, r = two_level_partition(6, 3, 2)
    >>> (g.tolist(), r.tolist())
    ([0, 0, 1, 1, 2, 2], [0, 0, 1])
    """
    if not 1 <= n_regions <= n_gateways <= n_nodes:
        raise ValueError(
            f"need 1 <= regions ({n_regions}) <= gateways ({n_gateways}) "
            f"<= nodes ({n_nodes})")
    gateway_of = (np.arange(n_nodes) * n_gateways) // n_nodes
    region_of = (np.arange(n_gateways) * n_regions) // n_gateways
    return jnp.asarray(gateway_of, jnp.int32), \
        jnp.asarray(region_of, jnp.int32)
