"""repro.core — the paper's contribution: distributed VB in natural-parameter
space (dSVB, Algorithm 1; dVB-ADMM, Algorithm 2) plus the cVB / noncoop /
nsg-dVB baselines, for conjugate-exponential models (Bayesian GMM instance)."""
from repro.core import algorithms, expfam, gmm, network, refperm  # noqa: F401
from repro.core.algorithms import (  # noqa: F401
    ALGORITHMS, VBRun, run_cvb, run_dsvb, run_dvb_admm, run_noncoop,
    run_nsg_dvb,
)
from repro.core.expfam import (  # noqa: F401
    GMMPosterior, enable_x64, noninformative_prior, pack_natural,
    unpack_natural,
)
from repro.core import linreg  # noqa: F401  (2nd conjugate-exp instance)
