"""repro.core — the paper's contribution: distributed VB in natural-parameter
space (dSVB, Algorithm 1; dVB-ADMM, Algorithm 2) plus the cVB / noncoop /
nsg-dVB baselines, for conjugate-exponential models.

The unified engine is `run_vb(model, data, topology, ...)` (core/engine.py)
over the `ConjugateExpModel` protocol (core/model.py); the named `run_*`
functions are backward-compatible wrappers binding the GMM instance."""
from repro.core import (  # noqa: F401
    algorithms, engine, expfam, gmm, model, network, refperm,
)
from repro.core.algorithms import (  # noqa: F401
    ALGORITHMS, VBRun, run_cvb, run_dsvb, run_dvb_admm, run_noncoop,
    run_nsg_dvb,
)
from repro.core.engine import (  # noqa: F401
    ADMMConsensus, Diffusion, FusionCenter, Isolated, MeshExecutor,
    RingDiffusion, Schedule, run_vb,
)
from repro.core.expfam import (  # noqa: F401
    GMMPosterior, enable_x64, noninformative_prior, pack_natural,
    unpack_natural,
)
from repro.core.model import ConjugateExpModel, GMMModel, LinRegModel  # noqa: F401
from repro.core import linreg  # noqa: F401  (2nd conjugate-exp instance)
