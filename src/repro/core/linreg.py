"""Second conjugate-exponential instance: distributed Bayesian linear
regression with Normal-Gamma conjugacy.

The paper's framework claims generality over conjugate-exponential models
(contribution 1); the GMM is its worked example.  This module instantiates
the same machinery for the classic WSN task of linear parameter estimation
(cf. the diffusion-LMS line of work the paper builds on [8]):

    y_ij = w^T x_ij + eps,   eps ~ N(0, lambda^{-1})
    lambda ~ Gamma(a0, b0),  w | lambda ~ N(m0, (lambda V0)^{-1})

The model has NO local latent variables, so the VBE step is trivial and the
local optimum phi*_i (Eq. 18) is an explicit function of the replicated
local sufficient statistics (X^T X, X^T y, y^T y, n).  The paper's VBM
consensus machinery applies verbatim in the natural-parameter space:

    u(w, lambda) = [ln lambda, lambda, lambda w, lambda w w^T]
    phi = [a - 1 + D/2,  -(b + m^T V m / 2),  V m,  -V/2]

cVB is exact single-shot averaging (Eq. 20); dSVB (Eq. 27) and dVB-ADMM
(Eqs. 38a/39/40) converge to the exact pooled Bayesian posterior —
verified in tests/test_linreg.py against the closed-form solution.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import expfam
from jax.scipy.special import digamma, gammaln

from repro.core import engine


class NGPosterior(NamedTuple):
    """Normal-Gamma hyperparameters: lambda~Ga(a,b), w|lambda~N(m,(l V)^-1)."""

    m: jnp.ndarray   # (D,)
    V: jnp.ndarray   # (D, D)  precision scale
    a: jnp.ndarray   # ()
    b: jnp.ndarray   # ()

    @property
    def D(self) -> int:
        return self.m.shape[-1]


def prior(D: int, *, a0: float = 1.0, b0: float = 1.0, v0: float = 1e-2,
          dtype=jnp.float64) -> NGPosterior:
    return NGPosterior(m=jnp.zeros((D,), dtype),
                       V=jnp.eye(D, dtype=dtype) * v0,
                       a=jnp.asarray(a0, dtype), b=jnp.asarray(b0, dtype))


def flat_dim(D: int) -> int:
    return 2 + D + D * D


#: block names of the flat Normal-Gamma message, in `block_labels` order:
#: n1 (Gamma shape), n2 (Gamma rate carrier), n3 (V m), n4 (-V/2).
BLOCK_NAMES = ("shape", "rate", "mean", "precision")


def block_labels(D: int):
    """(P,) int32 block-type label per coordinate (cf. expfam.block_labels);
    a host (numpy) array — static structure, usable inside jit."""
    import numpy as np
    return np.asarray([0, 1] + [2] * D + [3] * (D * D), np.int32)


def pack(q: NGPosterior) -> jnp.ndarray:
    n1 = q.a - 1.0 + q.D / 2.0
    n2 = -(q.b + 0.5 * q.m @ q.V @ q.m)
    n3 = q.V @ q.m
    n4 = -0.5 * q.V
    return jnp.concatenate([n1[None], n2[None], n3, n4.reshape(-1)])


def unpack(phi: jnp.ndarray, D: int) -> NGPosterior:
    n1, n2 = phi[0], phi[1]
    n3 = phi[2:2 + D]
    V = -2.0 * phi[2 + D:].reshape(D, D)
    m = jnp.linalg.solve(V, n3)
    a = n1 + 1.0 - D / 2.0
    b = -n2 - 0.5 * m @ V @ m
    return NGPosterior(m=m, V=V, a=a, b=b)


def log_partition(q: NGPosterior) -> jnp.ndarray:
    """A(phi) = ln Gamma(a) - a ln b - 1/2 ln|V| + D/2 ln 2pi."""
    return (gammaln(q.a) - q.a * jnp.log(q.b)
            - 0.5 * jnp.linalg.slogdet(q.V)[1]
            + q.D / 2.0 * jnp.log(2.0 * jnp.pi))


def expected_stats(q: NGPosterior):
    """E[u] = (E[ln l], E[l], E[l w], E[l w w^T])."""
    e_loglam = digamma(q.a) - jnp.log(q.b)
    e_lam = q.a / q.b
    e_lw = e_lam * q.m
    e_lww = jnp.linalg.inv(q.V) + e_lam * jnp.outer(q.m, q.m)
    return e_loglam, e_lam, e_lw, e_lww


def kl(q: NGPosterior, p: NGPosterior) -> jnp.ndarray:
    """KL(q||p) via the exp-family identity (Eq. 46 analogue)."""
    e_loglam, e_lam, e_lw, e_lww = expected_stats(q)
    dq, dp = pack(q), pack(p)
    D = q.D
    inner = ((dq[0] - dp[0]) * e_loglam + (dq[1] - dp[1]) * e_lam
             + (dq[2:2 + D] - dp[2:2 + D]) @ e_lw
             + jnp.sum((dq[2 + D:] - dp[2 + D:]).reshape(D, D) * e_lww))
    return inner - log_partition(q) + log_partition(p)


# ---------------------------------------------------------------------------
# Local optimum (Eq. 18) from replicated local sufficient statistics
# ---------------------------------------------------------------------------
def local_optimum(X, y, mask, q0: NGPosterior, replication: float):
    """phi*_i for node data (X (Ni,D), y (Ni,)) replicated `N` times."""
    w = mask
    # data-axis sums via expfam.ordered_sum (not einsum) so mask-zero
    # padding slots appended by the serving layer's bucketed admission
    # contribute exact +0.0 — the statistics stay BIT-equal to the
    # unpadded computation (see gmm.sufficient_stats).
    Xw = X * w[:, None]                                 # (n, D)
    XtX = expfam.ordered_sum(Xw[:, :, None] * X[:, None, :]) * replication
    Xty = expfam.ordered_sum(Xw * y[:, None]) * replication
    yty = expfam.ordered_sum((y * y * w)[:, None])[0] * replication
    n = expfam.ordered_sum(w[:, None])[0] * replication
    V = q0.V + XtX
    m = jnp.linalg.solve(V, q0.V @ q0.m + Xty)
    a = q0.a + n / 2.0
    b = q0.b + 0.5 * (yty + q0.m @ q0.V @ q0.m - m @ V @ m)
    return pack(NGPosterior(m=m, V=V, a=a, b=b))


def pooled_posterior(X_all, y_all, q0: NGPosterior) -> NGPosterior:
    """Exact Bayesian posterior on the pooled data — the reference."""
    mask = jnp.ones(X_all.shape[0], X_all.dtype)
    return unpack(local_optimum(X_all, y_all, mask, q0, 1.0),
                  q0.D)


# ---------------------------------------------------------------------------
# Distributed estimators — engine wrappers.  No local latents means phi*_i
# is constant across iterations, so the LinRegModel adapter treats the
# precomputed (N, P) phi* stack as the per-node "data" and the engine runs
# exactly the paper's consensus dynamics (Eqs. 27 / 38a+39) on it.  The
# single implementation of those equations lives in core/engine.py.
# ---------------------------------------------------------------------------
def _fixed_point_model(phi_star: jnp.ndarray):
    from repro.core import model as model_lib
    return model_lib.LinRegModel.from_flat_dim(phi_star.shape[-1])


def run_cvb(phi_star: jnp.ndarray) -> jnp.ndarray:
    """Eq. 20: fusion-centre average (exact in one step)."""
    return engine.FusionCenter().combine(phi_star)[0]


def run_dsvb(phi_star, weights, *, n_iters: int, tau: float = 0.2,
             d0: float = 1.0):
    """Eq. 27 with fixed local optima; returns (N, P) final iterates.
    Nodes start at their own local optimum (noncoop state)."""
    run = engine.run_vb(_fixed_point_model(phi_star), phi_star,
                        engine.Diffusion(weights), n_iters=n_iters,
                        schedule=engine.Schedule(tau=tau, d0=d0),
                        init_phi=phi_star, diagnostics=False)
    return run.phi


def run_admm(phi_star, adj, *, n_iters: int, rho: float = 0.5,
             xi: float = 0.05):
    """Eqs. 38a + 39 with fixed local optima."""
    run = engine.run_vb(_fixed_point_model(phi_star), phi_star,
                        engine.ADMMConsensus(adj, rho=rho, xi=xi,
                                             project=False),
                        n_iters=n_iters, init_phi=phi_star,
                        diagnostics=False)
    return run.phi
