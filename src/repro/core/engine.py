"""Unified conjugate-exponential VB engine: Model x Topology x Executor.

Every estimator in the paper is the same per-iteration kernel — each node
runs a VBE step + local VBM optimum to get phi*_i (Eq. 18) — followed by a
topology-specific rule for turning the stack {phi*_i} into the next iterate.
This module owns that second half ONCE; `core/algorithms.py` (GMM),
`core/linreg.py` (Normal-Gamma) and `core/distributed.py` (shard_map mesh
runners) are thin wrappers over `run_vb`.

Equation -> code map (the only implementations in the repo):

* Eq. 20   fusion-centre average                `FusionCenter.combine`
* Eq. 22/29 Robbins-Monro step size eta_t       `eta_schedule` / `Schedule`
* Eq. 27a  natural-gradient step                `_CombineTopology.step`
* Eq. 27b  diffusion combine                    `Diffusion.combine` /
                                                `RingDiffusion.combine`
                                                (`ring_combine*` collectives)
* Eq. 38a  ADMM primal update                   `ADMMConsensus.step`
* Eq. 38b  projection onto Omega                `ADMMConsensus.step` (via
                                                `model.project_to_domain`)
* Eq. 39   ADMM dual ascent                     `ADMMConsensus.step`
* Eq. 40   kappa_t dual-step ramp               `kappa_schedule`
* Eq. 46   KL performance metric                `kl_to_reference`
* Eq. 47   nearest-neighbour weights            `network.nearest_neighbor_weights`
                                                (ring case: `RingDiffusion`)

Executors: the default executor runs the node axis as a plain array axis
(whole runs jit + lax.scan); `MeshExecutor(mesh, axis)` runs the SAME step
function under shard_map with the node axis sharded over a mesh axis, with
each topology supplying its collective form (all_gather for arbitrary
graphs, ppermute for the ICI ring, psum-mean for the fusion centre).
Numerical equivalence of the two executors is asserted in the test-suite.

Backends: orthogonally to the executor, `run_vb(..., backend=)` selects
the COMPUTE implementation of the per-node hot path (model.local_optimum)
via core/backends.py — "reference" einsums or the "fused" Pallas kernel —
for models that support it.  Backend x executor parity is asserted in
tests/test_backends.py.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.dist import compat


# ---------------------------------------------------------------------------
# Step-size schedules (Eqs. 29 and 40)
# ---------------------------------------------------------------------------
def eta_schedule(t: jnp.ndarray, tau: float, d0: float = 1.0) -> jnp.ndarray:
    """eta_t = 1 / (d0 + tau * t); satisfies Robbins-Monro (Eq. 22)."""
    return 1.0 / (d0 + tau * t)


def kappa_schedule(t: jnp.ndarray, xi: float = 0.05) -> jnp.ndarray:
    """kappa_t = 1 - 1/(1 + xi t)^2 ramps the ADMM dual step (Eq. 40)."""
    return 1.0 - 1.0 / (1.0 + xi * t) ** 2


class Schedule(NamedTuple):
    """eta_t used by the natural-gradient step (27a).

    `eta_fixed=1.0` recovers the one-shot estimators (cVB / noncoop /
    nsg-dVB), where the iterate jumps straight to (a combination of) the
    local optima; `eta_fixed=None` is the paper's Robbins-Monro schedule.
    """

    tau: float = 0.2
    d0: float = 1.0
    eta_fixed: Optional[float] = None

    def eta(self, t: jnp.ndarray) -> jnp.ndarray:
        if self.eta_fixed is not None:
            return jnp.asarray(self.eta_fixed, t.dtype)
        return eta_schedule(t + 1.0, self.tau, self.d0)


ONE_SHOT = Schedule(eta_fixed=1.0)


# ---------------------------------------------------------------------------
# Ring collectives (Eq. 27b on the TPU ICI ring) — shared by the mesh
# executor AND the training-layer consensus optimiser (optim/consensus.py)
# ---------------------------------------------------------------------------
def _ring_perms(n: int):
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    return fwd, bwd


def ring_neighbors(x: jnp.ndarray, axis_name: str):
    """(x_{i-1}, x_{i+1}) along the mesh-axis ring, via two ppermutes."""
    fwd, bwd = _ring_perms(compat.axis_size(axis_name))
    return (jax.lax.ppermute(x, axis_name, fwd),
            jax.lax.ppermute(x, axis_name, bwd))


def ring_combine(x: jnp.ndarray, axis_name: str, w_self: float = 1.0 / 3.0,
                 compute_dtype=None) -> jnp.ndarray:
    """Eq. 27b with ring nearest-neighbour weights for ONE tensor per mesh
    slot: x_i <- w_self x_i + w_n (x_{i-1} + x_{i+1}).  With w_self = 1/3
    this is exactly Eq. 47 on a cycle graph.

    `compute_dtype` upcasts AFTER the ppermutes, so the wire traffic stays
    in the storage dtype (bf16 weights exchange bf16 bytes) while the
    weighted sum accumulates at higher precision.
    """
    left, right = ring_neighbors(x, axis_name)
    if compute_dtype is not None:
        x, left, right = (a.astype(compute_dtype) for a in (x, left, right))
    w_n = (1.0 - w_self) / 2.0
    return w_self * x + w_n * (left + right)


def ring_combine_block(varphi: jnp.ndarray, axis_name: str,
                       w_self: float = 1.0 / 3.0) -> jnp.ndarray:
    """Eq. 27b on a ring for a BLOCK of nodes per mesh slot (leading axis =
    local nodes).  Interior neighbours are a local roll; only the two
    boundary rows cross the ICI link (ppermute) — the minimal-traffic
    neighbour exchange."""
    fwd, bwd = _ring_perms(compat.axis_size(axis_name))
    prev_tail = jax.lax.ppermute(varphi[-1:], axis_name, fwd)
    next_head = jax.lax.ppermute(varphi[:1], axis_name, bwd)
    shifted_right = jnp.concatenate([prev_tail, varphi[:-1]], 0)  # phi_{i-1}
    shifted_left = jnp.concatenate([varphi[1:], next_head], 0)    # phi_{i+1}
    w_n = (1.0 - w_self) / 2.0
    return w_self * varphi + w_n * (shifted_right + shifted_left)


# ---------------------------------------------------------------------------
# Topologies / combiners
# ---------------------------------------------------------------------------
class _CombineTopology:
    """Topologies of the form: (27a) varphi_i = phi_i + eta (phi*_i - phi_i),
    then a linear combine of {varphi_i}.  Subclasses supply `combine`."""

    uses_schedule = True

    def shard_inputs(self) -> dict:
        """Per-node arrays the mesh executor must shard along the node axis
        (e.g. the rows of the combination-weight matrix)."""
        return {}

    def init_carry(self, phi0: jnp.ndarray):
        return None

    def combine(self, varphi, *, axis=None, local=None):
        raise NotImplementedError

    def step(self, model, phi, carry, phi_star, t, schedule: Schedule, *,
             axis=None, local=None):
        eta = schedule.eta(t.astype(phi.dtype))
        if schedule.eta_fixed == 1.0:
            varphi = phi_star                       # one-shot: jump to phi*
        else:
            varphi = phi + eta * (phi_star - phi)   # Eq. 27a
        return self.combine(varphi, axis=axis, local=local), carry


class FusionCenter(_CombineTopology):
    """Centralised reference: phi <- mean_i phi*_i exactly (Eq. 20)."""

    def combine(self, varphi, *, axis=None, local=None):
        if axis is None:
            mean = jnp.mean(varphi, axis=0)
        else:
            mean = jax.lax.pmean(jnp.mean(varphi, axis=0), axis)
        return jnp.broadcast_to(mean, varphi.shape)


class Isolated(_CombineTopology):
    """No communication (noncoop-VB): every node keeps its own iterate."""

    def combine(self, varphi, *, axis=None, local=None):
        return varphi


class Diffusion(_CombineTopology):
    """Arbitrary-graph diffusion combine phi_i <- sum_j w_ij varphi_j
    (Eq. 27b) with a row-stochastic weight matrix (e.g. Eq. 47)."""

    def __init__(self, weights: jnp.ndarray):
        self.weights = weights

    def shard_inputs(self) -> dict:
        return {"weights": self.weights}

    def combine(self, varphi, *, axis=None, local=None):
        if axis is None:
            return self.weights @ varphi
        # every node must see the messages addressed to it; on a mesh the
        # collective realising that for an arbitrary graph is an all_gather
        # followed by the local rows of W
        varphi_all = jax.lax.all_gather(varphi, axis, tiled=True)
        return local["weights"] @ varphi_all


class RingDiffusion(_CombineTopology):
    """Diffusion on the cycle graph — the TPU-native topology where the
    communication graph IS the ICI ring along a mesh axis, so the combine
    is two ppermutes and a weighted sum (no all_gather, no all_reduce)."""

    def __init__(self, w_self: float = 1.0 / 3.0):
        self.w_self = w_self

    def combine(self, varphi, *, axis=None, local=None):
        if axis is not None:
            return ring_combine_block(varphi, axis, self.w_self)
        w_n = (1.0 - self.w_self) / 2.0
        return (self.w_self * varphi
                + w_n * (jnp.roll(varphi, 1, axis=0)
                         + jnp.roll(varphi, -1, axis=0)))


class ADMMConsensus:
    """Consensus ADMM in natural-parameter space (Algorithm 2).

    Per iteration and node i with neighbours N_i (|N_i| = d_i):

      (38a) phi_i <- [phi*_i - 2 lam_i + rho sum_{j in N_i}(phi_i + phi_j)]
                     / (1 + 2 rho d_i)
      (38b) phi_i <- Proj_Omega(phi_i)                  (if project=True)
      (39)  lam_i <- lam_i + kappa_t rho/2 sum_{j in N_i}(phi_i - phi_j)
      (40)  kappa_t = 1 - 1/(1 + xi t)^2

    `lam_max` (off by default — None keeps Algorithm 2 verbatim) clips each
    dual coordinate to [-lam_max * |phi*_i|, +lam_max * |phi*_i|] after the
    Eq. 39 ascent.  The duals only need to cancel the disagreement part of
    phi*, so a bound proportional to the local optimum's magnitude damps
    the wind-up observed on imbalanced instances (|lam| growing to O(|phi|)
    and the Eq. 38b eigen-clip then amplifying the oscillation — see
    ROADMAP "dVB-ADMM numerics").

    Algorithm 2 has no natural-gradient step, so `run_vb`'s `schedule` does
    not apply to this topology (run_vb rejects a non-default one).
    """

    uses_schedule = False

    def __init__(self, adj: jnp.ndarray, rho: float = 0.5, xi: float = 0.05,
                 project: bool = True, lam_max: float | None = None):
        self.adj = adj
        self.rho = rho
        self.xi = xi
        self.project = project
        self.lam_max = lam_max

    def shard_inputs(self) -> dict:
        return {"adj": self.adj}

    def init_carry(self, phi0: jnp.ndarray):
        return jnp.zeros_like(phi0)                   # duals lambda_i

    def step(self, model, phi, lam, phi_star, t, schedule: Schedule, *,
             axis=None, local=None):
        adj_rows = self.adj if axis is None else local["adj"]
        deg = jnp.sum(adj_rows, axis=1)               # |N_i|

        def neigh_sum(z):                             # sum_{j in N_i} z_j
            if axis is None:
                return adj_rows @ z
            return adj_rows @ jax.lax.all_gather(z, axis, tiled=True)

        # (38a) primal
        phi_hat = (phi_star - 2.0 * lam
                   + self.rho * (deg[:, None] * phi + neigh_sum(phi)))
        phi_hat = phi_hat / (1.0 + 2.0 * self.rho * deg)[:, None]
        if self.project:
            phi_new = jax.vmap(model.project_to_domain)(phi_hat)  # (38b)
        else:
            phi_new = phi_hat
        # (39) dual ascent with the kappa_t ramp (40)
        kappa = kappa_schedule(t.astype(phi.dtype) + 1.0, self.xi)
        resid = deg[:, None] * phi_new - neigh_sum(phi_new)
        lam_new = lam + kappa * self.rho / 2.0 * resid
        if self.lam_max is not None:
            bound = self.lam_max * jnp.abs(phi_star)
            lam_new = jnp.clip(lam_new, -bound, bound)
        return phi_new, lam_new


# ---------------------------------------------------------------------------
# Metrics (Eq. 46) + run result
# ---------------------------------------------------------------------------
def kl_to_reference(model, phi_nodes: jnp.ndarray,
                    ref_phi: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Per-node KL to the ground-truth posterior (Eq. 46).

    `ref_phi` may be (P,) or a (n_refs, P) stack — e.g. component
    permutations of a mixture reference — in which case the
    permutation-invariant min-KL is reported.
    """
    if ref_phi is None:
        return jnp.zeros(phi_nodes.shape[0], phi_nodes.dtype)
    ref = ref_phi[None] if ref_phi.ndim == 1 else ref_phi
    return jax.vmap(
        lambda p: jnp.min(jax.vmap(lambda r: model.kl(p, r))(ref)))(phi_nodes)


class VBRun(NamedTuple):
    phi: jnp.ndarray            # (N, P) final natural parameters per node
    kl_mean: jnp.ndarray        # (T,)   mean_i KL(q_i || ground truth)
    kl_std: jnp.ndarray         # (T,)
    kl_nodes: jnp.ndarray       # (T, N) per-node trajectory
    consensus_err: Any = None   # (T,)   mean_i ||phi_i - mean_j phi_j||^2


class MeshExecutor(NamedTuple):
    """Run the node axis sharded over `axis` of `mesh` via shard_map."""

    mesh: Any
    axis: str = "data"


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------
def _scan_steps(model, data, topology, schedule, replication, ref_phi,
                n_iters, phi0, carry0, *, axis=None, local=None,
                diagnostics=True, metric_nodes=None):
    """The per-iteration kernel, shared verbatim by both executors."""

    def step(carry, t):
        phi, aux = carry
        phi_star = model.local_optimum(data, phi, replication)
        phi_new, aux_new = topology.step(model, phi, aux, phi_star, t,
                                         schedule, axis=axis, local=local)
        phi_m = phi_new if metric_nodes is None else phi_new[:metric_nodes]
        kl = kl_to_reference(model, phi_m, ref_phi)
        if diagnostics:
            mean = jnp.mean(phi_new, axis=0)
            if axis is not None:
                mean = jax.lax.pmean(mean, axis)
            msd = jnp.mean((phi_new - mean) ** 2)
            if axis is not None:
                msd = jax.lax.pmean(msd, axis)
        else:
            msd = jnp.zeros((), phi_new.dtype)
        return (phi_new, aux_new), (kl, msd)

    (phi, _), (kls, msds) = jax.lax.scan(step, (phi0, carry0),
                                         jnp.arange(n_iters))
    return phi, kls, msds


def run_vb(model, data, topology, *, n_iters: int,
           schedule: Schedule = Schedule(), replication: float | None = None,
           init_phi: Optional[jnp.ndarray] = None,
           ref_phi: Optional[jnp.ndarray] = None,
           executor: Optional[MeshExecutor] = None,
           backend=None,
           diagnostics: bool = True,
           metric_nodes: Optional[int] = None) -> VBRun:
    """Run distributed VB: `model` on `data` over `topology`.

    Parameters
    ----------
    model : ConjugateExpModel (see core/model.py)
    data : per-node data pytree; every leaf has leading node axis N
    topology : FusionCenter | Isolated | Diffusion | RingDiffusion |
        ADMMConsensus — how {phi*_i} becomes the next iterate
    n_iters : number of VB iterations (the scan length)
    schedule : eta_t of the natural-gradient step (27a); `ONE_SHOT` for the
        jump-to-optimum estimators
    replication : likelihood replication factor (paper App. A); defaults to
        the network size N, use 1.0 for non-cooperative runs
    init_phi : (N, P) initial naturals; defaults to the prior at every node
    ref_phi : (P,) or (n_refs, P) reference for the Eq. 46 metric
    executor : None = single-array (node axis is a plain array axis, whole
        run jits); MeshExecutor(mesh, axis) = shard_map over a mesh axis
    backend : per-run compute-backend override ("reference" | "fused" | a
        `core.backends.Backend` instance) for models that support backend
        selection via `with_backend` (GMMModel).  None keeps the model's
        own backend.  Orthogonal to `executor`: the backend picks the
        kernel, the executor picks how the node axis is laid out.
    diagnostics : also record per-iteration consensus error
    metric_nodes : evaluate the Eq. 46 metric on only the first
        `metric_nodes` rows (kl_nodes becomes (T, metric_nodes)) — used by
        cVB, whose iterates are identical across nodes.  Single-array
        executor only.

    Returns a `VBRun` regardless of executor; the two paths are numerically
    equivalent (asserted in tests/test_engine.py).
    """
    if backend is not None:
        with_backend = getattr(model, "with_backend", None)
        if with_backend is None:
            raise ValueError(
                f"{type(model).__name__} does not support compute-backend "
                "selection (no with_backend method)")
        model = with_backend(backend)
    if not getattr(topology, "uses_schedule", True) \
            and schedule != Schedule():
        raise ValueError(
            f"{type(topology).__name__} has no natural-gradient step "
            "(Eq. 27a); it ignores `schedule` — pass the default")
    if executor is not None and metric_nodes is not None:
        raise ValueError("metric_nodes is only supported on the "
                         "single-array executor")
    n_nodes = jax.tree_util.tree_leaves(data)[0].shape[0]
    if replication is None:
        replication = float(n_nodes)
    if init_phi is None:
        init_phi = jnp.broadcast_to(model.init_phi(),
                                    (n_nodes, model.flat_dim))
    carry0 = topology.init_carry(init_phi)

    if executor is None:
        phi, kls, msds = _scan_steps(
            model, data, topology, schedule, replication, ref_phi,
            n_iters, init_phi, carry0, diagnostics=diagnostics,
            metric_nodes=metric_nodes)
        return VBRun(phi=phi, kl_mean=jnp.mean(kls, 1),
                     kl_std=jnp.std(kls, 1), kl_nodes=kls,
                     consensus_err=msds if diagnostics else None)

    return _run_vb_sharded(model, data, topology, schedule, replication,
                           ref_phi, n_iters, init_phi, carry0,
                           executor, diagnostics)


def _run_vb_sharded(model, data, topology, schedule, replication, ref_phi,
                    n_iters, init_phi, carry0, executor: MeshExecutor,
                    diagnostics: bool) -> VBRun:
    """shard_map executor: node axis sharded over `executor.axis`."""
    mesh, axis = executor.mesh, executor.axis
    from repro.dist import sharding

    local_inputs = topology.shard_inputs()          # dict of (N, ...) arrays
    local_keys = tuple(sorted(local_inputs))
    has_carry = carry0 is not None

    in_specs, out_specs = sharding.vb_node_specs(
        data, axis=axis, has_carry=has_carry, n_local=len(local_keys))

    def run(data_l, phi_l, carry_l, *local_vals):
        local = dict(zip(local_keys, local_vals))
        phi, kls, msds = _scan_steps(
            model, data_l, topology, schedule, replication, ref_phi,
            n_iters, phi_l, carry_l if has_carry else None,
            axis=axis, local=local, diagnostics=diagnostics)
        return phi, kls, msds

    fn = compat.shard_map(run, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
    phi, kls, msds = fn(data, init_phi,
                        carry0 if has_carry else jnp.zeros((), init_phi.dtype),
                        *(local_inputs[k] for k in local_keys))
    return VBRun(phi=phi, kl_mean=jnp.mean(kls, 1), kl_std=jnp.std(kls, 1),
                 kl_nodes=kls, consensus_err=msds if diagnostics else None)
