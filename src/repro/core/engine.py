"""Unified conjugate-exponential VB engine: Model x Topology x Executor.

Every estimator in the paper is the same per-iteration kernel — each node
runs a VBE step + local VBM optimum to get phi*_i (Eq. 18) — followed by a
topology-specific rule for turning the stack {phi*_i} into the next iterate.
This module owns that second half ONCE; `core/algorithms.py` (GMM),
`core/linreg.py` (Normal-Gamma) and `core/distributed.py` (shard_map mesh
runners) are thin wrappers over `run_vb`.

Equation -> code map (the only implementations in the repo; the full map
with Eqs. 38-40 spelled out lives in docs/ARCHITECTURE.md):

* Eq. 20   fusion-centre average                `FusionCenter.combine`
* Eq. 22/29 Robbins-Monro step size eta_t       `eta_schedule` / `Schedule`
* Eq. 27a  natural-gradient step                `_CombineTopology.step`
* Eq. 27b  diffusion combine                    `Diffusion.combine` /
                                                `RingDiffusion.combine`
                                                (`ring_combine*` collectives)
* Eq. 38a  ADMM primal update                   `ADMMConsensus.step`
* Eq. 38b  projection onto Omega                `ADMMConsensus.step` (via
                                                `model.project_to_domain`)
* Eq. 39   ADMM dual ascent                     `ADMMConsensus.step`
* Eq. 40   kappa_t dual-step ramp               `kappa_schedule`
* Eq. 46   KL performance metric                `kl_to_reference`
* Eq. 47   nearest-neighbour weights            `network.nearest_neighbor_weights`
                                                (ring case: `RingDiffusion`)

Every graph topology runs dense ((N, N) matrix — the small-N parity
oracle) or sparse (`network.SparseGraph` edge lists via `_sparse_combine`
— O(E + N), 10k+ nodes), and two scenario topologies build on the sparse
layer: `PairwiseGossip` (asynchronous randomized link activation,
deterministic in (seed, absolute t)) and `HierarchicalFusion`
(sensor -> gateway -> region).  See docs/sparse-topologies.md.

`ADMMConsensus` additionally carries the adaptive-penalty consensus
subsystem (off by default; Algorithm 2 verbatim otherwise): residual
balancing of rho (Boyd et al., "Distributed Optimization and Statistical
Learning via ADMM", Sec. 3.4.1), per-block dual scaling over the model's
natural-parameter blocks, a residual-gated dual warmup, and dual reset on
Eq. 38b eigen-clip activation, all observable through the per-iteration
`ConsensusDiagnostics` record on `VBRun.consensus_diag`.  The convergence
story (why plain Algorithm 2 winds up on imbalanced instances and how the
subsystem fixes it) is docs/admm-convergence.md.

Executors: the default executor runs the node axis as a plain array axis
(whole runs jit + lax.scan); `MeshExecutor(mesh, axis)` runs the SAME step
function under shard_map with the node axis sharded over a mesh axis, with
each topology supplying its collective form (all_gather for arbitrary
graphs, ppermute for the ICI ring, psum-mean for the fusion centre).
Numerical equivalence of the two executors is asserted in the test-suite.

Backends: orthogonally to the executor, `run_vb(..., backend=)` selects
the COMPUTE implementation of the per-node hot path (model.local_optimum)
via core/backends.py — "reference" einsums or the "fused" Pallas kernel —
for models that support it.  Backend x executor parity is asserted in
tests/test_backends.py.

Streaming: `run_vb(..., minibatch=stream.MinibatchSpec(batch_size, seed))`
runs the stochastic form of every estimator — per-iteration reshuffled
minibatches with unbiased n_i/|B| statistics rescaling (data/stream.py),
which is what makes the Robbins-Monro `Schedule` a genuine stochastic
natural-gradient step.  Time-varying networks: `Diffusion`,
`RingDiffusion` and `ADMMConsensus` take `link_drop` / `link_mask_fn`
(see `_LinkSchedule`) to run over per-iteration failing links, with the
surviving fraction observable as `ConsensusDiagnostics.link_frac`.
Both compose with both executors and both backends
(tests/test_streaming.py).

Sessions: the engine is organised around an explicit, checkpointable
state object — `vb_init(model, data, topology, ...)` returns a `VBState`
pytree (phi, absolute iteration t, topology carry incl. ADMM duals/rho,
minibatch-sampler stream state, last diagnostics), `vb_step(state)`
advances one iteration and `vb_run(state, n_iters)` scans it.  All
per-iteration randomness is keyed on the absolute t carried in the state,
so runs split across calls (or checkpoint save/restore via
`checkpoint/ckpt.py`) are bit-exact with the unsplit run; `run_vb` is the
thin one-shot wrapper.  The serving layer (`serving/vb_service.py`)
batches many independent sessions along a leading fleet axis over
`session_step_fn`.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.core import network as network_lib
from repro.data import stream
from repro.dist import compat
from repro.telemetry import taps


# ---------------------------------------------------------------------------
# Step-size schedules (Eqs. 29 and 40)
# ---------------------------------------------------------------------------
def eta_schedule(t: jnp.ndarray, tau: float, d0: float = 1.0) -> jnp.ndarray:
    """eta_t = 1 / (d0 + tau * t); satisfies Robbins-Monro (Eq. 22).

    >>> import jax.numpy as jnp
    >>> [round(float(eta_schedule(jnp.asarray(t), tau=0.5)), 3)
    ...  for t in (1.0, 2.0, 10.0)]
    [0.667, 0.5, 0.167]
    """
    return 1.0 / (d0 + tau * t)


def kappa_schedule(t: jnp.ndarray, xi: float = 0.05) -> jnp.ndarray:
    """kappa_t = 1 - 1/(1 + xi t)^2 ramps the ADMM dual step (Eq. 40).

    >>> import jax.numpy as jnp
    >>> kap = kappa_schedule(jnp.arange(1.0, 100.0))
    >>> bool(kap[0] < 0.15), bool(kap[-1] > 0.95)
    (True, True)
    """
    return 1.0 - 1.0 / (1.0 + xi * t) ** 2


class Schedule(NamedTuple):
    """eta_t used by the natural-gradient step (27a).

    `eta_fixed=1.0` recovers the one-shot estimators (cVB / noncoop /
    nsg-dVB), where the iterate jumps straight to (a combination of) the
    local optima; `eta_fixed=None` is the paper's Robbins-Monro schedule.

    >>> import jax.numpy as jnp
    >>> round(float(Schedule(tau=0.2).eta(jnp.asarray(0.0))), 4)  # t=1
    0.8333
    >>> float(ONE_SHOT.eta(jnp.asarray(0.0)))              # jump to phi*
    1.0
    """

    tau: float = 0.2
    d0: float = 1.0
    eta_fixed: Optional[float] = None

    def eta(self, t: jnp.ndarray, hyper=None) -> jnp.ndarray:
        """eta_t.  `hyper` is the optional per-session lifted-hyper dict
        the serving layer threads through the fleet axis (see
        `hyper_names`): entries override the static `tau` / `d0` so
        sessions differing only in schedule constants share a compiled
        fleet.  None (every solo path) reproduces the static behaviour
        exactly."""
        if self.eta_fixed is not None:
            return jnp.asarray(self.eta_fixed, t.dtype)
        tau = self.tau if not hyper or "tau" not in hyper else hyper["tau"]
        d0 = self.d0 if not hyper or "d0" not in hyper else hyper["d0"]
        return eta_schedule(t + 1.0, tau, d0)


ONE_SHOT = Schedule(eta_fixed=1.0)


# ---------------------------------------------------------------------------
# Ring collectives (Eq. 27b on the TPU ICI ring) — shared by the mesh
# executor AND the training-layer consensus optimiser (optim/consensus.py)
# ---------------------------------------------------------------------------
def _ring_perms(n: int):
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    return fwd, bwd


def ring_neighbors(x: jnp.ndarray, axis_name: str):
    """(x_{i-1}, x_{i+1}) along the mesh-axis ring, via two ppermutes."""
    fwd, bwd = _ring_perms(compat.axis_size(axis_name))
    return (jax.lax.ppermute(x, axis_name, fwd),
            jax.lax.ppermute(x, axis_name, bwd))


def ring_combine(x: jnp.ndarray, axis_name: str, w_self: float = 1.0 / 3.0,
                 compute_dtype=None) -> jnp.ndarray:
    """Eq. 27b with ring nearest-neighbour weights for ONE tensor per mesh
    slot: x_i <- w_self x_i + w_n (x_{i-1} + x_{i+1}).  With w_self = 1/3
    this is exactly Eq. 47 on a cycle graph.

    `compute_dtype` upcasts AFTER the ppermutes, so the wire traffic stays
    in the storage dtype (bf16 weights exchange bf16 bytes) while the
    weighted sum accumulates at higher precision.
    """
    left, right = ring_neighbors(x, axis_name)
    if compute_dtype is not None:
        x, left, right = (a.astype(compute_dtype) for a in (x, left, right))
    w_n = (1.0 - w_self) / 2.0
    return w_self * x + w_n * (left + right)


def ring_combine_block(varphi: jnp.ndarray, axis_name: str,
                       w_self: float = 1.0 / 3.0) -> jnp.ndarray:
    """Eq. 27b on a ring for a BLOCK of nodes per mesh slot (leading axis =
    local nodes).  Interior neighbours are a local roll; only the two
    boundary rows cross the ICI link (ppermute) — the minimal-traffic
    neighbour exchange."""
    fwd, bwd = _ring_perms(compat.axis_size(axis_name))
    prev_tail = jax.lax.ppermute(varphi[-1:], axis_name, fwd)
    next_head = jax.lax.ppermute(varphi[:1], axis_name, bwd)
    shifted_right = jnp.concatenate([prev_tail, varphi[:-1]], 0)  # phi_{i-1}
    shifted_left = jnp.concatenate([varphi[1:], next_head], 0)    # phi_{i+1}
    w_n = (1.0 - w_self) / 2.0
    return w_self * varphi + w_n * (shifted_right + shifted_left)


# ---------------------------------------------------------------------------
# Residual balancing (Boyd et al. Sec. 3.4.1) — ONE rule shared by the VB
# consensus topology below and the training-layer consensus optimiser
# (optim/consensus.py)
# ---------------------------------------------------------------------------
def residual_balanced_rho(rho, r_norm, s_norm, *, mu: float = 10.0,
                          tau_incr: float = 2.0, tau_decr: float = 2.0,
                          rho_min: float = 1e-3, rho_max: float = 1e3):
    """One residual-balancing update of the ADMM penalty.

    Grow rho by `tau_incr` where the primal residual dominates
    (||r|| > mu ||s||: the iterates still disagree, press harder), shrink
    by `tau_decr` where the dual residual dominates (||s|| > mu ||r||: the
    penalty is bullying the local objectives), else leave unchanged;
    always clip to [rho_min, rho_max].  Shapes broadcast, so `rho` may be
    a scalar or a per-block vector.

    >>> import jax.numpy as jnp
    >>> float(residual_balanced_rho(jnp.asarray(1.0), 100.0, 1.0))
    2.0
    >>> float(residual_balanced_rho(jnp.asarray(1.0), 1.0, 100.0))
    0.5
    >>> float(residual_balanced_rho(jnp.asarray(1.0), 1.0, 2.0))
    1.0
    """
    grow = r_norm > mu * s_norm
    shrink = s_norm > mu * r_norm
    fac = jnp.where(grow, tau_incr, jnp.where(shrink, 1.0 / tau_decr, 1.0))
    return jnp.clip(rho * fac, rho_min, rho_max)


# ---------------------------------------------------------------------------
# Time-varying links (failing sensor links, Sec. II's unreliable networks)
# ---------------------------------------------------------------------------
class _LinkSchedule:
    """Per-iteration link-failure schedule shared by the topologies.

    Two forms, mutually exclusive:

    * `link_drop` — every undirected link independently fails with this
      probability each iteration (Bernoulli, deterministic in
      (`link_seed`, t) via `network.link_keep_matrix` /
      `network.ring_link_keep`, so both executors replay the identical
      failure pattern).
    * `link_mask_fn(t)` — an explicit keep-mask sequence: a traceable
      callable returning the iteration-t keep mask ((N, N) 0/1 symmetric
      for graph topologies, (N,) per ring edge for `RingDiffusion`).  An
      explicit adjacency sequence whose edges are a subset of the base
      graph is `lambda t: adj_seq[t]`-style.

    With neither set the topology is static and every code path is
    bit-identical to the time-invariant engine (golden-parity guarantee).
    """

    def __init__(self, link_drop: float = 0.0, link_seed: int = 0,
                 link_mask_fn: Optional[Callable] = None):
        if link_drop and link_mask_fn is not None:
            raise ValueError("pass link_drop OR link_mask_fn, not both")
        if not 0.0 <= link_drop <= 1.0:
            raise ValueError(f"link_drop must be a probability: {link_drop}")
        self.link_drop = float(link_drop)
        self.link_mask_fn = link_mask_fn
        self.time_varying = bool(link_drop) or link_mask_fn is not None
        self._link_key = (jax.random.PRNGKey(link_seed)
                          if self.time_varying and link_mask_fn is None
                          else None)

    def _require_t(self, t):
        if t is None:
            raise ValueError(
                "time-varying links need the iteration index: call "
                "combine(..., t=<iteration>) (run_vb supplies it "
                "automatically)")
        return t

    def keep_matrix(self, t, n: int, dtype) -> jnp.ndarray:
        t = self._require_t(t)
        if self.link_mask_fn is not None:
            return jnp.asarray(self.link_mask_fn(t)).astype(dtype)
        return network_lib.link_keep_matrix(self._link_key, t, n,
                                            self.link_drop, dtype)

    def keep_ring(self, t, n: int, dtype) -> jnp.ndarray:
        t = self._require_t(t)
        if self.link_mask_fn is not None:
            return jnp.asarray(self.link_mask_fn(t)).astype(dtype)
        return network_lib.ring_link_keep(self._link_key, t, n,
                                          self.link_drop, dtype)

    def keep_edges(self, t, n_undirected: int, dtype) -> jnp.ndarray:
        """Edge-list form: (E_undirected,) keep mask — one coin per
        undirected link (`network.sparse_link_keep`), so a failed link is
        failed both ways, exactly the dense contract.  A `link_mask_fn`
        must return the (E_undirected,) mask in the graph's link order."""
        t = self._require_t(t)
        if self.link_mask_fn is not None:
            return jnp.asarray(self.link_mask_fn(t)).astype(dtype)
        return network_lib.sparse_link_keep(self._link_key, t, n_undirected,
                                            self.link_drop, dtype)


def _local_rows(full: jnp.ndarray, n_local: int, axis: str) -> jnp.ndarray:
    """This shard's contiguous row block of a replicated (N, ...) array."""
    row0 = jax.lax.axis_index(axis) * n_local
    return jax.lax.dynamic_slice_in_dim(full, row0, n_local, axis=0)


def _segment_sum(x: jnp.ndarray, graph) -> jnp.ndarray:
    """sum over directed edges into each receiver — the sparse neighbour
    reduce.  Edges are receiver-sorted by `SparseGraph` construction."""
    return jax.ops.segment_sum(x, graph.receivers,
                               num_segments=graph.n_nodes,
                               indices_are_sorted=True)


def _sparse_combine(sw, varphi: jnp.ndarray,
                    keep_und: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Eq. 27b in edge-list form: phi_i <- w_self_i varphi_i
    + sum_{e: recv(e)=i} w_e varphi_send(e), via one `segment_sum` over
    the directed edges — O(E P) compute, O(N P + E) memory, never an
    (N, N) matrix.

    `keep_und` gates the undirected links of a time-varying network: the
    surviving weights renormalise per receiver (for Eq. 47 weights that
    IS Eq. 47 on the surviving graph — the dense `_effective_weights`
    semantics), and a fully isolated node (no live links AND zero
    self-weight) keeps its own iterate (`RingDiffusion._gated`
    semantics).
    """
    g = sw.graph
    w_e = sw.w_edge.astype(varphi.dtype)
    w_s = sw.w_self.astype(varphi.dtype)
    msg = varphi[g.senders]                        # (E, P)
    if keep_und is None:
        return w_s[:, None] * varphi + _segment_sum(w_e[:, None] * msg, g)
    w_e = w_e * keep_und[g.edge_id].astype(varphi.dtype)
    num = w_s[:, None] * varphi + _segment_sum(w_e[:, None] * msg, g)
    den = w_s + _segment_sum(w_e, g)
    isolated = den <= 0.0
    safe = jnp.where(isolated, jnp.ones_like(den), den)
    return jnp.where(isolated[:, None], varphi, num / safe[:, None])


# ---------------------------------------------------------------------------
# Topologies / combiners
# ---------------------------------------------------------------------------
class _CombineTopology:
    """Topologies of the form: (27a) varphi_i = phi_i + eta (phi*_i - phi_i),
    then a linear combine of {varphi_i}.  Subclasses supply `combine`.

    `step` returns (phi_next, carry_next, diag): the third slot is the
    per-iteration diagnostics pytree (None for combine topologies; only
    `ADMMConsensus` emits a `ConsensusDiagnostics`)."""

    uses_schedule = True
    emits_diagnostics = False

    def shard_inputs(self) -> dict:
        """Per-node arrays the mesh executor must shard along the node axis
        (e.g. the rows of the combination-weight matrix)."""
        return {}

    def init_carry(self, phi0: jnp.ndarray, model=None):
        return None

    def init_diag(self, model, phi0: jnp.ndarray):
        """Structure-stable t=0 value of the per-iteration diagnostics
        record (None for combine topologies: they emit none)."""
        return None

    def carry_specs(self, axis: str):
        """shard_map PartitionSpec pytree for `init_carry`'s output (leaf
        prefix: per-node arrays shard their leading node axis)."""
        from jax.sharding import PartitionSpec as P
        return P(axis)

    def combine(self, varphi, *, axis=None, local=None, t=None):
        raise NotImplementedError

    def step(self, model, phi, carry, phi_star, t, schedule: Schedule, *,
             axis=None, local=None, hyper=None):
        eta = schedule.eta(t.astype(phi.dtype), hyper)
        if schedule.eta_fixed == 1.0:
            varphi = phi_star                       # one-shot: jump to phi*
        else:
            varphi = phi + eta * (phi_star - phi)   # Eq. 27a
        return (self.combine(varphi, axis=axis, local=local, t=t),
                carry, None)


class FusionCenter(_CombineTopology):
    """Centralised reference: phi <- mean_i phi*_i exactly (Eq. 20).

    Every node ends up holding the same iterate — the fusion-centre average
    of the local optima:

    >>> import jax.numpy as jnp
    >>> varphi = jnp.asarray([[0.0, 2.0], [2.0, 4.0]])   # (N=2, P=2)
    >>> FusionCenter().combine(varphi).tolist()
    [[1.0, 3.0], [1.0, 3.0]]
    """

    def combine(self, varphi, *, axis=None, local=None, t=None):
        if axis is None:
            mean = jnp.mean(varphi, axis=0)
        else:
            mean = jax.lax.pmean(jnp.mean(varphi, axis=0), axis)
        return jnp.broadcast_to(mean, varphi.shape)


class Isolated(_CombineTopology):
    """No communication (noncoop-VB): every node keeps its own iterate.

    >>> import jax.numpy as jnp
    >>> varphi = jnp.asarray([[1.0], [2.0]])
    >>> bool(jnp.all(Isolated().combine(varphi) == varphi))
    True
    """

    def combine(self, varphi, *, axis=None, local=None, t=None):
        return varphi


class Diffusion(_CombineTopology):
    """Arbitrary-graph diffusion combine phi_i <- sum_j w_ij varphi_j
    (Eq. 27b) with a row-stochastic weight matrix (e.g. Eq. 47).

    `link_drop` / `link_mask_fn` make the network time-varying: each
    iteration the surviving off-diagonal entries are renormalised per row
    (for the Eq. 47 nearest-neighbour weights that IS Eq. 47 evaluated on
    the surviving graph — uniform over the still-reachable neighbourhood),
    so the combine stays row-stochastic over whatever links are up.

    `weights` is EITHER the dense (N, N) row-stochastic matrix (the
    paper-scale oracle) OR a `network.SparseWeights` edge-list bundle
    (`sparse_nearest_neighbor_weights` / `sparse_metropolis_weights` over
    a `SparseGraph`) — the latter runs the identical combine through
    `segment_sum` without ever materialising an N x N array, which is
    what carries the topology layer to 10k+ nodes
    (docs/sparse-topologies.md; dense/sparse parity is pinned at <= 1e-9
    in tests/test_sparse_topology.py).  In sparse mode a `link_mask_fn`
    returns the (E_undirected,) per-link keep mask instead of (N, N).

    >>> import jax.numpy as jnp
    >>> W = jnp.asarray([[0.5, 0.5], [0.5, 0.5]])        # 2-node clique
    >>> Diffusion(W).combine(jnp.asarray([[0.0], [4.0]])).tolist()
    [[2.0], [2.0]]
    >>> dead = Diffusion(W, link_mask_fn=lambda t: jnp.eye(2))  # all down
    >>> dead.combine(jnp.asarray([[0.0], [4.0]]), t=0).tolist()
    [[0.0], [4.0]]
    """

    def __init__(self, weights, *, link_drop: float = 0.0,
                 link_seed: int = 0,
                 link_mask_fn: Optional[Callable] = None):
        self.weights = weights
        self.sparse = isinstance(weights, network_lib.SparseWeights)
        self.links = _LinkSchedule(link_drop, link_seed, link_mask_fn)

    def shard_inputs(self) -> dict:
        # sparse mode: the edge arrays are not per-node rows, so they ride
        # into the shard_map body as replicated closure constants and the
        # combine slices its local rows out of the gathered result
        return {} if self.sparse else {"weights": self.weights}

    def _effective_weights(self, W_rows, t, *, axis):
        """Per-iteration weights: drop-masked, row-renormalised."""
        n = self.weights.shape[0]
        keep = self.links.keep_matrix(t, n, W_rows.dtype)
        # a node never loses itself: force the keep diagonal to 1 so a
        # zero-diagonal `link_mask_fn` (an adjacency sequence) cannot
        # delete the self-weight, and an all-links-down row renormalises
        # to the identity combine instead of zeroing phi_i
        keep = jnp.maximum(keep, jnp.eye(n, dtype=W_rows.dtype))
        if axis is not None:
            keep = _local_rows(keep, W_rows.shape[0], axis)
        W_eff = W_rows * keep
        rows = jnp.sum(W_eff, axis=1, keepdims=True)
        return W_eff / jnp.where(rows > 0, rows, jnp.ones_like(rows))

    def combine(self, varphi, *, axis=None, local=None, t=None):
        if self.sparse:
            sw = self.weights
            keep = (self.links.keep_edges(t, sw.graph.n_undirected,
                                          varphi.dtype)
                    if self.links.time_varying else None)
            if axis is None:
                return _sparse_combine(sw, varphi, keep)
            # every node must see the messages addressed to it; gather the
            # node axis, run the full edge-list combine, keep local rows
            varphi_all = jax.lax.all_gather(varphi, axis, tiled=True)
            return _local_rows(_sparse_combine(sw, varphi_all, keep),
                               varphi.shape[0], axis)
        if axis is None:
            W = self.weights
            if self.links.time_varying:
                W = self._effective_weights(W, t, axis=None)
            return W @ varphi
        # every node must see the messages addressed to it; on a mesh the
        # collective realising that for an arbitrary graph is an all_gather
        # followed by the local rows of W
        W = local["weights"]
        if self.links.time_varying:
            W = self._effective_weights(W, t, axis=axis)
        varphi_all = jax.lax.all_gather(varphi, axis, tiled=True)
        return W @ varphi_all


class RingDiffusion(_CombineTopology):
    """Diffusion on the cycle graph — the TPU-native topology where the
    communication graph IS the ICI ring along a mesh axis, so the combine
    is two ppermutes and a weighted sum (no all_gather, no all_reduce).

    With the default Eq. 47 ring weights each node keeps 1/3 and takes 1/3
    from each ring neighbour; any `w_self` splits the rest evenly:

    >>> import jax.numpy as jnp
    >>> varphi = jnp.asarray([[4.0], [8.0], [12.0]])
    >>> RingDiffusion(w_self=0.5).combine(varphi).tolist()
    [[7.0], [8.0], [9.0]]

    `graph=network.SparseGraph.ring(N)` switches the combine to the
    edge-list `segment_sum` path (same math; parity-pinned).  Because
    `SparseGraph.ring` orders link k as (k, k+1 mod N) — the coin order
    of `ring_link_keep` — the sparse path replays the IDENTICAL link
    failures for any `link_drop`/`link_seed` as the roll-based path.
    """

    def __init__(self, w_self: float = 1.0 / 3.0, *, link_drop: float = 0.0,
                 link_seed: int = 0,
                 link_mask_fn: Optional[Callable] = None,
                 graph=None):
        self.w_self = w_self
        self.links = _LinkSchedule(link_drop, link_seed, link_mask_fn)
        self.graph = graph
        if graph is not None:
            import numpy as np
            ring = network_lib.SparseGraph.ring(graph.n_nodes)
            for name in ("senders", "receivers", "edge_id"):
                if not np.array_equal(np.asarray(getattr(graph, name)),
                                      np.asarray(getattr(ring, name))):
                    raise ValueError(
                        "RingDiffusion(graph=) must be SparseGraph.ring(N) "
                        "(link k = (k, k+1 mod N) — the ring_link_keep "
                        "coin order)")

    def _sparse_weights(self, dtype):
        g = self.graph
        w_n = (1.0 - self.w_self) / 2.0
        return network_lib.SparseWeights(
            g, jnp.full((2 * g.n_undirected,), w_n, dtype),
            jnp.full((g.n_nodes,), self.w_self, dtype))

    def _gated(self, varphi, left, right, e_left, e_right):
        """Weighted combine over the surviving ring links only: dropped
        neighbours contribute nothing and the nominal weights renormalise
        over what is still connected (row-stochastic every iteration).
        A fully isolated node (both links down AND w_self == 0, so the
        renormaliser vanishes) keeps its own iterate."""
        w_n = (1.0 - self.w_self) / 2.0
        num = (self.w_self * varphi
               + w_n * (e_left[:, None] * left + e_right[:, None] * right))
        den = self.w_self + w_n * (e_left + e_right)
        isolated = den <= 0.0
        safe = jnp.where(isolated, jnp.ones_like(den), den)
        return jnp.where(isolated[:, None], varphi, num / safe[:, None])

    def combine(self, varphi, *, axis=None, local=None, t=None):
        if self.graph is not None:
            # edge-list path; a ring's (E_und,) link masks coincide with
            # the (N,) ring_link_keep masks (same ordering), so both link
            # forms drive it unchanged
            sw = self._sparse_weights(varphi.dtype)
            keep = (self.links.keep_edges(t, self.graph.n_undirected,
                                          varphi.dtype)
                    if self.links.time_varying else None)
            if axis is None:
                return _sparse_combine(sw, varphi, keep)
            varphi_all = jax.lax.all_gather(varphi, axis, tiled=True)
            return _local_rows(_sparse_combine(sw, varphi_all, keep),
                               varphi.shape[0], axis)
        if axis is not None:
            if not self.links.time_varying:
                return ring_combine_block(varphi, axis, self.w_self)
            n_local = varphi.shape[0]
            n = compat.axis_size(axis) * n_local
            e = self.links.keep_ring(t, n, varphi.dtype)  # e[i]: link i,i+1
            fwd, bwd = _ring_perms(compat.axis_size(axis))
            prev_tail = jax.lax.ppermute(varphi[-1:], axis, fwd)
            next_head = jax.lax.ppermute(varphi[:1], axis, bwd)
            left = jnp.concatenate([prev_tail, varphi[:-1]], 0)  # phi_{i-1}
            right = jnp.concatenate([varphi[1:], next_head], 0)  # phi_{i+1}
            e_left = _local_rows(jnp.roll(e, 1), n_local, axis)
            e_right = _local_rows(e, n_local, axis)
            return self._gated(varphi, left, right, e_left, e_right)
        if not self.links.time_varying:
            w_n = (1.0 - self.w_self) / 2.0
            return (self.w_self * varphi
                    + w_n * (jnp.roll(varphi, 1, axis=0)
                             + jnp.roll(varphi, -1, axis=0)))
        n = varphi.shape[0]
        e = self.links.keep_ring(t, n, varphi.dtype)     # e[i]: link (i,i+1)
        return self._gated(varphi,
                           jnp.roll(varphi, 1, axis=0),
                           jnp.roll(varphi, -1, axis=0),
                           jnp.roll(e, 1), e)


class PairwiseGossip(_CombineTopology):
    """Asynchronous randomized gossip (Boyd-Ghosh-Prabhakar-Shah style) on
    a `SparseGraph`: each iteration every undirected link activates
    independently with probability `p_activate` — deterministic in
    (`seed`, absolute t) via `network.sparse_link_keep`, so gossip runs
    compose with the split/resume contract exactly like `link_drop` — and
    each node averages with Eq. 47 weights over its ACTIVE neighbourhood:

        phi_i <- (varphi_i + sum_{active links (i,j)} varphi_j)
                 / (1 + |N_i^active(t)|)

    A node with no active link this iteration keeps its own iterate (the
    asynchronous-sensor semantics: nobody waits).  Two limits anchor it:
    `p_activate=1.0` is EXACTLY dense `Diffusion` with
    `nearest_neighbor_weights` on the same graph (parity-pinned), and
    p ~ 1/E activates one expected link per iteration — classic pairwise
    gossip, where the two endpoints exchange and average.

    >>> import jax.numpy as jnp
    >>> from repro.core import network
    >>> g = network.SparseGraph.ring(3)
    >>> all_on = PairwiseGossip(g, p_activate=1.0)
    >>> all_on.combine(jnp.asarray([[3.0], [6.0], [9.0]]), t=0).tolist()
    [[6.0], [6.0], [6.0]]
    """

    def __init__(self, graph, *, p_activate: float = 0.5, seed: int = 0):
        if not 0.0 < p_activate <= 1.0:
            raise ValueError(
                f"p_activate must be in (0, 1]: {p_activate}")
        if not isinstance(graph, network_lib.SparseGraph):
            raise ValueError("PairwiseGossip needs a network.SparseGraph "
                             "(use SparseGraph.from_dense for small "
                             "adjacency matrices)")
        self.graph = graph
        self.p_activate = float(p_activate)
        self.seed = int(seed)
        self._key = jax.random.PRNGKey(seed)

    def combine(self, varphi, *, axis=None, local=None, t=None):
        if t is None:
            raise ValueError(
                "PairwiseGossip draws its activation from the iteration "
                "index: call combine(..., t=<iteration>) (run_vb supplies "
                "it automatically)")
        g = self.graph
        # keep prob = 1 - drop: active with probability p_activate
        active = network_lib.sparse_link_keep(
            self._key, t, g.n_undirected, 1.0 - self.p_activate,
            varphi.dtype)
        varphi_all = (varphi if axis is None
                      else jax.lax.all_gather(varphi, axis, tiled=True))
        act_dir = active[g.edge_id]
        num = varphi_all + _segment_sum(
            act_dir[:, None] * varphi_all[g.senders], g)
        den = 1.0 + _segment_sum(act_dir, g)         # 1 + |N_i^active|
        out = num / den[:, None]
        return (out if axis is None
                else _local_rows(out, varphi.shape[0], axis))


class HierarchicalFusion(_CombineTopology):
    """Two-level sensor -> gateway -> region fusion: each gateway
    averages its sensors' iterates, each region averages its gateways'
    means, and every sensor blends its own iterate with its gateway and
    region means:

        gw_g  = mean_{i: gateway(i)=g} varphi_i
        rg_r  = mean_{g: region(g)=r} gw_g
        phi_i <- w_self varphi_i + w_gateway gw_{gateway(i)}
                 + (1 - w_self - w_gateway) rg_{region(gateway(i))}

    Row-stochastic by construction, O(N + G + R) memory via two
    `segment_sum`s — no N x N matrix, no peer-to-peer links.  Distinct
    regions are independent consensus islands (they never exchange); a
    single region with w_self = w_gateway = 0 degenerates to
    `FusionCenter` exactly (parity-pinned).  Build balanced assignments
    with `network.two_level_partition`.

    >>> import jax.numpy as jnp
    >>> from repro.core import network
    >>> gw, rg = network.two_level_partition(4, 2, 1)
    >>> h = HierarchicalFusion(gw, rg, w_self=0.0, w_gateway=0.0)
    >>> h.combine(jnp.asarray([[0.0], [2.0], [4.0], [6.0]])).tolist()
    [[3.0], [3.0], [3.0], [3.0]]
    """

    def __init__(self, gateway_of, region_of, *, w_self: float = 1.0 / 3.0,
                 w_gateway: float = 1.0 / 3.0):
        import numpy as np
        gw = np.asarray(gateway_of, np.int32)
        rg = np.asarray(region_of, np.int32)
        if gw.ndim != 1 or rg.ndim != 1:
            raise ValueError("gateway_of/region_of must be 1-D index maps")
        n_gateways = int(rg.shape[0])
        if gw.min(initial=0) < 0 or (gw.size and gw.max() >= n_gateways):
            raise ValueError("gateway_of must index into region_of")
        n_regions = int(rg.max()) + 1 if rg.size else 0
        if rg.min(initial=0) < 0:
            raise ValueError("region ids must be >= 0")
        gw_count = np.bincount(gw, minlength=n_gateways)
        rg_count = np.bincount(rg, minlength=n_regions)
        if (gw_count == 0).any() or (rg_count == 0).any():
            raise ValueError("every gateway needs >= 1 sensor and every "
                             "region >= 1 gateway")
        w_region = 1.0 - w_self - w_gateway
        if w_self < 0 or w_gateway < 0 or w_region < -1e-12:
            raise ValueError(
                f"weights must be a convex combination: w_self={w_self}, "
                f"w_gateway={w_gateway}, w_region={w_region}")
        self.gateway_of = jnp.asarray(gw)
        self.region_of = jnp.asarray(rg)
        self.n_gateways = n_gateways
        self.n_regions = n_regions
        self._gw_count = jnp.asarray(gw_count, jnp.int32)
        self._rg_count = jnp.asarray(rg_count, jnp.int32)
        self.w_self = float(w_self)
        self.w_gateway = float(w_gateway)
        self.w_region = float(max(w_region, 0.0))

    def combine(self, varphi, *, axis=None, local=None, t=None):
        dt = varphi.dtype
        full = (varphi if axis is None
                else jax.lax.all_gather(varphi, axis, tiled=True))
        gw_mean = jax.ops.segment_sum(
            full, self.gateway_of, num_segments=self.n_gateways) \
            / self._gw_count.astype(dt)[:, None]
        rg_mean = jax.ops.segment_sum(
            gw_mean, self.region_of, num_segments=self.n_regions) \
            / self._rg_count.astype(dt)[:, None]
        out = (self.w_self * full
               + self.w_gateway * gw_mean[self.gateway_of]
               + self.w_region * rg_mean[self.region_of[self.gateway_of]])
        return (out if axis is None
                else _local_rows(out, varphi.shape[0], axis))


class ConsensusDiagnostics(NamedTuple):
    """Per-iteration observability record of `ADMMConsensus` (each field
    gains a leading time axis T once stacked by the scan; see
    docs/admm-convergence.md for how to read it).

    primal_resid : ||r^t|| — RMS norm of the Eq. 39 disagreement
        sum_{j in N_i}(phi_i - phi_j), in natural-parameter space.  Per
        block (T, n_blocks) when `per_block=True`, else (T,).
    dual_resid : ||s^t|| = ||rho (phi^t - phi^{t-1})|| — Boyd's dual
        residual; same shape convention as `primal_resid`.
    rho : the penalty trajectory ((T,) scalar or (T, n_blocks)).
    kappa : the effective dual step-size ramp actually applied (0 while the
        dual warmup gate is closed; restarts after a ramp reset).
    clip_count : number of nodes whose Eq. 38b projection actually moved
        the primal iterate (eigen-clip / domain clamp activation).
    reset_count : number of nodes whose duals were reset/decayed this
        iteration (`dual_reset`); 0 when the feature is off.
    dual_on : 1.0 once the dual ascent is active (warmup gate open).
    link_frac : effective connectivity — the fraction of the nominal
        graph's (directed) adjacency entries alive this iteration;
        constant 1.0 on a static network, < 1 while links are down
        (`link_drop` / `link_mask_fn`).
    """

    primal_resid: jnp.ndarray
    dual_resid: jnp.ndarray
    rho: jnp.ndarray
    kappa: jnp.ndarray
    clip_count: jnp.ndarray
    reset_count: jnp.ndarray
    dual_on: jnp.ndarray
    link_frac: jnp.ndarray


class ADMMConsensus:
    """Consensus ADMM in natural-parameter space (Algorithm 2), plus the
    adaptive-penalty subsystem (all features off by default, which keeps
    Algorithm 2 bit-verbatim — golden-parity-tested).

    Per iteration and node i with neighbours N_i (|N_i| = d_i):

      (38a) phi_i <- [phi*_i - 2 lam_i + rho sum_{j in N_i}(phi_i + phi_j)]
                     / (1 + 2 rho d_i)
      (38b) phi_i <- Proj_Omega(phi_i)                  (if project=True)
      (39)  lam_i <- lam_i + kappa_t rho/2 sum_{j in N_i}(phi_i - phi_j)
      (40)  kappa_t = 1 - 1/(1 + xi t)^2

    Adaptive-penalty subsystem (the ROADMAP-named candidates, composable
    and individually switchable; diagnosis + recipes in
    docs/admm-convergence.md):

    * `adaptive_rho` — residual-balancing (Boyd Sec. 3.4.1) in
      natural-parameter space: every `adapt_every` iterations, grow rho by
      `tau_incr` when the primal residual dominates (||r|| > mu ||s||),
      shrink by `tau_decr` when the dual residual dominates, clipped to
      [rho_min, rho_max].  Enabling it also turns on the dual warmup and
      dual reset below (their "auto" default) — the blessed configuration
      that converges on the paper's GMM instances.
    * `dual_warmup` — residual-gated dual activation: the Eq. 39 ascent
      (and rho adaptation) stays off until the dual residual has fallen
      under `warmup_tol` x the primal residual for `warmup_window`
      consecutive iterations, i.e. until the penalty-method phase has
      equilibrated and the remaining error IS disagreement.  The Eq. 40
      ramp then counts from activation.  This is what stops the dual
      wind-up: ascending while phi*_i still moves with the E-step is what
      destabilised plain Algorithm 2.
    * `per_block` — per-block dual scaling: rho becomes one penalty per
      natural-parameter block of the model (`model.block_labels()`; for
      the GMM: alpha | nu | beta | beta*m | W^-1), each balanced
      independently, so the O(1e3) W^-1 coordinates cannot drown the O(1)
      blocks in the residual norms.
    * `dual_reset` — on Eq. 38b eigen-clip activation, multiply the
      affected node's duals by this factor (0.0 = full reset) and restart
      the kappa ramp: a projection that moved the iterate invalidates the
      geometry the duals were accumulated in.
    * `lam_max` — clip each dual coordinate to +-lam_max * |phi*_i| after
      the ascent (the PR-2 damping; superseded by the warmup gate but kept
      composable).

    Example — the convergent adaptive configuration, vs verbatim
    Algorithm 2:

    >>> import jax.numpy as jnp
    >>> adj = jnp.asarray([[0.0, 1.0], [1.0, 0.0]])      # two-node graph
    >>> plain = ADMMConsensus(adj)                       # Algorithm 2
    >>> adapt = ADMMConsensus(adj, adaptive_rho=True)    # the subsystem
    >>> (plain.emits_diagnostics, adapt.emits_diagnostics)
    (True, True)
    >>> adapt.dual_warmup, adapt.dual_reset             # "auto" resolution
    (True, 0.0)
    >>> plain.dual_warmup, plain.dual_reset
    (False, None)

    Algorithm 2 has no natural-gradient step, so `run_vb`'s `schedule` does
    not apply to this topology (run_vb rejects a non-default one).
    """

    uses_schedule = False
    emits_diagnostics = True

    def __init__(self, adj: jnp.ndarray, rho: float = 0.5, xi: float = 0.05,
                 project: bool = True, lam_max: float | None = None,
                 adaptive_rho: bool = False, mu: float = 10.0,
                 tau_incr: float = 2.0, tau_decr: float = 2.0,
                 adapt_every: int = 10, rho_min: float = 1e-3,
                 rho_max: float = 1e3, per_block: bool = False,
                 dual_warmup: bool | str = "auto", warmup_tol: float = 1e-3,
                 warmup_window: int = 10,
                 dual_reset: float | None | str = "auto",
                 clip_tol: float = 1e-9, link_drop: float = 0.0,
                 link_seed: int = 0,
                 link_mask_fn: Optional[Callable] = None):
        self.adj = adj                   # (N, N) dense or network.SparseGraph
        self.sparse = isinstance(adj, network_lib.SparseGraph)
        self.links = _LinkSchedule(link_drop, link_seed, link_mask_fn)
        self.rho = rho
        self.xi = xi
        self.project = project
        self.lam_max = lam_max
        self.adaptive_rho = adaptive_rho
        self.mu = mu
        self.tau_incr = tau_incr
        self.tau_decr = tau_decr
        self.adapt_every = adapt_every
        self.rho_min = rho_min
        self.rho_max = rho_max
        self.per_block = per_block
        self.dual_warmup = (adaptive_rho if dual_warmup == "auto"
                            else bool(dual_warmup))
        self.warmup_tol = warmup_tol
        self.warmup_window = warmup_window
        self.dual_reset = ((0.0 if adaptive_rho else None)
                           if dual_reset == "auto" else dual_reset)
        self.clip_tol = clip_tol

    @property
    def _plain(self) -> bool:
        """True = Algorithm 2 verbatim (the bit-exact golden path)."""
        return not (self.adaptive_rho or self.per_block or self.dual_warmup
                    or self.dual_reset is not None)

    def shard_inputs(self) -> dict:
        # sparse: edge arrays are not per-node rows — replicated closure
        # constants; neigh_sum gathers, reduces, and keeps local rows
        return {} if self.sparse else {"adj": self.adj}

    def init_carry(self, phi0: jnp.ndarray, model=None):
        lam0 = jnp.zeros_like(phi0)                   # duals lambda_i
        if self._plain:
            return lam0
        rho0 = self._rho0(model, phi0.dtype)
        dt = phi0.dtype
        # (duals, rho, consecutive-stable count, iters since dual
        #  activation, gate-open flag)
        return (lam0, rho0, jnp.asarray(0, jnp.int32), jnp.asarray(0.0, dt),
                jnp.asarray(not self.dual_warmup))

    def carry_specs(self, axis: str):
        from jax.sharding import PartitionSpec as P
        if self._plain:
            return P(axis)
        return (P(axis), P(), P(), P(), P())

    def _rho0(self, model, dt):
        if self.per_block:
            import numpy as np
            n_blocks = int(np.max(model.block_labels())) + 1
            return jnp.full((n_blocks,), self.rho, dt)
        return jnp.asarray(self.rho, dt)

    def init_diag(self, model, phi0: jnp.ndarray):
        """Zeroed `ConsensusDiagnostics` with the shapes `step` emits, so
        `VBState.diag` has a stable pytree structure from t=0 on."""
        dt = phi0.dtype
        rho0 = self._rho0(model, dt)
        resid_shape = rho0.shape if self.per_block else ()
        return ConsensusDiagnostics(
            primal_resid=jnp.zeros(resid_shape, dt),
            dual_resid=jnp.zeros(resid_shape, dt),
            rho=rho0,
            kappa=jnp.zeros((), dt),
            clip_count=jnp.zeros((), jnp.int32),
            reset_count=jnp.zeros((), jnp.int32),
            dual_on=jnp.zeros((), dt),
            link_frac=jnp.ones((), dt))

    # -- residual norms in natural-parameter space ------------------------
    def _block_norms(self, z, onehot, *, axis=None):
        """RMS norm of the (N, P) stack z — per block ((n_blocks,)) when
        `per_block`, else a scalar — with the node axis reduced globally
        under the mesh executor."""
        sq = jnp.sum(z * z, axis=0)                   # (P,)
        n = jnp.asarray(z.shape[0], z.dtype)
        if axis is not None:
            sq = jax.lax.psum(sq, axis)
            n = jax.lax.psum(n, axis)
        if onehot is not None:
            return jnp.sqrt((sq @ onehot) / (jnp.sum(onehot, 0) * n))
        return jnp.sqrt(jnp.sum(sq) / (n * z.shape[1]))

    def _graph_ops(self, phi, t, axis, local):
        """(deg, neigh_sum, link_frac) for this iteration's graph: the
        dense path masks + row-sums the (N, N) adjacency; the sparse path
        gates the directed edge list and reduces with `segment_sum` —
        per-iteration memory O(E + N), independent of N^2."""
        if self.sparse:
            g = self.adj
            if self.links.time_varying:
                # iteration-t links: one coin per undirected link, both
                # directions gated together (the dense keep contract)
                keep_und = self.links.keep_edges(t, g.n_undirected,
                                                 phi.dtype)
                keep_dir = keep_und[g.edge_id]
                link_frac = jnp.mean(keep_und).astype(phi.dtype)
                deg_full = _segment_sum(keep_dir, g)
            else:
                keep_dir = None
                link_frac = jnp.ones((), phi.dtype)
                deg_full = g.deg.astype(phi.dtype)
            n_local_nodes = phi.shape[0]
            deg = (deg_full if axis is None
                   else _local_rows(deg_full, n_local_nodes, axis))

            def neigh_sum(z):                        # sum_{j in N_i(t)} z_j
                z_all = (z if axis is None
                         else jax.lax.all_gather(z, axis, tiled=True))
                msg = z_all[g.senders]
                if keep_dir is not None:
                    msg = msg * keep_dir[:, None]
                s = _segment_sum(msg, g)
                return (s if axis is None
                        else _local_rows(s, n_local_nodes, axis))

            return deg, neigh_sum, link_frac

        adj_rows = self.adj if axis is None else local["adj"]
        if self.links.time_varying:
            # iteration-t adjacency: the consensus constraints (and hence
            # the 38a neighbour sums, degrees and the 39 disagreement) only
            # couple nodes whose link is up this iteration
            keep = self.links.keep_matrix(t, self.adj.shape[0], phi.dtype)
            if axis is not None:
                keep = _local_rows(keep, adj_rows.shape[0], axis)
            adj_rows = adj_rows * keep.astype(adj_rows.dtype)
            alive = jnp.sum(adj_rows)
            if axis is not None:
                alive = jax.lax.psum(alive, axis)
            link_frac = (alive / jnp.sum(self.adj)).astype(phi.dtype)
        else:
            link_frac = jnp.ones((), phi.dtype)
        deg = jnp.sum(adj_rows, axis=1)               # |N_i(t)|

        def neigh_sum(z):                             # sum_{j in N_i} z_j
            if axis is None:
                return adj_rows @ z
            return adj_rows @ jax.lax.all_gather(z, axis, tiled=True)

        return deg, neigh_sum, link_frac

    def step(self, model, phi, carry, phi_star, t, schedule: Schedule, *,
             axis=None, local=None, hyper=None):
        # `hyper` entries (serving fleet axis, see `hyper_names`) override
        # the static penalty/ramp constants; None — every solo path —
        # reproduces the static behaviour exactly.  Under adaptive_rho the
        # penalty lives in the carry (init_carry seeds it from self.rho),
        # so only xi is liftable there.
        rho = self.rho if not hyper or "rho" not in hyper else hyper["rho"]
        xi = self.xi if not hyper or "xi" not in hyper else hyper["xi"]
        deg, neigh_sum, link_frac = self._graph_ops(phi, t, axis, local)

        if self._plain:
            lam = carry
            # (38a) primal
            phi_hat = (phi_star - 2.0 * lam
                       + rho * (deg[:, None] * phi + neigh_sum(phi)))
            phi_hat = phi_hat / (1.0 + 2.0 * rho * deg)[:, None]
            if self.project:
                phi_new = jax.vmap(model.project_to_domain)(phi_hat)  # (38b)
            else:
                phi_new = phi_hat
            # (39) dual ascent with the kappa_t ramp (40)
            kappa = kappa_schedule(t.astype(phi.dtype) + 1.0, xi)
            resid = deg[:, None] * phi_new - neigh_sum(phi_new)
            lam_new = lam + kappa * rho / 2.0 * resid
            if self.lam_max is not None:
                bound = self.lam_max * jnp.abs(phi_star)
                lam_new = jnp.clip(lam_new, -bound, bound)
            clip_count = jnp.sum(
                jnp.max(jnp.abs(phi_new - phi_hat), axis=1) > self.clip_tol)
            if axis is not None:
                clip_count = jax.lax.psum(clip_count, axis)
            diag = ConsensusDiagnostics(
                primal_resid=self._block_norms(resid, None, axis=axis),
                dual_resid=self._block_norms(rho * (phi_new - phi),
                                             None, axis=axis),
                rho=jnp.asarray(rho, phi.dtype),
                kappa=kappa.astype(phi.dtype),
                clip_count=clip_count,
                reset_count=jnp.zeros((), jnp.int32),
                dual_on=jnp.ones((), phi.dtype),
                link_frac=link_frac)
            return phi_new, lam_new, diag
        return self._adaptive_step(model, phi, carry, phi_star, deg,
                                   neigh_sum, link_frac, xi, axis=axis)

    def _adaptive_step(self, model, phi, carry, phi_star, deg, neigh_sum,
                       link_frac, xi, *, axis=None):
        lam, rho_vec, stable, t_act, active = carry
        dt = phi.dtype
        if self.per_block:
            labels = model.block_labels()
            onehot = jax.nn.one_hot(labels, rho_vec.shape[0], dtype=dt)
            rho_coord = rho_vec[labels]               # (P,)
        else:
            onehot = None
            rho_coord = rho_vec                       # ()

        # (38a) primal, with the (possibly per-block) penalty
        phi_hat = (phi_star - 2.0 * lam
                   + rho_coord * (deg[:, None] * phi + neigh_sum(phi)))
        phi_hat = phi_hat / (1.0 + 2.0 * rho_coord * deg[:, None])
        if self.project:
            phi_new = jax.vmap(model.project_to_domain)(phi_hat)  # (38b)
        else:
            phi_new = phi_hat
        clip_active = (jnp.max(jnp.abs(phi_new - phi_hat), axis=1)
                       > self.clip_tol)               # (N,) eigen-clip fired
        any_clip = jnp.any(clip_active)
        if axis is not None:
            any_clip = jax.lax.psum(any_clip.astype(dt), axis) > 0.0

        resid = deg[:, None] * phi_new - neigh_sum(phi_new)
        r_norm = self._block_norms(resid, onehot, axis=axis)
        s_norm = self._block_norms(rho_coord * (phi_new - phi), onehot,
                                   axis=axis)
        r_tot = jnp.sqrt(jnp.sum(r_norm ** 2))
        s_tot = jnp.sqrt(jnp.sum(s_norm ** 2))

        # -- dual warmup gate: open once s << r for warmup_window iters --
        if self.dual_warmup:
            stable = jnp.where(s_tot < self.warmup_tol * r_tot,
                               stable + 1, 0)
            active = active | (stable >= self.warmup_window)
        t_act = jnp.where(active, t_act + 1.0, 0.0)
        if self.dual_reset is not None:
            t_act = jnp.where(any_clip, 0.0, t_act)   # ramp reset on clip
        kappa = jnp.where(t_act > 0.0,
                          kappa_schedule(t_act, xi), 0.0).astype(dt)

        # (39) dual ascent
        lam_new = lam + kappa * rho_coord / 2.0 * resid
        if self.lam_max is not None:
            bound = self.lam_max * jnp.abs(phi_star)
            lam_new = jnp.clip(lam_new, -bound, bound)
        if self.dual_reset is not None:
            lam_new = jnp.where(clip_active[:, None],
                                self.dual_reset * lam_new, lam_new)
            reset_count = jnp.sum(clip_active)
        else:
            reset_count = jnp.zeros((), jnp.int32)
        if axis is not None:
            reset_count = jax.lax.psum(reset_count, axis)
        clip_count = jnp.sum(clip_active)
        if axis is not None:
            clip_count = jax.lax.psum(clip_count, axis)

        # -- residual balancing (Boyd Sec. 3.4.1), gated on dual activity --
        if self.adaptive_rho:
            balanced = residual_balanced_rho(
                rho_vec, r_norm, s_norm, mu=self.mu, tau_incr=self.tau_incr,
                tau_decr=self.tau_decr, rho_min=self.rho_min,
                rho_max=self.rho_max)
            do = active & (jnp.mod(t_act, float(self.adapt_every)) == 0.0) \
                & (t_act > 0.0)
            rho_vec = jnp.where(do, balanced, rho_vec)

        diag = ConsensusDiagnostics(
            primal_resid=r_norm, dual_resid=s_norm, rho=rho_vec,
            kappa=kappa, clip_count=clip_count, reset_count=reset_count,
            dual_on=active.astype(dt), link_frac=link_frac)
        return phi_new, (lam_new, rho_vec, stable, t_act, active), diag


# ---------------------------------------------------------------------------
# Metrics (Eq. 46) + run result
# ---------------------------------------------------------------------------
def kl_to_reference(model, phi_nodes: jnp.ndarray,
                    ref_phi: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Per-node KL to the ground-truth posterior (Eq. 46).

    `ref_phi` may be (P,) or a (n_refs, P) stack — e.g. component
    permutations of a mixture reference — in which case the
    permutation-invariant min-KL is reported.
    """
    if ref_phi is None:
        return jnp.zeros(phi_nodes.shape[0], phi_nodes.dtype)
    ref = ref_phi[None] if ref_phi.ndim == 1 else ref_phi
    return jax.vmap(
        lambda p: jnp.min(jax.vmap(lambda r: model.kl(p, r))(ref)))(phi_nodes)


class VBRun(NamedTuple):
    phi: jnp.ndarray            # (N, P) final natural parameters per node
    kl_mean: jnp.ndarray        # (T,)   mean_i KL(q_i || ground truth)
    kl_std: jnp.ndarray         # (T,)
    kl_nodes: jnp.ndarray       # (T, N) per-node trajectory
    consensus_err: Any = None   # (T,)   mean_i ||phi_i - mean_j phi_j||^2
    consensus_diag: Any = None  # ConsensusDiagnostics (ADMM topologies)


class MeshExecutor(NamedTuple):
    """Run the node axis sharded over `axis` of `mesh` via shard_map."""

    mesh: Any
    axis: str = "data"


# ---------------------------------------------------------------------------
# Sessions + explicit state: the resumable half of the engine.  `run_vb`
# below is a thin (bit-exact) wrapper over vb_init -> vb_run.
# ---------------------------------------------------------------------------
class VBSession:
    """The STATIC half of a VB session: model x topology x executor x
    hyperparameters, plus the per-node data buffers.

    Everything here is configuration (or host-owned data arrays) that does
    not evolve with the iteration; the evolving arrays live in `VBState`,
    which carries a reference to its session as pytree *aux data* — so
    `jax.lax.scan` / `jax.jit` treat it as structure, and
    `checkpoint.ckpt.save` never serialises it (a checkpoint holds arrays
    only; `vb_init` rebuilds the session on restore).
    """

    __slots__ = ("model", "data", "topology", "schedule", "replication",
                 "ref_phi", "executor", "minibatch", "diagnostics",
                 "metric_nodes")

    def __init__(self, model, data, topology, schedule, replication,
                 ref_phi, executor, minibatch, diagnostics, metric_nodes):
        self.model = model
        self.data = data
        self.topology = topology
        self.schedule = schedule
        self.replication = replication
        self.ref_phi = ref_phi
        self.executor = executor
        self.minibatch = minibatch
        self.diagnostics = diagnostics
        self.metric_nodes = metric_nodes

    def with_data(self, data) -> "VBSession":
        """Same session over NEW per-node buffers — the mid-flight data
        arrival path (the streaming scenario the paper is written for).
        Every leaf must keep its shape and dtype: append new points into a
        node's padding slots via `model.append_node_data`, or replace a
        buffer outright."""
        old = jax.tree_util.tree_leaves(self.data)
        new = jax.tree_util.tree_leaves(data)
        if len(old) != len(new) or any(
                o.shape != n.shape or o.dtype != n.dtype
                for o, n in zip(old, new)):
            raise ValueError(
                "with_data: new buffers must match the session's data "
                "shapes/dtypes exactly (append into padding slots or "
                "replace same-shape buffers)")
        return VBSession(self.model, data, self.topology, self.schedule,
                         self.replication, self.ref_phi, self.executor,
                         self.minibatch, self.diagnostics, self.metric_nodes)


@jax.tree_util.register_pytree_with_keys_class
class VBState:
    """Checkpointable per-iteration state of a VB session (a pytree).

    phi : (N, P) current natural parameters per node.
    t : () int32 — ABSOLUTE iteration count.  Every per-iteration source
        of randomness (minibatch reshuffling epochs/windows, link-failure
        schedules, the eta_t/kappa_t ramps) is keyed on t, which is what
        makes a split run (`vb_run(s, a)` then `vb_run(., b)`) bit-exact
        with the unsplit `vb_run(s, a+b)`.
    carry : topology carry — ADMM duals lambda_i, and under the adaptive
        subsystem (rho, warmup-gate, ramp) state; None for combine
        topologies.
    stream : `stream.StreamState` (per-node keys + the current epoch's
        permutation) when the session streams minibatches, else None.
    diag : most recent `ConsensusDiagnostics` record (ADMM topologies;
        structure-stable from t=0 via `topology.init_diag`), else None.
    session : the static `VBSession` (pytree aux data — never serialised;
        `checkpoint.ckpt.save(path, state)` stores the arrays above and
        `ckpt.restore(path, vb_init(...))` re-attaches a fresh session).
    """

    __slots__ = ("phi", "t", "carry", "stream", "diag", "session")

    def __init__(self, phi, t, carry=None, stream=None, diag=None,
                 session=None):
        self.phi = phi
        self.t = t
        self.carry = carry
        self.stream = stream
        self.diag = diag
        self.session = session

    def tree_flatten_with_keys(self):
        from jax.tree_util import GetAttrKey
        children = tuple(
            (GetAttrKey(name), getattr(self, name))
            for name in ("phi", "t", "carry", "stream", "diag"))
        return children, self.session

    @classmethod
    def tree_unflatten(cls, session, children):
        return cls(*children, session=session)

    def replace(self, **kw) -> "VBState":
        args = {name: kw.pop(name, getattr(self, name))
                for name in ("phi", "t", "carry", "stream", "diag",
                             "session")}
        if kw:
            raise TypeError(f"unknown VBState fields: {sorted(kw)}")
        return VBState(**args)

    def with_data(self, data) -> "VBState":
        """State bound to updated per-node buffers (see
        `VBSession.with_data`)."""
        if self.session is None:
            raise ValueError("state has no session attached")
        return self.replace(session=self.session.with_data(data))

    def __repr__(self):
        n, p = self.phi.shape
        try:
            t = int(self.t)
        except (TypeError, jax.errors.TracerArrayConversionError):
            t = "<traced>"
        return (f"VBState(t={t}, nodes={n}, flat_dim={p}, "
                f"carry={'yes' if self.carry is not None else 'no'}, "
                f"stream={'yes' if self.stream is not None else 'no'})")


def vb_init(model, data, topology, *, schedule: Schedule = Schedule(),
            replication: float | None = None,
            init_phi: Optional[jnp.ndarray] = None,
            ref_phi: Optional[jnp.ndarray] = None,
            executor: Optional[MeshExecutor] = None,
            backend=None,
            minibatch: Optional[stream.MinibatchSpec] = None,
            diagnostics: bool = True,
            metric_nodes: Optional[int] = None) -> VBState:
    """Open a VB session: validate the configuration and return the t=0
    `VBState`.  Parameters are exactly `run_vb`'s (minus `n_iters`); see
    its docstring.  The returned state advances with `vb_step` /
    `vb_run`, checkpoints with `checkpoint.ckpt.save(path, state)`, and
    restores with `ckpt.restore(path, vb_init(<same config>))`.
    """
    if backend is not None:
        with_backend = getattr(model, "with_backend", None)
        if with_backend is None:
            raise ValueError(
                f"{type(model).__name__} does not support compute-backend "
                "selection (no with_backend method)")
        from repro.core import backends as backends_lib
        resolved = backends_lib.resolve(backend)
        supports = getattr(resolved, "supports", None)
        if supports is not None and not supports(model):
            # capability miss (e.g. the fused GMM kernel asked to run an
            # HMM): degrade to the model's own reference path — loudly,
            # but only once per (backend, model) pair per session: a
            # serving fleet re-opens sessions constantly and a warning
            # per vb_init is log spam.  The counter keeps every
            # occurrence observable.
            telemetry.inc("backend_fallback_total",
                          backend=resolved.name,
                          model=type(model).__name__)
            telemetry.warn_once(
                f"backend-fallback:{resolved.name}:{type(model).__name__}",
                f"backend {resolved.name!r} does not support "
                f"{type(model).__name__} (Backend.supports returned "
                "False); falling back to the reference backend",
                stacklevel=2)
            resolved = backends_lib.ReferenceBackend()
        model = with_backend(resolved)
    if not getattr(topology, "uses_schedule", True) \
            and schedule != Schedule():
        raise ValueError(
            f"{type(topology).__name__} has no natural-gradient step "
            "(Eq. 27a); it ignores `schedule` — pass the default")
    if executor is not None and metric_nodes is not None:
        raise ValueError("metric_nodes is only supported on the "
                         "single-array executor")
    n_nodes = jax.tree_util.tree_leaves(data)[0].shape[0]
    if replication is None:
        replication = float(n_nodes)
    if init_phi is None:
        init_phi = jnp.broadcast_to(model.init_phi(),
                                    (n_nodes, model.flat_dim))
    carry0 = topology.init_carry(init_phi, model)

    stream0 = None
    if minibatch is not None:
        if minibatch.batch_size < 1:
            raise ValueError(f"batch_size must be >= 1: {minibatch}")
        if getattr(model, "take_minibatch", None) is None:
            raise ValueError(
                f"{type(model).__name__} does not support streaming "
                "minibatches (no take_minibatch/data_mask methods)")
        if minibatch.control_variate not in (None, "svrg"):
            raise ValueError(
                f"unknown control_variate "
                f"{minibatch.control_variate!r}; expected None or 'svrg'")
        capacity = model.data_mask(data).shape[1]   # also validates shape
        if minibatch.batch_size > capacity:
            # covering the whole node = the bit-exact full-batch path
            minibatch = minibatch._replace(batch_size=int(capacity))
        stream0 = stream.init_state(n_nodes, minibatch.seed, int(capacity))
        if minibatch.control_variate == "svrg" \
                and minibatch.batch_size < capacity:
            # SVRG anchors: snapshot iterate + its full-batch optimum,
            # refreshed at epoch boundaries inside `_iteration`.  Inert
            # (structurally absent) at full batch, where the minibatch
            # path is already bit-exact with the full-batch run.
            stream0 = stream0._replace(
                anchor_phi=init_phi,
                anchor_full=model.local_optimum(data, init_phi,
                                                replication))

    diag0 = topology.init_diag(model, init_phi) if diagnostics else None
    session = VBSession(model, data, topology, schedule, replication,
                        ref_phi, executor, minibatch, diagnostics,
                        metric_nodes)
    return VBState(phi=init_phi, t=jnp.zeros((), jnp.int32), carry=carry0,
                   stream=stream0, diag=diag0, session=session)


def _iteration(model, data, base_mask, topology, schedule, replication,
               minibatch, phi, carry, st, t, *, axis=None, local=None,
               hyper=None):
    """ONE VB iteration — the kernel shared by `_scan_steps` (both
    executors), `vb_step`, and the serving fleet (`session_step_fn`).

    Streaming path: gather this iteration's per-node minibatch; the scaled
    mask (capacity/batch on selected points) keeps the sufficient
    statistics unbiased, so phi* becomes the stochastic estimate the
    Robbins-Monro eta_t (Eq. 22) assumes and the 27a step is a genuine
    stochastic natural-gradient step.
    """
    if minibatch is None:
        data_t, st_new = data, st
    else:
        st_new, idx, mb_mask = stream.advance(st, base_mask, t,
                                              minibatch.batch_size)
        data_t = model.take_minibatch(data, idx, mb_mask)
    if minibatch is not None and minibatch.control_variate == "svrg" \
            and st.anchor_phi is not None:
        # SVRG corrected estimator (data/stream.py module docstring):
        #   phi*_svrg = phi*_B(phi_t) - phi*_B(anchor) + phi*_full(anchor)
        # Exactly unbiased (statistics are linear in the scaled mask, so
        # E_B[phi*_B(anchor)] = phi*_full(anchor)); the anchor refreshes at
        # epoch boundaries with the CURRENT iterate, at which point the
        # two minibatch terms cancel exactly and the step is the full-batch
        # one.  Epoch parity with `advance` is automatic: both key on the
        # same absolute-t epoch arithmetic.
        def _refresh(_):
            return phi, model.local_optimum(data, phi, replication)

        def _keep(_):
            return st.anchor_phi, st.anchor_full

        anchor_phi, anchor_full = jax.lax.cond(
            st_new.epoch != st.epoch, _refresh, _keep, None)
        if taps.enabled() and axis is None:
            # 1 on the iterations that refreshed the SVRG anchor
            # (trace-time gated; see telemetry/taps.py)
            taps.tap("stream/svrg_anchor_refresh",
                     (st_new.epoch != st.epoch).astype(jnp.int32), t=t)
        st_new = st_new._replace(anchor_phi=anchor_phi,
                                 anchor_full=anchor_full)
        phi_star = (model.local_optimum(data_t, phi, replication)
                    - model.local_optimum(data_t, anchor_phi, replication)
                    + anchor_full)
    else:
        phi_star = model.local_optimum(data_t, phi, replication)
    phi_new, carry_new, diag = topology.step(model, phi, carry, phi_star, t,
                                             schedule, axis=axis,
                                             local=local, hyper=hyper)
    return phi_new, carry_new, st_new, diag


def session_step_fn(session: VBSession, *, axis=None, local=None):
    """One-iteration kernel over raw state pytrees, with the data buffers
    as an ARGUMENT: fn(data, phi, carry, stream, t, hyper=None) ->
    (phi', carry', stream', diag).  This is the function the serving
    layer (serving/vb_service.py) vmaps over a leading fleet axis —
    per-session data must be a mapped operand, which is why it is not
    closed over.  `hyper` is the per-session lifted-hyper dict (see
    `hyper_names`): the serving fleet maps it alongside the data so
    sessions differing only in schedule/penalty constants share one
    compiled step; None keeps the session's static values."""
    model, topology = session.model, session.topology
    schedule, replication = session.schedule, session.replication
    minibatch = session.minibatch

    def fn(data, phi, carry, st, t, hyper=None):
        base_mask = model.data_mask(data) if minibatch is not None else None
        return _iteration(model, data, base_mask, topology, schedule,
                          replication, minibatch, phi, carry, st, t,
                          axis=axis, local=local, hyper=hyper)

    return fn


def hyper_names(topology, schedule: Schedule) -> tuple:
    """Names of the hyperparameters a (topology, schedule) pair reads per
    ITERATION as plain scalars — the ones the serving layer can lift onto
    the fleet axis so sessions differing only in them share one compiled
    fleet (docs/bucketed-admission.md).

    * Robbins-Monro schedules (`eta_fixed=None` on a combine topology)
      read `tau` / `d0` in `Schedule.eta`.  A fixed eta is NOT lifted:
      `eta_fixed == 1.0` selects the one-shot jump as a static branch in
      `_CombineTopology.step`, so it must stay in the group key.
    * `ADMMConsensus` reads the penalty `rho` and ramp rate `xi` — except
      under `adaptive_rho`, where rho lives in the per-session carry
      (seeded by `init_carry`) and only `xi` is read statically.
    """
    names = []
    if getattr(topology, "uses_schedule", True) \
            and schedule.eta_fixed is None:
        names += ["tau", "d0"]
    if isinstance(topology, ADMMConsensus):
        names += ["xi"] if topology.adaptive_rho else ["rho", "xi"]
    return tuple(names)


def lifted_attr_names(topology) -> tuple:
    """Topology attributes excluded from the fleet-group signature
    because per-session values reach the step another way — via the
    lifted-hyper dict (`hyper_names`) or the carry (adaptive-rho ADMM
    seeds rho from `init_carry`).  Strictly a superset of the
    topology-owned `hyper_names` entries."""
    return ("rho", "xi") if isinstance(topology, ADMMConsensus) else ()


def session_hyper(topology, schedule: Schedule, dtype) -> dict:
    """The per-session lifted-hyper dict consumed by `session_step_fn`'s
    `hyper` argument: each `hyper_names` entry as a scalar array (the
    serving fleet stacks these along the leading fleet axis)."""
    out = {}
    for n in hyper_names(topology, schedule):
        src = schedule if n in ("tau", "d0") else topology
        out[n] = jnp.asarray(getattr(src, n), dtype)
    return out


def _scan_steps(model, data, topology, schedule, replication, ref_phi,
                n_iters, phi0, carry0, *, t0=None, stream0=None, axis=None,
                local=None, diagnostics=True, metric_nodes=None,
                minibatch=None):
    """`n_iters` iterations as one lax.scan, shared verbatim by both
    executors.  `t0` resumes from an absolute iteration count; `stream0`
    is the carried minibatch-sampler state."""
    base_mask = model.data_mask(data) if minibatch is not None else None

    def step(carry, t):
        phi, aux, st = carry
        phi_new, aux_new, st_new, diag = _iteration(
            model, data, base_mask, topology, schedule, replication,
            minibatch, phi, aux, st, t, axis=axis, local=local)
        phi_m = phi_new if metric_nodes is None else phi_new[:metric_nodes]
        kl = kl_to_reference(model, phi_m, ref_phi)
        if diagnostics:
            mean = jnp.mean(phi_new, axis=0)
            if axis is not None:
                mean = jax.lax.pmean(mean, axis)
            msd = jnp.mean((phi_new - mean) ** 2)
            if axis is not None:
                msd = jax.lax.pmean(msd, axis)
        else:
            msd = jnp.zeros((), phi_new.dtype)
            diag = None
        if taps.enabled() and axis is None:
            # opt-in device taps (telemetry/taps.py): stream the
            # per-iteration series out mid-flight via io_callback.  Trace
            # -time gated — with taps off this block leaves the jaxpr
            # byte-identical (pinned in tests/test_telemetry.py).  Not
            # supported under the mesh executor (axis is not None).
            taps.tap("vb/kl_mean", jnp.mean(kl), t=t)
            taps.tap("vb/consensus_msd", msd, t=t)
            if diag is not None and hasattr(diag, "rho"):
                taps.tap("vb/admm_rho", jnp.mean(diag.rho), t=t)
                taps.tap("vb/admm_primal_resid",
                         jnp.mean(diag.primal_resid), t=t)
                taps.tap("vb/admm_dual_resid",
                         jnp.mean(diag.dual_resid), t=t)
        return (phi_new, aux_new, st_new), (kl, msd, diag)

    ts = jnp.arange(n_iters)
    if t0 is not None:
        ts = ts + t0
    (phi, aux, st), (kls, msds, diags) = jax.lax.scan(
        step, (phi0, carry0, stream0), ts)
    return phi, aux, st, kls, msds, diags


def vb_run(state: VBState, n_iters: int) -> tuple[VBState, VBRun]:
    """Advance a session `n_iters` iterations; returns (state', VBRun).

    Scans the `vb_step` kernel from the state's absolute iteration count,
    so runs compose bit-exactly: `vb_run(s, a + b)` equals
    `vb_run(vb_run(s, a)[0], b)` on every topology, executor, backend and
    streaming configuration (tests/test_session.py) — iteration-indexed
    randomness (minibatch epochs, link-drop schedules) and the eta_t /
    kappa_t ramps are all functions of the absolute t carried in the
    state.  The `VBRun` covers the `n_iters` iterations of THIS call."""
    ses = state.session
    if ses is None:
        raise ValueError("VBState has no session attached — create states "
                         "with vb_init(...)")
    with telemetry.span("engine/vb_run", n_iters=int(n_iters)):
        return _vb_run_body(state, ses, n_iters)


def _vb_run_body(state, ses, n_iters):
    if ses.executor is None:
        phi, aux, st, kls, msds, diags = _scan_steps(
            ses.model, ses.data, ses.topology, ses.schedule,
            ses.replication, ses.ref_phi, n_iters, state.phi, state.carry,
            t0=state.t, stream0=state.stream, diagnostics=ses.diagnostics,
            metric_nodes=ses.metric_nodes, minibatch=ses.minibatch)
    else:
        phi, aux, st, kls, msds, diags = _run_vb_sharded(
            ses, n_iters, state.phi, state.carry, state.stream, state.t)
    if telemetry.enabled() and not isinstance(kls, jax.core.Tracer):
        # the diag-slot tap path (telemetry/taps.py): file the scan's own
        # per-iteration outputs as host series.  Reads arrays the run
        # materializes anyway, so this never changes a jaxpr; skipped when
        # vb_run is itself being traced (kls is a Tracer).
        import numpy as np
        ts = np.arange(int(state.t), int(state.t) + int(n_iters))
        taps.record_series("vb_run/kl_mean", jnp.mean(kls, 1), ts=ts)
        if ses.diagnostics:
            taps.record_series("vb_run/consensus_msd", msds, ts=ts)
        if diags is not None and hasattr(diags, "rho"):
            flat = lambda a: (a if a.ndim == 1
                              else a.reshape(a.shape[0], -1).mean(1))
            taps.record_series("vb_run/admm_rho", flat(diags.rho), ts=ts)
            taps.record_series("vb_run/admm_primal_resid",
                               flat(diags.primal_resid), ts=ts)
            taps.record_series("vb_run/admm_dual_resid",
                               flat(diags.dual_resid), ts=ts)
    diag_last = (jax.tree_util.tree_map(lambda a: a[-1], diags)
                 if diags is not None else None)
    state_new = VBState(
        phi=phi, t=state.t + jnp.asarray(n_iters, state.t.dtype),
        carry=aux, stream=st, diag=diag_last, session=ses)
    run = VBRun(phi=phi, kl_mean=jnp.mean(kls, 1), kl_std=jnp.std(kls, 1),
                kl_nodes=kls, consensus_err=msds if ses.diagnostics else None,
                consensus_diag=diags)
    return state_new, run


def vb_step(state: VBState) -> VBState:
    """Advance a session by ONE iteration (= `vb_run(state, 1)[0]`)."""
    state, _ = vb_run(state, 1)
    return state


def run_vb(model, data, topology, *, n_iters: int,
           schedule: Schedule = Schedule(), replication: float | None = None,
           init_phi: Optional[jnp.ndarray] = None,
           ref_phi: Optional[jnp.ndarray] = None,
           executor: Optional[MeshExecutor] = None,
           backend=None,
           minibatch: Optional[stream.MinibatchSpec] = None,
           diagnostics: bool = True,
           metric_nodes: Optional[int] = None) -> VBRun:
    """Run distributed VB: `model` on `data` over `topology`.

    Parameters
    ----------
    model : ConjugateExpModel (see core/model.py)
    data : per-node data pytree; every leaf has leading node axis N
    topology : FusionCenter | Isolated | Diffusion | RingDiffusion |
        ADMMConsensus — how {phi*_i} becomes the next iterate
    n_iters : number of VB iterations (the scan length)
    schedule : eta_t of the natural-gradient step (27a); `ONE_SHOT` for the
        jump-to-optimum estimators
    replication : likelihood replication factor (paper App. A); defaults to
        the network size N, use 1.0 for non-cooperative runs
    init_phi : (N, P) initial naturals; defaults to the prior at every node
    ref_phi : (P,) or (n_refs, P) reference for the Eq. 46 metric
    executor : None = single-array (node axis is a plain array axis, whole
        run jits); MeshExecutor(mesh, axis) = shard_map over a mesh axis
    backend : per-run compute-backend override ("reference" | "fused" | a
        `core.backends.Backend` instance) for models that support backend
        selection via `with_backend` (GMMModel).  None keeps the model's
        own backend.  Orthogonal to `executor`: the backend picks the
        kernel, the executor picks how the node axis is laid out.
    minibatch : `stream.MinibatchSpec(batch_size, seed)` switches the run
        to streaming stochastic VB — each iteration every node estimates
        phi*_i from a `batch_size` window of its per-epoch reshuffled
        local data (selected points reweighted by capacity/batch_size so
        the statistics stay unbiased, composing with `replication`).
        Deterministic per (seed, node, iteration):
        both executors and both compute backends see identical batches.
        `batch_size >= n_per_node` reproduces the full-batch run
        bit-for-bit.  `control_variate="svrg"` re-centres every
        minibatch estimate on a full-batch anchor refreshed each epoch
        (still exactly unbiased; anchors ride the resumable stream
        state, and the full-batch degeneracy stays bit-exact).
    diagnostics : also record per-iteration consensus error
    metric_nodes : evaluate the Eq. 46 metric on only the first
        `metric_nodes` rows (kl_nodes becomes (T, metric_nodes)) — used by
        cVB, whose iterates are identical across nodes.  Single-array
        executor only.

    Returns a `VBRun` regardless of executor; the two paths are numerically
    equivalent (asserted in tests/test_engine.py).  Topologies that emit
    per-iteration diagnostics (`ADMMConsensus`) populate
    `VBRun.consensus_diag` with a `ConsensusDiagnostics` record.

    Example (Bayesian linear regression, whose local optima are a constant
    (N, P) stack, over a two-node fusion centre):

    >>> import jax.numpy as jnp
    >>> from repro.core import linreg
    >>> from repro.core.model import LinRegModel
    >>> mdl = LinRegModel(linreg.prior(2))
    >>> phi_star = jnp.stack([mdl.init_phi() + 1.0, mdl.init_phi() - 1.0])
    >>> run = run_vb(mdl, phi_star, FusionCenter(), n_iters=3,
    ...              schedule=ONE_SHOT)
    >>> run.phi.shape, run.kl_nodes.shape
    ((2, 8), (3, 2))
    >>> bool(jnp.all(run.phi[0] == run.phi[1]))          # consensus: exact
    True

    `run_vb` is a thin wrapper over the resumable session API — it is
    exactly `vb_run(vb_init(<same arguments>), n_iters)[1]`, and is
    bit-exact with the pre-session engine on every estimator, executor,
    backend and streaming configuration (the golden-parity and
    executor-equivalence suites are the oracle).  Use `vb_init` /
    `vb_step` / `vb_run` directly to pause, checkpoint, resume, or feed
    newly-arrived data mid-run; use `serving.vb_service.VBService` to
    serve fleets of sessions.
    """
    state = vb_init(model, data, topology, schedule=schedule,
                    replication=replication, init_phi=init_phi,
                    ref_phi=ref_phi, executor=executor, backend=backend,
                    minibatch=minibatch, diagnostics=diagnostics,
                    metric_nodes=metric_nodes)
    _, run = vb_run(state, n_iters)
    return run


def _run_vb_sharded(session: VBSession, n_iters, phi0, carry0, stream0, t0):
    """shard_map executor: node axis sharded over `executor.axis`.

    Returns the same (phi, carry, stream, kls, msds, diags) tuple as
    `_scan_steps` — the final carry/stream come back through the
    shard_map outputs with the state specs from
    `dist/sharding.vb_node_specs`, so `vb_run` can rebuild a complete
    `VBState` under this executor too.
    """
    mesh, axis = session.executor.mesh, session.executor.axis
    from jax.sharding import PartitionSpec
    from repro.dist import sharding

    model, data, topology = session.model, session.data, session.topology
    local_inputs = topology.shard_inputs()          # dict of (N, ...) arrays
    local_keys = tuple(sorted(local_inputs))
    has_carry = carry0 is not None
    has_stream = stream0 is not None
    diagnostics = session.diagnostics
    # diagnostics pytrees are reduced with psum/pmean inside the step, so
    # every shard returns the identical (replicated) value
    has_diag = diagnostics and getattr(topology, "emits_diagnostics", False)

    # stream state: keys/permutation (and the SVRG anchors, when carried)
    # are per-node data, the epoch counter is replicated (epoch boundaries
    # are global) — stream.state_specs mirrors the state's None structure
    stream_specs = (stream.state_specs(stream0, axis)
                    if has_stream else None)
    in_specs, out_specs = sharding.vb_node_specs(
        data, axis=axis, has_carry=has_carry, n_local=len(local_keys),
        carry_specs=topology.carry_specs(axis) if has_carry else None,
        stream_specs=stream_specs)
    if has_diag:
        out_specs = out_specs + (PartitionSpec(),)

    def run(data_l, phi_l, carry_l, stream_l, *local_vals):
        local = dict(zip(local_keys, local_vals))
        phi, aux, st, kls, msds, diags = _scan_steps(
            model, data_l, topology, session.schedule, session.replication,
            session.ref_phi, n_iters, phi_l,
            carry_l if has_carry else None, t0=t0,
            stream0=stream_l if has_stream else None,
            axis=axis, local=local, diagnostics=diagnostics,
            minibatch=session.minibatch)
        aux = aux if has_carry else jnp.zeros((), phi.dtype)
        st = st if has_stream else jnp.zeros((), phi.dtype)
        if has_diag:
            return phi, aux, st, kls, msds, diags
        return phi, aux, st, kls, msds

    fn = compat.shard_map(run, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
    out = fn(data, phi0,
             carry0 if has_carry else jnp.zeros((), phi0.dtype),
             stream0 if has_stream else jnp.zeros((), phi0.dtype),
             *(local_inputs[k] for k in local_keys))
    phi, aux, st, kls, msds = out[:5]
    diags = out[5] if has_diag else None
    return (phi, aux if has_carry else None, st if has_stream else None,
            kls, msds, diags)
