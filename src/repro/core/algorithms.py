"""The paper's five VB estimators over a sensor network — GMM instance.

All five are ONE engine call: the Bayesian-GMM `ConjugateExpModel`
(core/model.py) composed with a topology (core/engine.py), which owns the
single implementation of Eqs. 20 / 27a-b / 38a-b / 39 / 40:

* cVB        — FusionCenter, one-shot      phi <- mean_i phi*_i   (Eq. 20)
* noncoop-VB — Isolated, one-shot, unreplicated data
* nsg-dVB    — Diffusion, one-shot (neighbour averaging of local optima)
* dSVB       — Algorithm 1: Schedule(tau, d0) (27a) + Diffusion (27b)
* dVB-ADMM   — Algorithm 2: ADMMConsensus (38a [+38b], 39, 40)

These wrappers keep the original `run_*` signatures (and the `ALGORITHMS`
registry) so tests, benchmarks and examples are untouched; new code should
call `engine.run_vb` directly.  See core/distributed.py for the shard_map /
ppermute mesh-parallel execution of the same step functions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import engine, expfam
from repro.core import model as model_lib
from repro.core.engine import (  # noqa: F401  (re-exported legacy API)
    VBRun, eta_schedule, kappa_schedule,
)
from repro.core.expfam import GMMPosterior


def _init_phi(prior: GMMPosterior, n_nodes: int) -> jnp.ndarray:
    phi0 = expfam.pack_natural(prior)
    return jnp.broadcast_to(phi0, (n_nodes,) + phi0.shape)


def _perturbed_init(prior: GMMPosterior, x: jnp.ndarray, key,
                    spread: float = 1.0) -> GMMPosterior:
    """Random-restart initialisation: prior with means scattered over the
    data range (the paper uses random initialisations for the MC runs)."""
    K, D = prior.K, prior.D
    lo = jnp.min(x.reshape(-1, D), axis=0)
    hi = jnp.max(x.reshape(-1, D), axis=0)
    m = lo + (hi - lo) * jax.random.uniform(key, (K, D), prior.m.dtype)
    return prior._replace(m=prior.m + spread * (m - prior.m))


def _gmm_run(x, mask, prior, topology, schedule, *, n_iters, K, D,
             replication=None, ref_phi=None, init_q=None, metric_nodes=None,
             backend=None):
    mdl = model_lib.GMMModel(prior, K, D, backend=backend)
    phi0 = _init_phi(prior if init_q is None else init_q, x.shape[0])
    return engine.run_vb(mdl, (x, mask), topology, n_iters=n_iters,
                         schedule=schedule, replication=replication,
                         init_phi=phi0, ref_phi=ref_phi,
                         metric_nodes=metric_nodes)


# ---------------------------------------------------------------------------
# cVB — centralised reference (fusion centre computes Eq. 20 exactly)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit,
                   static_argnames=("n_iters", "K", "D", "backend"))
def run_cvb(x, mask, prior: GMMPosterior, *, n_iters: int, K: int, D: int,
            ref_phi=None, init_q: GMMPosterior | None = None,
            backend=None) -> VBRun:
    # all nodes share the fusion-centre iterate: evaluate the Eq. 46 metric
    # on one representative node and report zero spread (kl_nodes is (T, 1))
    run = _gmm_run(x, mask, prior, engine.FusionCenter(), engine.ONE_SHOT,
                   n_iters=n_iters, K=K, D=D, ref_phi=ref_phi,
                   init_q=init_q, metric_nodes=1, backend=backend)
    return VBRun(phi=run.phi, kl_mean=run.kl_nodes[:, 0],
                 kl_std=jnp.zeros(n_iters, run.phi.dtype),
                 kl_nodes=run.kl_nodes,
                 consensus_err=run.consensus_err)


# ---------------------------------------------------------------------------
# noncoop-VB — isolated nodes, unreplicated local data
# ---------------------------------------------------------------------------
@functools.partial(jax.jit,
                   static_argnames=("n_iters", "K", "D", "backend"))
def run_noncoop(x, mask, prior: GMMPosterior, *, n_iters: int, K: int, D: int,
                ref_phi=None, init_q: GMMPosterior | None = None,
                backend=None) -> VBRun:
    return _gmm_run(x, mask, prior, engine.Isolated(), engine.ONE_SHOT,
                    n_iters=n_iters, K=K, D=D, replication=1.0,
                    ref_phi=ref_phi, init_q=init_q, backend=backend)


# ---------------------------------------------------------------------------
# nsg-dVB — one-step averaging of local optima (the Sec. III-A strawman)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit,
                   static_argnames=("n_iters", "K", "D", "backend"))
def run_nsg_dvb(x, mask, weights, prior: GMMPosterior, *, n_iters: int,
                K: int, D: int, ref_phi=None,
                init_q: GMMPosterior | None = None, backend=None) -> VBRun:
    return _gmm_run(x, mask, prior, engine.Diffusion(weights),
                    engine.ONE_SHOT, n_iters=n_iters, K=K, D=D,
                    ref_phi=ref_phi, init_q=init_q, backend=backend)


# ---------------------------------------------------------------------------
# dSVB — Algorithm 1 (stochastic natural gradient + diffusion)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit,
                   static_argnames=("n_iters", "K", "D", "backend"))
def run_dsvb(x, mask, weights, prior: GMMPosterior, *, n_iters: int,
             K: int, D: int, tau: float = 0.2, d0: float = 1.0,
             ref_phi=None, init_q: GMMPosterior | None = None,
             backend=None) -> VBRun:
    return _gmm_run(x, mask, prior, engine.Diffusion(weights),
                    engine.Schedule(tau=tau, d0=d0), n_iters=n_iters,
                    K=K, D=D, ref_phi=ref_phi, init_q=init_q,
                    backend=backend)


# ---------------------------------------------------------------------------
# dVB-ADMM — Algorithm 2 (consensus ADMM in natural-parameter space)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit,
                   static_argnames=("n_iters", "K", "D", "project",
                                    "backend", "adaptive_rho", "per_block",
                                    "dual_warmup", "dual_reset"))
def run_dvb_admm(x, mask, adj, prior: GMMPosterior, *, n_iters: int,
                 K: int, D: int, rho: float = 0.5, xi: float = 0.05,
                 project: bool = True, lam_max: float | None = None,
                 adaptive_rho: bool = False, per_block: bool = False,
                 dual_warmup: bool | str = "auto",
                 dual_reset: float | None | str = "auto",
                 ref_phi=None, init_q: GMMPosterior | None = None,
                 backend=None) -> VBRun:
    """Algorithm 2; defaults are the paper verbatim.  `adaptive_rho=True`
    enables the convergent adaptive-penalty configuration (residual
    balancing + dual warmup + dual reset — engine.ADMMConsensus); the
    per-iteration `ConsensusDiagnostics` comes back on
    `VBRun.consensus_diag`.  Finer-grained knobs: call `engine.run_vb`
    with an `engine.ADMMConsensus` directly."""
    topology = engine.ADMMConsensus(adj, rho=rho, xi=xi, project=project,
                                    lam_max=lam_max,
                                    adaptive_rho=adaptive_rho,
                                    per_block=per_block,
                                    dual_warmup=dual_warmup,
                                    dual_reset=dual_reset)
    return _gmm_run(x, mask, prior, topology, engine.Schedule(),
                    n_iters=n_iters, K=K, D=D, ref_phi=ref_phi,
                    init_q=init_q, backend=backend)


ALGORITHMS = {
    "cvb": run_cvb,
    "noncoop": run_noncoop,
    "nsg_dvb": run_nsg_dvb,
    "dsvb": run_dsvb,
    "dvb_admm": run_dvb_admm,
}
