"""The paper's five VB estimators over a sensor network.

All algorithms share the same per-iteration kernel: every node runs a VBE
step + local VBM optimum to get phi*_{theta,i} (gmm.local_vbm_optimum_nodes),
then differ in how the stack {phi*_i} is turned into the next iterate:

* cVB        — fusion centre: phi <- mean_i phi*_i                    (Eq. 20)
* noncoop-VB — no communication: phi_i <- phi*_i (unreplicated data)
* nsg-dVB    — one-step neighbour averaging of the local optima
* dSVB       — Algorithm 1: natural-gradient step (27a) + diffusion (27b)
* dVB-ADMM   — Algorithm 2: primal (38a) [+ projection (38b)] + dual (39)

Everything is a jax.lax.scan over iterations so whole runs jit; the node axis
is a plain array axis here (see core/distributed.py for the shard_map /
ppermute mesh-parallel runner).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import expfam, gmm
from repro.core.expfam import GMMPosterior


# ---------------------------------------------------------------------------
# Step-size schedules (Eqs. 29 and 40)
# ---------------------------------------------------------------------------
def eta_schedule(t: jnp.ndarray, tau: float, d0: float = 1.0) -> jnp.ndarray:
    """eta_t = 1 / (d0 + tau * t); satisfies Robbins-Monro (Eq. 22)."""
    return 1.0 / (d0 + tau * t)


def kappa_schedule(t: jnp.ndarray, xi: float = 0.05) -> jnp.ndarray:
    """kappa_t = 1 - 1/(1 + xi t)^2 ramps the ADMM dual step (Eq. 40)."""
    return 1.0 - 1.0 / (1.0 + xi * t) ** 2


# ---------------------------------------------------------------------------
# Run result
# ---------------------------------------------------------------------------
class VBRun(NamedTuple):
    phi: jnp.ndarray          # (N, P) final natural parameters per node
    kl_mean: jnp.ndarray      # (T,)   mean_i KL(q_i || ground truth) per iter
    kl_std: jnp.ndarray       # (T,)
    kl_nodes: jnp.ndarray     # (T, N) per-node trajectory


def _metrics(phi_nodes, ref_phi, K, D):
    """Per-node KL to the ground-truth posterior (Eq. 46).

    `ref_phi` may be (P,) for a fixed component labelling or (n_perms, P) —
    a stack of component permutations of the reference — in which case the
    permutation-invariant min-KL is reported (mixture components have no
    canonical order; the paper's metric implicitly assumes aligned labels).
    """
    if ref_phi is None:
        z = jnp.zeros(phi_nodes.shape[0], phi_nodes.dtype)
        return z
    if ref_phi.ndim == 1:
        ref_phi = ref_phi[None]
    kl = jax.vmap(lambda p: jnp.min(jax.vmap(
        lambda r: expfam.gmm_kl_flat(p, r, K, D))(ref_phi)))(phi_nodes)
    return kl


def _init_phi(prior: GMMPosterior, n_nodes: int) -> jnp.ndarray:
    phi0 = expfam.pack_natural(prior)
    return jnp.broadcast_to(phi0, (n_nodes,) + phi0.shape)


def _perturbed_init(prior: GMMPosterior, x: jnp.ndarray, key,
                    spread: float = 1.0) -> GMMPosterior:
    """Random-restart initialisation: prior with means scattered over the
    data range (the paper uses random initialisations for the MC runs)."""
    K, D = prior.K, prior.D
    lo = jnp.min(x.reshape(-1, D), axis=0)
    hi = jnp.max(x.reshape(-1, D), axis=0)
    m = lo + (hi - lo) * jax.random.uniform(key, (K, D), prior.m.dtype)
    return prior._replace(m=prior.m + spread * (m - prior.m))


# ---------------------------------------------------------------------------
# cVB — centralised reference (fusion centre computes Eq. 20 exactly)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n_iters", "K", "D"))
def run_cvb(x, mask, prior: GMMPosterior, *, n_iters: int, K: int, D: int,
            ref_phi=None, init_q: GMMPosterior | None = None) -> VBRun:
    n_nodes = x.shape[0]
    q0 = prior if init_q is None else init_q
    phi = expfam.pack_natural(q0)

    def step(phi, t):
        phis = jnp.broadcast_to(phi, (n_nodes,) + phi.shape)
        phi_star = gmm.local_vbm_optimum_nodes(
            x, phis, prior, float(n_nodes), K, D, mask)
        phi_new = jnp.mean(phi_star, axis=0)                      # Eq. 20
        kl = _metrics(phi_new[None], ref_phi, K, D)
        return phi_new, jnp.concatenate([kl, kl])  # mean == node value

    phi, kls = jax.lax.scan(step, phi, jnp.arange(n_iters))
    kl_nodes = kls[:, :1]
    return VBRun(phi=jnp.broadcast_to(phi, (n_nodes,) + phi.shape),
                 kl_mean=kl_nodes[:, 0], kl_std=jnp.zeros(n_iters, phi.dtype),
                 kl_nodes=kl_nodes)


# ---------------------------------------------------------------------------
# noncoop-VB — isolated nodes, unreplicated local data
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n_iters", "K", "D"))
def run_noncoop(x, mask, prior: GMMPosterior, *, n_iters: int, K: int, D: int,
                ref_phi=None, init_q: GMMPosterior | None = None) -> VBRun:
    n_nodes = x.shape[0]
    phi = _init_phi(prior if init_q is None else init_q, n_nodes)

    def step(phi, t):
        phi_star = gmm.local_vbm_optimum_nodes(
            x, phi, prior, 1.0, K, D, mask)
        kl = _metrics(phi_star, ref_phi, K, D)
        return phi_star, kl

    phi, kls = jax.lax.scan(step, phi, jnp.arange(n_iters))
    return VBRun(phi=phi, kl_mean=jnp.mean(kls, 1), kl_std=jnp.std(kls, 1),
                 kl_nodes=kls)


# ---------------------------------------------------------------------------
# nsg-dVB — one-step averaging of local optima (the Sec. III-A strawman)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n_iters", "K", "D"))
def run_nsg_dvb(x, mask, weights, prior: GMMPosterior, *, n_iters: int,
                K: int, D: int, ref_phi=None,
                init_q: GMMPosterior | None = None) -> VBRun:
    n_nodes = x.shape[0]
    phi = _init_phi(prior if init_q is None else init_q, n_nodes)

    def step(phi, t):
        phi_star = gmm.local_vbm_optimum_nodes(
            x, phi, prior, float(n_nodes), K, D, mask)
        phi_new = weights @ phi_star
        kl = _metrics(phi_new, ref_phi, K, D)
        return phi_new, kl

    phi, kls = jax.lax.scan(step, phi, jnp.arange(n_iters))
    return VBRun(phi=phi, kl_mean=jnp.mean(kls, 1), kl_std=jnp.std(kls, 1),
                 kl_nodes=kls)


# ---------------------------------------------------------------------------
# dSVB — Algorithm 1 (stochastic natural gradient + diffusion)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit,
                   static_argnames=("n_iters", "K", "D"))
def run_dsvb(x, mask, weights, prior: GMMPosterior, *, n_iters: int,
             K: int, D: int, tau: float = 0.2, d0: float = 1.0,
             ref_phi=None, init_q: GMMPosterior | None = None) -> VBRun:
    n_nodes = x.shape[0]
    phi = _init_phi(prior if init_q is None else init_q, n_nodes)

    def step(phi, t):
        # VBE + local VBM optimum (lines 4-5 of Algorithm 1)
        phi_star = gmm.local_vbm_optimum_nodes(
            x, phi, prior, float(n_nodes), K, D, mask)
        # (27a): natural-gradient step  phi + eta (phi* - phi)
        eta = eta_schedule(t.astype(phi.dtype) + 1.0, tau, d0)
        varphi = phi + eta * (phi_star - phi)
        # (27b): diffusion combine with neighbours
        phi_new = weights @ varphi
        kl = _metrics(phi_new, ref_phi, K, D)
        return phi_new, kl

    phi, kls = jax.lax.scan(step, phi, jnp.arange(n_iters))
    return VBRun(phi=phi, kl_mean=jnp.mean(kls, 1), kl_std=jnp.std(kls, 1),
                 kl_nodes=kls)


# ---------------------------------------------------------------------------
# dVB-ADMM — Algorithm 2 (consensus ADMM in natural-parameter space)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit,
                   static_argnames=("n_iters", "K", "D", "project"))
def run_dvb_admm(x, mask, adj, prior: GMMPosterior, *, n_iters: int,
                 K: int, D: int, rho: float = 0.5, xi: float = 0.05,
                 project: bool = True, ref_phi=None,
                 init_q: GMMPosterior | None = None) -> VBRun:
    n_nodes = x.shape[0]
    deg = jnp.sum(adj, axis=1)                                    # |N_i|
    phi = _init_phi(prior if init_q is None else init_q, n_nodes)
    lam = jnp.zeros_like(phi)                                     # lambda_i

    def step(carry, t):
        phi, lam = carry
        # VBE + local optimum (lines 5-6 of Algorithm 2)
        phi_star = gmm.local_vbm_optimum_nodes(
            x, phi, prior, float(n_nodes), K, D, mask)
        # (38a) primal:  (phi* - 2 lam + rho sum_j (phi_i + phi_j)) /(1+2 rho d)
        neigh_sum = adj @ phi                                     # sum_j phi_j
        phi_hat = (phi_star - 2.0 * lam
                   + rho * (deg[:, None] * phi + neigh_sum))
        phi_hat = phi_hat / (1.0 + 2.0 * rho * deg)[:, None]
        if project:
            # (38b) projection onto the natural-parameter domain Omega
            phi_new = jax.vmap(
                lambda p: expfam.project_to_domain(p, K, D))(phi_hat)
        else:
            phi_new = phi_hat
        # (39) dual ascent with the kappa_t ramp (Eq. 40)
        kappa = kappa_schedule(t.astype(phi.dtype) + 1.0, xi)
        resid = deg[:, None] * phi_new - adj @ phi_new            # sum_j (i-j)
        lam_new = lam + kappa * rho / 2.0 * resid
        kl = _metrics(phi_new, ref_phi, K, D)
        return (phi_new, lam_new), kl

    (phi, lam), kls = jax.lax.scan(step, (phi, lam), jnp.arange(n_iters))
    return VBRun(phi=phi, kl_mean=jnp.mean(kls, 1), kl_std=jnp.std(kls, 1),
                 kl_nodes=kls)


ALGORITHMS = {
    "cvb": run_cvb,
    "noncoop": run_noncoop,
    "nsg_dvb": run_nsg_dvb,
    "dsvb": run_dsvb,
    "dvb_admm": run_dvb_admm,
}
