"""Mesh-parallel execution of the paper's algorithms via shard_map.

These are the same engine step functions as core/algorithms.py — the ONLY
difference is the executor: `engine.MeshExecutor(mesh, axis)` shards the
node axis over a mesh axis and each topology swaps its dense combine for
the equivalent collective:

* `Diffusion` / `ADMMConsensus` — the *faithful* arbitrary-graph
  algorithms: the combine `W @ varphi` needs every node's message, which on
  an arbitrary graph is realised as an `all_gather` along the axis followed
  by the local rows of W.  (On a real WSN each node only receives from
  neighbours; on a TPU mesh the all_gather is the collective that
  implements "every node can see the messages addressed to it".)

* `RingDiffusion` — the TPU-adapted topology: the communication graph *is*
  the ICI ring along a mesh axis, so the combine is two `lax.ppermute`s
  (left+right neighbour) and a weighted sum — no all_gather, no all_reduce.
  This is the pattern the framework layer's `dp_mode=diffusion` optimiser
  uses (see repro/optim/consensus.py) and the basis of the beyond-paper
  collective-bytes reduction measured in EXPERIMENTS.md.

Numerical equivalence of the sharded and single-array executors is asserted
in tests/test_distributed.py and tests/test_engine.py (run in a subprocess
with host-platform devices).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import engine
from repro.core import model as model_lib

# Backward-compatible alias: the ring combine primitive now lives in the
# engine (shared with optim/consensus.py).
ring_diffusion_combine = engine.ring_combine_block


def run_dsvb_sharded(mesh: Mesh, x, mask, weights, prior, *, n_iters: int,
                     K: int, D: int, tau: float = 0.2, d0: float = 1.0,
                     axis: str = "data", backend=None) -> jnp.ndarray:
    """Faithful dSVB with the node axis sharded over `axis`.

    x (N, Ni, D), mask (N, Ni), weights (N, N) row-stochastic.  Returns the
    final (N, P) natural parameters (fully replicated logical output).
    `backend` selects the compute backend (core/backends.py) — the fused
    Pallas kernel runs on each shard's local slice of the node axis.
    """
    run = engine.run_vb(
        model_lib.GMMModel(prior, K, D, backend=backend), (x, mask),
        engine.Diffusion(weights), n_iters=n_iters,
        schedule=engine.Schedule(tau=tau, d0=d0),
        executor=engine.MeshExecutor(mesh, axis), diagnostics=False)
    return run.phi


def run_dsvb_ring_sharded(mesh: Mesh, x, mask, prior, *, n_iters: int,
                          K: int, D: int, tau: float = 0.2, d0: float = 1.0,
                          w_self: float = 1.0 / 3.0,
                          axis: str = "data", backend=None) -> jnp.ndarray:
    """dSVB on the TPU-native ring topology: node blocks per mesh slot along
    `axis`, combine via ppermute only (no all_gather)."""
    run = engine.run_vb(
        model_lib.GMMModel(prior, K, D, backend=backend), (x, mask),
        engine.RingDiffusion(w_self), n_iters=n_iters,
        schedule=engine.Schedule(tau=tau, d0=d0),
        executor=engine.MeshExecutor(mesh, axis), diagnostics=False)
    return run.phi


def run_admm_sharded(mesh: Mesh, x, mask, adj, prior, *, n_iters: int,
                     K: int, D: int, rho: float = 0.5, xi: float = 0.05,
                     project: bool = True, lam_max: float | None = None,
                     axis: str = "data", backend=None) -> jnp.ndarray:
    """Faithful dVB-ADMM with the node axis sharded over `axis`."""
    run = engine.run_vb(
        model_lib.GMMModel(prior, K, D, backend=backend), (x, mask),
        engine.ADMMConsensus(adj, rho=rho, xi=xi, project=project,
                             lam_max=lam_max),
        n_iters=n_iters, executor=engine.MeshExecutor(mesh, axis),
        diagnostics=False)
    return run.phi


def run_vb_sharded(mesh: Mesh, model, data, topology, *, n_iters: int,
                   axis: str = "data", **kw) -> engine.VBRun:
    """Generic entry point: any ConjugateExpModel x topology on a mesh."""
    return engine.run_vb(model, data, topology, n_iters=n_iters,
                         executor=engine.MeshExecutor(mesh, axis), **kw)


__all__ = [
    "ring_diffusion_combine", "run_dsvb_sharded", "run_dsvb_ring_sharded",
    "run_admm_sharded", "run_vb_sharded",
]
