"""Mesh-parallel execution of the paper's algorithms via shard_map.

Two levels of fidelity:

* `run_dsvb_sharded` / `run_admm_sharded` — the *faithful* arbitrary-graph
  algorithms with the node axis sharded over the mesh `data` axis.  The
  diffusion combine `W @ varphi` needs every node's message, which on an
  arbitrary graph is realised as an `all_gather` along `data` followed by the
  local rows of W.  (On a real WSN each node only receives from neighbours;
  on a TPU mesh the all_gather is the collective that implements "every node
  can see the messages addressed to it".)

* `ring_diffusion_combine` — the TPU-adapted topology: the communication
  graph *is* the ICI ring along a mesh axis, so the combine is two
  `lax.ppermute`s (left+right neighbour) and a weighted sum — no all_gather,
  no all_reduce.  This is the pattern the framework layer's `dp_mode=
  diffusion` optimiser uses (see repro/optim/consensus.py) and the basis of
  the beyond-paper collective-bytes reduction measured in EXPERIMENTS.md.

Numerical equivalence of the sharded and single-array runners is asserted in
tests/test_distributed.py (run in a subprocess with host-platform devices).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import expfam, gmm
from repro.core.algorithms import eta_schedule, kappa_schedule


def ring_diffusion_combine(varphi: jnp.ndarray, axis_name: str,
                           w_self: float = 1.0 / 3.0) -> jnp.ndarray:
    """Eq. 27b on a ring: phi_i = w_self*phi_i + w_n*(phi_{i-1} + phi_{i+1}).

    Uses two collective_permutes (the TPU ICI-native neighbour exchange);
    with w_self = 1/3 this is exactly the nearest-neighbour rule (Eq. 47)
    on a cycle graph.
    """
    n = jax.lax.axis_size(axis_name)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    # Node-level ring shift for a block of `B` nodes per mesh slot: interior
    # neighbours are a local roll; only the two boundary rows cross the ICI
    # link (ppermute) — the minimal-traffic neighbour exchange.
    prev_tail = jax.lax.ppermute(varphi[-1:], axis_name, fwd)
    next_head = jax.lax.ppermute(varphi[:1], axis_name, bwd)
    shifted_right = jnp.concatenate([prev_tail, varphi[:-1]], 0)  # phi_{i-1}
    shifted_left = jnp.concatenate([varphi[1:], next_head], 0)    # phi_{i+1}
    w_n = (1.0 - w_self) / 2.0
    return w_self * varphi + w_n * (shifted_right + shifted_left)


def _vbe_local(x, mask, phi, prior, n_nodes, K, D):
    return gmm.local_vbm_optimum_nodes(x, phi, prior, float(n_nodes), K, D,
                                       mask)


def run_dsvb_sharded(mesh: Mesh, x, mask, weights, prior, *, n_iters: int,
                     K: int, D: int, tau: float = 0.2, d0: float = 1.0,
                     axis: str = "data") -> jnp.ndarray:
    """Faithful dSVB with the node axis sharded over `axis`.

    x (N, Ni, D), mask (N, Ni), weights (N, N) row-stochastic.  Returns the
    final (N, P) natural parameters (fully replicated logical output).
    """
    n_nodes = x.shape[0]
    phi0 = jnp.broadcast_to(expfam.pack_natural(prior),
                            (n_nodes, expfam.flat_dim(K, D)))

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis)),
        out_specs=P(axis))
    def run(x_l, mask_l, w_rows, phi_l):
        def step(phi_l, t):
            phi_star = _vbe_local(x_l, mask_l, phi_l, prior, n_nodes, K, D)
            eta = eta_schedule(t.astype(phi_l.dtype) + 1.0, tau, d0)
            varphi = phi_l + eta * (phi_star - phi_l)
            # arbitrary graph: gather everyone's message, apply local W rows
            varphi_all = jax.lax.all_gather(varphi, axis, tiled=True)
            return w_rows @ varphi_all, None

        phi_l, _ = jax.lax.scan(step, phi_l, jnp.arange(n_iters))
        return phi_l

    return run(x, mask, weights, phi0)


def run_dsvb_ring_sharded(mesh: Mesh, x, mask, prior, *, n_iters: int,
                          K: int, D: int, tau: float = 0.2, d0: float = 1.0,
                          axis: str = "data") -> jnp.ndarray:
    """dSVB on the TPU-native ring topology: one node per mesh slot along
    `axis`, combine via ppermute only (no all_gather)."""
    n_nodes = x.shape[0]
    phi0 = jnp.broadcast_to(expfam.pack_natural(prior),
                            (n_nodes, expfam.flat_dim(K, D)))

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=P(axis))
    def run(x_l, mask_l, phi_l):
        def step(phi_l, t):
            phi_star = _vbe_local(x_l, mask_l, phi_l, prior, n_nodes, K, D)
            eta = eta_schedule(t.astype(phi_l.dtype) + 1.0, tau, d0)
            varphi = phi_l + eta * (phi_star - phi_l)
            return ring_diffusion_combine(varphi, axis), None

        phi_l, _ = jax.lax.scan(step, phi_l, jnp.arange(n_iters))
        return phi_l

    return run(x, mask, phi0)


def run_admm_sharded(mesh: Mesh, x, mask, adj, prior, *, n_iters: int,
                     K: int, D: int, rho: float = 0.5, xi: float = 0.05,
                     project: bool = True, axis: str = "data") -> jnp.ndarray:
    """Faithful dVB-ADMM with the node axis sharded over `axis`."""
    n_nodes = x.shape[0]
    pdim = expfam.flat_dim(K, D)
    phi0 = jnp.broadcast_to(expfam.pack_natural(prior), (n_nodes, pdim))
    lam0 = jnp.zeros((n_nodes, pdim), phi0.dtype)

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=P(axis))
    def run(x_l, mask_l, adj_rows, phi_l, lam_l):
        deg_l = jnp.sum(adj_rows, axis=1)

        def step(carry, t):
            phi_l, lam_l = carry
            phi_star = _vbe_local(x_l, mask_l, phi_l, prior, n_nodes, K, D)
            phi_all = jax.lax.all_gather(phi_l, axis, tiled=True)
            neigh_sum = adj_rows @ phi_all
            phi_hat = (phi_star - 2.0 * lam_l
                       + rho * (deg_l[:, None] * phi_l + neigh_sum))
            phi_hat = phi_hat / (1.0 + 2.0 * rho * deg_l)[:, None]
            if project:
                phi_new = jax.vmap(
                    lambda p: expfam.project_to_domain(p, K, D))(phi_hat)
            else:
                phi_new = phi_hat
            kappa = kappa_schedule(t.astype(phi_l.dtype) + 1.0, xi)
            phi_new_all = jax.lax.all_gather(phi_new, axis, tiled=True)
            resid = deg_l[:, None] * phi_new - adj_rows @ phi_new_all
            lam_new = lam_l + kappa * rho / 2.0 * resid
            return (phi_new, lam_new), None

        (phi_l, _), _ = jax.lax.scan(step, (phi_l, lam_l),
                                     jnp.arange(n_iters))
        return phi_l

    return run(x, mask, adj, phi0, lam0)
