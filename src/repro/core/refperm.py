"""Component-permutation utilities for the Eq. 46 metric.

Mixture components carry no canonical order, so the KL between an estimated
posterior and the ground-truth posterior is only meaningful modulo a
permutation of components.  We build the stack of all K! permuted references
once (host-side) and let algorithms._metrics take the min.
"""
from __future__ import annotations

import itertools

import jax.numpy as jnp
import numpy as np

from repro.core import expfam
from repro.core.expfam import GMMPosterior


def permuted_refs(ref: GMMPosterior, max_k_factorial: int = 720) -> jnp.ndarray:
    """(K!, P) stack of pack_natural over all component permutations."""
    K = ref.K
    perms = list(itertools.permutations(range(K)))
    if len(perms) > max_k_factorial:
        raise ValueError(f"K={K} too large for exhaustive permutation matching")
    stack = []
    for p in perms:
        idx = np.asarray(p)
        q = GMMPosterior(alpha=ref.alpha[idx], m=ref.m[idx],
                         beta=ref.beta[idx], W=ref.W[idx], nu=ref.nu[idx])
        stack.append(expfam.pack_natural(q))
    return jnp.stack(stack)
