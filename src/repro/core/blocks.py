"""Composable exponential-family blocks — the model layer's building bricks.

The paper's contribution 1 claims generality over "a very general class of
conjugate-exponential models"; this module makes that claim structural.  A
conjugate-exponential global posterior factorises into independent
exponential-family *blocks* (Dirichlet mixing weights, Normal-Wishart
component banks, Normal-Gamma regression rows, ...), and everything the
engine needs from a model — the flat Eq. 45 message, the Eq. 38b domain
projection, the Eq. 46 KL metric, the per-block labels of the adaptive
consensus layer — is a concatenation of per-block quantities:

* `ExpFamBlock` names the per-block surface: a contiguous segment of the
  flat natural-parameter vector with pack/unpack, log-partition A(phi),
  expected sufficient statistics grad A, KL, domain projection, and label
  structure.
* `DirichletBlock`, `NormalWishartBlock`, `NormalGammaBlock` are the three
  concrete families, extracted from core/expfam.py / core/linreg.py (the
  family math stays there; the blocks own the composable interface).  Each
  supports a bank of `rows` independent factors, so one block type covers
  the GMM mixing weights (1 Dirichlet row), HMM transition matrices (K
  Dirichlet rows), and PPCA loading matrices (D Normal-Gamma rows).
* `BlockModel` is the protocol-level default implementation of
  `model.ConjugateExpModel`: `pack` / `unpack` / `kl` /
  `project_to_domain` / `block_labels` / `pad_to_capacity` /
  `take_minibatch` / `data_mask` / `append_node_data` are all derived from
  the block list and the (arrays..., mask) data convention.  A new model
  adapter supplies its block tuple, the hyper split/join, and its
  `local_optimum` — and drops into every topology, executor, and the
  streaming/session/serving layers for free (models/hmm.py and
  models/ppca.py are exactly that).

The composed flat layouts reproduce the pre-refactor monoliths bit-for-bit:
`GMMModel` over (DirichletBlock, NormalWishartBlock) packs/projects/scores
identically to the old expfam.py code paths, which is what keeps every
golden-parity and padding bit-invisibility test green across the refactor.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backends, expfam, linreg
from repro.core.expfam import NWParams
from repro.core.linreg import NGPosterior


@runtime_checkable
class ExpFamBlock(Protocol):
    """One exponential-family factor bank = one contiguous segment of the
    flat natural-parameter message.

    `dim` is the segment length; `label_names` names the coordinate groups
    inside the segment (the per-block view consumed by the adaptive
    consensus layer); hyper containers are block-specific pytrees with a
    leading `rows` axis.  `kl` has a family-generic default via the
    exp-family identity KL = (phi_q - phi_p)' E_q[u] - A(q) + A(p); the
    shipped blocks implement it with the exact summation order of the
    pre-refactor per-model code so the refactor is bit-invisible.
    """

    @property
    def dim(self) -> int:
        """Number of flat coordinates this block owns."""
        ...

    @property
    def label_names(self) -> tuple:
        """Names of the block's coordinate groups (label id order)."""
        ...

    def labels(self) -> np.ndarray:
        """(dim,) int32 group label per coordinate, indexing label_names.
        Host (numpy): static packing structure, usable inside jit."""
        ...

    def pack(self, h) -> jnp.ndarray:
        """Hyper container -> (dim,) natural-parameter segment."""
        ...

    def unpack(self, x: jnp.ndarray):
        """(dim,) segment -> hyper container (inverse of pack)."""
        ...

    def log_partition(self, h) -> jnp.ndarray:
        """A(phi) of the block (scalar; summed over rows)."""
        ...

    def expected_stats(self, h) -> jnp.ndarray:
        """grad_phi A = E[u], laid out exactly like `pack` ((dim,))."""
        ...

    def project(self, x: jnp.ndarray) -> jnp.ndarray:
        """Projection of the segment onto the block's domain (Eq. 38b)."""
        ...

    def kl(self, x: jnp.ndarray, x_ref: jnp.ndarray) -> jnp.ndarray:
        """KL(q(x) || p(x_ref)) of the block (scalar)."""
        ...


def default_kl(block: ExpFamBlock, x: jnp.ndarray,
               x_ref: jnp.ndarray) -> jnp.ndarray:
    """Family-generic block KL via the exp-family identity
    KL = (phi_q - phi_p)' E_q[u] - A(q) + A(p)  (Eq. 46 analogue).
    Any new `ExpFamBlock` gets its KL for free from `pack`/`log_partition`/
    `expected_stats`; the shipped blocks override with the historical
    summation order for bit-stability."""
    hq, hp = block.unpack(x), block.unpack(x_ref)
    inner = jnp.sum((x - x_ref) * block.expected_stats(hq))
    return inner - block.log_partition(hq) + block.log_partition(hp)


# ---------------------------------------------------------------------------
# Concrete blocks
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DirichletBlock:
    """Bank of `rows` independent Dirichlet factors over K categories.

    rows=1 is the GMM mixing-weight block; rows=K is an HMM transition
    matrix (one Dirichlet per source state).  Hyper container: alpha
    (rows, K).  Flat coords: (alpha - 1).reshape(-1)."""

    K: int
    rows: int = 1
    name: str = "alpha"
    min_alpha: float = 1e-3

    @property
    def dim(self) -> int:
        return self.rows * self.K

    @property
    def label_names(self) -> tuple:
        return (self.name,)

    def labels(self) -> np.ndarray:
        return np.zeros(self.dim, np.int32)

    def pack(self, alpha: jnp.ndarray) -> jnp.ndarray:
        return (alpha - 1.0).reshape(-1)

    def unpack(self, x: jnp.ndarray) -> jnp.ndarray:
        return x.reshape(self.rows, self.K) + 1.0

    def log_partition(self, alpha: jnp.ndarray) -> jnp.ndarray:
        return jnp.sum(expfam.dirichlet_log_partition(alpha))

    def expected_stats(self, alpha: jnp.ndarray) -> jnp.ndarray:
        return expfam.dirichlet_expected_log(alpha).reshape(-1)

    def project(self, x: jnp.ndarray) -> jnp.ndarray:
        alpha = jnp.maximum(x + 1.0, self.min_alpha)
        return alpha - 1.0

    def kl(self, x: jnp.ndarray, x_ref: jnp.ndarray) -> jnp.ndarray:
        aq, ap = self.unpack(x), self.unpack(x_ref)
        inner = jnp.sum((aq - ap) * expfam.dirichlet_expected_log(aq))
        return (inner - jnp.sum(expfam.dirichlet_log_partition(aq))
                + jnp.sum(expfam.dirichlet_log_partition(ap)))


@dataclasses.dataclass(frozen=True)
class NormalWishartBlock:
    """Bank of K Normal-Wishart factors (mu_k, Lambda_k) in D dims — the
    GMM/HMM emission block.  Hyper container: `expfam.NWParams`; flat
    layout: per-component [n1, n4, n3 (D), vec(n2) (D*D)] (Eq. 45)."""

    K: int
    D: int
    min_beta: float = 1e-6
    min_eig: float = 1e-8

    @property
    def dim(self) -> int:
        return self.K * (2 + self.D + self.D * self.D)

    @property
    def label_names(self) -> tuple:
        return ("nu", "beta", "mean", "winv")

    def labels(self) -> np.ndarray:
        D = self.D
        per = [0, 1] + [2] * D + [3] * (D * D)
        return np.asarray(per * self.K, np.int32)

    def pack(self, h: NWParams) -> jnp.ndarray:
        return expfam.nw_pack(h)

    def unpack(self, x: jnp.ndarray) -> NWParams:
        return expfam.nw_unpack(x, self.K, self.D)

    def log_partition(self, h: NWParams) -> jnp.ndarray:
        return jnp.sum(expfam.nw_log_partition(h))

    def expected_stats(self, h: NWParams) -> jnp.ndarray:
        return expfam.nw_expected_stats_flat(h)

    def project(self, x: jnp.ndarray) -> jnp.ndarray:
        return expfam.nw_project(x, self.K, self.D, min_beta=self.min_beta,
                                 min_eig=self.min_eig)

    def kl(self, x: jnp.ndarray, x_ref: jnp.ndarray) -> jnp.ndarray:
        return expfam.nw_kl(self.unpack(x), self.unpack(x_ref))


@dataclasses.dataclass(frozen=True)
class NormalGammaBlock:
    """Bank of `rows` independent Normal-Gamma factors over D coefficients.

    rows=1 is Bayesian linear regression (core/linreg.py); rows=D_obs is a
    PPCA/factor-analysis loading matrix (one regression row per observed
    dimension).  Hyper container: `linreg.NGPosterior` with a leading rows
    axis on every field; flat layout per row: [n1, n2, n3 (D), vec(n4)].

    `project` is the identity: consensus averages of Normal-Gamma naturals
    stay in the domain (the -V/2 carriers average to averages of negative-
    definite matrices), matching the paper's linear-regression discussion.
    """

    D: int
    rows: int = 1

    @property
    def dim(self) -> int:
        return self.rows * linreg.flat_dim(self.D)

    @property
    def label_names(self) -> tuple:
        return ("shape", "rate", "mean", "precision")

    def labels(self) -> np.ndarray:
        D = self.D
        per = [0, 1] + [2] * D + [3] * (D * D)
        return np.asarray(per * self.rows, np.int32)

    def _strip(self, h: NGPosterior) -> NGPosterior:
        return NGPosterior(m=h.m[0], V=h.V[0], a=h.a[0], b=h.b[0])

    def pack(self, h: NGPosterior) -> jnp.ndarray:
        if self.rows == 1:
            return linreg.pack(self._strip(h))
        return jax.vmap(linreg.pack)(h).reshape(-1)

    def unpack(self, x: jnp.ndarray) -> NGPosterior:
        if self.rows == 1:
            q = linreg.unpack(x, self.D)
            return NGPosterior(m=q.m[None], V=q.V[None], a=q.a[None],
                               b=q.b[None])
        return jax.vmap(lambda xi: linreg.unpack(xi, self.D))(
            x.reshape(self.rows, linreg.flat_dim(self.D)))

    def log_partition(self, h: NGPosterior) -> jnp.ndarray:
        if self.rows == 1:
            return linreg.log_partition(self._strip(h))
        return jnp.sum(jax.vmap(linreg.log_partition)(h))

    def expected_stats(self, h: NGPosterior) -> jnp.ndarray:
        def one(q: NGPosterior) -> jnp.ndarray:
            e_loglam, e_lam, e_lw, e_lww = linreg.expected_stats(q)
            return jnp.concatenate([e_loglam[None], e_lam[None], e_lw,
                                    e_lww.reshape(-1)])

        if self.rows == 1:
            return one(self._strip(h))
        return jax.vmap(one)(h).reshape(-1)

    def project(self, x: jnp.ndarray) -> jnp.ndarray:
        return x

    def kl(self, x: jnp.ndarray, x_ref: jnp.ndarray) -> jnp.ndarray:
        hq, hp = self.unpack(x), self.unpack(x_ref)
        if self.rows == 1:
            return linreg.kl(self._strip(hq), self._strip(hp))
        return jnp.sum(jax.vmap(linreg.kl)(hq, hp))


# ---------------------------------------------------------------------------
# Protocol-level default implementations over a block list
# ---------------------------------------------------------------------------
class BlockModel:
    """`ConjugateExpModel` defaults derived from a tuple of `ExpFamBlock`s.

    Subclasses set `self.blocks` and `self.prior` in their `__init__` and
    implement:

    * `split_hyper(q)` — model hyper container -> per-block hyper tuple,
    * `join_hyper(parts)` — the inverse,
    * `local_optimum(data, phi_nodes, replication)` — the model's VBE step
      + local VBM optimum (Eqs. 17a, 18); everything else is derived.

    Data convention of the derived data-plumbing defaults: `data` is a
    tuple `(*arrays, mask)` whose every leaf carries the per-node sample
    axis at position 1 — `(x (N, T, ...), mask (N, T))` — which is what
    makes `pad_to_capacity` / `take_minibatch` / `append_node_data`
    expressible once for every adapter.  Models with a different layout
    (LinRegModel's optional precomputed phi* stack) override the accessors.
    """

    blocks: tuple = ()
    prior: Any = None

    # -- flat-message structure ---------------------------------------------
    @property
    def flat_dim(self) -> int:
        return sum(b.dim for b in self.blocks)

    def _segments(self):
        """[(block, start, stop)] of each block's flat segment."""
        out, off = [], 0
        for b in self.blocks:
            out.append((b, off, off + b.dim))
            off += b.dim
        return out

    def split_hyper(self, q) -> tuple:
        raise NotImplementedError

    def join_hyper(self, parts: tuple):
        raise NotImplementedError

    def pack(self, q) -> jnp.ndarray:
        parts = self.split_hyper(q)
        return jnp.concatenate(
            [b.pack(h) for b, h in zip(self.blocks, parts)])

    def unpack(self, phi: jnp.ndarray):
        return self.join_hyper(tuple(
            b.unpack(phi[lo:hi]) for b, lo, hi in self._segments()))

    def init_phi(self) -> jnp.ndarray:
        if self.prior is None:
            raise ValueError(f"{type(self).__name__} built without a prior")
        return self.pack(self.prior)

    def project_to_domain(self, phi: jnp.ndarray) -> jnp.ndarray:
        return jnp.concatenate(
            [b.project(phi[lo:hi]) for b, lo, hi in self._segments()])

    def kl(self, phi: jnp.ndarray, phi_ref: jnp.ndarray) -> jnp.ndarray:
        total = None
        for b, lo, hi in self._segments():
            term = b.kl(phi[lo:hi], phi_ref[lo:hi])
            total = term if total is None else total + term
        return total

    @property
    def BLOCK_NAMES(self) -> tuple:
        """Concatenated label names of all blocks (block_labels id order)."""
        return tuple(n for b in self.blocks for n in b.label_names)

    def block_labels(self) -> np.ndarray:
        parts, base = [], 0
        for b in self.blocks:
            parts.append(b.labels().astype(np.int32) + base)
            base += len(b.label_names)
        return np.concatenate(parts).astype(np.int32)

    def local_optimum(self, data: Any, phi_nodes: jnp.ndarray,
                      replication: float) -> jnp.ndarray:
        raise NotImplementedError

    # -- compute-backend selection ------------------------------------------
    def with_backend(self, backend) -> "BlockModel":
        """Default: only the reference path exists (the model's own
        `local_optimum`).  Models with a fused hot path (GMMModel)
        override; `engine.vb_init` checks `Backend.supports(model)` first
        and falls back to the reference backend instead of reaching this
        error."""
        resolved = backends.resolve(backend)
        if resolved.name != "reference":
            raise ValueError(
                f"{type(self).__name__} has no {resolved.name!r} compute "
                "backend; its local VBM optimum runs on the reference "
                "path only")
        return self

    # -- data plumbing (streaming / serving defaults) -----------------------
    def data_mask(self, data: Any) -> jnp.ndarray:
        return data[-1]

    def take_minibatch(self, data: Any, idx: jnp.ndarray,
                       mb_mask: jnp.ndarray) -> Any:
        arrs = data[:-1]
        out = []
        for a in arrs:
            ix = idx.reshape(idx.shape + (1,) * (a.ndim - 2))
            out.append(jnp.take_along_axis(a, ix, axis=1))
        return (*out, mb_mask)

    def append_node_data(self, data: Any, node: int, points: Any) -> Any:
        """Default for the `(x, mask)` layout: write `points` (leading axis
        = new samples, trailing axes = x's per-sample shape) into node
        `node`'s free mask-zero slots."""
        x, mask = data
        points = jnp.asarray(points, x.dtype)
        if points.ndim == x.ndim - 2:
            points = points[None]
        slots = self._free_slots(mask, node, points.shape[0])
        return (x.at[node, slots].set(points),
                mask.at[node, slots].set(jnp.ones((), mask.dtype)))

    def _free_slots(self, mask: jnp.ndarray, node: int,
                    n_new: int) -> jnp.ndarray:
        free = jnp.where(mask[node] <= 0)[0]            # host-side eager
        if free.shape[0] < n_new:
            raise ValueError(
                f"node {node}: buffer full ({int(free.shape[0])} free "
                f"slot(s), {n_new} new point(s))")
        return free[:n_new]

    def pad_to_capacity(self, data: Any, capacity: int) -> Any:
        T = self.data_mask(data).shape[1]
        if capacity < T:
            raise ValueError(
                f"capacity {capacity} < current buffer size {T}")
        if capacity == T:
            return data
        pad = capacity - T
        return jax.tree_util.tree_map(
            lambda a: jnp.pad(a, ((0, 0), (0, pad))
                              + ((0, 0),) * (a.ndim - 2)), data)
