"""Conjugate-exponential model adapters for the unified VB engine.

The paper's contribution 1 is that dSVB and dVB-ADMM apply to the *general
class* of conjugate-exponential models: every algorithm only ever touches a
model through (a) the flat natural-parameter vector phi exchanged between
nodes (Eq. 45), (b) the per-node local VBM optimum phi*_i (Eq. 18), (c) the
projection onto the natural-parameter domain Omega (Eq. 38b) and (d) the KL
metric d(phi, phi_hat) (Eq. 46).  `ConjugateExpModel` names exactly that
surface; `engine.run_vb` is written against it and nothing else.

Since PR 9 every adapter is a `blocks.BlockModel`: the model declares its
tuple of exponential-family blocks (core/blocks.py) and the hyper
split/join, and pack/unpack/KL/projection/block-labels plus the streaming
and serving data plumbing (pad_to_capacity / take_minibatch /
append_node_data) are protocol-level defaults derived from the block list.
An adapter only owns its `local_optimum`.  Two instances live here:

* `GMMModel`   — the paper's Bayesian Gaussian mixture (Sec. IV + App. A):
  DirichletBlock(1 row) + NormalWishartBlock, wrapping core/gmm.py.
  Mixture components carry no canonical order, so the reference for the KL
  metric may be a stack of component permutations (core/refperm.py); the
  engine takes the min.
* `LinRegModel` — Bayesian linear regression with Normal-Gamma conjugacy
  (core/linreg.py): a single NormalGammaBlock row, the classic
  diffusion-LMS WSN task.  The model has no local latent variables, so the
  VBE step is trivial and phi*_i is constant across iterations:
  `local_optimum` accepts either raw node data (X, y, mask) or a
  precomputed (N, P) phi* stack.

The model zoo (`models/hmm.py` HMMModel, `models/ppca.py` PPCAModel)
composes the same blocks into further members of the class — see
docs/model-zoo.md.
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import backends, blocks, linreg
from repro.core.expfam import GMMPosterior, NWParams
from repro.core.linreg import NGPosterior


@runtime_checkable
class ConjugateExpModel(Protocol):
    """What the engine needs from a conjugate-exponential model.

    Any object with this surface runs under every topology and executor of
    `engine.run_vb` — that is the paper's contribution-1 generality claim
    as an API.  `blocks.BlockModel` provides default implementations of
    everything except `local_optimum` from a tuple of exponential-family
    blocks.  Example (the shipped GMM instance):

    >>> from repro.core import expfam, model
    >>> mdl = model.GMMModel(expfam.noninformative_prior(3, 2), K=3, D=2)
    >>> isinstance(mdl, model.ConjugateExpModel)
    True
    >>> mdl.flat_dim                      # P of the Eq. 45 message
    27
    >>> mdl.init_phi().shape              # the prior, packed
    (27,)
    """

    @property
    def flat_dim(self) -> int:
        """Length P of the flat natural-parameter message (Eq. 45)."""
        ...

    def pack(self, q) -> jnp.ndarray:
        """Hyperparameters -> flat natural parameters phi."""
        ...

    def unpack(self, phi: jnp.ndarray):
        """Flat natural parameters -> hyperparameter container."""
        ...

    def init_phi(self) -> jnp.ndarray:
        """Default (P,) starting point (the prior's natural parameters)."""
        ...

    def local_optimum(self, data: Any, phi_nodes: jnp.ndarray,
                      replication: float) -> jnp.ndarray:
        """Per-node VBE step + local VBM optimum phi*_i (Eqs. 17a, 18).

        `data` is the stacked per-node data pytree; `phi_nodes` is (N, P);
        `replication` is the likelihood replication factor (the network
        size N for cooperative runs, 1 for non-cooperative).  Returns the
        (N, P) stack of local optima.
        """
        ...

    def project_to_domain(self, phi: jnp.ndarray) -> jnp.ndarray:
        """Projection of one (P,) point onto the domain Omega (Eq. 38b)."""
        ...

    def kl(self, phi: jnp.ndarray, phi_ref: jnp.ndarray) -> jnp.ndarray:
        """d(phi, phi_ref) of Eq. 46: KL(Q(.|phi) || P(.|phi_ref))."""
        ...

    def block_labels(self) -> jnp.ndarray:
        """(P,) int32 block-type label per flat coordinate — the per-block
        view of phi used by the adaptive consensus layer (per-block dual
        scaling / residual norms).  Labels index the model's BLOCK_NAMES.
        """
        ...

    def data_mask(self, data: Any) -> jnp.ndarray:
        """(N, T) per-sample validity mask of the stacked node data — the
        base mask the streaming layer (data/stream.py) subsamples from."""
        ...

    def take_minibatch(self, data: Any, idx: jnp.ndarray,
                       mb_mask: jnp.ndarray) -> Any:
        """Gather the per-iteration minibatch: `idx` (N, B) indexes each
        node's sample axis, `mb_mask` (N, B) is the pre-scaled minibatch
        mask from `stream.minibatch_select` (selected-point weight T/B,
        so statistics stay unbiased).  Returns a data pytree of the same
        structure with the sample axis shrunk to B."""
        ...

    def append_node_data(self, data: Any, node: int, points: Any) -> Any:
        """Mid-flight data arrival: write `points` (the model's per-node
        observation format, leading axis = new samples) into node
        `node`'s free padding slots (mask == 0) and mark them valid.
        Returns a data pytree of IDENTICAL shapes/dtypes (buffers are
        fixed-capacity), so a live session/fleet keeps its compiled step.
        Raises ValueError when the node's buffer has no free capacity.
        Host-side (eager) — the serving layer calls it between slices."""
        ...

    def pad_to_capacity(self, data: Any, capacity: int) -> Any:
        """Grow every node's sample buffer to `capacity` slots by appending
        mask-zero padding (values zero, mask zero).  The serving layer's
        bucketed admission (serving/admission.py) pads sessions up to a
        shared ladder rung so near-same-shape sessions share one compiled
        fleet; the appended slots are inert — the engine's ordered
        reductions keep the padded trajectory BIT-equal to the unpadded
        one — and double as free capacity for `append_node_data`.
        Raises ValueError if `capacity` is below the current buffer size.
        Host-side (eager)."""
        ...


# ---------------------------------------------------------------------------
# Bayesian GMM (the paper's worked example)
# ---------------------------------------------------------------------------
class GMMModel(blocks.BlockModel):
    """Dirichlet x Normal-Wishart mixture posterior in natural-param space.

    `backend` selects the compute implementation of the per-iteration hot
    path (core/backends.py): "reference" (default; core/gmm.py einsums) or
    "fused" (node-batched single-pass Pallas kernel + jitted VBM
    post-stage), or any `backends.Backend` instance — e.g.
    `backends.FusedBackend(precision=PrecisionPolicy(data_dtype=bf16))`.
    """

    #: capability tag consumed by `backends.Backend.supports`: the fused
    #: Pallas kernel implements exactly the GMM E-step.
    kernel_family = "gmm"

    def __init__(self, prior: GMMPosterior, K: int | None = None,
                 D: int | None = None,
                 backend: str | backends.Backend | None = None):
        self.prior = prior
        self.K = K if K is not None else prior.K
        self.D = D if D is not None else prior.D
        self.backend = backends.resolve(backend)
        self.blocks = (blocks.DirichletBlock(self.K),
                       blocks.NormalWishartBlock(self.K, self.D))

    def with_backend(self, backend) -> "GMMModel":
        """Same model, different compute backend (used by run_vb(backend=))."""
        return GMMModel(self.prior, self.K, self.D, backend=backend)

    def split_hyper(self, q: GMMPosterior) -> tuple:
        return (q.alpha[None], NWParams(m=q.m, beta=q.beta, W=q.W, nu=q.nu))

    def join_hyper(self, parts: tuple) -> GMMPosterior:
        alpha, nw = parts
        return GMMPosterior(alpha=alpha[0], m=nw.m, beta=nw.beta, W=nw.W,
                            nu=nw.nu)

    def local_optimum(self, data, phi_nodes, replication):
        x, mask = data
        return self.backend.local_vbm_optimum_nodes(
            x, mask, phi_nodes, self.prior, replication, self.K, self.D)


# ---------------------------------------------------------------------------
# Bayesian linear regression (Normal-Gamma) — the generality instance
# ---------------------------------------------------------------------------
class LinRegModel(blocks.BlockModel):
    """y = w^T x + N(0, lambda^-1), lambda ~ Ga, w|lambda ~ N (conjugate)."""

    def __init__(self, prior: NGPosterior | None = None,
                 D: int | None = None):
        if prior is None and D is None:
            raise ValueError("LinRegModel needs a prior or a dimension D")
        self.prior = prior
        self.D = D if D is not None else prior.D
        self.blocks = (blocks.NormalGammaBlock(self.D),)

    @classmethod
    def from_flat_dim(cls, P: int) -> "LinRegModel":
        """Recover D from P = 2 + D + D^2 (integer root)."""
        D = int(round((-1.0 + (1.0 + 4.0 * (P - 2)) ** 0.5) / 2.0))
        if linreg.flat_dim(D) != P:
            raise ValueError(f"no integer D with flat_dim(D) == {P}")
        return cls(D=D)

    def with_backend(self, backend) -> "LinRegModel":
        """LinRegModel has no data hot loop (phi* is a one-time closed form),
        so only the reference backend applies."""
        resolved = backends.resolve(backend)
        if resolved.name != "reference":
            raise ValueError(
                f"LinRegModel has no {resolved.name!r} compute backend; "
                "its VBE step is trivial (no per-iteration data pass)")
        return self

    def split_hyper(self, q: NGPosterior) -> tuple:
        return (jax.tree_util.tree_map(lambda a: a[None], q),)

    def join_hyper(self, parts: tuple) -> NGPosterior:
        return jax.tree_util.tree_map(lambda a: a[0], parts[0])

    def local_optimum(self, data, phi_nodes, replication):
        # No local latents: phi*_i does not depend on the current iterate.
        # `data` is either a precomputed (N, P) phi* stack or raw node data.
        if hasattr(data, "ndim") and data.ndim == 2 \
                and data.shape[-1] == self.flat_dim:
            return data
        X, y, mask = data
        return jax.vmap(
            lambda Xi, yi, mi: linreg.local_optimum(
                Xi, yi, mi, self.prior, replication))(X, y, mask)

    def _raw_data(self, data):
        if hasattr(data, "ndim") and data.ndim == 2 \
                and data.shape[-1] == self.flat_dim:
            raise ValueError(
                "cannot minibatch a precomputed (N, P) phi* stack; pass "
                "raw (X, y, mask) node data to stream LinRegModel")
        return data

    def data_mask(self, data):
        return self._raw_data(data)[-1]

    def take_minibatch(self, data, idx, mb_mask):
        return super().take_minibatch(self._raw_data(data), idx, mb_mask)

    def append_node_data(self, data, node, points):
        """`points` is an (X_new (M, D), y_new (M,)) pair."""
        X, y, mask = self._raw_data(data)
        X_new, y_new = points
        X_new = jnp.asarray(X_new, X.dtype)
        y_new = jnp.asarray(y_new, y.dtype)
        if X_new.ndim == 1:
            X_new, y_new = X_new[None], jnp.atleast_1d(y_new)
        slots = self._free_slots(mask, node, X_new.shape[0])
        return (X.at[node, slots].set(X_new),
                y.at[node, slots].set(y_new),
                mask.at[node, slots].set(jnp.ones((), mask.dtype)))
