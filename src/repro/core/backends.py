"""Compute backends: WHICH implementation runs the per-iteration hot path.

The engine (core/engine.py) is written against `ConjugateExpModel`; for the
Bayesian GMM the per-node VBE step + local VBM optimum (Eqs. 17a/18,
Appendix A) dominates every paper experiment.  This module makes that
compute pluggable while everything exchanged between nodes stays in
natural-parameter space (the Khan information-geometry view: the message
phi is backend-invariant, only the arithmetic that produces phi* varies):

* `ReferenceBackend` ("reference") — the naive three-pass einsum path in
  core/gmm.py.  Ground truth; what the fused path is parity-tested against.
* `FusedBackend` ("fused") — one call goes data -> phi*:
    1. unpack phi, precompute the per-node per-component kernel terms
       (gmm.estep_terms) in `PrecisionPolicy.accum_dtype`,
    2. run the node-batched single-pass Pallas kernel
       (kernels/gmm_estep.gmm_estep_nodes): responsibilities + sufficient
       statistics in ONE sweep over the data, f32 accumulation,
    3. a fused post-stage — replication scaling + the Appendix-A VBM
       hyperparameter update (gmm.posterior_from_stats) + expfam.pack_natural
       — all inside the same jit.
  Data may stream in a narrow dtype (`PrecisionPolicy.data_dtype=bf16`)
  while accumulation stays f32, mirroring `ring_combine`'s `compute_dtype`
  convention.

Backends are selected by name or instance via `GMMModel(..., backend=)` or
per-run via `run_vb(..., backend=)`, and compose with both executors: the
fused kernel maps over whatever slice of the node axis the executor hands
it, so under `MeshExecutor`/shard_map each shard runs the kernel on its
local nodes.  Off-TPU the kernel executes in pallas interpret mode
(numerics-identical); on a TPU backend the same call compiles to Mosaic.

Every backend is a frozen dataclass: hashable, so wrappers may pass backend
instances through `jax.jit` static arguments.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import expfam, gmm
from repro.core.expfam import GMMPosterior


class PrecisionPolicy(NamedTuple):
    """Dtype contract of the fused hot path.

    data_dtype : streaming dtype for x/mask entering the kernel (None =
        leave as given).  bf16 halves HBM traffic on TPU; the kernel
        upcasts blocks in VMEM.
    accum_dtype : dtype of the unpack/precompute and the VBM post-stage
        (statistics always accumulate in f32 inside the kernel).
    out_dtype : dtype of the returned phi* stack (None = match the
        incoming phi iterate, so the engine's scan carry keeps its dtype).

    Example — stream bf16, accumulate f32 (the TPU-friendly setting):

    >>> import jax.numpy as jnp
    >>> policy = PrecisionPolicy(data_dtype=jnp.bfloat16)
    >>> backend = FusedBackend(precision=policy)
    >>> backend.name, backend.precision.accum_dtype is jnp.float32
    ('fused', True)
    """

    data_dtype: Any = None
    accum_dtype: Any = jnp.float32
    out_dtype: Any = None


@runtime_checkable
class Backend(Protocol):
    """What a GMM compute backend provides to GMMModel.local_optimum.

    Backends are selected by name, instance, or per run — all equivalent:

    >>> resolve(None).name                    # default
    'reference'
    >>> resolve("fused").name                 # by name
    'fused'
    >>> resolve(ReferenceBackend()).name      # instances pass through
    'reference'

    and plug in via ``GMMModel(..., backend=)`` or
    ``engine.run_vb(..., backend=)``.
    """

    name: str

    def supports(self, model) -> bool:
        """Capability check: can this backend run `model`'s hot path?

        `engine.vb_init` consults this before binding a backend to a model
        and falls back to the reference path (with a warning) when the
        answer is no — selecting the fused kernel for a non-GMM model must
        degrade gracefully, not crash inside the kernel."""
        ...

    def local_vbm_optimum_nodes(self, x, mask, phi_nodes,
                                prior: GMMPosterior, replication,
                                K: int, D: int) -> jnp.ndarray:
        """(N, Ni, D) data + (N, P) iterates -> (N, P) local optima phi*."""
        ...


@dataclasses.dataclass(frozen=True)
class ReferenceBackend:
    """core/gmm.py as-is: three einsum passes over the data per iteration."""

    name: str = dataclasses.field(default="reference", init=False)

    def supports(self, model) -> bool:
        """The reference path IS the model's own `local_optimum` — every
        conjugate-exponential adapter supports it by construction."""
        return True

    def local_vbm_optimum_nodes(self, x, mask, phi_nodes, prior,
                                replication, K, D):
        return gmm.local_vbm_optimum_nodes(x, phi_nodes, prior, replication,
                                           K, D, mask)


@functools.partial(
    jax.jit, static_argnames=("K", "D", "block_t", "data_dtype",
                              "accum_dtype", "out_dtype"))
def _fused_local_vbm(x, mask, phi_nodes, prior, replication, *, K, D,
                     block_t, data_dtype, accum_dtype, out_dtype):
    """data -> phi* in one jitted call (kernel + fused VBM post-stage)."""
    from repro.kernels import ops

    acc = accum_dtype
    out = out_dtype if out_dtype is not None else phi_nodes.dtype

    def terms(phi):
        q = expfam.unpack_natural(phi.astype(acc), K, D)
        return gmm.estep_terms(q, dtype=acc)

    log_prior, Wn, b, c = jax.vmap(terms)(phi_nodes)
    if data_dtype is not None:
        x = x.astype(data_dtype)
    mask = mask.astype(x.dtype)
    # replication scaling happens kernel-side (at statistics-emit time)
    _, R, sum_x, sum_xx = ops.gmm_estep_nodes(x, mask, log_prior, Wn, b, c,
                                              replication,
                                              block_t=block_t,
                                              return_r=False)

    # fused post-stage: Appendix-A VBM update + pack
    prior_acc = jax.tree_util.tree_map(lambda a: a.astype(acc), prior)

    def post(R_i, sx_i, sxx_i):
        stats = gmm.SuffStats(R=R_i.astype(acc), sum_x=sx_i.astype(acc),
                              sum_xx=sxx_i.astype(acc))
        return expfam.pack_natural(gmm.posterior_from_stats(stats, prior_acc))

    return jax.vmap(post)(R, sum_x, sum_xx).astype(out)


@dataclasses.dataclass(frozen=True)
class FusedBackend:
    """Single-pass Pallas VBE kernel + jitted VBM post-stage."""

    block_t: int = 512
    precision: PrecisionPolicy = PrecisionPolicy()
    name: str = dataclasses.field(default="fused", init=False)

    def supports(self, model) -> bool:
        """The Pallas kernel implements exactly the GMM E-step; models tag
        their hot-path family via a `kernel_family` class attribute."""
        return getattr(model, "kernel_family", None) == "gmm"

    def local_vbm_optimum_nodes(self, x, mask, phi_nodes, prior,
                                replication, K, D):
        p = self.precision
        return _fused_local_vbm(
            x, mask, phi_nodes, prior, replication, K=K, D=D,
            block_t=self.block_t, data_dtype=p.data_dtype,
            accum_dtype=p.accum_dtype, out_dtype=p.out_dtype)


_BY_NAME = {"reference": ReferenceBackend, "fused": FusedBackend}


def resolve(backend: str | Backend | None) -> Backend:
    """None -> reference; a name -> default instance; instances pass through."""
    if backend is None:
        return ReferenceBackend()
    if isinstance(backend, str):
        try:
            return _BY_NAME[backend]()
        except KeyError:
            raise ValueError(
                f"unknown backend {backend!r}; expected one of "
                f"{sorted(_BY_NAME)} or a Backend instance") from None
    if not isinstance(backend, Backend):
        raise TypeError(f"not a compute backend: {backend!r}")
    return backend
