"""Exponential-family machinery for the conjugate-exponential VB framework.

The paper (Hua & Li, Eq. 7-11) optimises variational posteriors directly in
the *natural-parameter space* of a conjugate-exponential model.  This module
implements that space for the two families the Bayesian GMM needs:

* Dirichlet over mixing coefficients       pi ~ Dir(alpha)
* Normal-Wishart over (mu_k, Lambda_k)     (mu, L) ~ NW(m, beta, W, nu)

plus the flat packing/unpacking used as the *message* exchanged between nodes
(Eq. 45): phi_theta = [phi_pi, phi_{mu_1,L_1}, ..., phi_{mu_K,L_K}].

Layout of the flat natural-parameter vector for K components in D dims::

    [ alpha-1 (K) | per-component blocks (K * (2 + D + D*D)) ]
    block_k = [ n1, n4, n3 (D), vec(n2) (D*D) ]
      n1 = (nu - D) / 2
      n2 = -1/2 W^{-1} - beta/2 m m^T        (symmetric, stored dense)
      n3 = beta m
      n4 = -beta / 2

All functions are pure jnp and vectorise over arbitrary leading axes of the
hyperparameter pytrees (we use a leading K axis, and algorithms add a leading
node axis on the flat vectors).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.scipy.special import digamma, gammaln, multigammaln


def enable_x64() -> None:
    """Faithful-layer entry points call this: the GMM VB recursions involve
    log-determinants and digammas of counts ~1e4; float64 keeps the KL metric
    (Eq. 46) trustworthy.  The framework layer never calls it."""
    jax.config.update("jax_enable_x64", True)


def ordered_sum(a: jnp.ndarray, chunk: int = 32) -> jnp.ndarray:
    """Sum over the leading (sample) axis, BIT-invariant to appended zero
    rows.

    XLA is free to re-tile a plain reduce (or a dot_general contraction)
    when the axis length changes, so `sum(x)` and `sum(pad(x, zeros))`
    can differ in the last ulp — which breaks the serving layer's
    padded-session == unpadded-solo bit-equality contract
    (serving/admission.py bucketing).  This formulation pins the
    association order by construction: pad to a multiple of `chunk`, sum
    each fixed-shape (chunk, ...) block, and fold the block sums with a
    SEQUENTIAL `lax.scan`.  Appending zero rows only appends all-zero
    blocks, and `acc + 0.0` is exact, so the result is bit-identical for
    any amount of trailing zero padding.

    >>> import jax.numpy as jnp
    >>> a = jnp.linspace(0.0, 1.0, 7)[:, None]
    >>> b = jnp.concatenate([a, jnp.zeros((90, 1))])
    >>> bool(jnp.all(ordered_sum(a) == ordered_sum(b)))
    True
    """
    T = a.shape[0]
    Tp = max(chunk, -(-T // chunk) * chunk)
    if Tp != T:
        a = jnp.pad(a, ((0, Tp - T),) + ((0, 0),) * (a.ndim - 1))
    blocks = a.reshape((Tp // chunk, chunk) + a.shape[1:])

    def fold(acc, blk):
        return acc + jnp.sum(blk, axis=0), None

    out, _ = jax.lax.scan(fold, jnp.zeros(a.shape[1:], a.dtype), blocks)
    return out


# ---------------------------------------------------------------------------
# Hyperparameter container for the GMM global posterior q(pi) prod_k q(mu,L)
# ---------------------------------------------------------------------------
class GMMPosterior(NamedTuple):
    """Hyperparameters of Dir(alpha) x prod_k NW(m, beta, W, nu)."""

    alpha: jnp.ndarray  # (K,)
    m: jnp.ndarray      # (K, D)
    beta: jnp.ndarray   # (K,)
    W: jnp.ndarray      # (K, D, D)  Wishart scale matrix
    nu: jnp.ndarray     # (K,)       Wishart dof

    @property
    def K(self) -> int:
        return self.alpha.shape[-1]

    @property
    def D(self) -> int:
        return self.m.shape[-1]


def noninformative_prior(K: int, D: int, *, alpha0: float = 1.0,
                         beta0: float = 1.0, nu0: float | None = None,
                         w0_scale: float = 1.0, m0: jnp.ndarray | None = None,
                         dtype=jnp.float64) -> GMMPosterior:
    """Broad conjugate prior (paper Sec. V: 'non-informative priors')."""
    if nu0 is None:
        nu0 = float(D)
    if m0 is None:
        m0 = jnp.zeros((D,), dtype)
    return GMMPosterior(
        alpha=jnp.full((K,), alpha0, dtype),
        m=jnp.broadcast_to(m0.astype(dtype), (K, D)),
        beta=jnp.full((K,), beta0, dtype),
        W=jnp.broadcast_to(jnp.eye(D, dtype=dtype) * w0_scale, (K, D, D)),
        nu=jnp.full((K,), nu0, dtype),
    )


class NWParams(NamedTuple):
    """Hyperparameters of a bank of K Normal-Wishart factors — the GMM
    posterior minus its Dirichlet part.  This is the hyper container of
    `blocks.NormalWishartBlock`; every nw_* function in this module is
    written against the (m, beta, W, nu) surface, so it accepts either an
    `NWParams` or a full `GMMPosterior`."""

    m: jnp.ndarray      # (K, D)
    beta: jnp.ndarray   # (K,)
    W: jnp.ndarray      # (K, D, D)
    nu: jnp.ndarray     # (K,)

    @property
    def K(self) -> int:
        return self.beta.shape[-1]

    @property
    def D(self) -> int:
        return self.m.shape[-1]


# ---------------------------------------------------------------------------
# Natural parameters <-> hyperparameters  (Eq. 45 + Appendix B)
# ---------------------------------------------------------------------------
def flat_dim(K: int, D: int) -> int:
    return K + K * (2 + D + D * D)


#: names of the natural-parameter blocks of the flat GMM message, in the
#: order of the `block_labels` ids: the Dirichlet block, then per-component
#: n1 (nu), n4 (beta), n3 (beta*m) and n2 (the W^-1 carrier).
BLOCK_NAMES = ("alpha", "nu", "beta", "mean", "winv")


def block_labels(K: int, D: int):
    """(P,) int32 block-type label per coordinate of the flat message.

    The flat natural-parameter vector mixes coordinates whose magnitudes
    differ by orders (alpha ~ counts, n2 ~ -W^-1/2): per-block views let
    the consensus layer compute residual norms and penalties per block
    instead of letting the big blocks drown the small ones
    (`engine.ADMMConsensus(per_block=True)`).  Labels index `BLOCK_NAMES`.
    Returned as a host (numpy) array: it is static packing structure, and
    consumers use it inside jit (block counts must stay concrete).
    """
    import numpy as np
    per = [1, 2] + [3] * D + [4] * (D * D)
    return np.asarray([0] * K + per * K, np.int32)


def nw_pack(q) -> jnp.ndarray:
    """Normal-Wishart bank -> its flat natural-parameter segment: the
    per-component [n1, n4, n3, vec(n2)] blocks of Eq. 45, flattened.
    Accepts an `NWParams` or a `GMMPosterior` (only m/beta/W/nu are read).
    """
    K, D = q.beta.shape[-1], q.m.shape[-1]
    n1 = (q.nu - D) / 2.0                                            # (K,)
    n4 = -q.beta / 2.0                                               # (K,)
    n3 = q.beta[:, None] * q.m                                       # (K, D)
    W_inv = jnp.linalg.inv(q.W)                                      # (K, D, D)
    mmT = q.m[:, :, None] * q.m[:, None, :]
    n2 = -0.5 * W_inv - 0.5 * q.beta[:, None, None] * mmT            # (K, D, D)
    blocks = jnp.concatenate(
        [n1[:, None], n4[:, None], n3, n2.reshape(K, D * D)], axis=-1)
    return blocks.reshape(-1)


def nw_unpack(seg: jnp.ndarray, K: int, D: int) -> NWParams:
    """Flat Normal-Wishart segment -> NWParams (inverse of `nw_pack`)."""
    blocks = seg.reshape(K, 2 + D + D * D)
    n1 = blocks[:, 0]
    n4 = blocks[:, 1]
    n3 = blocks[:, 2:2 + D]
    n2 = blocks[:, 2 + D:].reshape(K, D, D)
    beta = -2.0 * n4
    m = n3 / beta[:, None]
    nu = 2.0 * n1 + D
    mmT = m[:, :, None] * m[:, None, :]
    W_inv = -2.0 * n2 - beta[:, None, None] * mmT
    W = jnp.linalg.inv(W_inv)
    return NWParams(m=m, beta=beta, W=W, nu=nu)


def pack_natural(q: GMMPosterior) -> jnp.ndarray:
    """GMMPosterior -> flat natural-parameter message (Eq. 45)."""
    return jnp.concatenate([q.alpha - 1.0, nw_pack(q)])


def unpack_natural(phi: jnp.ndarray, K: int, D: int) -> GMMPosterior:
    """Flat natural-parameter message -> GMMPosterior (inverse of pack)."""
    alpha = phi[:K] + 1.0
    nw = nw_unpack(phi[K:], K, D)
    return GMMPosterior(alpha=alpha, m=nw.m, beta=nw.beta, W=nw.W, nu=nw.nu)


def nw_project(seg: jnp.ndarray, K: int, D: int, *,
               min_beta: float = 1e-6, min_eig: float = 1e-8) -> jnp.ndarray:
    """Projection of a flat Normal-Wishart segment onto its domain: clamps
    beta and nu and projects the W^{-1} carrier onto the PSD cone by
    eigenvalue clipping (the closest point in Frobenius norm)."""
    blocks = seg.reshape(K, 2 + D + D * D)
    n1 = blocks[:, 0]
    n4 = jnp.minimum(blocks[:, 1], -min_beta / 2.0)   # beta >= min_beta
    n3 = blocks[:, 2:2 + D]
    n2 = blocks[:, 2 + D:].reshape(K, D, D)
    beta = -2.0 * n4
    m = n3 / beta[:, None]
    nu = jnp.maximum(2.0 * n1 + D, (D - 1.0) + 1e-3)
    n1 = (nu - D) / 2.0
    mmT = m[:, :, None] * m[:, None, :]
    W_inv = -2.0 * n2 - beta[:, None, None] * mmT
    W_inv = 0.5 * (W_inv + jnp.swapaxes(W_inv, -1, -2))
    eigval, eigvec = jnp.linalg.eigh(W_inv)
    # relative floor: reconstruction error of eigh scales with ||W^-1||, so
    # an absolute 1e-8 floor would not survive the round trip at large norms
    floor = jnp.maximum(min_eig,
                        1e-10 * jnp.max(jnp.abs(eigval), -1, keepdims=True))
    eigval = jnp.maximum(eigval, floor)
    W_inv = jnp.einsum("kij,kj,klj->kil", eigvec, eigval, eigvec)
    n2 = -0.5 * W_inv - 0.5 * beta[:, None, None] * mmT
    blocks = jnp.concatenate(
        [n1[:, None], n4[:, None], n3, n2.reshape(K, D * D)], axis=-1)
    return blocks.reshape(-1)


def project_to_domain(phi: jnp.ndarray, K: int, D: int, *,
                      min_alpha: float = 1e-3, min_beta: float = 1e-6,
                      min_eig: float = 1e-8) -> jnp.ndarray:
    """Euclidean projection of a natural-parameter point onto (the interior
    of) the domain Omega (Eq. 38b).

    Omega requires alpha_k > 0, beta_k > 0, nu_k > D - 1 and W^{-1} > 0.
    The Dirichlet and Normal-Wishart segments project independently (the
    domain is a product set), so this is the concatenation of the two
    per-family projections — exactly how `blocks.BlockModel` composes them.
    """
    alpha = jnp.maximum(phi[:K] + 1.0, min_alpha)
    return jnp.concatenate([alpha - 1.0,
                            nw_project(phi[K:], K, D, min_beta=min_beta,
                                       min_eig=min_eig)])


def in_domain(phi: jnp.ndarray, K: int, D: int) -> jnp.ndarray:
    """Boolean: does phi lie in the natural-parameter domain Omega (Eq. 8)?"""
    q = unpack_natural(phi, K, D)
    W_inv = jnp.linalg.inv(q.W)  # round-trips the packed -2 n2 - beta mm^T
    # Use eigenvalues of the W^{-1} implied by the raw coordinates.
    blocks = phi[K:].reshape(K, 2 + D + D * D)
    n2 = blocks[:, 2 + D:].reshape(K, D, D)
    beta = -2.0 * blocks[:, 1]
    m = blocks[:, 2:2 + D] / beta[:, None]
    W_inv = -2.0 * n2 - beta[:, None, None] * (m[:, :, None] * m[:, None, :])
    eigs = jnp.linalg.eigvalsh(0.5 * (W_inv + jnp.swapaxes(W_inv, -1, -2)))
    ok = (
        jnp.all(q.alpha > 0)
        & jnp.all(q.beta > 0)
        & jnp.all(q.nu > q.D - 1)
        & jnp.all(eigs > 0)
    )
    return ok


# ---------------------------------------------------------------------------
# Log-partition functions A(phi) and expected sufficient statistics (Eq. 10a)
# ---------------------------------------------------------------------------
def dirichlet_log_partition(alpha: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(gammaln(alpha), -1) - gammaln(jnp.sum(alpha, -1))


def dirichlet_expected_log(alpha: jnp.ndarray) -> jnp.ndarray:
    """E[ln pi_k] = psi(alpha_k) - psi(sum alpha)."""
    return digamma(alpha) - digamma(jnp.sum(alpha, -1, keepdims=True))


def wishart_expected_logdet(W: jnp.ndarray, nu: jnp.ndarray) -> jnp.ndarray:
    """E[ln |Lambda|] for Lambda ~ W(W, nu)  (Appendix A)."""
    D = W.shape[-1]
    j = jnp.arange(1, D + 1, dtype=W.dtype)
    return (jnp.sum(digamma((nu[..., None] + 1.0 - j) / 2.0), -1)
            + D * jnp.log(2.0) + jnp.linalg.slogdet(W)[1])


def nw_log_partition(q: GMMPosterior) -> jnp.ndarray:
    """A(phi_k) for each Normal-Wishart component (Appendix B), shape (K,)."""
    D = q.D
    return (-D / 2.0 * jnp.log(q.beta)
            + q.nu / 2.0 * jnp.linalg.slogdet(q.W)[1]
            + q.nu * D / 2.0 * jnp.log(2.0)
            + multigammaln(q.nu / 2.0, D))


def nw_expected_stats(q: GMMPosterior):
    """E[u] = (E[ln|L|], E[L], E[L mu], E[mu^T L mu]) per component."""
    e_logdet = wishart_expected_logdet(q.W, q.nu)                  # (K,)
    e_L = q.nu[:, None, None] * q.W                                # (K, D, D)
    e_Lmu = jnp.einsum("kij,kj->ki", e_L, q.m)                     # (K, D)
    e_quad = q.D / q.beta + jnp.einsum("ki,kij,kj->k", q.m, e_L, q.m)
    return e_logdet, e_L, e_Lmu, e_quad


def gmm_log_partition(q: GMMPosterior) -> jnp.ndarray:
    """A(phi) of the joint Dir x prod NW global distribution (scalar)."""
    return dirichlet_log_partition(q.alpha) + jnp.sum(nw_log_partition(q))


def nw_expected_stats_flat(q) -> jnp.ndarray:
    """E[u] of the Normal-Wishart bank laid out exactly like `nw_pack`:
    per-component [E ln|L|, E mu'L mu, E L mu, vec(E L)], flattened."""
    K, D = q.beta.shape[-1], q.m.shape[-1]
    e_logdet, e_L, e_Lmu, e_quad = nw_expected_stats(q)
    blocks = jnp.concatenate(
        [e_logdet[:, None], e_quad[:, None], e_Lmu, e_L.reshape(K, D * D)],
        axis=-1)
    return blocks.reshape(-1)


def expected_sufficient_stats(q: GMMPosterior) -> jnp.ndarray:
    """grad_phi A(phi) laid out exactly like the flat packing.

    By Eq. 10a this is E[u(z)]; verified against jax.grad of the packed
    log-partition in the test-suite (a strong invariant of the packing).
    """
    e_logpi = dirichlet_expected_log(q.alpha)                      # (K,)
    return jnp.concatenate([e_logpi, nw_expected_stats_flat(q)])


# ---------------------------------------------------------------------------
# KL divergences (Appendix B) -- the paper's performance metric (Eq. 46)
# ---------------------------------------------------------------------------
def dirichlet_kl(alpha: jnp.ndarray, alpha_hat: jnp.ndarray) -> jnp.ndarray:
    e_logpi = dirichlet_expected_log(alpha)
    return (jnp.sum((alpha - alpha_hat) * e_logpi)
            - dirichlet_log_partition(alpha)
            + dirichlet_log_partition(alpha_hat))


def nw_kl(q: GMMPosterior, p: GMMPosterior) -> jnp.ndarray:
    """sum_k KL(NW(q_k) || NW(p_k)) via the exp-family identity
    KL = (phi_q - phi_p)^T E_q[u] - A(phi_q) + A(phi_p)."""
    def nat(qq: GMMPosterior):
        n1 = (qq.nu - qq.D) / 2.0
        W_inv = jnp.linalg.inv(qq.W)
        mmT = qq.m[:, :, None] * qq.m[:, None, :]
        n2 = -0.5 * W_inv - 0.5 * qq.beta[:, None, None] * mmT
        n3 = qq.beta[:, None] * qq.m
        n4 = -qq.beta / 2.0
        return n1, n2, n3, n4

    q1, q2, q3, q4 = nat(q)
    p1, p2, p3, p4 = nat(p)
    e_logdet, e_L, e_Lmu, e_quad = nw_expected_stats(q)
    inner = ((q1 - p1) * e_logdet
             + jnp.einsum("kij,kij->k", q2 - p2, e_L)
             + jnp.einsum("ki,ki->k", q3 - p3, e_Lmu)
             + (q4 - p4) * e_quad)
    return jnp.sum(inner - nw_log_partition(q) + nw_log_partition(p))


def gmm_kl(q: GMMPosterior, p: GMMPosterior) -> jnp.ndarray:
    """d(phi, phi_hat) of Eq. 46: KL(Q(theta|phi) || P(theta|phi_hat))."""
    return dirichlet_kl(q.alpha, p.alpha) + nw_kl(q, p)


def gmm_kl_flat(phi: jnp.ndarray, phi_hat: jnp.ndarray, K: int, D: int):
    return gmm_kl(unpack_natural(phi, K, D), unpack_natural(phi_hat, K, D))
