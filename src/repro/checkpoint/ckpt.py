"""Minimal pytree checkpointing (orbax is unavailable offline).

Flattens a pytree by key-path into a compressed .npz plus a tiny structure
manifest; restores exactly (dtypes preserved, bf16 via uint16 view).
Atomic write (tmp + rename) so a crashed save never corrupts the latest
checkpoint.  Step-numbered files with `latest_step` discovery.
"""
from __future__ import annotations

import json
import os
import re

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = "bfloat16"


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, tree, step: int | None = None) -> str:
    if step is not None:
        path = os.path.join(path, f"ckpt_{step:08d}.npz")
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    arrays, meta = {}, {}
    for i, (key, arr) in enumerate(sorted(flat.items())):
        name = f"a{i}"
        if arr.dtype == jnp.bfloat16:
            arrays[name] = arr.view(np.uint16)
            meta[key] = {"name": name, "dtype": _BF16}
        else:
            arrays[name] = arr
            meta[key] = {"name": name, "dtype": str(arr.dtype)}
    tmp = path + ".tmp"
    np.savez_compressed(tmp, __meta__=np.frombuffer(
        json.dumps(meta).encode(), np.uint8), **arrays)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)
    return path


def restore(path: str, tree_like, step: int | None = None):
    """Restore into the structure of `tree_like` (shapes must match)."""
    if step is not None:
        path = os.path.join(path, f"ckpt_{step:08d}.npz")
    with np.load(path) as z:
        meta = json.loads(bytes(z["__meta__"]).decode())
        flat = {}
        for key, info in meta.items():
            arr = z[info["name"]]
            if info["dtype"] == _BF16:
                arr = arr.view(jnp.bfloat16)
            flat[key] = arr

    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path_k, leaf in paths:
        key = jax.tree_util.keystr(path_k)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None
