"""Shared neural layers for the assigned-architecture zoo.

Pure functions over explicit parameter pytrees (dicts of jnp arrays).  All
matmul-bearing ops accept a `compute_dtype`; accumulation-sensitive math
(softmax, norms, rotary, recurrences) runs in float32.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings — full / half (chatglm "RoPE 2d") / M-RoPE
# ---------------------------------------------------------------------------
def _rope_angles(positions: jnp.ndarray, dim: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, dim/2)."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def _rotate(x, cos, sin):
    """x (..., S, H, dim) rotated pairwise-interleaved-free (GPT-NeoX style:
    split halves)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    cos = cos[..., None, :]   # broadcast over heads: (..., S, 1, d2)
    sin = sin[..., None, :]
    xf1 = x1.astype(jnp.float32)
    xf2 = x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1)
    return out.astype(x.dtype)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, cfg: ModelConfig):
    """x (B, S, H, hd); positions (B, S) or (3, B, S) for mrope."""
    hd = x.shape[-1]
    if cfg.rope_style == "half":
        # chatglm: rotary over the first half of head dims, rest untouched
        d_rot = hd // 2
        cos, sin = _rope_angles(positions, d_rot, cfg.rope_theta)
        return jnp.concatenate(
            [_rotate(x[..., :d_rot], cos, sin), x[..., d_rot:]], -1)
    if cfg.rope_style == "mrope":
        # qwen2-vl: the hd/2 frequency slots are split into (t, h, w)
        # sections, each driven by its own position-id stream.
        sections = cfg.mrope_sections or (hd // 4, hd // 8, hd // 8)
        assert sum(sections) == hd // 2, (sections, hd)
        cos_parts, sin_parts = [], []
        for sec_idx in range(3):
            cos, sin = _rope_angles(positions[sec_idx], hd, cfg.rope_theta)
            cos_parts.append(cos)
            sin_parts.append(sin)
        # select section slices from each stream (static python offsets)
        splits = [0]
        for s in sections:
            splits.append(splits[-1] + int(s))
        sel_cos = jnp.concatenate(
            [cos_parts[i][..., splits[i]:splits[i + 1]] for i in range(3)], -1)
        sel_sin = jnp.concatenate(
            [sin_parts[i][..., splits[i]:splits[i + 1]] for i in range(3)], -1)
        return _rotate(x, sel_cos, sel_sin)
    cos, sin = _rope_angles(positions, hd, cfg.rope_theta)
    return _rotate(x, cos, sin)


def default_positions(cfg: ModelConfig, batch: int, seq: int,
                      offset: jnp.ndarray | int = 0) -> jnp.ndarray:
    pos = jnp.arange(seq)[None, :] + offset
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.rope_style == "mrope":
        return jnp.broadcast_to(pos[None], (3, batch, seq))
    return pos


# ---------------------------------------------------------------------------
# Attention (GQA; full-causal, sliding-window, and cached-decode variants)
# ---------------------------------------------------------------------------
def attn_params(key, cfg: ModelConfig, dtype):
    hd = cfg.hd
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (cfg.d_model, cfg.n_heads * hd), 0, dtype),
        "wk": dense_init(kk, (cfg.d_model, cfg.n_kv_heads * hd), 0, dtype),
        "wv": dense_init(kv, (cfg.d_model, cfg.n_kv_heads * hd), 0, dtype),
        "wo": dense_init(ko, (cfg.n_heads * hd, cfg.d_model), 0, dtype),
    }


def _qkv(x, p, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    hd = cfg.hd
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg)
    k = apply_rope(k, positions, cfg)
    return q, k, v


def sdpa(q, k, v, mask, scale):
    """q (B,Sq,Hkv,G,hd), k/v (B,Skv,Hkv,hd), mask (B,1,1,Sq,Skv) add-mask."""
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = logits + mask
    w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", w, v)


def causal_mask(seq: int, window: int = 0, dtype=jnp.float32):
    i = jnp.arange(seq)[:, None]
    j = jnp.arange(seq)[None, :]
    ok = j <= i
    if window > 0:
        ok &= j > i - window
    return jnp.where(ok, 0.0, -1e30).astype(dtype)[None, None, None]


CHUNKED_ATTN_THRESHOLD = 2048
ATTN_Q_CHUNK = 1024


def chunked_sdpa(q, k, v, scale, *, window: int = 0,
                 q_chunk: int = ATTN_Q_CHUNK, windowed_kv: bool = False):
    """Memory-bounded attention: scan over query chunks with full K/V.

    The scan is UNROLLED so the lowered HLO contains every chunk's einsums —
    XLA's cost analysis (and therefore the dry-run roofline) counts the true
    attention FLOPs, and peak memory is O(q_chunk * S) logits instead of
    O(S^2).  This is the XLA-level analogue of the Pallas flash kernel
    (kernels/flash_attention.py), used on the non-kernel path.

    windowed_kv (sliding-window archs only): each chunk attends to a
    dynamic_slice of window + q_chunk keys ending at its last row, turning
    the per-chunk work from O(q_chunk * S) into O(q_chunk * window).
    """
    B, S, Hkv, G, hd = q.shape
    q_chunk = min(q_chunk, S)
    nq = S // q_chunk
    assert nq * q_chunk == S, (S, q_chunk)
    qc = q.reshape(B, nq, q_chunk, Hkv, G, hd)
    qc = jnp.moveaxis(qc, 1, 0)                       # (nq, B, bq, Hkv, G, hd)
    use_slice = windowed_kv and window > 0 and window + q_chunk < S
    kv_len = window + q_chunk if use_slice else S

    def one(carry, inp):
        ci, qb = inp
        i = ci * q_chunk + jnp.arange(q_chunk)[:, None]       # abs q rows
        if use_slice:
            start = jnp.clip(ci * q_chunk + q_chunk - kv_len, 0, S - kv_len)
            kb = jax.lax.dynamic_slice(k, (0, start, 0, 0),
                                       (B, kv_len, Hkv, hd))
            vb = jax.lax.dynamic_slice(v, (0, start, 0, 0),
                                       (B, kv_len, Hkv, hd))
            j = start + jnp.arange(kv_len)[None, :]           # abs key cols
        else:
            kb, vb = k, v
            j = jnp.arange(S)[None, :]
        ok = j <= i
        if window > 0:
            ok &= j > i - window
        mask = jnp.where(ok, 0.0, -1e30)[None, None, None].astype(jnp.float32)
        return carry, sdpa(qb, kb, vb, mask, scale)

    _, out = jax.lax.scan(one, 0, (jnp.arange(nq), qc), unroll=True)
    return jnp.moveaxis(out, 0, 1).reshape(B, S, Hkv, G, hd)


def attention_block(x, p, cfg: ModelConfig, positions, *, window: int = 0):
    """Training/prefill attention.  Returns (out (B,S,d), k, v for caching)."""
    B, S, _ = x.shape
    q, k, v = _qkv(x, p, cfg, positions)
    if cfg.attn_flat_heads:
        # broadcast KV so the (flat) head axis shards over "model" cleanly
        g = cfg.n_heads // cfg.n_kv_heads
        kq = jnp.repeat(k, g, axis=2)
        vq = jnp.repeat(v, g, axis=2)
        qg = q.reshape(B, S, cfg.n_heads, 1, cfg.hd)
    else:
        g = cfg.n_heads // cfg.n_kv_heads
        kq, vq = k, v
        qg = q.reshape(B, S, cfg.n_kv_heads, g, cfg.hd)
    scale = 1.0 / math.sqrt(cfg.hd)
    if S > CHUNKED_ATTN_THRESHOLD:
        out = chunked_sdpa(qg, kq, vq, scale, window=window,
                           q_chunk=cfg.attn_q_chunk,
                           windowed_kv=cfg.windowed_kv)
    else:
        mask = causal_mask(S, window, jnp.float32)
        out = sdpa(qg, kq, vq, mask, scale)
    out = out.reshape(B, S, cfg.n_heads * cfg.hd) @ p["wo"]
    return out, k, v


def attention_decode(x, p, cfg: ModelConfig, cache_k, cache_v, pos, *,
                     window: int = 0):
    """Single-token decode.  cache_k/v (B, Sc, Hkv, hd); pos scalar int32.

    Full-attention archs use Sc = seq_len; sliding-window archs use a ring
    buffer Sc = window (keys RoPE'd at absolute positions before writing).
    Returns (out (B,1,d), new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    Sc = cache_k.shape[1]
    positions = default_positions(cfg, B, 1, pos)
    q, k, v = _qkv(x, p, cfg, positions)
    slot = pos % Sc if window > 0 else pos
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0))
    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, 1, cfg.n_kv_heads, g, cfg.hd)
    from repro.dist import compat
    model_ax = compat.auto_axis_sizes().get("model", 1)
    if model_ax > 1 and cfg.n_kv_heads % model_ax != 0:
        # kv heads not model-shardable -> the cache is head_dim-sharded
        # (engine.cache_shardings); align q's hd axis with it so the QK^T
        # contraction partial-sums small logits instead of all-gathering
        # the 100s-of-MiB cache
        from repro.dist.sharding import constrain_last_dim_model
        qg = constrain_last_dim_model(qg)
    idx = jnp.arange(Sc)
    if window > 0:
        valid = idx <= pos  # ring buffer: slots written so far (all, once warm)
    else:
        valid = idx <= pos
    mask = jnp.where(valid, 0.0, -1e30).astype(jnp.float32)[None, None, None,
                                                            None, :]
    out = sdpa(qg, cache_k.astype(q.dtype), cache_v.astype(q.dtype), mask,
               1.0 / math.sqrt(cfg.hd))
    out = out.reshape(B, 1, cfg.n_heads * cfg.hd) @ p["wo"]
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------
def mlp_params(key, cfg: ModelConfig, dtype, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, (cfg.d_model, d_ff), 0, dtype),
        "wg": dense_init(k2, (cfg.d_model, d_ff), 0, dtype),
        "wo": dense_init(k3, (d_ff, cfg.d_model), 0, dtype),
    }


def mlp_block(x, p):
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def _vocab_rows(cfg: ModelConfig) -> int:
    return max(cfg.vocab_pad, cfg.vocab_size)


def embed_params(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    V = _vocab_rows(cfg)
    p = {"tok": (jax.random.normal(k1, (V, cfg.d_model)) *
                 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k2, (cfg.d_model, V), 0, dtype)
    if cfg.frontend != "none":
        # projector from the (stubbed) modality encoder's output space
        k3 = jax.random.fold_in(k2, 7)
        p["frontend_proj"] = dense_init(
            k3, (cfg.d_model, cfg.d_model), 0, dtype)
    return p


def embed(tokens, p, cfg: ModelConfig, frontend_embeds=None):
    """tokens (B, S) int32.  For vlm/audio archs, the first `frontend_len`
    positions take (projected) stub embeddings instead of token embeddings."""
    x = p["tok"][tokens]
    if frontend_embeds is not None and cfg.frontend_len > 0:
        fe = frontend_embeds.astype(x.dtype) @ p["frontend_proj"]
        x = jnp.concatenate([fe, x[:, cfg.frontend_len:]], axis=1)
    return x


def unembed(x, p, cfg: ModelConfig):
    w = p["tok"].T if cfg.tie_embeddings else p["unembed"]
    logits = (x @ w).astype(jnp.float32)
    if _vocab_rows(cfg) > cfg.vocab_size:
        pad_mask = jnp.arange(logits.shape[-1]) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits
