"""PPCAModel: Bayesian probabilistic PCA / factor analysis over the block
layer.

Fourth member of the conjugate-exponential family — the distributed-VB
stress model of the D-MFVI line of work (Babagholami-Mohamadabadi et al.):
each sensor observes T iid D-dimensional points generated from a shared
Q-dimensional latent subspace,

    z_j ~ N(0, I_Q),
    x_jd | z_j ~ N(w_d^T z_j, lambda_d^{-1}),   d = 1..D

with the fully conjugate per-row Normal-Gamma prior lambda_d ~ Gamma,
w_d | lambda_d ~ N(m0, (lambda_d V0)^{-1}).  The global posterior over the
loading matrix is a BANK of D independent Normal-Gamma rows — exactly
`blocks.NormalGammaBlock(Q, rows=D)`, the same family as Bayesian linear
regression with the latent coordinates z as the (inferred) design matrix.
The adapter is a one-block `blocks.BlockModel`; the hyper container is a
`linreg.NGPosterior` with a leading rows axis.

VBE step (per node): with the current loading posterior, each point's
latent factor is Gaussian with shared covariance

    Sigma_z = (I_Q + sum_d E[lambda_d w_d w_d^T])^{-1},
    mu_j    = Sigma_z sum_d E[lambda_d w_d] x_jd,

VBM optimum (per row d): the Bayesian-linreg update of core/linreg.py with
the replicated latent statistics Szz = sum_j w_j (Sigma_z + mu_j mu_j^T),
Szx_d = sum_j w_j mu_j x_jd, Sxx_d = sum_j w_j x_jd^2, n = sum_j w_j —
Eqs. 17a/18 once more.  The flat natural parameters are LINEAR in these
statistics (the linreg algebra), and the statistics are linear in the
mask, so streaming minibatches and the SVRG control variate stay exactly
unbiased, and `expfam.ordered_sum` reductions keep bucketed-admission
padding bit-invisible.

Data convention: the protocol default `(x (N, T, D), mask (N, T))`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blocks, expfam, linreg
from repro.core.linreg import NGPosterior


def prior(D: int, Q: int, *, a0: float = 1.0, b0: float = 1.0,
          v0: float = 1e-2, dtype=jnp.float64) -> NGPosterior:
    """Row-stacked broad Normal-Gamma prior over the (D, Q) loading matrix."""
    one = linreg.prior(Q, a0=a0, b0=b0, v0=v0, dtype=dtype)
    return NGPosterior(
        m=jnp.broadcast_to(one.m, (D, Q)),
        V=jnp.broadcast_to(one.V, (D, Q, Q)),
        a=jnp.broadcast_to(one.a, (D,)),
        b=jnp.broadcast_to(one.b, (D,)))


def latent_posterior(x: jnp.ndarray, q: NGPosterior):
    """VBE step on one node: (T, D) points + rows posterior ->
    (Sigma_z (Q, Q), mu (T, Q)) of the per-point latent factors."""
    Q = q.m.shape[-1]
    e_lam = q.a / q.b                                              # (D,)
    V_inv = jnp.linalg.inv(q.V)                                    # (D, Q, Q)
    e_lww = V_inv + e_lam[:, None, None] * (
        q.m[:, :, None] * q.m[:, None, :])                         # (D, Q, Q)
    sigma_inv = jnp.eye(Q, dtype=x.dtype) + jnp.sum(e_lww, axis=0)
    sigma = jnp.linalg.inv(sigma_inv)                              # (Q, Q)
    A = e_lam[:, None] * q.m                                       # (D, Q)
    mu = (x @ A) @ sigma.T                                         # (T, Q)
    return sigma, mu


class PPCAModel(blocks.BlockModel):
    """Bank-of-Normal-Gamma-rows factor analysis (Bayesian PPCA)."""

    def __init__(self, prior: NGPosterior, D: int | None = None,
                 Q: int | None = None):
        self.prior = prior
        self.D = D if D is not None else prior.m.shape[0]
        self.Q = Q if Q is not None else prior.m.shape[-1]
        self.blocks = (blocks.NormalGammaBlock(self.Q, rows=self.D),)

    def split_hyper(self, q: NGPosterior) -> tuple:
        return (q,)

    def join_hyper(self, parts: tuple) -> NGPosterior:
        return parts[0]

    def local_optimum(self, data, phi_nodes, replication):
        x, mask = data
        return jax.vmap(lambda xi, mi, phii: self._local_one(
            xi, mi, phii, replication))(x, mask, phi_nodes)

    def _local_one(self, x, w, phi, replication):
        """One node: (T, D) points + (T,) scaled mask -> phi* (P,)."""
        q = self.unpack(phi)
        sigma, mu = latent_posterior(x, q)

        # replicated latent statistics; sample-axis reductions through
        # expfam.ordered_sum (padding bit-invisibility, cf. linreg)
        p0 = self.prior
        wx = x * w[:, None]                                        # (T, D)
        muw = mu * w[:, None]                                      # (T, Q)
        n = expfam.ordered_sum(w[:, None])[0] * replication
        Szz = (expfam.ordered_sum(muw[:, :, None] * mu[:, None, :])
               * replication + n * sigma)                          # (Q, Q)
        Szx = expfam.ordered_sum(
            wx[:, :, None] * mu[:, None, :]) * replication         # (D, Q)
        Sxx = expfam.ordered_sum(wx * x) * replication             # (D,)

        def row(V0, m0, a0, b0, szx, sxx):
            V = V0 + Szz
            m = jnp.linalg.solve(V, V0 @ m0 + szx)
            a = a0 + n / 2.0
            b = b0 + 0.5 * (sxx + m0 @ V0 @ m0 - m @ V @ m)
            return NGPosterior(m=m, V=V, a=a, b=b)

        q_new = jax.vmap(row)(p0.V, p0.m, p0.a, p0.b, Szx, Sxx)
        return self.pack(q_new)


def perturbed_init(prior: NGPosterior, key, scale: float = 0.1) -> NGPosterior:
    """Random-restart initialisation: the prior with the loading-row means
    jittered (cf. hmm.perturbed_init).  The zero-mean prior is a fixed
    point of the VB iteration — m = 0 makes every latent mean 0, which
    keeps m = 0 — so runs must start off it."""
    m = prior.m + scale * jax.random.normal(key, prior.m.shape,
                                            prior.m.dtype)
    return prior._replace(m=m)


# ---------------------------------------------------------------------------
# Synthetic sensor subspace data (examples + tests)
# ---------------------------------------------------------------------------
def sample_sensors(n_nodes: int, n_per_node: int, *, D: int = 6, Q: int = 2,
                   seed: int = 0, noise: float = 0.1, dtype=np.float64):
    """Ground-truth PPCA data: one shared (D, Q) loading matrix, iid latent
    factors per point, per-dimension noise 1/lambda = noise^2.  Returns
    (x (N, T, D), mask (N, T), W_true (D, Q))."""
    rng = np.random.default_rng(seed)
    W_true = rng.normal(size=(D, Q)) / np.sqrt(Q)
    z = rng.normal(size=(n_nodes, n_per_node, Q))
    x = z @ W_true.T + noise * rng.normal(size=(n_nodes, n_per_node, D))
    return (x.astype(dtype), np.ones((n_nodes, n_per_node), dtype),
            W_true.astype(dtype))
