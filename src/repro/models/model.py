"""Unified model assembly for all assigned architectures.

Three block kinds, resolved per layer from `cfg.layer_kinds()`:
  attn — pre-norm attention (full-causal or sliding-window) + MLP or MoE
  rec  — Griffin RG-LRU recurrent block + MLP
  ssm  — Mamba-2 SSD block (single-norm residual, no MLP; d_ff == 0)

Homogeneous stacks (every dense/moe/ssm arch) use weight-stacked
`jax.lax.scan` over layers — keeps the lowered HLO size O(1) in depth, which
matters for the 40-pair dry-run compile budget.  Mixed-pattern archs
(recurrentgemma) unroll a python loop over a params list.

Public entry points:
  init_params(cfg, key)
  forward(cfg, params, tokens, frontend_embeds=None, collect_cache=False)
  init_cache(cfg, batch, cache_len, dtype)
  decode_step(cfg, params, token, cache, pos)
  param_count(cfg, active_only=False)
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers, mamba2, moe, rglru


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------
def _layer_params(key, cfg: ModelConfig, kind: str, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind == "attn":
        p = {"norm1": jnp.zeros((cfg.d_model,), dtype),
             "attn": layers.attn_params(k1, cfg, dtype),
             "norm2": jnp.zeros((cfg.d_model,), dtype)}
        if cfg.is_moe:
            p["moe"] = moe.moe_params(k2, cfg, dtype)
        else:
            p["mlp"] = layers.mlp_params(k2, cfg, dtype)
        return p
    if kind == "rec":
        return {"norm1": jnp.zeros((cfg.d_model,), dtype),
                "rec": rglru.rec_params(k1, cfg, dtype),
                "norm2": jnp.zeros((cfg.d_model,), dtype),
                "mlp": layers.mlp_params(k2, cfg, dtype)}
    if kind == "ssm":
        return {"norm": jnp.zeros((cfg.d_model,), dtype),
                "ssm": mamba2.ssm_params(k1, cfg, dtype)}
    raise ValueError(kind)


def _homogeneous(cfg: ModelConfig) -> bool:
    kinds = cfg.layer_kinds()
    return cfg.scan_layers and all(k == kinds[0] for k in kinds)


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = layers.dtype_of(cfg.param_dtype)
    ke, kl = jax.random.split(key)
    params: dict[str, Any] = {"embed": layers.embed_params(ke, cfg, dtype),
                              "final_norm": jnp.zeros((cfg.d_model,), dtype)}
    kinds = cfg.layer_kinds()
    if _homogeneous(cfg):
        lkeys = jax.random.split(kl, cfg.n_layers)
        params["blocks"] = jax.vmap(
            lambda k: _layer_params(k, cfg, kinds[0], dtype))(lkeys)
    else:
        lkeys = jax.random.split(kl, cfg.n_layers)
        params["blocks"] = [
            _layer_params(lkeys[i], cfg, kinds[i], dtype)
            for i in range(cfg.n_layers)]
    return params


# ---------------------------------------------------------------------------
# Per-layer forward (training / prefill)
# ---------------------------------------------------------------------------
def _layer_fwd(x, p, cfg: ModelConfig, kind: str, positions, *,
               collect_cache: bool, use_kernels: bool):
    """Returns (x, aux_loss, cache_entry)."""
    # re-assert batch sharding at every layer boundary: without this GSPMD
    # drifts to batch-replicated layouts inside the unrolled attention
    # chunk loop (observed: 64 GiB collective-permutes of global-batch
    # cotangents on yi-6b train_4k — see EXPERIMENTS.md §Perf)
    from repro.dist.sharding import constrain_batch_dim
    x = constrain_batch_dim(x)
    if kind == "attn":
        h = layers.rms_norm(x, p["norm1"], cfg.norm_eps)
        if use_kernels:
            from repro.models import kernel_adapters
            a, k, v = kernel_adapters.flash_attention_block(
                h, p["attn"], cfg, positions, window=cfg.window)
        else:
            a, k, v = layers.attention_block(
                h, p["attn"], cfg, positions, window=cfg.window)
        x = x + a
        h2 = layers.rms_norm(x, p["norm2"], cfg.norm_eps)
        if cfg.is_moe:
            f, aux = moe.moe_block(h2, p["moe"], cfg)
        else:
            f, aux = layers.mlp_block(h2, p["mlp"]), 0.0
        x = x + f
        cache = _attn_cache_entry(cfg, k, v) if collect_cache else None
        return x, aux, cache
    if kind == "rec":
        h = layers.rms_norm(x, p["norm1"], cfg.norm_eps)
        if collect_cache:
            r, state = rglru.rec_block(h, p["rec"], cfg, return_state=True)
        else:
            r, state = rglru.rec_block(h, p["rec"], cfg), None
        x = x + r
        h2 = layers.rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + layers.mlp_block(h2, p["mlp"])
        return x, 0.0, state
    if kind == "ssm":
        h = layers.rms_norm(x, p["norm"], cfg.norm_eps)
        if collect_cache:
            s, state = mamba2.ssm_block(h, p["ssm"], cfg, return_state=True,
                                        use_kernel=use_kernels)
        else:
            s, state = mamba2.ssm_block(h, p["ssm"], cfg,
                                        use_kernel=use_kernels), None
        x = x + s
        return x, 0.0, state
    raise ValueError(kind)


def _attn_cache_entry(cfg: ModelConfig, k, v):
    """Trim prefill K/V to the ring-buffer window for sliding-window archs."""
    if cfg.window > 0 and k.shape[1] > cfg.window:
        k, v = k[:, -cfg.window:], v[:, -cfg.window:]
    return (k, v)


# ---------------------------------------------------------------------------
# Whole-model forward
# ---------------------------------------------------------------------------
def forward(cfg: ModelConfig, params, tokens, frontend_embeds=None, *,
            collect_cache: bool = False, use_kernels: bool = False):
    """tokens (B, S) -> dict(logits (B,S,V) f32, aux_loss, cache?)."""
    from repro.dist.sharding import constrain_batch_dim
    B, S = tokens.shape
    x = layers.embed(tokens, params["embed"], cfg, frontend_embeds)
    x = constrain_batch_dim(x.astype(layers.dtype_of(cfg.compute_dtype)))
    positions = layers.default_positions(cfg, B, S)
    kinds = cfg.layer_kinds()

    if _homogeneous(cfg):
        kind = kinds[0]

        def body(x, p):
            x, aux, cache = _layer_fwd(
                x, p, cfg, kind, positions,
                collect_cache=collect_cache, use_kernels=use_kernels)
            return x, (aux, cache)

        if cfg.remat:
            body = jax.checkpoint(body)
        x, (auxs, caches) = jax.lax.scan(body, x, params["blocks"])
        aux_loss = jnp.sum(jnp.asarray(auxs))
        cache = caches  # stacked (n_layers, ...) pytree or None
    else:
        aux_loss = 0.0
        cache = []
        for i, p in enumerate(params["blocks"]):
            fwd = functools.partial(
                _layer_fwd, cfg=cfg, kind=kinds[i], positions=positions,
                collect_cache=collect_cache, use_kernels=use_kernels)
            if cfg.remat:
                fwd = jax.checkpoint(fwd)
            x, aux, c = fwd(x, p)
            aux_loss = aux_loss + aux
            cache.append(c)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = constrain_batch_dim(layers.unembed(x, params["embed"], cfg))
    out = {"logits": logits, "aux_loss": aux_loss}
    if collect_cache:
        out["cache"] = cache
    return out


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def _cache_len(cfg: ModelConfig, seq_len: int) -> int:
    return min(seq_len, cfg.window) if cfg.window > 0 else seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16):
    """Empty decode cache for generation from scratch (no prefill)."""
    kinds = cfg.layer_kinds()
    sc = _cache_len(cfg, seq_len)

    def entry(kind):
        if kind == "attn":
            shp = (batch, sc, cfg.n_kv_heads, cfg.hd)
            return (jnp.zeros(shp, dtype), jnp.zeros(shp, dtype))
        if kind == "rec":
            w = rglru._lru_width(cfg)
            return (jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
                    jnp.zeros((batch, w), jnp.float32))
        if kind == "ssm":
            d_in, H, N = mamba2._dims(cfg)
            return (jnp.zeros((batch, cfg.conv_width - 1, d_in + 2 * N),
                              dtype),
                    jnp.zeros((batch, H, cfg.ssm_head_dim, N), jnp.float32))
        raise ValueError(kind)

    if _homogeneous(cfg):
        one = entry(kinds[0])
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape),
            one)
    return [entry(k) for k in kinds]


def _layer_decode(x, p, cfg: ModelConfig, kind: str, cache_entry, pos):
    if kind == "attn":
        h = layers.rms_norm(x, p["norm1"], cfg.norm_eps)
        ck, cv = cache_entry
        a, ck, cv = layers.attention_decode(h, p["attn"], cfg, ck, cv, pos,
                                            window=cfg.window)
        x = x + a
        h2 = layers.rms_norm(x, p["norm2"], cfg.norm_eps)
        if cfg.is_moe:
            f, _ = moe.moe_block(h2, p["moe"], cfg)
        else:
            f = layers.mlp_block(h2, p["mlp"])
        return x + f, (ck, cv)
    if kind == "rec":
        h = layers.rms_norm(x, p["norm1"], cfg.norm_eps)
        r, state = rglru.rec_decode_step(h, p["rec"], cfg, cache_entry)
        x = x + r
        h2 = layers.rms_norm(x, p["norm2"], cfg.norm_eps)
        return x + layers.mlp_block(h2, p["mlp"]), state
    if kind == "ssm":
        h = layers.rms_norm(x, p["norm"], cfg.norm_eps)
        s, state = mamba2.ssm_decode_step(h, p["ssm"], cfg, cache_entry)
        return x + s, state
    raise ValueError(kind)


def decode_step(cfg: ModelConfig, params, token, cache, pos):
    """token (B, 1) int32, pos scalar int32 -> (logits (B,1,V), new cache)."""
    x = params["embed"]["tok"][token].astype(
        layers.dtype_of(cfg.compute_dtype))
    kinds = cfg.layer_kinds()
    if _homogeneous(cfg):
        kind = kinds[0]

        def body(x, pc):
            p, c = pc
            x, c = _layer_decode(x, p, cfg, kind, c, pos)
            return x, c

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    else:
        new_cache = []
        for i, p in enumerate(params["blocks"]):
            x, c = _layer_decode(x, p, cfg, kinds[i], cache[i], pos)
            new_cache.append(c)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.unembed(x, params["embed"], cfg)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Analytic parameter counts (for 6ND roofline model-FLOPs)
# ---------------------------------------------------------------------------
def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    d, hd = cfg.d_model, cfg.hd
    total = cfg.vocab_size * d
    if not cfg.tie_embeddings:
        total += d * cfg.vocab_size
    if cfg.frontend != "none":
        total += d * d
    for kind in cfg.layer_kinds():
        if kind == "attn":
            total += d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd
            total += cfg.n_heads * hd * d + 2 * d
            if cfg.is_moe:
                e = cfg.experts_per_token if active_only else cfg.n_experts
                total += d * cfg.n_experts + e * 3 * d * cfg.d_ff
            else:
                total += 3 * d * cfg.d_ff
        elif kind == "rec":
            w = rglru._lru_width(cfg)
            total += 2 * d * w + 2 * w * w + cfg.conv_width * w + w * d
            total += 3 * d * cfg.d_ff + 2 * d
        elif kind == "ssm":
            d_in, H, N = mamba2._dims(cfg)
            total += d * (2 * d_in + 2 * N + H)
            total += cfg.conv_width * (d_in + 2 * N)
            total += d_in * d + d_in + d + 3 * H
    return total + d
