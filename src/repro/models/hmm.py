"""HMMModel: conjugate hidden Markov chains over the block layer.

Third member of the conjugate-exponential family the engine serves — the
model D-MFVI-style distributed VB papers use to stress transition-structure
conjugacy.  Each sensor observes S iid chains of length L:

    z_1 ~ Cat(pi),  z_{l+1} | z_l ~ Cat(A[z_l]),  x_l | z_l ~ N(mu_k, L_k^-1)

with the fully conjugate prior pi ~ Dir, A[k] ~ Dir per row, (mu_k, L_k) ~
Normal-Wishart.  The global posterior factorises into exactly three
exponential-family blocks, so the adapter is a `blocks.BlockModel`
composition with ZERO new engine/serving code:

    DirichletBlock(K, rows=1, "pi")     initial-state weights
    DirichletBlock(K, rows=K, "trans")  one Dirichlet per transition row
    NormalWishartBlock(K, D)            the GMM emission bank (reused)

The VBE step is Beal's variational forward-backward: sub-normalised
parameters exp E[ln pi], exp E[ln A], exp E[ln emission] feed a standard
log-space alpha/beta recursion, giving per-chain state marginals gamma and
pairwise marginals xi.  The VBM optimum adds the replicated expected counts
to the prior — Dirichlet counts for pi (gamma_1) and A (sum_l xi_l), and
the GMM sufficient statistics (gmm.sufficient_stats on the gamma-weighted
flattened chains) for the emissions: Eqs. 17a/18 verbatim, three blocks at
once.

Data convention: `(x (N, S, L, D), mask (N, S))` — axis 1 is the SAMPLE
axis (whole chains are the iid unit), so the protocol-level streaming /
padding / append plumbing applies unchanged: minibatches subsample chains
with unbiased T/B rescaling (per-chain statistics are linear in the scaled
mask), and bucketed-admission padding appends mask-zero chains whose
statistics are exact +0.0 through `expfam.ordered_sum` — bit-invisible.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.special import logsumexp

from repro.core import blocks, expfam, gmm
from repro.core.expfam import GMMPosterior, NWParams


class HMMPosterior(NamedTuple):
    """Hyperparameters of the three-block HMM posterior."""

    pi: jnp.ndarray     # (K,)     Dirichlet over the initial state
    trans: jnp.ndarray  # (K, K)   one Dirichlet per transition row
    m: jnp.ndarray      # (K, D)   Normal-Wishart emission bank
    beta: jnp.ndarray   # (K,)
    W: jnp.ndarray      # (K, D, D)
    nu: jnp.ndarray     # (K,)

    @property
    def K(self) -> int:
        return self.pi.shape[-1]

    @property
    def D(self) -> int:
        return self.m.shape[-1]


def noninformative_prior(K: int, D: int, *, alpha0: float = 1.0,
                         trans0: float = 1.0, beta0: float = 1.0,
                         nu0: float | None = None, w0_scale: float = 1.0,
                         dtype=jnp.float64) -> HMMPosterior:
    """Broad conjugate prior: uniform Dirichlets + the GMM emission prior."""
    g = expfam.noninformative_prior(K, D, alpha0=alpha0, beta0=beta0,
                                    nu0=nu0, w0_scale=w0_scale, dtype=dtype)
    return HMMPosterior(pi=g.alpha, trans=jnp.full((K, K), trans0, dtype),
                        m=g.m, beta=g.beta, W=g.W, nu=g.nu)


def _emission_loglik(x: jnp.ndarray, nw: NWParams) -> jnp.ndarray:
    """(L, D) chain -> (L, K) expected emission log-densities
    E[ln N(x_l | mu_k, L_k^-1)] (the Appendix-A responsibility terms minus
    the mixing weight)."""
    D = x.shape[-1]
    e_logdet = expfam.wishart_expected_logdet(nw.W, nw.nu)         # (K,)
    diff = x[:, None, :] - nw.m[None, :, :]                        # (L, K, D)
    maha = jnp.einsum("jki,kil,jkl->jk", diff, nw.W, diff)
    e_quad = D / nw.beta[None, :] + nw.nu[None, :] * maha
    return (0.5 * e_logdet[None, :]
            - 0.5 * D * jnp.log(2.0 * jnp.pi) - 0.5 * e_quad)


def forward_backward(log_emit: jnp.ndarray, log_pi: jnp.ndarray,
                     log_A: jnp.ndarray):
    """Variational forward-backward on ONE chain, in log space.

    log_emit (L, K), log_pi (K,) = E[ln pi], log_A (K, K) = E[ln A]
    (sub-normalised: Beal's VBEM uses the exponentials of expected logs).
    Returns (gamma (L, K) state marginals, xi (L-1, K, K) pairwise
    marginals, both normalised).
    """
    L, K = log_emit.shape

    def fstep(la, le):
        la_new = logsumexp(la[:, None] + log_A, axis=0) + le
        return la_new, la_new

    la0 = log_pi + log_emit[0]
    _, las = jax.lax.scan(fstep, la0, log_emit[1:])
    log_alpha = jnp.concatenate([la0[None], las])                  # (L, K)

    def bstep(lb, le):
        lb_new = logsumexp(log_A + (le + lb)[None, :], axis=1)
        return lb_new, lb_new

    _, lbs = jax.lax.scan(bstep, jnp.zeros((K,), log_emit.dtype),
                          log_emit[1:], reverse=True)
    log_beta = jnp.concatenate([lbs, jnp.zeros((1, K), log_emit.dtype)])

    gamma = jax.nn.softmax(log_alpha + log_beta, axis=-1)          # (L, K)
    lx = (log_alpha[:-1, :, None] + log_A[None]
          + (log_emit[1:] + log_beta[1:])[:, None, :])             # (L-1,K,K)
    xi = jax.nn.softmax(lx.reshape(L - 1, K * K),
                        axis=-1).reshape(L - 1, K, K)
    return gamma, xi


class HMMModel(blocks.BlockModel):
    """Dirichlet(pi) x Dirichlet-rows(A) x Normal-Wishart emission HMM."""

    def __init__(self, prior: HMMPosterior, K: int | None = None,
                 D: int | None = None):
        self.prior = prior
        self.K = K if K is not None else prior.K
        self.D = D if D is not None else prior.D
        self.blocks = (blocks.DirichletBlock(self.K, name="pi"),
                       blocks.DirichletBlock(self.K, rows=self.K,
                                             name="trans"),
                       blocks.NormalWishartBlock(self.K, self.D))

    def split_hyper(self, q: HMMPosterior) -> tuple:
        return (q.pi[None], q.trans,
                NWParams(m=q.m, beta=q.beta, W=q.W, nu=q.nu))

    def join_hyper(self, parts: tuple) -> HMMPosterior:
        pi, trans, nw = parts
        return HMMPosterior(pi=pi[0], trans=trans, m=nw.m, beta=nw.beta,
                            W=nw.W, nu=nw.nu)

    def local_optimum(self, data, phi_nodes, replication):
        x, mask = data
        return jax.vmap(lambda xi, mi, phii: self._local_one(
            xi, mi, phii, replication))(x, mask, phi_nodes)

    def _local_one(self, x, w, phi, replication):
        """One node: (S, L, D) chains + (S,) scaled mask -> phi* (P,)."""
        K, D = self.K, self.D
        S, L = x.shape[0], x.shape[1]
        q = self.unpack(phi)
        log_pi = expfam.dirichlet_expected_log(q.pi)                # (K,)
        log_A = expfam.dirichlet_expected_log(q.trans)              # (K, K)
        nw = NWParams(m=q.m, beta=q.beta, W=q.W, nu=q.nu)

        def per_chain(xc):
            return forward_backward(_emission_loglik(xc, nw), log_pi, log_A)

        gamma, xi = jax.vmap(per_chain)(x)      # (S, L, K), (S, L-1, K, K)

        # Expected counts, replicated (Appendix-A style).  The chain axis
        # is the sample axis: reductions go through expfam.ordered_sum so
        # mask-zero padding chains contribute exact +0.0 (bit-invisible
        # under bucketed admission); within-chain sums are fixed-length.
        pi_counts = replication * expfam.ordered_sum(
            w[:, None] * gamma[:, 0, :])                            # (K,)
        trans_counts = replication * expfam.ordered_sum(
            w[:, None, None] * jnp.sum(xi, axis=1))                 # (K, K)

        # Emission block: gamma-weighted chains, flattened to one sample
        # axis (row-major keeps padded chains at the tail), reuse the GMM
        # statistics + Appendix-A VBM update verbatim.
        r = (w[:, None, None] * gamma).reshape(S * L, K)
        stats = gmm.sufficient_stats(x.reshape(S * L, D), r, replication)
        prior_g = GMMPosterior(alpha=self.prior.pi, m=self.prior.m,
                               beta=self.prior.beta, W=self.prior.W,
                               nu=self.prior.nu)
        emis = gmm.posterior_from_stats(stats, prior_g)

        return self.pack(HMMPosterior(
            pi=self.prior.pi + pi_counts,
            trans=self.prior.trans + trans_counts,
            m=emis.m, beta=emis.beta, W=emis.W, nu=emis.nu))


def perturbed_init(prior: HMMPosterior, x: jnp.ndarray, key,
                   spread: float = 1.0) -> HMMPosterior:
    """Random-restart initialisation: the prior with emission means
    scattered over the data range (cf. algorithms._perturbed_init) — the
    exchangeable-component symmetry of the prior is a fixed point of the
    VB iteration, so runs must start off it."""
    K, D = prior.K, prior.D
    flat = x.reshape(-1, D)
    lo, hi = jnp.min(flat, axis=0), jnp.max(flat, axis=0)
    m = lo + (hi - lo) * jax.random.uniform(key, (K, D), prior.m.dtype)
    return prior._replace(m=prior.m + spread * (m - prior.m))


# ---------------------------------------------------------------------------
# Synthetic sensor chains (examples + tests)
# ---------------------------------------------------------------------------
def sample_chains(n_nodes: int, n_chains: int, length: int, *,
                  K: int = 3, D: int = 2, seed: int = 0,
                  self_loop: float = 0.8, sep: float = 4.0,
                  dtype=np.float64):
    """Ground-truth HMM chains per sensor: sticky uniform-offdiagonal
    transitions, well-separated spherical Gaussian emissions.  Returns
    (x (N, S, L, D), mask (N, S), pi_true, A_true, means)."""
    rng = np.random.default_rng(seed)
    pi = np.full(K, 1.0 / K)
    A = np.full((K, K), (1.0 - self_loop) / (K - 1))
    np.fill_diagonal(A, self_loop)
    ang = 2.0 * np.pi * np.arange(K) / K
    means = np.zeros((K, D))
    circ = sep * np.stack([np.cos(ang), np.sin(ang)], -1)
    means[:, :min(D, 2)] = circ[:, :min(D, 2)]
    x = np.zeros((n_nodes, n_chains, length, D), dtype)
    for i in range(n_nodes):
        for s in range(n_chains):
            z = rng.choice(K, p=pi)
            for l in range(length):
                x[i, s, l] = means[z] + rng.normal(size=D)
                z = rng.choice(K, p=A[z])
    mask = np.ones((n_nodes, n_chains), dtype)
    return x, mask, pi, A, means
