"""Adapters wiring the Pallas kernels into the model block interface."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import layers


def flash_attention_block(x, p, cfg: ModelConfig, positions, *,
                          window: int = 0):
    """Drop-in for layers.attention_block using the flash kernel."""
    B, S, _ = x.shape
    q, k, v = layers._qkv(x, p, cfg, positions)
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    out = out.reshape(B, S, cfg.n_heads * cfg.hd) @ p["wo"]
    return out, k, v
