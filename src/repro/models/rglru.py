"""RG-LRU recurrent block (RecurrentGemma / Griffin).  [arXiv:2402.19427]

    r_t = sigmoid(W_a xi_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x xi_t + b_x)          (input gate)
    log a_t = -c * softplus(Lambda) * r_t  (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * xi_t)

computed over the sequence with a log-depth associative scan (TPU-friendly);
decode carries (conv_buf, h).  The full residual block is Griffin's
"recurrent block": two input linears -> (gelu gate | temporal conv -> RG-LRU)
-> elementwise merge -> output linear.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers

_C = 8.0


def _lru_width(cfg: ModelConfig) -> int:
    return cfg.lru_width or cfg.d_model


def rec_params(key, cfg: ModelConfig, dtype):
    d, w = cfg.d_model, _lru_width(cfg)
    ks = jax.random.split(key, 6)
    return {
        "in_x": layers.dense_init(ks[0], (d, w), 0, dtype),
        "in_gate": layers.dense_init(ks[1], (d, w), 0, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w)) *
                   0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": layers.dense_init(ks[3], (w, w), 0, dtype),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": layers.dense_init(ks[4], (w, w), 0, dtype),
        "b_i": jnp.zeros((w,), jnp.float32),
        # Lambda parameterised so a ~ U[0.9, 0.999] at r=1 (Griffin init)
        "lam": jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, w)) / _C)).astype(jnp.float32),
        "out": layers.dense_init(ks[5], (w, d), 0, dtype),
    }


def _gates(xi, p):
    xf = xi.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(xf @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    return a, gated_in


def rglru_scan(xi, p, h0=None):
    """xi (B, S, w) -> (h_seq (B, S, w), h_final (B, w)) via associative scan."""
    a, gin = _gates(xi, p)                       # (B, S, w) f32
    if h0 is not None:
        # fold the carry into the first step: h_1 = a_1 h_0 + gin_1
        gin = gin.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2

    a_s, h_seq = jax.lax.associative_scan(combine, (a, gin), axis=1)
    return h_seq.astype(xi.dtype), h_seq[:, -1, :]


def rec_block(x, p, cfg: ModelConfig, *, return_state: bool = False):
    """Griffin recurrent block.  x (B, S, d)."""
    gate = jax.nn.gelu((x @ p["in_gate"]).astype(jnp.float32))
    xi = x @ p["in_x"]
    xi_conv = _conv(xi, p)
    h_seq, h_fin = rglru_scan(xi_conv, p)
    merged = (h_seq.astype(jnp.float32) * gate).astype(x.dtype)
    out = merged @ p["out"]
    if return_state:
        W = cfg.conv_width
        conv_buf = jnp.pad(xi, ((0, 0), (max(0, W - 1 - xi.shape[1]), 0),
                                (0, 0)))[:, -(W - 1):, :]
        return out, (conv_buf, h_fin.astype(jnp.float32))
    return out


def _conv(xi, p):
    W = p["conv_w"].shape[0]
    xp = jnp.pad(xi, ((0, 0), (W - 1, 0), (0, 0)))
    return sum(xp[:, i:i + xi.shape[1], :] * p["conv_w"][i]
               for i in range(W)) + p["conv_b"]


def rec_decode_step(x, p, cfg: ModelConfig, state):
    """x (B, 1, d); state = (conv_buf (B, W-1, w), h (B, w))."""
    conv_buf, h = state
    gate = jax.nn.gelu((x[:, 0, :] @ p["in_gate"]).astype(jnp.float32))
    xi = x[:, 0, :] @ p["in_x"]
    seq = jnp.concatenate([conv_buf, xi[:, None, :]], axis=1)
    xi_c = jnp.einsum("bwc,wc->bc", seq, p["conv_w"]) + p["conv_b"]
    a, gin = _gates(xi_c[:, None, :], p)
    h = a[:, 0, :] * h + gin[:, 0, :]
    merged = (h * gate).astype(x.dtype)
    out = (merged @ p["out"])[:, None, :]
    return out, (seq[:, 1:, :], h)
