from repro.models import model  # noqa: F401
from repro.models.hmm import HMMModel, HMMPosterior  # noqa: F401
from repro.models.ppca import PPCAModel  # noqa: F401
