"""Mixture-of-Experts FFN with sort-free capacity dispatch.

TPU-native dispatch: instead of the (T, E, C) one-hot einsum (quadratic
FLOPs in tokens) or a ragged all_to_all, tokens are placed into a static
(E * C, d) buffer via scatter and read back via gather — zero matmul FLOPs
for routing, static shapes, drop-on-overflow semantics (capacity_factor).
Expert FFNs are batched einsums over the leading expert axis, so the d_ff
dimension shards over the mesh "model" axis for every assigned config
(including E values like 40 that don't divide the axis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers


def moe_params(key, cfg: ModelConfig, dtype):
    kr, k1, k2, k3 = jax.random.split(key, 4)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": layers.dense_init(kr, (d, E), 0, jnp.float32),
        "wi": layers.dense_init(k1, (E, d, f), 1, dtype),
        "wg": layers.dense_init(k2, (E, d, f), 1, dtype),
        "wo": layers.dense_init(k3, (E, f, d), 1, dtype),
    }


def moe_block(x: jnp.ndarray, p, cfg: ModelConfig):
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar).

    With cfg.moe_local_dispatch and an ambient mesh, routing + the capacity
    scatter/gather run per data shard under shard_map (per-shard capacity,
    zero cross-shard dispatch traffic); expert FFN weights stay
    model-sharded via the auto axes.
    """
    from repro.dist import compat
    mesh = compat.current_mesh()
    if cfg.moe_local_dispatch and mesh is not None:
        import functools
        from jax.sharding import PartitionSpec as P
        sizes = compat.auto_axis_sizes()
        axes = tuple(a for a in ("pod", "data")
                     if sizes.get(a, 1) > 1
                     and x.shape[0] % sizes[a] == 0)
        # local dispatch leaves the expert weights on auto (GSPMD) axes, a
        # partial-manual shard_map — hard XLA CHECK failure on older JAX,
        # so fall back to global dispatch there
        if axes and compat.PARTIAL_MANUAL_OK:
            fn = compat.shard_map(
                functools.partial(_moe_dispatch, cfg=cfg,
                                  axis_names=axes),
                mesh=mesh, axis_names=set(axes),
                in_specs=(P(axes), P()), out_specs=(P(axes), P()),
                check_vma=False)
            return fn(x, p)
    return _moe_dispatch(x, p, cfg=cfg, axis_names=())


def _moe_dispatch(x, p, *, cfg: ModelConfig, axis_names=()):
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.experts_per_token
    cap = max(1, int(T * k / E * cfg.capacity_factor))
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"])              # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)              # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # position of each (token, slot) within its expert's capacity buffer.
    # Two-level blocked cumsum: a single (T*k, E) cumsum is costed (and on
    # some backends executed) as an O(n^2) reduce-window; block-local scans
    # + a tiny scan over block totals is O(n * blk) with identical results
    # (§Perf: granite-moe train_4k Tc dropped ~50x with this).
    flat_e = expert_idx.reshape(T * k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # (T*k, E)
    blk = 1024
    n = T * k
    nb = (n + blk - 1) // blk
    pad = nb * blk - n
    oh = jnp.pad(onehot, ((0, pad), (0, 0))).reshape(nb, blk, E)
    local = jnp.cumsum(oh, axis=1)                               # in-block
    block_tot = local[:, -1, :]                                  # (nb, E)
    offsets = jnp.cumsum(block_tot, axis=0) - block_tot          # exclusive
    pos = (local - oh + offsets[:, None, :]).reshape(nb * blk, E)[:n]
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos < cap
    dest = jnp.where(keep, flat_e * cap + pos, E * cap)          # drop slot

    # scatter tokens into the (E*C, d) buffer (duplicated per chosen expert)
    src = jnp.repeat(xt, k, axis=0)                              # (T*k, d)
    buf = jnp.zeros((E * cap + 1, d), x.dtype).at[dest].set(src)
    xe = buf[: E * cap].reshape(E, cap, d)

    # expert FFN (SwiGLU), batched over experts; f shards over "model"
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"])) * \
        jnp.einsum("ecd,edf->ecf", xe, p["wi"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"])                  # (E, C, d)

    # gather back and mix with gate values
    ybuf = jnp.concatenate(
        [ye.reshape(E * cap, d), jnp.zeros((1, d), ye.dtype)], 0)
    yslots = ybuf[dest].reshape(T, k, d)
    gates = (gate_vals * keep.reshape(T, k)).astype(x.dtype)
    out = jnp.einsum("tkd,tk->td", yslots, gates).reshape(B, S, d)

    # load-balancing auxiliary loss (Switch-style)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_idx, E, dtype=jnp.float32), axis=(0, 1))
    mean_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * mean_probs)
    for a in axis_names:                       # local-dispatch mode
        aux = jax.lax.pmean(aux, a)
    return out, aux
