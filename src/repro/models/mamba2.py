"""Mamba-2 (SSD — state-space duality) block.  [arXiv:2405.21060]

The sequence transform is the scalar-decay SSM
    h_t = exp(dt_t * A_h) h_{t-1} + dt_t * B_t (x)  ,  y_t = C_t . h_t + D x_t
computed with the chunked SSD algorithm: quadratic attention-like math inside
chunks of length L (MXU-friendly), linear state passing across chunks.  The
Pallas TPU kernel in repro/kernels/ssd_scan.py implements the same chunked
schedule with VMEM-resident blocks; this file is the pure-jnp path used for
training forward/backward and as the kernel oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    return d_in, n_heads, cfg.ssm_state


def ssm_params(key, cfg: ModelConfig, dtype):
    d_in, H, N = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    conv_dim = d_in + 2 * N  # x, B, C pass through the depthwise conv
    return {
        # fused in-projection: [z (d_in) | x (d_in) | B (N) | C (N) | dt (H)]
        "in_proj": layers.dense_init(
            ks[0], (d, 2 * d_in + 2 * N + H), 0, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_dim)) *
                   0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.zeros((d_in,), dtype),
        "out_proj": layers.dense_init(ks[2], (d_in, d), 0, dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv.  x (B, S, C), w (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    return out + b


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, h0=None):
    """Chunked SSD scan.

    x  (B, S, H, P)   head inputs            dt (B, S, H)  softplus'd steps
    A  (H,)           negative decay rates   Bm/Cm (B, S, N)  shared across H
    Returns (y (B, S, H, P), final_state (B, H, P, N)).
    """
    Bb, S, H, P = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    nc = S // L
    assert nc * L == S, (S, L)
    xc = x.reshape(Bb, nc, L, H, P)
    dtc = dt.reshape(Bb, nc, L, H)
    Bc = Bm.reshape(Bb, nc, L, N)
    Cc = Cm.reshape(Bb, nc, L, N)

    dA = dtc * A[None, None, None, :]                 # (B,nc,L,H) log-decay<=0
    cum = jnp.cumsum(dA, axis=2)                      # inclusive cumsum
    # --- intra-chunk (quadratic, causal-masked) ---
    # M[l, l'] = C_l . B_l' * exp(cum_l - cum_l') * dt_l'  for l' <= l
    cb = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)        # (B,nc,L,L)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # (B,nc,L,L,H)
    mask = jnp.tril(jnp.ones((L, L), bool))
    # mask BEFORE exp: exp on the (positive) masked-out entries overflows and
    # its where-gradient would be inf * 0 = NaN in the backward pass
    seg = jnp.where(mask[None, None, :, :, None], seg, -jnp.inf)
    gates = jnp.exp(seg)
    M = cb[..., None] * gates * dtc[:, :, None, :, :]         # (B,nc,L,L,H)
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", M.astype(x.dtype), xc)

    # --- chunk summaries:  S_c = sum_l exp(cum_L - cum_l) dt_l B_l x_l ---
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)           # (B,nc,L,H)
    wx = (dtc * decay_to_end)[..., None] * xc                 # (B,nc,L,H,P)
    S_c = jnp.einsum("bcln,bclhp->bchpn", Bc, wx.astype(jnp.float32))

    # --- cross-chunk recurrence over nc (sequential scan) ---
    chunk_decay = jnp.exp(cum[:, :, -1, :])                   # (B,nc,H)
    if h0 is None:
        h0 = jnp.zeros((Bb, H, P, N), jnp.float32)

    def step(h, inp):
        dcy, s_new = inp                                      # (B,H), (B,H,P,N)
        h_out = h                                             # state BEFORE chunk
        h_next = dcy[:, :, None, None] * h + s_new
        return h_next, h_out

    dcy_t = jnp.moveaxis(chunk_decay, 1, 0)                   # (nc,B,H)
    s_t = jnp.moveaxis(S_c, 1, 0)                             # (nc,B,H,P,N)
    h_final, h_prevs = jax.lax.scan(step, h0, (dcy_t, s_t))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                     # (B,nc,H,P,N)

    # --- inter-chunk contribution:  y_l += C_l . (exp(cum_l) h_prev) ---
    in_decay = jnp.exp(cum)                                   # (B,nc,L,H)
    y_inter = jnp.einsum("bcln,bchpn->bclhp", Cc,
                         h_prevs) * in_decay[..., None]
    y = y_intra + y_inter.astype(x.dtype)
    return y.reshape(Bb, S, H, P), h_final


def ssm_block(x, p, cfg: ModelConfig, *, return_state: bool = False,
              use_kernel: bool = False):
    """Full Mamba-2 block: in_proj -> conv -> SSD -> gated norm -> out_proj."""
    d_in, H, N = _dims(cfg)
    B, S, _ = x.shape
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * N], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, S, H, cfg.ssm_head_dim)
    if use_kernel:
        from repro.kernels import ops
        y, state = ops.ssd_scan(xh, dt, A, Bm, Cm, chunk=cfg.ssm_chunk)
    else:
        y, state = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + p["D"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(B, S, d_in)
    y = layers.rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        # conv tail: last (W-1) pre-conv inputs, for decode continuation
        conv_buf = jnp.pad(  # handles S < W-1 (not in practice)
            (x @ p["in_proj"])[:, :, d_in:2 * d_in + 2 * N],
            ((0, 0), (max(0, cfg.conv_width - 1 - S), 0), (0, 0))
        )[:, -(cfg.conv_width - 1):, :]
        return out, (conv_buf, state)
    return out


def ssm_decode_step(x, p, cfg: ModelConfig, state):
    """One decode step.  x (B, 1, d); state = (conv_buf (B,W-1,Cc), h (B,H,P,N))."""
    d_in, H, N = _dims(cfg)
    conv_buf, h = state
    B = x.shape[0]
    zxbcdt = x[:, 0, :] @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * N], axis=-1)
    # causal conv over the rolling buffer
    seq = jnp.concatenate([conv_buf, xbc[:, None, :]], axis=1)  # (B, W, C)
    conv_out = jnp.einsum("bwc,wc->bc", seq, p["conv_w"]) + p["conv_b"]
    xbc_t = jax.nn.silu(conv_out)
    xs, Bm, Cm = jnp.split(xbc_t, [d_in, d_in + N], axis=-1)
    dt_t = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, H)
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, H, cfg.ssm_head_dim).astype(jnp.float32)
    decay = jnp.exp(dt_t * A[None, :])                           # (B, H)
    upd = (dt_t[..., None, None] * Bm[:, None, None, :]
           * xh[..., :, None])                                   # (B,H,P,N)
    h = decay[..., None, None] * h + upd
    y = jnp.einsum("bhpn,bn->bhp", h, Cm)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B, d_in).astype(x.dtype)
    y = layers.rms_norm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None, :]
    new_buf = seq[:, 1:, :]
    return out, (new_buf, h)
