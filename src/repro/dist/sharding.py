"""Partitioning policy: which tensor dims land on which mesh axes.

One rule set shared by training, serving and the dry-run lowering:

* an optional leading **replica** axis (consensus data-parallel state) maps
  to ``replica_axis``;
* leading **scan** axes (the stacked-layer axis of homogeneous models) are
  never sharded;
* the **last** divisible payload dim takes ``"model"`` (tensor parallel);
* with ``fsdp=True`` the first remaining divisible payload dim takes
  ``"data"`` (ZeRO-3 style parameter sharding);
* anything indivisible replicates.

Also provides the activation sharding-constraint helpers
(`constrain_batch_dim`, `constrain_last_dim_model`) used inside model
forward passes to stop GSPMD drifting to replicated layouts, and
`batch_spec` for input batches.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import compat


def _axis_size(mesh: Mesh, name: str) -> int:
    return compat.axis_sizes(mesh).get(name, 1)


def spec_for(shape, mesh: Mesh, *, fsdp: bool = False, n_scan_axes: int = 0,
             replica_axis: str | None = None) -> P:
    """PartitionSpec for a parameter of `shape` under the policy above."""
    rank = len(shape)
    spec: list = [None] * rank
    lead = 0
    if replica_axis is not None and rank > 0:
        spec[0] = replica_axis
        lead = 1
    lead += n_scan_axes
    model_size = _axis_size(mesh, "model")
    data_size = _axis_size(mesh, "data")

    model_dim = None
    if model_size > 1:
        for ax in range(rank - 1, lead - 1, -1):
            if shape[ax] % model_size == 0 and shape[ax] >= 2 * model_size:
                model_dim = ax
                spec[ax] = "model"
                break
    if fsdp and data_size > 1 and replica_axis != "data":
        for ax in range(lead, rank):
            if ax == model_dim:
                continue
            if shape[ax] % data_size == 0 and shape[ax] >= 2 * data_size:
                spec[ax] = "data"
                break
    return P(*spec)


def param_shardings(tree, mesh: Mesh, *, fsdp: bool = False,
                    scanned: bool = False, replica_axis: str | None = None,
                    no_fsdp_keys: tuple = ()):
    """NamedSharding pytree for a parameter (or optimizer-moment) tree.

    `scanned` marks one leading stacked-layer axis on every leaf (after the
    replica axis, if any).  Leaves whose path contains a key in
    `no_fsdp_keys` opt out of fsdp (e.g. locally-dispatched MoE experts).
    """
    n_scan = 1 if scanned else 0

    def one(path, leaf):
        keys = {getattr(k, "key", getattr(k, "name", None)) for k in path}
        use_fsdp = fsdp and not (keys & set(no_fsdp_keys))
        return NamedSharding(mesh, spec_for(
            leaf.shape, mesh, fsdp=use_fsdp, n_scan_axes=n_scan,
            replica_axis=replica_axis))

    return jax.tree_util.tree_map_with_path(one, tree)


def vb_node_specs(data, *, axis: str, has_carry: bool, n_local: int,
                  carry_specs=None, stream_specs=None):
    """(in_specs, out_specs) for the VB engine's shard_map executor
    (core/engine._run_vb_sharded): every per-node array — the data pytree's
    leaves, the phi iterate, the topology carry (ADMM duals) and the
    topology's `shard_inputs` rows (weight/adjacency rows) — shards its
    leading node axis over the mesh axis `axis`.

    This is the partitioning rule for the session-state pytree
    (`engine.VBState`): the state slots (phi, carry, stream) appear in
    BOTH spec tuples, because the executor now returns the final state —
    not just the iterate — so `vb_run` can resume / checkpoint under the
    mesh executor too.  Outputs are (phi (N, P), carry, stream,
    kl trajectories (T, N), consensus error (T,)).

    `carry_specs` overrides the default node-sharded carry spec for
    topologies whose carry mixes per-node state with replicated scalars
    (the adaptive `ADMMConsensus` carries duals (N, P) plus the penalty /
    warmup-gate state, which every shard holds identically — see
    `ADMMConsensus.carry_specs`).

    `stream_specs` is the spec pytree for the streaming sampler state
    (`data/stream.StreamState`: per-node keys and epoch permutation
    node-sharded, the epoch counter replicated — the engine passes it);
    without it the slot carries a replicated dummy scalar.

    One home for the engine's partitioning rule so the compute backends
    (core/backends.py) and the executors agree on what "node-sharded"
    means: a backend always receives the LOCAL slice of the node axis and
    never needs to know the mesh.
    """
    node = P(axis)
    data_specs = jax.tree_util.tree_map(lambda _: node, data)
    if has_carry:
        carry_spec = carry_specs if carry_specs is not None else node
    else:
        carry_spec = P()
    stream_spec = stream_specs if stream_specs is not None else P()
    in_specs = (data_specs, node, carry_spec, stream_spec) \
        + (node,) * n_local
    out_specs = (node, carry_spec, stream_spec, P(None, axis), P(None))
    return in_specs, out_specs


def batch_spec(mesh: Mesh) -> P:
    """Batch-dim spec: shard dim 0 over whichever of (pod, data) exist."""
    axes = tuple(a for a in ("pod", "data")
                 if a in mesh.axis_names and _axis_size(mesh, a) > 1)
    return P(axes) if axes else P()


# ---------------------------------------------------------------------------
# Activation sharding constraints (no-ops without an ambient mesh, and on
# axes that are manual inside a shard_map body)
# ---------------------------------------------------------------------------
def _dp_axes_for(batch: int) -> tuple:
    sizes = compat.auto_axis_sizes()
    axes, rem = [], batch
    for a in ("pod", "data"):
        s = sizes.get(a, 1)
        if s > 1 and rem % s == 0:
            axes.append(a)
            rem //= s
    return tuple(axes)


def constrain_batch_dim(x):
    """Re-assert that dim 0 (batch) is sharded over the data-parallel axes."""
    mesh = compat.current_mesh()
    if mesh is None or compat.current_manual_axes():
        return x
    axes = _dp_axes_for(x.shape[0])
    if not axes:
        return x
    spec = P(axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_last_dim_model(x):
    """Pin the trailing dim to the "model" axis (head_dim-sharded paths)."""
    mesh = compat.current_mesh()
    if mesh is None or compat.current_manual_axes():
        return x
    sizes = compat.auto_axis_sizes()
    if sizes.get("model", 1) <= 1 or x.shape[-1] % sizes["model"] != 0:
        return x
    spec = P(*([None] * (x.ndim - 1)), "model")
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
