"""JAX version compatibility for mesh + shard_map entry points.

The codebase targets the modern spelling (``jax.shard_map`` with
``axis_names=``/``check_vma=``, ``jax.set_mesh`` as a context manager,
``jax.sharding.get_abstract_mesh``).  The pinned container ships an older
JAX where the same functionality lives under ``jax.experimental.shard_map``
(with ``auto=``/``check_rep=``) and there is no ambient-mesh setter beyond
``with mesh:``.  Every mesh-aware call site goes through this module so the
rest of the code can be written once.
"""
from __future__ import annotations

import contextlib
import threading

import jax

try:  # modern JAX
    _native_shard_map = jax.shard_map  # type: ignore[attr-defined]
    _HAS_NATIVE = True
except AttributeError:
    from jax.experimental.shard_map import shard_map as _exp_shard_map
    _HAS_NATIVE = False

# Partial-manual shard_map (manual over a subset of mesh axes, the rest
# auto/GSPMD) trips an XLA SPMD-partitioner CHECK on older JAX; callers that
# can fall back to fully-manual should consult this flag.
PARTIAL_MANUAL_OK = _HAS_NATIVE


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=None, check_rep=None):
    """``jax.shard_map`` with the modern kwargs on any supported JAX.

    ``axis_names`` marks the manual axes (the rest stay auto/GSPMD);
    ``check_vma`` is the new name of ``check_rep``.
    """
    names = (frozenset(axis_names) if axis_names is not None
             else frozenset(mesh.axis_names))

    def wrapped(*args):
        with manual_axes(names):
            return f(*args)

    if _HAS_NATIVE:
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        if check_vma is not None:
            kw["check_vma"] = check_vma
        elif check_rep is not None:
            kw["check_vma"] = check_rep
        return _native_shard_map(wrapped, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, **kw)
    kw = {}
    auto = frozenset(mesh.axis_names) - names
    if auto:
        kw["auto"] = auto
    flag = check_vma if check_vma is not None else check_rep
    if flag is not None:
        kw["check_rep"] = flag
    return _exp_shard_map(wrapped, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)


# ---------------------------------------------------------------------------
# Ambient mesh (jax.set_mesh replacement)
# ---------------------------------------------------------------------------
class _MeshState(threading.local):
    def __init__(self):
        self.stack = []          # meshes entered via use_mesh
        self.manual = []         # frozensets of manual axis names


_STATE = _MeshState()


@contextlib.contextmanager
def use_mesh(mesh):
    """Ambient-mesh context: the portable spelling of ``jax.set_mesh``.

    Also enters ``with mesh:`` so bare-PartitionSpec sharding constraints
    resolve on older JAX.
    """
    _STATE.stack.append(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        _STATE.stack.pop()


@contextlib.contextmanager
def manual_axes(names):
    """Record that `names` are manual (shard_map) axes for the enclosed
    trace, so sharding constraints skip them."""
    _STATE.manual.append(frozenset(names))
    try:
        yield
    finally:
        _STATE.manual.pop()


def current_mesh():
    """The ambient mesh, or None.  Sources: use_mesh() stack, then the
    thread-resources env populated by a plain ``with mesh:`` block."""
    if _STATE.stack:
        return _STATE.stack[-1]
    try:
        from jax._src import mesh as mesh_lib
        pm = mesh_lib.thread_resources.env.physical_mesh
        if pm is not None and not pm.empty:
            return pm
    except Exception:
        pass
    return None


def current_manual_axes() -> frozenset:
    if _STATE.manual:
        return frozenset().union(*_STATE.manual)
    return frozenset()


def axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def axis_size(axis_name: str) -> int:
    """Static size of a named (shard_map) axis, on any supported JAX."""
    try:
        return jax.lax.axis_size(axis_name)  # type: ignore[attr-defined]
    except AttributeError:
        from jax._src import core as _core
        return _core.axis_frame(axis_name)


def auto_axis_sizes() -> dict:
    """name -> size for ambient mesh axes NOT currently manual."""
    mesh = current_mesh()
    if mesh is None:
        return {}
    manual = current_manual_axes()
    return {a: s for a, s in axis_sizes(mesh).items() if a not in manual}
