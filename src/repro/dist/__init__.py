"""repro.dist — mesh/sharding utilities shared by training and serving.

`sharding` holds the PartitionSpec policy (which tensor dims go on which
mesh axes); `compat` smooths over JAX API differences so the same call
sites work on the pinned container JAX and on newer releases.
"""
from repro.dist import compat, sharding  # noqa: F401
