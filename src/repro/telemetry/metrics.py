"""Process-wide metrics registry: counters, gauges, histograms.

One `MetricsRegistry` instance (the module default lives in
`repro.telemetry`) holds every labeled series the instrumented layers
emit — scheduler counters from `serving/driver.py`, bucket decisions
from `serving/admission.py`, kernel wall-time histograms from
`kernels/ops.py`, per-run VB series filed by the tap layer.  The design
constraints, in order:

1. **Disabled is free.**  Recording goes through the facade helpers in
   `repro.telemetry` (`inc` / `set_gauge` / `observe`), which are a
   single bool check when telemetry is off — nothing here allocates or
   locks until the first enabled record.
2. **Cheap snapshot/export.**  `snapshot()` returns plain-python rows;
   `to_jsonl()` is one JSON object per series (greppable, appendable);
   `to_prometheus()` is the standard text exposition format, so the
   dump drops into promtool / Grafana unchanged.
3. **Thread-safe.**  The driver's scheduler thread, the checkpoint
   writer thread, and user threads all record concurrently; one
   registry lock serialises series creation and updates (the values are
   tiny — contention is not a concern at scheduler rates).

Series identity is (name, sorted labels).  The same name may not be
reused with a different instrument kind (ValueError — a counter cannot
silently become a gauge between layers).
"""
from __future__ import annotations

import json
import threading
from typing import Optional

# Default histogram bucket upper bounds: log-ish spacing that covers
# microsecond kernel timings through multi-second checkpoint writes when
# the recorded unit is seconds or microseconds alike.
DEFAULT_BUCKETS = (1e-4, 1e-3, 1e-2, 0.1, 1.0, 10.0, 100.0, 1e3, 1e4,
                   1e5, 1e6)


class _Series:
    """One labeled series.  `kind` is "counter" | "gauge" | "histogram"."""

    __slots__ = ("name", "kind", "labels", "value", "sum", "count",
                 "bounds", "bucket_counts", "_lock")

    def __init__(self, name: str, kind: str, labels: tuple,
                 bounds: Optional[tuple] = None):
        self.name = name
        self.kind = kind
        self.labels = labels                 # tuple of (key, value) pairs
        self.value = 0.0                     # counter total / gauge level
        self.sum = 0.0                       # histogram only
        self.count = 0                       # histogram only
        self.bounds = bounds                 # histogram only
        self.bucket_counts = ([0] * (len(bounds) + 1) if bounds is not None
                              else None)    # +1: the +Inf bucket
        self._lock = threading.Lock()

    # -- recording (one method per kind; the registry hands back bound
    #    methods so hot paths skip the kind dispatch) ----------------------
    def inc(self, value: float = 1.0) -> None:
        with self._lock:
            self.value += value

    def set(self, value: float) -> None:
        with self._lock:
            self.value = float(value)

    def observe(self, value: float) -> None:
        value = float(value)
        with self._lock:
            self.sum += value
            self.count += 1
            for i, b in enumerate(self.bounds):
                if value <= b:
                    self.bucket_counts[i] += 1
                    break
            else:
                self.bucket_counts[-1] += 1

    # -- export -----------------------------------------------------------
    def row(self) -> dict:
        out = {"name": self.name, "kind": self.kind,
               "labels": dict(self.labels)}
        if self.kind == "histogram":
            with self._lock:
                out.update(count=self.count, sum=self.sum,
                           buckets={("+Inf" if i == len(self.bounds)
                                     else repr(self.bounds[i])): c
                                    for i, c in
                                    enumerate(self.bucket_counts)})
        else:
            out["value"] = self.value
        return out


def _label_str(labels: tuple, extra: tuple = ()) -> str:
    pairs = labels + extra
    if not pairs:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in pairs)
    return "{" + body + "}"


class MetricsRegistry:
    """Registry of labeled counter/gauge/histogram series.

    >>> reg = MetricsRegistry()
    >>> reg.counter("requests_total", route="vb").inc()
    >>> reg.counter("requests_total", route="vb").inc(2)
    >>> reg.gauge("queue_depth").set(7)
    >>> reg.histogram("write_seconds", bounds=(0.1, 1.0)).observe(0.25)
    >>> [r["value"] for r in reg.snapshot() if r["kind"] == "counter"]
    [3.0]
    >>> "queue_depth 7" in reg.to_prometheus()
    True
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._series: dict[tuple, _Series] = {}

    def _get(self, name: str, kind: str, labels: dict,
             bounds: Optional[tuple] = None) -> _Series:
        key = (name, tuple(sorted(labels.items())))
        s = self._series.get(key)
        if s is None:
            with self._lock:
                s = self._series.get(key)
                if s is None:
                    s = _Series(name, kind, key[1], bounds)
                    self._series[key] = s
        if s.kind != kind:
            raise ValueError(
                f"metric {name!r} already registered as {s.kind}, "
                f"cannot re-register as {kind}")
        return s

    def counter(self, name: str, **labels) -> _Series:
        return self._get(name, "counter", labels)

    def gauge(self, name: str, **labels) -> _Series:
        return self._get(name, "gauge", labels)

    def histogram(self, name: str, bounds: tuple = DEFAULT_BUCKETS,
                  **labels) -> _Series:
        return self._get(name, "histogram", labels, tuple(bounds))

    # -- export -----------------------------------------------------------
    def snapshot(self) -> list[dict]:
        """Plain-python rows, one per series, sorted by (name, labels)."""
        with self._lock:
            series = sorted(self._series.values(),
                            key=lambda s: (s.name, s.labels))
        return [s.row() for s in series]

    def to_jsonl(self) -> str:
        """One JSON object per line per series (the driver's drain dump)."""
        return "\n".join(json.dumps(r, default=float)
                         for r in self.snapshot())

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (one # TYPE line per metric
        name, then the samples; histograms expand to _bucket/_sum/_count
        with cumulative `le` buckets)."""
        with self._lock:
            series = sorted(self._series.values(),
                            key=lambda s: (s.name, s.labels))
        lines, typed = [], set()
        for s in series:
            if s.name not in typed:
                lines.append(f"# TYPE {s.name} {s.kind}")
                typed.add(s.name)
            if s.kind == "histogram":
                with s._lock:
                    cum = 0
                    for i, c in enumerate(s.bucket_counts):
                        cum += c
                        le = ("+Inf" if i == len(s.bounds)
                              else repr(s.bounds[i]))
                        lines.append(
                            f"{s.name}_bucket"
                            f"{_label_str(s.labels, (('le', le),))} {cum}")
                    lines.append(
                        f"{s.name}_sum{_label_str(s.labels)} {s.sum}")
                    lines.append(
                        f"{s.name}_count{_label_str(s.labels)} {s.count}")
            else:
                v = s.value
                val = f"{int(v)}" if float(v).is_integer() else f"{v}"
                lines.append(f"{s.name}{_label_str(s.labels)} {val}")
        return "\n".join(lines) + ("\n" if lines else "")

    def clear(self) -> None:
        with self._lock:
            self._series.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._series)
