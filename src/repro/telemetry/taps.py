"""Jit-safe device taps: per-iteration series out of compiled VB steps.

Two complementary paths get device-side series to the host:

1. **Diag-slot recording** (`record_series`, used by `core.engine.vb_run`):
   the engine's scan already emits per-iteration ``(kl, msd, diag)``
   outputs — the "diag slot".  When host telemetry is enabled, `vb_run`
   files those materialized arrays here after the scan returns.  This
   path NEVER changes a jaxpr (it reads outputs that exist anyway), so
   it is on whenever `repro.telemetry` is enabled.

2. **Device taps** (`tap`, opt-in via `taps.enable()`): an
   ``io_callback(ordered=False)`` inserted *inside* the traced step so
   values stream out at slice boundaries while the computation is still
   in flight — useful for watching a long driver run live rather than
   post-hoc.  Inserting a callback changes the jaxpr and forces a
   recompile, so this switch is independent of the host-telemetry
   switch and is OFF by default; the disabled path is a trace-time
   Python bool check, so with taps off the emitted jaxpr is
   byte-identical to an uninstrumented build (pinned by
   ``tests/test_telemetry.py::test_tap_disabled_jaxpr_identical``).

Tap callbacks are unordered: the runtime may invoke them out of
iteration order (and once per batch element under ``vmap``), so each
record carries its own iteration index `t` when the caller has one;
`series()` sorts by `t` before returning.  Taps are supported on the
single-array executor paths; under the mesh/shard_map executor the
callback insertion is not supported and taps should stay disabled.

The switch is read at TRACE time and JAX caches traces per (function
object, input avals): a step function traced while taps were off will
keep its untapped trace even if taps are enabled afterwards.  Enable
taps before the first trace of the function you want to watch (in the
driver: before the first `tick()`), or rebuild the jitted function.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

import numpy as np

_enabled = False
_lock = threading.Lock()
# name -> list of (t or None, np.ndarray) records, in arrival order
_buffer: dict[str, list] = {}


def enable() -> None:
    """Turn on device-tap insertion for subsequently traced functions."""
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def enabled() -> bool:
    return _enabled


@contextmanager
def enabled_scope():
    """Enable taps for the duration of a with-block (tests, debugging)."""
    global _enabled
    prev = _enabled
    _enabled = True
    try:
        yield
    finally:
        _enabled = prev


def _sink(name: str, t, value) -> None:
    # host side of the io_callback; also the direct entry point for
    # record()/record_series().  np.asarray copies the device buffer so
    # later donation/reuse cannot corrupt the record.
    with _lock:
        _buffer.setdefault(name, []).append(
            (None if t is None else np.asarray(t), np.asarray(value)))


def tap(name: str, value, t=None) -> None:
    """Emit `value` (any array) from inside a traced function.

    No-op — and no jaxpr change — when taps are disabled at trace time.
    `t` is an optional iteration index used to order unordered arrivals.
    """
    if not _enabled:
        return
    from jax.experimental import io_callback
    if t is None:
        io_callback(lambda v: _sink(name, None, v), None, value,
                    ordered=False)
    else:
        io_callback(lambda ti, v: _sink(name, ti, v), None, t, value,
                    ordered=False)


def record(name: str, value, t=None) -> None:
    """Host-side single record (no callback; callable anywhere)."""
    _sink(name, t, value)


def record_series(name: str, values, ts=None) -> None:
    """File a whole per-iteration series (the vb_run diag-slot path).

    `values` is a (T, ...) array; `ts` an optional (T,) iteration-index
    array (absolute t, so resumed runs interleave correctly).
    """
    values = np.asarray(values)
    ts = None if ts is None else np.asarray(ts)
    with _lock:
        recs = _buffer.setdefault(name, [])
        for i in range(values.shape[0]):
            recs.append((None if ts is None else ts[i], values[i]))


def series(name: str):
    """Return (ts, values) numpy arrays for a tapped series.

    `ts` is None when no record carried an index; otherwise records are
    sorted by t (unordered callbacks may arrive out of order).  Raises
    KeyError for unknown names (see `names()`).
    """
    with _lock:
        recs = list(_buffer[name])
    if recs and recs[0][0] is not None:
        recs.sort(key=lambda r: int(np.min(r[0])))
        return (np.stack([r[0] for r in recs]),
                np.stack([r[1] for r in recs]))
    return None, np.stack([r[1] for r in recs]) if recs else np.empty((0,))


def names() -> list[str]:
    with _lock:
        return sorted(_buffer)


def counts() -> dict:
    """{name: number of records} — cheap progress probe for live runs."""
    with _lock:
        return {k: len(v) for k, v in _buffer.items()}


def clear() -> None:
    with _lock:
        _buffer.clear()
