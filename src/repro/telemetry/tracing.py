"""Structured span tracing with Chrome-trace export.

`span(name, **args)` is a context manager that records one "complete"
event (Chrome trace phase ``X``) with microsecond start/duration; nested
spans on the same thread render as a flame stack in ``chrome://tracing``
or Perfetto because the viewer nests by time containment per
(pid, tid).  `instant(name, **args)` drops a zero-duration marker
(phase ``i``) — used for admission / rebucket / eviction decisions that
have no meaningful duration but should be visible on the timeline next
to the slice spans that surround them.

Like the metrics registry, recording is thread-safe (the driver's
scheduler loop, the `CheckpointWriter` daemon thread, and the caller's
thread all emit concurrently) and the disabled path never reaches this
module — `repro.telemetry.span` returns a shared null context after a
single bool check.

The export format is the Chrome Trace Event JSON object form::

    {"traceEvents": [{"name": ..., "ph": "X", "ts": ..., "dur": ...,
                      "pid": ..., "tid": ..., "args": {...}}, ...],
     "displayTimeUnit": "ms"}

Timestamps come from ``time.perf_counter`` relative to tracer creation,
so a trace always starts near t=0 regardless of process uptime.
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager


class Tracer:
    """In-memory Chrome-trace event buffer.

    >>> tr = Tracer()
    >>> with tr.span("outer"):
    ...     with tr.span("inner", k=3):
    ...         tr.instant("mark")
    >>> [e["name"] for e in sorted(tr.events, key=lambda e: e["ts"])]
    ['outer', 'inner', 'mark']
    >>> tr.to_chrome()["traceEvents"][0]["ph"] in ("X", "i")
    True
    """

    def __init__(self):
        self._lock = threading.Lock()
        self.events: list[dict] = []
        self._t0 = time.perf_counter()
        self._pid = os.getpid()

    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _record(self, event: dict) -> None:
        with self._lock:
            self.events.append(event)

    @contextmanager
    def span(self, name: str, **args):
        """Record a complete event covering the with-block's duration."""
        tid = threading.get_ident()
        ts = self.now_us()
        try:
            yield
        finally:
            dur = self.now_us() - ts
            ev = {"name": name, "ph": "X", "ts": ts, "dur": dur,
                  "pid": self._pid, "tid": tid}
            if args:
                ev["args"] = args
            self._record(ev)

    def instant(self, name: str, **args) -> None:
        """Record a zero-duration marker (thread-scoped instant event)."""
        ev = {"name": name, "ph": "i", "s": "t", "ts": self.now_us(),
              "pid": self._pid, "tid": threading.get_ident()}
        if args:
            ev["args"] = args
        self._record(ev)

    # -- export -----------------------------------------------------------
    def to_chrome(self) -> dict:
        """The Chrome Trace Event JSON object (loadable as-is)."""
        with self._lock:
            events = sorted(self.events, key=lambda e: e["ts"])
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path: str) -> str:
        """Write the trace to `path`; returns the path for chaining."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, default=float)
        return path

    def span_names(self) -> list[str]:
        with self._lock:
            return sorted({e["name"] for e in self.events})

    def clear(self) -> None:
        with self._lock:
            self.events.clear()
            self._t0 = time.perf_counter()

    def __len__(self) -> int:
        with self._lock:
            return len(self.events)
