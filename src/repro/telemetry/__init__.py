"""Unified telemetry: metrics registry + span tracing + device taps.

One switch, three layers:

- **Metrics** (`metrics.MetricsRegistry`): labeled counters / gauges /
  histograms with JSON-lines and Prometheus-text export.  The process
  default registry lives here; instrumented layers record through the
  module-level helpers below.
- **Tracing** (`tracing.Tracer`): `span(name)` / `instant(name)` events
  with Chrome-trace export — driver slices, compiles, checkpoint
  writes, and admission/rebucket decisions on one timeline.
- **Taps** (`taps`): jit-safe per-iteration series out of compiled VB
  steps.  Device-side `taps.tap(...)` insertion has its OWN switch
  (`taps.enable()`) because inserting an `io_callback` changes the
  jaxpr and forces a recompile; everything else here is host-side only
  and can never change a compiled program.

Disabled (the default) must be free: every helper below is a single
module-bool check before touching any registry/tracer state, so
instrumented hot paths (driver tick, kernel wrappers, `vb_run`) cost
one branch when telemetry is off.  `tests/test_telemetry.py` pins that
the `vb_step` jaxpr and driver compile counts are byte-identical with
telemetry disabled, and `tools/bench_gate.py` enforces the
`vb_driver_poisson` row so the disabled-path overhead stays
unmeasurable.

Typical use (see docs/observability.md for the catalogue)::

    from repro import telemetry

    telemetry.enable()
    ... run a driver / vb_run ...
    telemetry.export_chrome_trace("trace.json")   # chrome://tracing
    open("metrics.prom", "w").write(telemetry.to_prometheus())
    telemetry.disable(); telemetry.reset()        # tests
"""
from __future__ import annotations

from contextlib import contextmanager, nullcontext

from . import taps
from .metrics import DEFAULT_BUCKETS, MetricsRegistry
from .tracing import Tracer

__all__ = [
    "MetricsRegistry", "Tracer", "DEFAULT_BUCKETS", "taps",
    "enable", "disable", "enabled", "enabled_scope", "reset",
    "registry", "tracer",
    "inc", "set_gauge", "observe",
    "span", "instant",
    "snapshot", "to_jsonl", "to_prometheus", "export_chrome_trace",
    "warn_once",
]

_ENABLED = False
_REGISTRY = MetricsRegistry()
_TRACER = Tracer()
_NULL_CONTEXT = nullcontext()
_WARNED: set = set()


def enable() -> None:
    """Turn on host-side telemetry (metrics + spans).  Device taps have
    a separate switch — `telemetry.taps.enable()` — because they change
    jaxprs; enabling host telemetry alone never recompiles anything."""
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    global _ENABLED
    _ENABLED = False


def enabled() -> bool:
    return _ENABLED


@contextmanager
def enabled_scope():
    """Enable host telemetry for a with-block (tests, benchmarks)."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = True
    try:
        yield
    finally:
        _ENABLED = prev


def reset() -> None:
    """Clear metrics, trace events, tap buffers, and warn-once state."""
    _REGISTRY.clear()
    _TRACER.clear()
    taps.clear()
    _WARNED.clear()


def registry() -> MetricsRegistry:
    return _REGISTRY


def tracer() -> Tracer:
    return _TRACER


# -- fast-path recording helpers (no-ops when disabled) -------------------
def inc(name: str, value: float = 1.0, **labels) -> None:
    if _ENABLED:
        _REGISTRY.counter(name, **labels).inc(value)


def set_gauge(name: str, value: float, **labels) -> None:
    if _ENABLED:
        _REGISTRY.gauge(name, **labels).set(value)


def observe(name: str, value: float, **labels) -> None:
    if _ENABLED:
        _REGISTRY.histogram(name, **labels).observe(value)


def span(name: str, **args):
    """Context manager: a Chrome-trace complete event, or a shared null
    context when disabled (one bool check, zero allocation)."""
    if _ENABLED:
        return _TRACER.span(name, **args)
    return _NULL_CONTEXT


def instant(name: str, **args) -> None:
    if _ENABLED:
        _TRACER.instant(name, **args)


def warn_once(key: str, message: str, category=UserWarning,
              stacklevel: int = 2) -> bool:
    """Issue `warnings.warn(message)` only the first time `key` is seen
    this session (cleared by `reset()`).  Returns True when the warning
    fired — callers pair it with an unconditional counter so repeat
    occurrences stay countable even though they stop warning.  Active
    regardless of the enabled switch: deduplicating a warning is not
    telemetry overhead, it removes log spam."""
    if key in _WARNED:
        return False
    _WARNED.add(key)
    import warnings
    warnings.warn(message, category, stacklevel=stacklevel + 1)
    return True


# -- export ---------------------------------------------------------------
def snapshot() -> list:
    return _REGISTRY.snapshot()


def to_jsonl() -> str:
    return _REGISTRY.to_jsonl()


def to_prometheus() -> str:
    return _REGISTRY.to_prometheus()


def export_chrome_trace(path: str) -> str:
    return _TRACER.export_chrome_trace(path)
