"""Multi-tenant VB serving: fleets of sensor-network sessions.

The LM serving engine (serving/engine.py) admits token requests, batches
them, and steps the batch; this module gives the VB core the same shape.
A `VBRequest` is one independent sensor network (dataset + topology +
hyper + iteration budget); the `VBService` is the stable public API over
the continuous-batching scheduler in `serving/driver.py`:

* **admits** requests into fleet groups keyed by the BUCKETED data
  shape signature plus the static run configuration: per-node data
  buffers are padded with mask-zero slots up to a shared capacity-ladder
  rung (`admission.bucket_capacity`, bit-equal by the engine's ordered
  reductions) and per-iteration hyperparameters like the schedule's tau
  or ADMM's rho are lifted to per-slot fleet arrays
  (`engine.hyper_names`) — so mixed-shape, mixed-hyper tenants run as
  ONE device batch (docs/bucketed-admission.md);
* **fleet-batches** each group along a leading slot axis: the engine's
  one-iteration kernel (`engine.session_step_fn`) is vmapped over the
  fleet, so 16 networks cost one compiled step, not 16 — and composes
  with `engine.MeshExecutor`, putting the vmap INSIDE a shard_map body
  so the node axis is sharded while the fleet axis is vectorised;
* **schedules continuously** (`serving/driver.py`): sessions join and
  leave their fleet mid-flight with zero recompilation (fixed-capacity
  slots with `max_fleet`, power-of-two auto-growth otherwise), finished
  sessions are EVICTED at slice boundaries so their slots go back to the
  arrival queue, and `run` is a thin drive-to-drain wrapper over
  `driver.tick()`; `start()`/`drain()`/`stop()` expose the background
  scheduler thread for real-time arrival workloads;
* supports **mid-flight data arrival** between slices — the streaming
  scenario the paper is written for: `push_data` appends new
  observations into a node's padding slots (`model.append_node_data`,
  fixed-capacity buffers so the compiled step survives) and
  `replace_data` swaps a session's buffers wholesale; both un-latch the
  session's convergence flag, re-queueing an already-evicted session;
* **checkpoints** sessions via `checkpoint/ckpt.py`: `save_session`
  writes one session's full resumable state (phi, absolute t, topology
  carry, stream state, budget/tol bookkeeping, data buffers) — on the
  background `CheckpointWriter` thread with `wait=False` — and
  `submit(request, restore_from=path)` resumes it — bit-exact, because
  the engine keys every per-iteration source of randomness on the
  absolute t (see `engine.VBState`).

Example::

    svc = VBService(slice_iters=20, max_fleet=8)
    rid = svc.submit(VBRequest(model=mdl, data=(x, mask),
                               topology=engine.Diffusion(W),
                               n_iters=400, tol=1e-8))
    results = svc.run()            # drive every admitted session to done
    results[rid].phi               # (N, P) final natural parameters
    svc.stats()                    # DriverStats: compiles/occupancy/...
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

from repro.core import engine
from repro.data import stream as stream_lib
from repro.serving.driver import (DriverStats, SessionStatus,  # noqa: F401
                                  VBDriver)


class VBRequest(NamedTuple):
    """One tenant: an independent sensor network to run to convergence.

    model / data / topology / schedule / replication / minibatch mean
    exactly what they mean for `engine.run_vb`; `n_iters` is the
    iteration BUDGET (the session finishes early if `tol` is hit) and
    `tol` (> 0 to enable) is the early-stop threshold on the rms
    per-iteration change of the natural parameters.
    """

    model: Any
    data: Any
    topology: Any
    n_iters: int
    schedule: engine.Schedule = engine.Schedule()
    replication: Optional[float] = None
    init_phi: Any = None
    minibatch: Optional[stream_lib.MinibatchSpec] = None
    tol: float = 0.0


class VBService:
    """Admit, batch, step, stream into, and checkpoint VB sessions.

    slice_iters : iterations per slice — the scheduling quantum: between
        slices the driver admits arrivals, evicts finished sessions,
        applies pushed data, checkpoints, or answers status.
    executor : optional `engine.MeshExecutor` — shard every fleet's node
        axis over a mesh axis (the fleet vmap moves inside the
        shard_map body).
    max_fleet : fixed fleet capacity (continuous batching: arrivals
        beyond it queue until an eviction frees a slot, with zero
        recompilation); None = power-of-two auto-growth.
    bucket / bucket_min : capacity-bucketed admission (see `VBDriver`):
        "pow2" (default) pads data buffers to power-of-two ladder rungs
        so near-same-shape sessions share one compiled fleet; a float
        > 1 is a custom ladder growth factor; None = exact-signature
        grouping only.
    ckpt_dir / ckpt_every : background-checkpoint every occupied slot
        each `ckpt_every` slices into `<ckpt_dir>/<rid>.npz`.
    """

    def __init__(self, *, slice_iters: int = 25,
                 executor: Optional[engine.MeshExecutor] = None,
                 max_fleet: Optional[int] = None,
                 bucket: Optional[str | float] = "pow2",
                 bucket_min: int = 8,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 0):
        self.driver = VBDriver(slice_iters=slice_iters, executor=executor,
                               max_fleet=max_fleet, bucket=bucket,
                               bucket_min=bucket_min, ckpt_dir=ckpt_dir,
                               ckpt_every=ckpt_every)

    @property
    def slice_iters(self) -> int:
        return self.driver.slice_iters

    @property
    def executor(self):
        return self.driver.executor

    @property
    def _groups(self):
        return self.driver._groups

    # -- admission --------------------------------------------------------
    def submit(self, req: VBRequest, *, arrive_at: Optional[int] = None,
               restore_from: Optional[str] = None) -> str:
        """Admit one session; returns its id.  `arrive_at` defers
        admission to that slice boundary; `restore_from` loads a
        `save_session` checkpoint into the fresh slot (the request must
        describe the same shapes), resuming it bit-exactly."""
        return self.driver.submit(req, arrive_at=arrive_at,
                                  restore_from=restore_from)

    # -- stepping ---------------------------------------------------------
    def step_slice(self) -> int:
        """Advance every group with active sessions by one slice (one
        driver tick); returns the number of sessions still open."""
        return self.driver.tick()

    def run(self, max_slices: Optional[int] = None):
        """Drive every submitted session to done (or `max_slices`);
        returns {rid: SessionStatus}."""
        n = 0
        while self.driver.tick() > 0:
            n += 1
            if max_slices is not None and n >= max_slices:
                break
        self.driver.flush_checkpoints()
        return {rid: self.status(rid) for rid in self.driver.sessions}

    def start(self) -> None:
        """Start the background scheduler: submissions and pushed data
        are picked up at slice boundaries without a host driving loop."""
        self.driver.start()

    def drain(self) -> None:
        """Block until every submitted session is done (background or
        inline) and all background checkpoint writes landed."""
        self.driver.drain()

    def stop(self) -> None:
        self.driver.stop()

    # -- observation ------------------------------------------------------
    def status(self, rid: str) -> SessionStatus:
        return self.driver.status(rid)

    def stats(self) -> DriverStats:
        return self.driver.stats()

    @property
    def sessions(self) -> list[str]:
        return self.driver.sessions

    # -- mid-flight data arrival -----------------------------------------
    def push_data(self, rid: str, node: int, points: Any) -> None:
        """Append freshly-arrived observations to one node's buffer
        (into padding slots — `model.append_node_data`) and un-latch the
        session's convergence flag so it keeps iterating on the new
        evidence; an evicted session re-enters the arrival queue."""
        self.driver.push_data(rid, node, points)

    def replace_data(self, rid: str, data: Any) -> None:
        """Replace a session's data buffers wholesale (same shapes)."""
        self.driver.replace_data(rid, data)

    def extend_budget(self, rid: str, extra_iters: int) -> None:
        self.driver.extend_budget(rid, extra_iters)

    # -- checkpointing ----------------------------------------------------
    def save_session(self, rid: str, path: str, *, wait: bool = True) -> str:
        """Write one session's full resumable state (incl. data buffers
        and budget bookkeeping) as a `checkpoint/ckpt.py` .npz; with
        `wait=False` the write happens on the background writer."""
        return self.driver.save_session(rid, path, wait=wait)
