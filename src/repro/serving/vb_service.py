"""Multi-tenant VB serving: fleets of sensor-network sessions.

The LM serving engine (serving/engine.py) admits token requests, batches
them, and steps the batch; this module gives the VB core the same shape.
A `VBRequest` is one independent sensor network (dataset + topology +
hyper + iteration budget); the `VBService`:

* **admits** requests into fleet groups keyed by
  `admission.shape_signature(data)` plus the static run configuration —
  sessions that share model/topology objects, data shapes and hyper run
  as ONE device batch;
* **fleet-batches** each group along a leading session axis: the
  engine's one-iteration kernel (`engine.session_step_fn`) is vmapped
  over the fleet, so 16 networks cost one compiled step, not 16 — and
  composes with `engine.MeshExecutor`, putting the vmap INSIDE a
  shard_map body so the node axis is sharded while the fleet axis is
  vectorised;
* **steps in slices** (`slice_iters` iterations per `step_slice` call),
  with per-session budgets and early stop: a session whose rms phi
  change per iteration falls under its `tol` (or whose budget is
  exhausted) freezes in place — its state stops evolving and its
  absolute `t` stops counting — while its fleet-mates keep iterating;
* supports **mid-flight data arrival** between slices — the streaming
  scenario the paper is written for: `push_data` appends new
  observations into a node's padding slots (`model.append_node_data`,
  fixed-capacity buffers so the compiled step survives) and
  `replace_data` swaps a session's buffers wholesale; both un-latch the
  session's convergence flag;
* **checkpoints** sessions via `checkpoint/ckpt.py`: `save_session`
  writes one session's full resumable state (phi, absolute t, topology
  carry, stream state, budget/tol bookkeeping, data buffers) and
  `submit(request, restore_from=path)` resumes it — bit-exact, because
  the engine keys every per-iteration source of randomness on the
  absolute t (see `engine.VBState`).

Example::

    svc = VBService(slice_iters=20)
    rid = svc.submit(VBRequest(model=mdl, data=(x, mask),
                               topology=engine.Diffusion(W),
                               n_iters=400, tol=1e-8))
    results = svc.run()            # drive every admitted session to done
    results[rid].phi               # (N, P) final natural parameters
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core import engine
from repro.data import stream as stream_lib
from repro.serving import admission


class VBRequest(NamedTuple):
    """One tenant: an independent sensor network to run to convergence.

    model / data / topology / schedule / replication / minibatch mean
    exactly what they mean for `engine.run_vb`; `n_iters` is the
    iteration BUDGET (the session finishes early if `tol` is hit) and
    `tol` (> 0 to enable) is the early-stop threshold on the rms
    per-iteration change of the natural parameters.
    """

    model: Any
    data: Any
    topology: Any
    n_iters: int
    schedule: engine.Schedule = engine.Schedule()
    replication: Optional[float] = None
    init_phi: Any = None
    minibatch: Optional[stream_lib.MinibatchSpec] = None
    tol: float = 0.0


class SessionStatus(NamedTuple):
    """Host-side snapshot of one admitted session."""

    rid: str
    t: int                  # absolute iterations actually applied
    budget: int
    converged: bool         # early-stop latch (tol reached)
    done: bool              # converged or budget exhausted
    delta: float            # last applied step's rms phi change
    phi: Any                # (N, P) current natural parameters


def _tree_stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _tree_index(tree, i):
    return jax.tree_util.tree_map(lambda leaf: leaf[i], tree)


def _tree_set(tree, i, value):
    return jax.tree_util.tree_map(lambda leaf, v: leaf.at[i].set(v),
                                  tree, value)


def _gated_step(step_fn, axis=None):
    """Wrap the engine's one-iteration kernel with per-session budget /
    early-stop gating: inactive sessions (converged, or budget spent)
    keep their state bit-for-bit and their absolute t frozen, so a
    session that early-stops inside a fleet ends in exactly the state a
    solo `vb_run` of the same length would have produced.  Under the
    mesh executor (`axis`) the early-stop delta is pmean-reduced so
    every shard takes the identical stop decision."""

    def one(data, phi, carry, st, t, conv, budget, tol, delta_prev):
        active = jnp.logical_and(~conv, t < budget)
        phi2, carry2, st2, _ = step_fn(data, phi, carry, st, t)
        msq = jnp.mean((phi2 - phi) ** 2)
        if axis is not None:
            msq = jax.lax.pmean(msq, axis)
        delta = jnp.sqrt(msq).astype(phi.dtype)
        conv2 = jnp.logical_or(conv,
                               jnp.logical_and(tol > 0.0, delta < tol))
        gate = lambda new, old: jax.tree_util.tree_map(
            lambda a, b: jnp.where(active, a, b), new, old)
        return (jnp.where(active, phi2, phi),
                gate(carry2, carry),
                gate(st2, st),
                t + active.astype(t.dtype),
                jnp.where(active, conv2, conv),
                jnp.where(active, delta, delta_prev))

    return one


def _slice_scan(one, k):
    """k gated iterations over the vmapped fleet as one lax.scan."""

    def slice_fn(data, phi, carry, st, t, conv, budget, tol, delta):
        def body(c, _):
            phi, carry, st, t, conv, delta = c
            return jax.vmap(one)(data, phi, carry, st, t, conv, budget,
                                 tol, delta), None

        init = (phi, carry, st, t, conv, delta)
        (phi, carry, st, t, conv, delta), _ = jax.lax.scan(
            body, init, None, length=k)
        return phi, carry, st, t, conv, delta

    return slice_fn


class _Group:
    """One fleet: same-shape sessions batched along a leading axis."""

    def __init__(self, session: engine.VBSession, executor):
        self.session = session          # template (data ignored per-slot)
        self.executor = executor
        self.rids: list[str] = []
        self.data = None                # stacked (B, ...) pytree
        self.phi = self.carry = self.stream = None
        self.t = self.conv = self.budget = self.tol = self.delta = None
        self._compiled = {}             # (k, B) -> jitted slice fn

    @property
    def size(self) -> int:
        return len(self.rids)

    def add(self, rid: str, state: engine.VBState, budget: int, tol: float):
        dt = state.phi.dtype
        one_data = state.session.data
        new = dict(
            data=_tree_stack([one_data]), phi=_tree_stack([state.phi]),
            carry=_tree_stack([state.carry]),
            stream=_tree_stack([state.stream]),
            t=state.t[None], conv=jnp.zeros((1,), bool),
            budget=jnp.asarray([budget], state.t.dtype),
            tol=jnp.asarray([tol], dt), delta=jnp.zeros((1,), dt))
        if self.rids:
            for name, val in new.items():
                cur = getattr(self, name)
                setattr(self, name, jax.tree_util.tree_map(
                    lambda a, b: jnp.concatenate([a, b]), cur, val))
            self._compiled.clear()      # fleet size changed -> recompile
        else:
            for name, val in new.items():
                setattr(self, name, val)
        self.rids.append(rid)

    # -- slice execution --------------------------------------------------
    def _slice_fn(self, k: int):
        key = (k, self.size)
        if key not in self._compiled:
            if self.executor is None:
                one = _gated_step(engine.session_step_fn(self.session))
                self._compiled[key] = jax.jit(_slice_scan(one, k))
            else:
                self._compiled[key] = self._mesh_slice_fn(k)
        return self._compiled[key]

    def _mesh_slice_fn(self, k: int):
        """MeshExecutor composition: shard_map over the NODE axis with
        the fleet vmap inside — the fleet axis is a plain leading batch
        axis on every shard, the topology collectives run over the mesh
        axis exactly as in `engine._run_vb_sharded`."""
        from jax.sharding import PartitionSpec as P

        from repro.dist import compat, sharding

        mesh, axis = self.executor.mesh, self.executor.axis
        ses = self.session
        topology = ses.topology
        local_inputs = topology.shard_inputs()
        local_keys = tuple(sorted(local_inputs))

        # ONE partitioning rule: take the engine executor's state specs
        # (dist/sharding.vb_node_specs) and shift every state slot one
        # axis right for the leading fleet dimension; the topology's
        # shard_inputs rows are fleet-shared and keep their specs.
        has_carry = self.carry is not None
        has_stream = self.stream is not None
        base_in, _ = sharding.vb_node_specs(
            self.data, axis=axis, has_carry=has_carry,
            n_local=len(local_keys),
            carry_specs=topology.carry_specs(axis) if has_carry else None,
            stream_specs=(stream_lib.StreamState(
                keys=P(axis), perm=P(axis), epoch=P())
                if has_stream else None))
        data_b, phi_b, carry_b, stream_b = base_in[:4]
        local_specs = base_in[4:]

        def fleet(spec):                # unbatched spec -> fleet spec
            return jax.tree_util.tree_map(
                lambda s: P(*((None,) + tuple(s))), spec,
                is_leaf=lambda s: isinstance(s, P))

        data_specs = fleet(data_b)
        phi_spec = fleet(phi_b)
        carry_spec = fleet(carry_b) if has_carry else carry_b
        stream_spec = fleet(stream_b) if has_stream else stream_b
        rep = P()                       # per-session scalars: replicated
        in_specs = (data_specs, phi_spec, carry_spec, stream_spec,
                    rep, rep, rep, rep, rep) + local_specs
        out_specs = (phi_spec, carry_spec, stream_spec, rep, rep, rep)

        def run(data_l, phi_l, carry_l, st_l, t, conv, budget, tol, delta,
                *local_vals):
            local = dict(zip(local_keys, local_vals))
            one = _gated_step(
                engine.session_step_fn(ses, axis=axis, local=local),
                axis=axis)
            return _slice_scan(one, k)(data_l, phi_l, carry_l, st_l, t,
                                       conv, budget, tol, delta)

        fn = compat.shard_map(run, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=False)

        def call(data, phi, carry, st, t, conv, budget, tol, delta):
            return fn(data, phi, carry, st, t, conv, budget, tol, delta,
                      *(local_inputs[kk] for kk in local_keys))

        return call

    def step_slice(self, k: int) -> None:
        out = self._slice_fn(k)(self.data, self.phi, self.carry,
                                self.stream, self.t, self.conv,
                                self.budget, self.tol, self.delta)
        (self.phi, self.carry, self.stream, self.t, self.conv,
         self.delta) = out

    # -- host-side views --------------------------------------------------
    def done_mask(self) -> np.ndarray:
        return np.asarray(self.conv) | (np.asarray(self.t)
                                        >= np.asarray(self.budget))

    def state_tree(self, i: int) -> dict:
        """One session's full resumable state (checkpoint payload)."""
        return dict(phi=self.phi[i], t=self.t[i],
                    carry=_tree_index(self.carry, i),
                    stream=_tree_index(self.stream, i),
                    conv=self.conv[i], budget=self.budget[i],
                    tol=self.tol[i], delta=self.delta[i],
                    data=_tree_index(self.data, i))

    def load_state_tree(self, i: int, tree: dict) -> None:
        self.phi = self.phi.at[i].set(tree["phi"])
        self.t = self.t.at[i].set(tree["t"])
        self.carry = _tree_set(self.carry, i, tree["carry"])
        self.stream = _tree_set(self.stream, i, tree["stream"])
        self.conv = self.conv.at[i].set(tree["conv"])
        self.budget = self.budget.at[i].set(tree["budget"])
        self.tol = self.tol.at[i].set(tree["tol"])
        self.delta = self.delta.at[i].set(tree["delta"])
        self.data = _tree_set(self.data, i, tree["data"])


def _static_sig(obj):
    """Hashable structural signature of a model/topology configuration.

    Two separately-constructed objects of the same type whose attributes
    agree — with ARRAYS compared by identity, so `Diffusion(W)` built
    twice over the same weight matrix signs equal — produce the same
    signature and therefore share a fleet group.  Anything unrecognised
    falls back to object identity (conservative: splits groups, never
    wrongly merges them).
    """
    if isinstance(obj, (int, float, bool, str, bytes, type(None))):
        return obj
    if isinstance(obj, (jnp.ndarray, np.ndarray)):
        return ("arr", id(obj))
    if isinstance(obj, tuple):           # incl. NamedTuples (Schedule etc.)
        return (type(obj).__name__,) + tuple(_static_sig(v) for v in obj)
    if hasattr(obj, "__dict__") or hasattr(obj, "__slots__"):
        names = (sorted(vars(obj)) if hasattr(obj, "__dict__")
                 else sorted(n for n in obj.__slots__ if hasattr(obj, n)))
        return (type(obj).__name__,) + tuple(
            (n, _static_sig(getattr(obj, n))) for n in names)
    try:
        hash(obj)
        return obj
    except TypeError:
        return ("id", id(obj))


class VBService:
    """Admit, batch, step, stream into, and checkpoint VB sessions.

    slice_iters : iterations per `step_slice` call — the scheduling
        quantum: between slices the host may admit more sessions, push
        freshly-arrived data, checkpoint, or inspect status.
    executor : optional `engine.MeshExecutor` — shard every fleet's node
        axis over a mesh axis (the fleet vmap moves inside the
        shard_map body).
    """

    def __init__(self, *, slice_iters: int = 25,
                 executor: Optional[engine.MeshExecutor] = None):
        if slice_iters < 1:
            raise ValueError(f"slice_iters must be >= 1: {slice_iters}")
        self.slice_iters = slice_iters
        self.executor = executor
        self._groups: dict[tuple, _Group] = {}
        self._where: dict[str, tuple[tuple, int]] = {}  # rid -> (key, idx)
        self._counter = 0

    # -- admission --------------------------------------------------------
    def _group_key(self, req: VBRequest) -> tuple:
        # structural signatures (arrays by identity), so tenants built as
        # `Diffusion(W)` per request still share one fleet as long as
        # they share the weight matrix / adjacency / prior arrays
        return (_static_sig(req.model), _static_sig(req.topology),
                admission.shape_signature(req.data), req.schedule,
                req.replication, req.minibatch)

    def submit(self, req: VBRequest, *,
               restore_from: Optional[str] = None) -> str:
        """Admit one session; returns its id.  `restore_from` loads a
        `save_session` checkpoint into the fresh slot (the request must
        describe the same shapes), resuming it bit-exactly."""
        if req.n_iters < 1:
            raise ValueError(f"n_iters must be >= 1: {req.n_iters}")
        state = engine.vb_init(
            req.model, req.data, req.topology, schedule=req.schedule,
            replication=req.replication, init_phi=req.init_phi,
            minibatch=req.minibatch, diagnostics=False)
        key = self._group_key(req)
        group = self._groups.get(key)
        if group is None:
            group = _Group(state.session, self.executor)
            self._groups[key] = group
        rid = f"s{self._counter:04d}"
        self._counter += 1
        group.add(rid, state, req.n_iters, req.tol)
        self._where[rid] = (key, group.size - 1)
        if restore_from is not None:
            idx = group.size - 1
            restored = ckpt.restore(restore_from, group.state_tree(idx))
            group.load_state_tree(idx, restored)
        return rid

    def _locate(self, rid: str) -> tuple[_Group, int]:
        if rid not in self._where:
            raise KeyError(f"unknown session {rid!r}")
        key, idx = self._where[rid]
        return self._groups[key], idx

    # -- stepping ---------------------------------------------------------
    def step_slice(self) -> int:
        """Advance every group with unfinished sessions by one slice;
        returns the number of sessions still not done."""
        for group in self._groups.values():
            if not bool(group.done_mask().all()):
                group.step_slice(self.slice_iters)
        return int(sum((~g.done_mask()).sum()
                       for g in self._groups.values()))

    def run(self, max_slices: Optional[int] = None):
        """Drive every admitted session to done (or `max_slices`);
        returns {rid: SessionStatus}."""
        n = 0
        while self.step_slice() > 0:
            n += 1
            if max_slices is not None and n >= max_slices:
                break
        return {rid: self.status(rid) for rid in self._where}

    # -- observation ------------------------------------------------------
    def status(self, rid: str) -> SessionStatus:
        group, i = self._locate(rid)
        t = int(group.t[i])
        budget = int(group.budget[i])
        conv = bool(group.conv[i])
        return SessionStatus(rid=rid, t=t, budget=budget, converged=conv,
                             done=conv or t >= budget,
                             delta=float(group.delta[i]),
                             phi=group.phi[i])

    @property
    def sessions(self) -> list[str]:
        return list(self._where)

    # -- mid-flight data arrival -----------------------------------------
    def push_data(self, rid: str, node: int, points: Any) -> None:
        """Append freshly-arrived observations to one node's buffer
        (into padding slots — `model.append_node_data`) and un-latch the
        session's convergence flag so it keeps iterating on the new
        evidence."""
        group, i = self._locate(rid)
        data_i = _tree_index(group.data, i)
        new = group.session.model.append_node_data(data_i, node, points)
        group.data = _tree_set(group.data, i, new)
        group.conv = group.conv.at[i].set(False)

    def replace_data(self, rid: str, data: Any) -> None:
        """Replace a session's data buffers wholesale (same shapes)."""
        group, i = self._locate(rid)
        sig_new = admission.shape_signature(data)
        sig_old = admission.shape_signature(_tree_index(group.data, i))
        if sig_new != sig_old:
            raise ValueError(
                f"replace_data: shape signature mismatch "
                f"({sig_new} != {sig_old})")
        group.data = _tree_set(group.data, i, data)
        group.conv = group.conv.at[i].set(False)

    def extend_budget(self, rid: str, extra_iters: int) -> None:
        group, i = self._locate(rid)
        group.budget = group.budget.at[i].add(extra_iters)
        group.conv = group.conv.at[i].set(False)

    # -- checkpointing ----------------------------------------------------
    def save_session(self, rid: str, path: str) -> str:
        """Write one session's full resumable state (incl. data buffers
        and budget bookkeeping) as a `checkpoint/ckpt.py` .npz."""
        group, i = self._locate(rid)
        return ckpt.save(path, group.state_tree(i))
