"""Request-admission helpers shared by the serving engines.

Both serving stacks admit heterogeneous requests and must turn them into
fixed-shape device batches:

* the LM `serving.engine.Engine` admits variable-length prompts and packs
  them into one right-aligned (B, L) token batch (`right_aligned_batch`),
  grouping prompts into waves by `bucket_capacity` rung when bucketing is
  enabled;
* the VB `serving.vb_service.VBService` admits sensor-network sessions:
  requests whose data pytrees agree in shape and dtype
  (`shape_signature`) share a vmapped fleet, and the BUCKET LADDER below
  (`bucket_capacity` / `bucket_for`) lets near-same-shape sessions share
  one too — each session's per-node data capacity is padded up to the
  next ladder rung with mask-zero slots (`model.pad_to_capacity`), which
  the engine's ordered reductions keep bit-equal to the unpadded run
  (docs/bucketed-admission.md).  Everything here keys on the model's
  protocol surface only (`data_mask` / `pad_to_capacity`, both
  block-layer defaults since PR 9), so the whole model zoo — GMM, LinReg,
  HMM, PPCA (docs/model-zoo.md) — buckets identically with zero
  per-model code.

One home for those rules so the two engines cannot drift apart, plus
`data_axis_mesh` — the "1-D data mesh over whatever devices exist" both
serving smokes want (the LM smoke used to hardcode a single-device mesh).
"""
from __future__ import annotations

import hashlib

import jax
import numpy as np

from repro import telemetry

# Arrays at or under this many bytes are signed by content digest in
# `static_signature`; larger ones fall back to identity (conservative:
# splits groups, never wrongly merges them — and never pays an O(size)
# hash on a big data buffer at admission time).
DIGEST_MAX_BYTES = 1 << 16


def bucket_capacity(n: int, *, growth: float = 2.0,
                    min_size: int = 8) -> int:
    """Smallest ladder rung >= n: the bucketed capacity a session of true
    per-node data capacity `n` is padded to.  Rungs start at `min_size`
    and grow geometrically by `growth` (2.0 = power-of-two; ~1.25 gives
    the finer tensor2tensor-style boundaries ladder, at most ~25% padded
    slots per node at the cost of more distinct compiled fleets).

    >>> [bucket_capacity(n) for n in (1, 8, 9, 25, 64, 65)]
    [8, 8, 16, 32, 64, 128]
    >>> bucket_capacity(25, growth=1.25, min_size=8)   # 8,10,13,17,22,28
    28
    """
    if n < 1:
        raise ValueError(f"capacity must be >= 1: {n}")
    if growth <= 1.0:
        raise ValueError(f"growth must be > 1.0: {growth}")
    cap = int(min_size)
    while cap < n:
        # max(+1) keeps the ladder strictly increasing for tiny growth
        cap = max(cap + 1, int(-(-cap * growth // 1)))
    # bucket-decision observability: which rungs admissions land on, and
    # how many padded slots each decision costs (docs/observability.md)
    telemetry.inc("admission_bucket_total", rung=cap)
    telemetry.inc("admission_padded_slots_total", value=cap - n)
    return cap


def bucket_for(signature: tuple, *, growth: float = 2.0,
               min_size: int = 8) -> tuple:
    """Bucketed admission key: a `shape_signature` with every array
    entry's SECOND axis (the per-node sample/capacity axis of stacked
    sensor-network data) rounded up to its ladder rung.  Two sessions
    whose signatures bucket equal may share one compiled fleet once
    their data is padded to the rung (`model.pad_to_capacity`).

    >>> import jax.numpy as jnp
    >>> a = shape_signature((jnp.zeros((4, 25, 2)), jnp.zeros((4, 25))))
    >>> b = shape_signature((jnp.zeros((4, 32, 2)), jnp.zeros((4, 32))))
    >>> bucket_for(a) == bucket_for(b)
    True
    >>> bucket_for(a) == bucket_for(shape_signature(jnp.zeros((5, 25))))
    False
    """
    def one(entry):
        shape, dtype = entry
        if len(shape) >= 2:
            shape = (shape[0],
                     bucket_capacity(shape[1], growth=growth,
                                     min_size=min_size)) + shape[2:]
        return (shape, dtype)

    return (signature[0],) + tuple(one(e) for e in signature[1:])


def right_aligned_batch(seqs, length: int | None = None,
                        dtype=np.int32, pad_value: int = 0) -> np.ndarray:
    """Stack variable-length 1-D sequences into a right-aligned (B, L)
    array (left-padded with `pad_value`), the layout the LM prefill
    expects.  `length` pads to a fixed L and must cover the longest
    sequence (ValueError otherwise — truncation is the caller's policy);
    default: the longest sequence.

    >>> right_aligned_batch([[1, 2, 3], [7]]).tolist()
    [[1, 2, 3], [0, 0, 7]]
    >>> right_aligned_batch([[1, 2]], length=4).tolist()
    [[0, 0, 1, 2]]
    """
    seqs = [np.asarray(s, dtype) for s in seqs]
    longest = max((s.shape[0] for s in seqs), default=0)
    if length is None:
        length = longest
    if length < longest:
        raise ValueError(f"length {length} < longest sequence {longest}")
    out = np.full((len(seqs), length), pad_value, dtype)
    for i, s in enumerate(seqs):
        if s.shape[0]:
            out[i, length - s.shape[0]:] = s
    return out


def shape_signature(tree) -> tuple:
    """Hashable shape/dtype signature of a pytree — requests whose data
    signatures (and static hyper) agree may share one compiled batch.

    >>> import jax.numpy as jnp
    >>> a = (jnp.zeros((3, 4)), jnp.zeros((3,), jnp.int32))
    >>> b = (jnp.ones((3, 4)), jnp.ones((3,), jnp.int32))
    >>> shape_signature(a) == shape_signature(b)
    True
    >>> shape_signature(a) == shape_signature((a[0],))
    False
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return (str(treedef),) + tuple(
        (tuple(leaf.shape), str(leaf.dtype)) for leaf in leaves)


def static_signature(obj, *, ignore: tuple = ()):
    """Hashable structural signature of a model/topology configuration.

    Two separately-constructed objects of the same type whose attributes
    agree produce the same signature and therefore share a fleet group.
    Small arrays (<= DIGEST_MAX_BYTES) are signed by CONTENT — (shape,
    dtype, bytes digest) — so `Diffusion(W)` built twice over two
    equal-valued weight matrices signs equal; larger arrays fall back to
    object identity, as does anything unrecognised (conservative: splits
    groups, never wrongly merges them).

    `ignore` drops the named TOP-LEVEL attributes from the signature —
    the serving driver uses it to strip per-session hyperparameters that
    the engine lifts onto the fleet axis (engine.lifted_attr_names), so
    e.g. two `ADMMConsensus` topologies differing only in `rho` share a
    compiled fleet.
    """
    import jax.numpy as jnp

    if isinstance(obj, (int, float, bool, str, bytes, type(None))):
        return obj
    if isinstance(obj, (jnp.ndarray, np.ndarray)):
        a = np.asarray(obj)
        if a.nbytes <= DIGEST_MAX_BYTES:
            digest = hashlib.sha1(np.ascontiguousarray(a).tobytes())
            return ("arr", tuple(a.shape), str(a.dtype),
                    digest.hexdigest())
        return ("arr", id(obj))
    if isinstance(obj, tuple):           # incl. NamedTuples (Schedule etc.)
        return (type(obj).__name__,) + tuple(static_signature(v)
                                             for v in obj)
    if hasattr(obj, "__dict__") or hasattr(obj, "__slots__"):
        names = (sorted(vars(obj)) if hasattr(obj, "__dict__")
                 else sorted(n for n in obj.__slots__ if hasattr(obj, n)))
        return (type(obj).__name__,) + tuple(
            (n, static_signature(getattr(obj, n)))
            for n in names if n not in ignore)
    try:
        hash(obj)
        return obj
    except TypeError:
        return ("id", id(obj))


def data_axis_mesh(axis: str = "data"):
    """1-D mesh with `axis` spanning ALL available devices.  The serving
    smokes default to this instead of hardcoding a single-device mesh, so
    multi-device hosts (or XLA_FLAGS host-platform devices) are used."""
    return jax.make_mesh((jax.device_count(),), (axis,))
