"""Request-admission helpers shared by the serving engines.

Both serving stacks admit heterogeneous requests and must turn them into
fixed-shape device batches:

* the LM `serving.engine.Engine` admits variable-length prompts and packs
  them into one right-aligned (B, L) token batch (`right_aligned_batch`);
* the VB `serving.vb_service.VBService` admits sensor-network sessions
  and may only fleet-batch requests whose data pytrees agree exactly in
  shape and dtype (`shape_signature` is the admission key that decides
  which sessions share a vmapped fleet).

One home for those rules so the two engines cannot drift apart, plus
`data_axis_mesh` — the "1-D data mesh over whatever devices exist" both
serving smokes want (the LM smoke used to hardcode a single-device mesh).
"""
from __future__ import annotations

import jax
import numpy as np


def right_aligned_batch(seqs, length: int | None = None,
                        dtype=np.int32, pad_value: int = 0) -> np.ndarray:
    """Stack variable-length 1-D sequences into a right-aligned (B, L)
    array (left-padded with `pad_value`), the layout the LM prefill
    expects.  `length` pads to a fixed L and must cover the longest
    sequence (ValueError otherwise — truncation is the caller's policy);
    default: the longest sequence.

    >>> right_aligned_batch([[1, 2, 3], [7]]).tolist()
    [[1, 2, 3], [0, 0, 7]]
    >>> right_aligned_batch([[1, 2]], length=4).tolist()
    [[0, 0, 1, 2]]
    """
    seqs = [np.asarray(s, dtype) for s in seqs]
    longest = max((s.shape[0] for s in seqs), default=0)
    if length is None:
        length = longest
    if length < longest:
        raise ValueError(f"length {length} < longest sequence {longest}")
    out = np.full((len(seqs), length), pad_value, dtype)
    for i, s in enumerate(seqs):
        if s.shape[0]:
            out[i, length - s.shape[0]:] = s
    return out


def shape_signature(tree) -> tuple:
    """Hashable shape/dtype signature of a pytree — requests whose data
    signatures (and static hyper) agree may share one compiled batch.

    >>> import jax.numpy as jnp
    >>> a = (jnp.zeros((3, 4)), jnp.zeros((3,), jnp.int32))
    >>> b = (jnp.ones((3, 4)), jnp.ones((3,), jnp.int32))
    >>> shape_signature(a) == shape_signature(b)
    True
    >>> shape_signature(a) == shape_signature((a[0],))
    False
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return (str(treedef),) + tuple(
        (tuple(leaf.shape), str(leaf.dtype)) for leaf in leaves)


def static_signature(obj):
    """Hashable structural signature of a model/topology configuration.

    Two separately-constructed objects of the same type whose attributes
    agree — with ARRAYS compared by identity, so `Diffusion(W)` built
    twice over the same weight matrix signs equal — produce the same
    signature and therefore share a fleet group.  Anything unrecognised
    falls back to object identity (conservative: splits groups, never
    wrongly merges them).
    """
    import jax.numpy as jnp

    if isinstance(obj, (int, float, bool, str, bytes, type(None))):
        return obj
    if isinstance(obj, (jnp.ndarray, np.ndarray)):
        return ("arr", id(obj))
    if isinstance(obj, tuple):           # incl. NamedTuples (Schedule etc.)
        return (type(obj).__name__,) + tuple(static_signature(v)
                                             for v in obj)
    if hasattr(obj, "__dict__") or hasattr(obj, "__slots__"):
        names = (sorted(vars(obj)) if hasattr(obj, "__dict__")
                 else sorted(n for n in obj.__slots__ if hasattr(obj, n)))
        return (type(obj).__name__,) + tuple(
            (n, static_signature(getattr(obj, n))) for n in names)
    try:
        hash(obj)
        return obj
    except TypeError:
        return ("id", id(obj))


def data_axis_mesh(axis: str = "data"):
    """1-D mesh with `axis` spanning ALL available devices.  The serving
    smokes default to this instead of hardcoding a single-device mesh, so
    multi-device hosts (or XLA_FLAGS host-platform devices) are used."""
    return jax.make_mesh((jax.device_count(),), (axis,))
