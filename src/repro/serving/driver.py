"""Continuous-batching serving driver: fixed-capacity VB fleets with
mid-flight join/leave, an arrival queue, eviction, and background
checkpoint writes — the LM-inference-server scheduling model applied to
sensor-network VB sessions.

The synchronous `VBService` loop (PR 5) serialized everything: admission
resized the fleet (recompiling the slice function), a finished session's
slot kept burning device cycles until the whole group drained, and
checkpoint I/O blocked stepping.  This module replaces that with the
continuous-batching decomposition used by LM inference engines:

* **SlotTable** — host-side allocator for a FIXED-capacity fleet.  The
  compiled slice function only ever sees one `(k, capacity)` shape, so
  sessions join and leave by `.at[slot].set(...)` writes with **zero
  recompilation** (`FleetGroup` asserts this via its `compiles` counter).
* **Active mask for free** — a free or evicted slot is written as
  `conv=True, budget=0`: the per-session budget/early-stop gate that
  `_gated_step` already applies IS the active mask, so no new in-kernel
  machinery is needed and frozen slots stay bit-for-bit inert.
* **ArrivalQueue** — thread-safe `(arrive_at, seq)` heap.  `tick()`
  admits every ready arrival at the slice boundary, dispatches one slice
  per group (JAX async dispatch), does host-side work — checkpoint
  snapshots, bookkeeping — while the device runs, then syncs the small
  per-slot flag vectors and **evicts** sessions that converged or spent
  their budget, freeing their slots for the next arrival.
* **CheckpointWriter** — a daemon thread doing device→host transfer and
  .npz compression off the scheduler thread, overlapped with the
  in-flight slice.
* **Bucketed admission** — fleet groups are keyed by the BUCKETED data
  shape: per-node buffers pad with mask-zero slots up to a capacity
  ladder rung (`admission.bucket_capacity`) and per-iteration hyper
  constants (tau/d0, rho/xi) lift to per-slot fleet arrays
  (`engine.session_hyper`), so mixed-shape mixed-hyper sessions share
  one compiled fleet — bit-equal to their solo runs via the engine's
  ordered reductions (docs/bucketed-admission.md).
* **Eviction is safe** because of the absolute-`t` resumability contract
  (engine.VBState): every per-iteration source — minibatch epochs, link
  drops, eta/kappa ramps — is a pure function of the session's own `t`,
  so a session's trajectory is independent of WHEN its slices run and a
  finished-then-extended session re-enters any free slot bit-exactly.

`VBDriver` is the scheduler; `serving/vb_service.py` keeps its public
API as a thin wrapper, and `serving/engine.py`'s LM `Engine` reuses
`SlotTable`/`ArrivalQueue`/`DriverStats` for its prefill/decode waves.
"""
from __future__ import annotations

import heapq
import itertools
import os
import queue as queue_lib
import threading
import time
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.checkpoint import ckpt
from repro.core import engine
from repro.data import stream as stream_lib
from repro.serving import admission


# ---------------------------------------------------------------------------
# Generic scheduling primitives (shared with the LM serving engine)
# ---------------------------------------------------------------------------
class ArrivalQueue:
    """Thread-safe arrival queue ordered by (arrive_at, submission seq)."""

    def __init__(self):
        self._heap: list[tuple[float, int, Any]] = []
        self._lock = threading.Lock()
        self._seq = itertools.count()

    def push(self, item: Any, arrive_at: float = 0.0) -> None:
        with self._lock:
            heapq.heappush(self._heap,
                           (float(arrive_at), next(self._seq), item))

    def push_entry(self, entry: tuple[float, int, Any]) -> None:
        """Re-queue a popped entry unchanged (keeps its FIFO position)."""
        with self._lock:
            heapq.heappush(self._heap, entry)

    def pop_ready(self, now: float) -> list[tuple[float, int, Any]]:
        out = []
        with self._lock:
            while self._heap and self._heap[0][0] <= now:
                out.append(heapq.heappop(self._heap))
        return out

    def next_arrival(self) -> Optional[float]:
        with self._lock:
            return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        with self._lock:
            return len(self._heap)


class SlotTable:
    """Fixed-capacity slot allocator: which fleet row belongs to which
    request id.  Lowest free slot first, so admission is deterministic."""

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._free = list(range(self.capacity - 1, -1, -1))
        self.rids: list[Optional[str]] = [None] * self.capacity

    def alloc(self, rid: str) -> Optional[int]:
        if not self._free:
            return None
        slot = self._free.pop()
        self.rids[slot] = rid
        return slot

    def free(self, slot: int) -> Optional[str]:
        rid, self.rids[slot] = self.rids[slot], None
        self._free.append(slot)
        self._free.sort(reverse=True)
        return rid

    def grow(self, new_capacity: int) -> None:
        extra = range(self.capacity, new_capacity)
        self.rids.extend([None] * (new_capacity - self.capacity))
        self._free = sorted(self._free + list(extra), reverse=True)
        self.capacity = new_capacity

    def occupied(self) -> list[tuple[int, str]]:
        return [(i, r) for i, r in enumerate(self.rids) if r is not None]

    @property
    def n_occupied(self) -> int:
        return self.capacity - len(self._free)


class BucketStats(NamedTuple):
    """Per-fleet-group (= per admission bucket) scheduler counters."""

    label: str               # "<Model>/N<nodes>/cap<rung>" or ".../exact"
    bucket_capacity: Optional[int]  # data-capacity rung (None = unbucketed)
    slots: int               # fleet slot capacity now
    admitted: int            # sessions ever admitted into this group
    active: int              # now: occupied slots that still have work
    occupancy: float         # time-averaged active/slots over stepped slices
    padding_waste: float     # 1 - occupancy: stepped-but-masked slot frac
    data_pad_frac: float     # mean fraction of mask-zero rung-padding
    #                          slots per admitted session (0 = exact fit)


class DriverStats(NamedTuple):
    """Host-side scheduler counters (cumulative unless noted)."""

    slices: int          # device slices dispatched
    compiles: int        # slice-fn traces across all groups (incl. retired)
    admitted: int        # sessions placed into a fleet slot
    evicted: int         # sessions removed at a slice boundary
    queue_depth: int     # now: sessions waiting for arrival time or a slot
    active: int          # now: occupied slots that still have work
    capacity: int        # now: total fleet slots across groups
    occupancy: float     # time-averaged active/capacity over stepped slices
    padding_waste: float  # 1 - occupancy: fraction of stepped slots masked
    checkpoints: int     # background checkpoint writes completed
    buckets: tuple = ()  # per-group BucketStats breakdown (VB driver only)
    checkpoint_errors: int = 0  # background checkpoint writes that raised


class _PendingSave:
    """Tiny future for one background checkpoint write."""

    def __init__(self):
        self._done = threading.Event()
        self.path: Optional[str] = None
        self.exc: Optional[BaseException] = None

    def wait(self, timeout: Optional[float] = None) -> str:
        self._done.wait(timeout)
        if self.exc is not None:
            raise self.exc
        return self.path


class CheckpointWriter:
    """Background checkpoint writes: the device→host transfer and .npz
    compression run on a daemon thread, overlapped with the in-flight
    device slice (the snapshot refs are captured at the slice boundary,
    so what lands on disk is always a valid resumable boundary state)."""

    def __init__(self):
        self._q: queue_lib.Queue = queue_lib.Queue()
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self.completed = 0
        self.errors = 0     # failed writes (counted even when nobody waits)

    def submit(self, tree: Any, path: str) -> _PendingSave:
        pending = _PendingSave()
        with self._lock:
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(target=self._worker,
                                                daemon=True)
                self._thread.start()
        self._q.put((tree, path, pending))
        return pending

    def _worker(self) -> None:
        while True:
            tree, path, pending = self._q.get()
            t0 = time.perf_counter()
            try:
                with telemetry.span("driver/checkpoint",
                                    file=os.path.basename(path)):
                    pending.path = ckpt.save(path, jax.device_get(tree))
                self.completed += 1
                telemetry.inc("driver_checkpoints_total")
                telemetry.observe("driver_checkpoint_write_seconds",
                                  time.perf_counter() - t0)
            except BaseException as e:
                # Surfaced via pending.wait() when someone holds the
                # future — but the driver's periodic autosaves never
                # wait, so the error must ALSO land somewhere visible:
                # the `errors` counter feeds DriverStats.checkpoint_errors
                # and the telemetry counter.  Swallowing keeps the
                # daemon thread (and the scheduler) alive.
                pending.exc = e
                self.errors += 1
                telemetry.inc("driver_checkpoint_errors_total")
            finally:
                pending._done.set()
                self._q.task_done()

    def flush(self) -> None:
        self._q.join()


# ---------------------------------------------------------------------------
# Pytree helpers + the gated slice kernel (moved from vb_service)
# ---------------------------------------------------------------------------
def _tree_stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _tree_index(tree, i):
    return jax.tree_util.tree_map(lambda leaf: leaf[i], tree)


def _tree_set(tree, i, value):
    return jax.tree_util.tree_map(lambda leaf, v: leaf.at[i].set(v),
                                  tree, value)


def _gated_step(step_fn, axis=None):
    """Wrap the engine's one-iteration kernel with per-session budget /
    early-stop gating: inactive sessions (converged, or budget spent)
    keep their state bit-for-bit and their absolute t frozen, so a
    session that early-stops inside a fleet ends in exactly the state a
    solo `vb_run` of the same length would have produced.  A FREE slot
    is simply a session with `conv=True, budget=0` — the same gate is
    the driver's active mask.  Under the mesh executor (`axis`) the
    early-stop delta is pmean-reduced so every shard takes the identical
    stop decision."""

    def one(data, phi, carry, st, t, conv, budget, tol, delta_prev, hyper):
        active = jnp.logical_and(~conv, t < budget)
        phi2, carry2, st2, _ = step_fn(data, phi, carry, st, t, hyper)
        msq = jnp.mean((phi2 - phi) ** 2)
        if axis is not None:
            msq = jax.lax.pmean(msq, axis)
        delta = jnp.sqrt(msq).astype(phi.dtype)
        conv2 = jnp.logical_or(conv,
                               jnp.logical_and(tol > 0.0, delta < tol))
        gate = lambda new, old: jax.tree_util.tree_map(
            lambda a, b: jnp.where(active, a, b), new, old)
        return (jnp.where(active, phi2, phi),
                gate(carry2, carry),
                gate(st2, st),
                t + active.astype(t.dtype),
                jnp.where(active, conv2, conv),
                jnp.where(active, delta, delta_prev))

    return one


def _slice_scan(one, k):
    """k gated iterations over the vmapped fleet as one lax.scan.
    `hyper` is the per-slot lifted-hyper pytree (engine.session_hyper),
    mapped alongside the data — constant within the slice."""

    def slice_fn(data, phi, carry, st, t, conv, budget, tol, delta, hyper):
        def body(c, _):
            phi, carry, st, t, conv, delta = c
            return jax.vmap(one)(data, phi, carry, st, t, conv, budget,
                                 tol, delta, hyper), None

        init = (phi, carry, st, t, conv, delta)
        (phi, carry, st, t, conv, delta), _ = jax.lax.scan(
            body, init, None, length=k)
        return phi, carry, st, t, conv, delta

    return slice_fn


# ---------------------------------------------------------------------------
# FleetGroup: one fixed-capacity fleet of same-shape sessions
# ---------------------------------------------------------------------------
class FleetGroup:
    """One fleet: same-shape sessions batched along a leading slot axis
    of FIXED capacity.  Free slots hold an inert copy of the template
    state (conv latched, zero budget), so join/leave are `.at[slot]`
    writes and the compiled slice function never retraces mid-flight.
    `max_fleet=None` falls back to power-of-two auto-growth (capacity
    doubles when full — the shape-bucketing groundwork for ROADMAP
    item 1's bucketed admission)."""

    def __init__(self, session: engine.VBSession, executor,
                 max_fleet: Optional[int] = None,
                 bucket_capacity: Optional[int] = None):
        self.session = session          # template (data ignored per-slot)
        self.executor = executor
        self.max_fleet = max_fleet
        self.bucket_capacity = bucket_capacity  # data rung; None = exact
        self.slots: Optional[SlotTable] = None
        self.data = None                # (capacity, ...) pytrees
        self.phi = self.carry = self.stream = None
        self.t = self.conv = self.budget = self.tol = self.delta = None
        self.hyper = None               # per-slot lifted-hyper pytree
        # host mirrors of the per-slot flag vectors (refreshed by
        # fetch_flags after each slice; mutated in step with control ops)
        self.host_t = self.host_conv = None
        self.host_budget = self.host_delta = None
        self._compiled = {}             # k -> compiled slice fn
        self._retired_compiles = 0
        # per-bucket accounting (read by VBDriver.stats)
        self.n_admitted = 0
        self.pad_frac_sum = 0.0         # sum over admits of padded-slot frac
        self.occ_active = 0             # sum of active counts over slices
        self.occ_slots = 0              # sum of capacities over slices

    @property
    def capacity(self) -> int:
        return 0 if self.slots is None else self.slots.capacity

    # -- allocation -------------------------------------------------------
    def _alloc(self, record: dict) -> None:
        cap = 1 if self.max_fleet is None else int(self.max_fleet)
        bcast = lambda leaf: jnp.broadcast_to(leaf[None], (cap,) + leaf.shape)
        self.data = jax.tree_util.tree_map(bcast, record["data"])
        self.phi = bcast(record["phi"])
        self.carry = jax.tree_util.tree_map(bcast, record["carry"])
        self.stream = jax.tree_util.tree_map(bcast, record["stream"])
        self.hyper = jax.tree_util.tree_map(bcast, record["hyper"])
        self.t = bcast(record["t"])
        self.conv = jnp.ones((cap,), bool)          # free slots: inert
        self.budget = jnp.zeros((cap,), record["t"].dtype)
        dt = record["phi"].dtype
        self.tol = jnp.zeros((cap,), dt)
        self.delta = jnp.zeros((cap,), dt)
        self.host_t = np.zeros((cap,), np.int64)
        self.host_conv = np.ones((cap,), bool)
        self.host_budget = np.zeros((cap,), np.int64)
        self.host_delta = np.zeros((cap,), np.float64)
        self.slots = SlotTable(cap)

    def _grow(self) -> None:
        old = self.capacity
        new = old * 2
        pad = lambda leaf: jnp.concatenate(
            [leaf, jnp.broadcast_to(leaf[:1], (new - old,) + leaf.shape[1:])])
        self.data = jax.tree_util.tree_map(pad, self.data)
        self.phi = pad(self.phi)
        self.carry = jax.tree_util.tree_map(pad, self.carry)
        self.stream = jax.tree_util.tree_map(pad, self.stream)
        self.hyper = jax.tree_util.tree_map(pad, self.hyper)
        self.t = pad(self.t)
        self.conv = jnp.concatenate(
            [self.conv, jnp.ones((new - old,), bool)])
        self.budget = jnp.concatenate(
            [self.budget, jnp.zeros((new - old,), self.budget.dtype)])
        self.tol = jnp.concatenate(
            [self.tol, jnp.zeros((new - old,), self.tol.dtype)])
        self.delta = jnp.concatenate(
            [self.delta, jnp.zeros((new - old,), self.delta.dtype)])
        self.host_t = np.concatenate(
            [self.host_t, np.zeros((new - old,), np.int64)])
        self.host_conv = np.concatenate(
            [self.host_conv, np.ones((new - old,), bool)])
        self.host_budget = np.concatenate(
            [self.host_budget, np.zeros((new - old,), np.int64)])
        self.host_delta = np.concatenate(
            [self.host_delta, np.zeros((new - old,), np.float64)])
        self.slots.grow(new)
        self._clear_compiled()          # capacity is a new shape bucket

    # -- join / leave -----------------------------------------------------
    def admit(self, rid: str, record: dict) -> Optional[int]:
        """Place one session record into a free slot; None if the fleet
        is full (fixed capacity) — the caller keeps it queued."""
        if self.slots is None:
            self._alloc(record)
        slot = self.slots.alloc(rid)
        if slot is None:
            if self.max_fleet is not None:
                return None
            self._grow()
            slot = self.slots.alloc(rid)
        self.load_state_tree(slot, record)
        self.host_t[slot] = int(record["t"])
        self.host_conv[slot] = bool(np.asarray(record["conv"]))
        self.host_budget[slot] = int(record["budget"])
        self.host_delta[slot] = float(record["delta"])
        return slot

    def evict(self, slot: int) -> dict:
        """Snapshot a slot's resumable state and mark the slot free
        (inert: conv latched, zero budget)."""
        record = self.state_tree(slot)
        self.conv = self.conv.at[slot].set(True)
        self.budget = self.budget.at[slot].set(0)
        self.host_conv[slot] = True
        self.host_budget[slot] = 0
        self.slots.free(slot)
        return record

    # -- slice execution --------------------------------------------------
    def _slice_fn(self, k: int):
        if k not in self._compiled:
            if self.executor is None:
                one = _gated_step(engine.session_step_fn(self.session))
                self._compiled[k] = jax.jit(_slice_scan(one, k))
            else:
                self._compiled[k] = self._mesh_slice_fn(k)
        return self._compiled[k]

    def _mesh_slice_fn(self, k: int):
        """MeshExecutor composition: shard_map over the NODE axis with
        the fleet vmap inside — the fleet axis is a plain leading batch
        axis on every shard, the topology collectives run over the mesh
        axis exactly as in `engine._run_vb_sharded`."""
        from jax.sharding import PartitionSpec as P

        from repro.dist import compat, sharding

        mesh, axis = self.executor.mesh, self.executor.axis
        ses = self.session
        topology = ses.topology
        local_inputs = topology.shard_inputs()
        local_keys = tuple(sorted(local_inputs))

        # ONE partitioning rule: take the engine executor's state specs
        # (dist/sharding.vb_node_specs) and shift every state slot one
        # axis right for the leading fleet dimension; the topology's
        # shard_inputs rows are fleet-shared and keep their specs.
        has_carry = self.carry is not None
        has_stream = self.stream is not None
        base_in, _ = sharding.vb_node_specs(
            self.data, axis=axis, has_carry=has_carry,
            n_local=len(local_keys),
            carry_specs=topology.carry_specs(axis) if has_carry else None,
            stream_specs=(stream_lib.state_specs(self.stream, axis)
                          if has_stream else None))
        data_b, phi_b, carry_b, stream_b = base_in[:4]
        local_specs = base_in[4:]

        def fleet(spec):                # unbatched spec -> fleet spec
            return jax.tree_util.tree_map(
                lambda s: P(*((None,) + tuple(s))), spec,
                is_leaf=lambda s: isinstance(s, P))

        data_specs = fleet(data_b)
        phi_spec = fleet(phi_b)
        carry_spec = fleet(carry_b) if has_carry else carry_b
        stream_spec = fleet(stream_b) if has_stream else stream_b
        rep = P()                       # per-session scalars: replicated
        hyper_spec = jax.tree_util.tree_map(lambda _: rep, self.hyper)
        in_specs = (data_specs, phi_spec, carry_spec, stream_spec,
                    rep, rep, rep, rep, rep, hyper_spec) + local_specs
        out_specs = (phi_spec, carry_spec, stream_spec, rep, rep, rep)

        def run(data_l, phi_l, carry_l, st_l, t, conv, budget, tol, delta,
                hyper, *local_vals):
            local = dict(zip(local_keys, local_vals))
            one = _gated_step(
                engine.session_step_fn(ses, axis=axis, local=local),
                axis=axis)
            return _slice_scan(one, k)(data_l, phi_l, carry_l, st_l, t,
                                       conv, budget, tol, delta, hyper)

        fn = compat.shard_map(run, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=False)

        def call(data, phi, carry, st, t, conv, budget, tol, delta, hyper):
            return fn(data, phi, carry, st, t, conv, budget, tol, delta,
                      hyper, *(local_inputs[kk] for kk in local_keys))

        return call

    def step_slice(self, k: int) -> None:
        """Dispatch one k-iteration slice (async: returns immediately
        with futures; host work may overlap until fetch_flags syncs)."""
        first = k not in self._compiled
        fn = self._slice_fn(k)
        with telemetry.span("driver/slice", k=k, slots=self.capacity):
            if first:
                # the first dispatch of a (k, capacity) shape pays the
                # trace+compile; nested so timelines separate compile
                # cost from steady-state slice dispatch
                with telemetry.span("driver/compile", k=k,
                                    slots=self.capacity):
                    out = fn(self.data, self.phi, self.carry,
                             self.stream, self.t, self.conv, self.budget,
                             self.tol, self.delta, self.hyper)
            else:
                out = fn(self.data, self.phi, self.carry, self.stream,
                         self.t, self.conv, self.budget, self.tol,
                         self.delta, self.hyper)
        (self.phi, self.carry, self.stream, self.t, self.conv,
         self.delta) = out

    def fetch_flags(self) -> None:
        """Sync the small per-slot flag vectors device -> host."""
        with telemetry.span("driver/sync"):
            t, conv, delta = jax.device_get((self.t, self.conv,
                                             self.delta))
        self.host_t = np.asarray(t).astype(np.int64)
        self.host_conv = np.asarray(conv).astype(bool)
        self.host_delta = np.asarray(delta).astype(np.float64)

    # -- host-side views --------------------------------------------------
    def done_mask(self) -> np.ndarray:
        return self.host_conv | (self.host_t >= self.host_budget)

    def active_count(self) -> int:
        if self.slots is None:
            return 0
        done = self.done_mask()
        return sum(1 for i, _ in self.slots.occupied() if not done[i])

    @property
    def compiles(self) -> int:
        """Cumulative slice-fn traces, surviving cache clears.  jit
        exposes its trace count via `_cache_size`; the mesh closure
        counts as one trace per (k, capacity)."""
        live = 0
        for fn in self._compiled.values():
            cs = getattr(fn, "_cache_size", None)
            live += int(cs()) if callable(cs) else 1
        return self._retired_compiles + live

    def _clear_compiled(self) -> None:
        self._retired_compiles = self.compiles
        self._compiled.clear()

    def state_tree(self, i: int) -> dict:
        """One session's full resumable state (checkpoint payload)."""
        return dict(phi=self.phi[i], t=self.t[i],
                    carry=_tree_index(self.carry, i),
                    stream=_tree_index(self.stream, i),
                    conv=self.conv[i], budget=self.budget[i],
                    tol=self.tol[i], delta=self.delta[i],
                    data=_tree_index(self.data, i),
                    hyper=_tree_index(self.hyper, i))

    def load_state_tree(self, i: int, tree: dict) -> None:
        self.phi = self.phi.at[i].set(tree["phi"])
        self.t = self.t.at[i].set(tree["t"])
        self.carry = _tree_set(self.carry, i, tree["carry"])
        self.stream = _tree_set(self.stream, i, tree["stream"])
        self.conv = self.conv.at[i].set(tree["conv"])
        self.budget = self.budget.at[i].set(tree["budget"])
        self.tol = self.tol.at[i].set(tree["tol"])
        self.delta = self.delta.at[i].set(tree["delta"])
        self.data = _tree_set(self.data, i, tree["data"])
        self.hyper = _tree_set(self.hyper, i, tree["hyper"])


class SessionStatus(NamedTuple):
    """Host-side snapshot of one session (admitted, queued or evicted)."""

    rid: str
    t: int                  # absolute iterations actually applied
    budget: int
    converged: bool         # early-stop latch (tol reached)
    done: bool              # converged or budget exhausted
    delta: float            # last applied step's rms phi change
    phi: Any                # (N, P) current natural parameters
    queued: bool = False    # waiting for arrival time or a free slot
    evicted: bool = False   # finished and removed from its fleet slot
    latency_s: float = 0.0  # submit -> finished wall time (0 while open)


# ---------------------------------------------------------------------------
# VBDriver: the continuous-batching scheduler
# ---------------------------------------------------------------------------
class VBDriver:
    """Continuous-batching scheduler for VB sessions.

    slice_iters : device iterations per slice — the scheduling quantum.
    max_fleet : fixed slot capacity per fleet group (arrivals beyond it
        queue until an eviction frees a slot); None = power-of-two
        auto-growth, the drop-in behaviour `VBService` defaults to.
    executor : optional `engine.MeshExecutor` (node axis sharded, fleet
        vmap inside the shard_map body).
    bucket : capacity-bucketed admission.  "pow2" (default) pads each
        session's per-node data buffers up to the next power-of-two
        ladder rung (`admission.bucket_capacity`) with mask-zero slots,
        so near-same-shape sessions share one compiled fleet; a float
        (> 1) is a custom ladder growth factor (e.g. 1.25); None keeps
        the PR-6 exact-signature grouping.  Bit-safe: the engine's
        ordered reductions make padded trajectories bit-equal to
        unpadded ones (docs/bucketed-admission.md).  Minibatch sessions
        are never padded (the streaming sampler's epoch permutations are
        a function of the true capacity), nor are data pytrees the model
        cannot pad (no `pad_to_capacity`, e.g. a LinReg phi* stack).
    bucket_min : smallest ladder rung.
    ckpt_dir / ckpt_every : when set, every `ckpt_every` slices each
        occupied slot's boundary state is handed to the background
        `CheckpointWriter` as `<ckpt_dir>/<rid>.npz`.

    Sessions differing ONLY in per-iteration hyperparameters — the
    schedule's tau/d0, ADMM's rho/xi (`engine.hyper_names`) — also share
    a fleet: those constants are lifted to per-slot arrays mapped through
    the compiled step alongside the data (`engine.session_hyper`).

    Drive it synchronously (`tick()` / `drain()`) or start the
    background scheduler thread (`start()`), then `submit` / `push_data`
    / `extend_budget` from any thread; control ops apply at slice
    boundaries (the driver lock serializes them with the device loop).
    """

    def __init__(self, *, slice_iters: int = 25,
                 max_fleet: Optional[int] = None,
                 executor: Optional[engine.MeshExecutor] = None,
                 bucket: Optional[str | float] = "pow2",
                 bucket_min: int = 8,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 0):
        if slice_iters < 1:
            raise ValueError(f"slice_iters must be >= 1: {slice_iters}")
        if max_fleet is not None and max_fleet < 1:
            raise ValueError(f"max_fleet must be >= 1: {max_fleet}")
        if bucket is None or bucket == "pow2":
            self._bucket_growth = 2.0 if bucket == "pow2" else None
        else:
            self._bucket_growth = float(bucket)
            if self._bucket_growth <= 1.0:
                raise ValueError(f"bucket growth must be > 1.0: {bucket}")
        self.bucket = bucket
        self.bucket_min = int(bucket_min)
        self.slice_iters = slice_iters
        self.max_fleet = max_fleet
        self.executor = executor
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self._groups: dict[tuple, FleetGroup] = {}
        self._where: dict[str, tuple[tuple, int]] = {}  # rid -> (key, slot)
        self._queue = ArrivalQueue()
        self._queued: dict[str, dict] = {}              # rid -> entry
        self._finished: dict[str, dict] = {}            # rid -> fin record
        self._meta: dict[str, dict] = {}
        self._order: list[str] = []
        self._counter = 0
        self._clock = 0                 # slice-boundary clock (arrive_at)
        self._slices = 0
        self._n_admitted = 0
        self._n_evicted = 0
        self._occ_active = 0            # sum of active counts over slices
        self._occ_slots = 0             # sum of capacities over slices
        self._writer = CheckpointWriter()
        self._lock = threading.RLock()
        self._wake = threading.Event()
        self._stop_evt = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- admission --------------------------------------------------------
    def _session_key(self, model, topology, schedule, replication,
                     minibatch, data) -> tuple:
        """Fleet-group key: structural signatures (small arrays by
        content digest), shapes of the ALREADY-BUCKETED data, and only
        the hyperparameters the compiled step actually specializes on —
        lifted ones (`engine.lifted_attr_names` / the schedule's tau+d0)
        are stripped, since per-session values flow through the fleet's
        hyper arrays (or the carry) instead of the trace."""
        topo_sig = admission.static_signature(
            topology, ignore=engine.lifted_attr_names(topology))
        # tau/d0 are dead when eta is fixed and lifted otherwise; only
        # eta_fixed itself picks a static branch (the one-shot jump)
        sched_key = ("eta_fixed", schedule.eta_fixed)
        return (admission.static_signature(model), topo_sig,
                admission.shape_signature(data), sched_key,
                replication, minibatch)

    def _bucket_plan(self, req):
        """(data on the ladder rung, (true_cap, rung)) — or
        (req.data, None) when bucketing does not apply: disabled,
        minibatch (epoch permutations are a function of the true
        capacity), or a data pytree the model cannot pad."""
        if self.bucket is None or req.minibatch is not None:
            return req.data, None
        pad = getattr(req.model, "pad_to_capacity", None)
        mask_of = getattr(req.model, "data_mask", None)
        if pad is None or mask_of is None:
            return req.data, None
        try:
            true_cap = int(mask_of(req.data).shape[1])
        except (ValueError, IndexError):    # e.g. LinReg phi* stack
            return req.data, None
        rung = admission.bucket_capacity(true_cap,
                                         growth=self._bucket_growth,
                                         min_size=self.bucket_min)
        data = pad(req.data, rung) if rung != true_cap else req.data
        return data, (true_cap, rung)

    def submit(self, req, *, arrive_at: Optional[int] = None,
               restore_from: Optional[str] = None) -> str:
        """Queue one session (any object with the `VBRequest` fields);
        returns its id.  `arrive_at` defers admission until that slice
        boundary; `restore_from` loads a `save_session` checkpoint into
        the fresh record (the request must describe the same shapes),
        resuming it bit-exactly."""
        if req.n_iters < 1:
            raise ValueError(f"n_iters must be >= 1: {req.n_iters}")
        data, bucket = self._bucket_plan(req)
        state = engine.vb_init(
            req.model, data, req.topology, schedule=req.schedule,
            replication=req.replication, init_phi=req.init_phi,
            minibatch=req.minibatch, diagnostics=False)
        dt = state.phi.dtype
        record = dict(phi=state.phi, t=state.t, carry=state.carry,
                      stream=state.stream, conv=jnp.zeros((), bool),
                      budget=jnp.asarray(req.n_iters, state.t.dtype),
                      tol=jnp.asarray(req.tol, dt),
                      delta=jnp.zeros((), dt), data=state.session.data,
                      hyper=engine.session_hyper(req.topology,
                                                 req.schedule, dt))
        if restore_from is not None:
            record = ckpt.restore(restore_from, record)
        key = self._session_key(req.model, req.topology, req.schedule,
                                req.replication, req.minibatch, data)
        with self._lock:
            rid = f"s{self._counter:04d}"
            self._counter += 1
            self._order.append(rid)
            at = self._clock if arrive_at is None else int(arrive_at)
            self._meta[rid] = dict(submitted=time.monotonic(),
                                   finished=None, arrive_at=at,
                                   bucket=bucket)
            entry = dict(rid=rid, key=key, session=state.session,
                         record=record, bucket=bucket)
            self._queued[rid] = entry
            self._queue.push(entry, at)
            self._try_admit()
        self._wake.set()
        return rid

    def _try_admit(self) -> None:
        """Admit every ready arrival that a fleet slot can take (lock
        held).  Fleet-full entries go back on the queue in FIFO order."""
        for at, seq, entry in self._queue.pop_ready(self._clock):
            rid, rec = entry["rid"], entry["record"]
            if bool(np.asarray(rec["conv"])) \
                    or int(rec["t"]) >= int(rec["budget"]):
                # e.g. restored from a finished checkpoint: nothing to run
                self._queued.pop(rid, None)
                self._retire(rid, dict(record=rec, key=entry["key"],
                                       session=entry["session"]))
                continue
            bucket = self._meta[rid].get("bucket")
            group = self._groups.get(entry["key"])
            if group is None:
                group = FleetGroup(entry["session"], self.executor,
                                   max_fleet=self.max_fleet,
                                   bucket_capacity=(bucket[1] if bucket
                                                    else None))
                self._groups[entry["key"]] = group
            slot = group.admit(rid, rec)
            if slot is None:
                self._queue.push_entry((at, seq, entry))
                continue
            self._queued.pop(rid, None)
            self._where[rid] = (entry["key"], slot)
            self._n_admitted += 1
            group.n_admitted += 1
            telemetry.inc("driver_admitted_total")
            telemetry.instant("driver/admit", rid=rid, slot=slot)
            if bucket is not None:
                group.pad_frac_sum += (bucket[1] - bucket[0]) / bucket[1]

    def _retire(self, rid: str, fin: dict) -> None:
        self._finished[rid] = fin
        if self._meta[rid]["finished"] is None:
            self._meta[rid]["finished"] = time.monotonic()

    # -- the scheduling loop ----------------------------------------------
    def tick(self) -> int:
        """One slice boundary: admit ready arrivals, dispatch one slice
        per fleet with active work, overlap host-side checkpoint
        snapshots with the device slice, then sync flags, evict finished
        sessions and advance the clock.  Returns #sessions still open."""
        with self._lock:
            self._try_admit()
            stepped = [g for g in self._groups.values()
                       if g.active_count() > 0]
            snaps = []
            if self.ckpt_dir and self.ckpt_every and stepped \
                    and (self._slices + 1) % self.ckpt_every == 0:
                for g in stepped:       # boundary state, pre-dispatch refs
                    snaps.extend((rid, g.state_tree(slot))
                                 for slot, rid in g.slots.occupied())
            for g in stepped:
                n_act = g.active_count()
                self._occ_active += n_act
                self._occ_slots += g.capacity
                g.occ_active += n_act
                g.occ_slots += g.capacity
                g.step_slice(self.slice_iters)      # async dispatch
            if stepped:
                self._slices += 1
            for rid, tree in snaps:     # writer overlaps the device slice
                self._writer.submit(
                    tree, os.path.join(self.ckpt_dir, f"{rid}.npz"))
            for g in stepped:
                g.fetch_flags()                     # device -> host sync
            self._evict_done()
            self._clock += 1
            if telemetry.enabled():
                # fleet health gauges at every slice boundary (one bool
                # check when telemetry is off)
                occ = (self._occ_active / self._occ_slots
                       if self._occ_slots else 0.0)
                telemetry.set_gauge("driver_queue_depth",
                                    len(self._queued))
                telemetry.set_gauge("driver_active", sum(
                    g.active_count() for g in self._groups.values()))
                telemetry.set_gauge("driver_capacity", sum(
                    g.capacity for g in self._groups.values()))
                telemetry.set_gauge("driver_occupancy", occ)
                telemetry.set_gauge("driver_padding_waste",
                                    (1.0 - occ) if self._occ_slots
                                    else 0.0)
            return self._remaining_locked()

    def _evict_done(self) -> None:
        for key, group in self._groups.items():
            if group.slots is None:
                continue
            done = group.done_mask()
            for slot, rid in group.slots.occupied():
                if done[slot]:
                    record = group.evict(slot)
                    del self._where[rid]
                    self._n_evicted += 1
                    telemetry.inc("driver_evicted_total")
                    telemetry.instant("driver/evict", rid=rid, slot=slot)
                    self._retire(rid, dict(record=record, key=key,
                                           session=group.session))

    def _remaining_locked(self) -> int:
        return (sum(g.active_count() for g in self._groups.values())
                + len(self._queued))

    def remaining(self) -> int:
        with self._lock:
            return self._remaining_locked()

    def drain(self, max_slices: Optional[int] = None,
              poll: float = 0.002) -> int:
        """Run until no session is open (or `max_slices` dispatched).
        With the background thread running this just waits; otherwise it
        pumps `tick()` inline.  Returns #sessions still open."""
        if self._thread is not None and self._thread.is_alive():
            while self.remaining() > 0:
                time.sleep(poll)
            self._writer.flush()
            return 0
        n = 0
        left = self.tick()
        while left > 0:
            n += 1
            if max_slices is not None and n >= max_slices:
                break
            left = self.tick()
        self._writer.flush()
        return left

    def start(self) -> None:
        """Start the background scheduler thread (idempotent)."""
        with self._lock:
            if self._thread is not None and self._thread.is_alive():
                return
            self._stop_evt.clear()
            self._thread = threading.Thread(target=self._loop, daemon=True)
            self._thread.start()

    def _loop(self) -> None:
        while not self._stop_evt.is_set():
            if self.tick() == 0:
                self._wake.clear()
                self._wake.wait(timeout=0.02)

    def stop(self) -> None:
        self._stop_evt.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None

    # -- observation ------------------------------------------------------
    def status(self, rid: str) -> SessionStatus:
        with self._lock:
            meta = self._meta.get(rid)
            if meta is None:
                raise KeyError(f"unknown session {rid!r}")
            lat = ((meta["finished"] - meta["submitted"])
                   if meta["finished"] is not None else 0.0)
            if rid in self._where:
                key, i = self._where[rid]
                g = self._groups[key]
                t, budget = int(g.host_t[i]), int(g.host_budget[i])
                conv = bool(g.host_conv[i])
                return SessionStatus(
                    rid=rid, t=t, budget=budget, converged=conv,
                    done=conv or t >= budget, delta=float(g.host_delta[i]),
                    phi=g.phi[i], latency_s=lat)
            rec = (self._finished[rid]["record"] if rid in self._finished
                   else self._queued[rid]["record"])
            t, budget = int(rec["t"]), int(rec["budget"])
            conv = bool(np.asarray(rec["conv"]))
            return SessionStatus(
                rid=rid, t=t, budget=budget, converged=conv,
                done=conv or t >= budget, delta=float(rec["delta"]),
                phi=rec["phi"], queued=rid in self._queued,
                evicted=rid in self._finished, latency_s=lat)

    @property
    def sessions(self) -> list[str]:
        with self._lock:
            return list(self._order)

    def _bucket_stats(self) -> tuple:
        out = []
        for g in self._groups.values():
            data = g.data if g.data is not None else g.session.data
            n_nodes = jax.tree_util.tree_leaves(data)[0].shape[
                1 if g.data is not None else 0]
            cap = g.bucket_capacity
            label = (f"{type(g.session.model).__name__}/N{n_nodes}/"
                     + (f"cap{cap}" if cap is not None else "exact"))
            occ = g.occ_active / g.occ_slots if g.occ_slots else 0.0
            out.append(BucketStats(
                label=label, bucket_capacity=cap, slots=g.capacity,
                admitted=g.n_admitted, active=g.active_count(),
                occupancy=occ,
                padding_waste=(1.0 - occ) if g.occ_slots else 0.0,
                data_pad_frac=(g.pad_frac_sum / g.n_admitted
                               if g.n_admitted else 0.0)))
        return tuple(sorted(out, key=lambda b: b.label))

    def stats(self) -> DriverStats:
        with self._lock:
            active = sum(g.active_count() for g in self._groups.values())
            capacity = sum(g.capacity for g in self._groups.values())
            compiles = sum(g.compiles for g in self._groups.values())
            occ = (self._occ_active / self._occ_slots
                   if self._occ_slots else 0.0)
            return DriverStats(
                slices=self._slices, compiles=compiles,
                admitted=self._n_admitted, evicted=self._n_evicted,
                queue_depth=len(self._queued), active=active,
                capacity=capacity, occupancy=occ,
                padding_waste=(1.0 - occ) if self._occ_slots else 0.0,
                checkpoints=self._writer.completed,
                buckets=self._bucket_stats(),
                checkpoint_errors=self._writer.errors)

    # -- mid-flight control ops (apply at slice boundaries) ---------------
    def push_data(self, rid: str, node: int, points: Any) -> None:
        """Append freshly-arrived observations to one node's buffer
        (into padding slots — `model.append_node_data`) and un-latch the
        session's convergence flag.  An EVICTED session whose budget
        still has room goes back through the arrival queue and resumes
        in any free slot (bit-exact, absolute-t contract).

        A BUCKETED session whose buffer overflows is not an error: the
        session is evicted from its fleet, its buffers regrown to the
        next ladder rung that fits, and it re-enters the queue under the
        larger bucket's group key — same absolute-t resume contract, so
        the trajectory matches a solo run on the regrown buffers."""
        with self._lock:
            if rid in self._where:
                key, i = self._where[rid]
                g = self._groups[key]
                data_i = _tree_index(g.data, i)
                try:
                    new = g.session.model.append_node_data(data_i, node,
                                                           points)
                except ValueError:
                    if self._meta[rid].get("bucket") is None:
                        raise
                    record = g.evict(i)
                    del self._where[rid]
                    self._n_evicted += 1
                    self._retire(rid, dict(record=record, key=key,
                                           session=g.session))
                    self._rebucket(rid, node, points)
                    self._maybe_requeue(rid)
                else:
                    g.data = _tree_set(g.data, i, new)
                    g.conv = g.conv.at[i].set(False)
                    g.host_conv[i] = False
            elif rid in self._finished or rid in self._queued:
                fin = (self._finished.get(rid) or self._queued[rid])
                rec = fin["record"]
                try:
                    rec["data"] = fin["session"].model.append_node_data(
                        rec["data"], node, points)
                except ValueError:
                    if self._meta[rid].get("bucket") is None:
                        raise
                    self._rebucket(rid, node, points)
                else:
                    rec["conv"] = jnp.zeros((), bool)
                if rid in self._finished:
                    self._maybe_requeue(rid)
            else:
                raise KeyError(f"unknown session {rid!r}")
        self._wake.set()

    def _rebucket(self, rid: str, node: int, points: Any) -> None:
        """Grow an overflowing bucketed session to the next ladder rung
        that fits `points`, append them, and re-key it (lock held; the
        rid is in `_finished` or `_queued`)."""
        fin = self._finished.get(rid) or self._queued[rid]
        rec, ses = fin["record"], fin["session"]
        model = ses.model
        true_cap, rung = self._meta[rid]["bucket"]
        data = rec["data"]
        for _ in range(64):             # each rung at least doubles room
            rung = admission.bucket_capacity(
                rung + 1, growth=self._bucket_growth,
                min_size=self.bucket_min)
            grown = model.pad_to_capacity(data, rung)
            try:
                grown = model.append_node_data(grown, node, points)
                break
            except ValueError:
                continue
        else:
            raise ValueError(
                f"session {rid!r}: could not grow buffers to fit "
                "pushed points")
        rec["data"] = grown
        rec["conv"] = jnp.zeros((), bool)
        telemetry.inc("driver_rebucket_total")
        telemetry.instant("driver/rebucket", rid=rid, rung=rung)
        self._meta[rid]["bucket"] = (true_cap, rung)
        fin["session"] = engine.VBSession(
            model, grown, ses.topology, ses.schedule, ses.replication,
            ses.ref_phi, ses.executor, ses.minibatch, ses.diagnostics,
            ses.metric_nodes)
        fin["key"] = self._session_key(model, ses.topology, ses.schedule,
                                       ses.replication, ses.minibatch,
                                       grown)

    def replace_data(self, rid: str, data: Any) -> None:
        """Replace a session's data buffers wholesale (same shapes; a
        bucketed session accepts any data that pads to its rung)."""
        with self._lock:
            bucket = self._meta.get(rid, {}).get("bucket")
            if bucket is not None:
                if rid in self._where:
                    model = self._groups[self._where[rid][0]].session.model
                else:
                    fin = (self._finished.get(rid)
                           or self._queued.get(rid))
                    model = fin["session"].model if fin else None
                if model is not None:
                    data = model.pad_to_capacity(data, bucket[1])
            cur = self._current_data(rid)
            sig_new = admission.shape_signature(data)
            sig_old = admission.shape_signature(cur)
            if sig_new != sig_old:
                raise ValueError(
                    f"replace_data: shape signature mismatch "
                    f"({sig_new} != {sig_old})")
            if rid in self._where:
                key, i = self._where[rid]
                g = self._groups[key]
                g.data = _tree_set(g.data, i, data)
                g.conv = g.conv.at[i].set(False)
                g.host_conv[i] = False
            else:
                fin = (self._finished.get(rid) or self._queued[rid])
                fin["record"]["data"] = jax.tree_util.tree_map(
                    jnp.asarray, data)
                fin["record"]["conv"] = jnp.zeros((), bool)
                if rid in self._finished:
                    self._maybe_requeue(rid)
        self._wake.set()

    def _current_data(self, rid: str):
        if rid in self._where:
            key, i = self._where[rid]
            return _tree_index(self._groups[key].data, i)
        if rid in self._finished:
            return self._finished[rid]["record"]["data"]
        if rid in self._queued:
            return self._queued[rid]["record"]["data"]
        raise KeyError(f"unknown session {rid!r}")

    def extend_budget(self, rid: str, extra_iters: int) -> None:
        with self._lock:
            if rid in self._where:
                key, i = self._where[rid]
                g = self._groups[key]
                g.budget = g.budget.at[i].add(extra_iters)
                g.conv = g.conv.at[i].set(False)
                g.host_budget[i] += extra_iters
                g.host_conv[i] = False
            elif rid in self._finished or rid in self._queued:
                fin = (self._finished.get(rid) or self._queued[rid])
                rec = fin["record"]
                rec["budget"] = rec["budget"] + jnp.asarray(
                    extra_iters, rec["budget"].dtype)
                rec["conv"] = jnp.zeros((), bool)
                if rid in self._finished:
                    self._maybe_requeue(rid)
            else:
                raise KeyError(f"unknown session {rid!r}")
        self._wake.set()

    def _maybe_requeue(self, rid: str) -> None:
        """Re-queue an evicted session that has work again (new data or
        extended budget); absolute-t resumability makes re-admission
        into any free slot bit-safe."""
        fin = self._finished[rid]
        rec = fin["record"]
        if bool(np.asarray(rec["conv"])) \
                or int(rec["t"]) >= int(rec["budget"]):
            return
        del self._finished[rid]
        self._meta[rid]["finished"] = None
        telemetry.inc("driver_requeue_total")
        telemetry.instant("driver/requeue", rid=rid)
        entry = dict(rid=rid, key=fin["key"], session=fin["session"],
                     record=rec)
        self._queued[rid] = entry
        self._queue.push(entry, self._clock)
        self._try_admit()

    # -- checkpointing ----------------------------------------------------
    def save_session(self, rid: str, path: str, *, wait: bool = True) -> str:
        """Write one session's full resumable state (incl. data buffers
        and budget bookkeeping) as a `checkpoint/ckpt.py` .npz.  With
        `wait=False` the device→host transfer and compression happen on
        the background writer thread (call `flush_checkpoints` or rely
        on `drain` before reading the file)."""
        with self._lock:
            if rid in self._where:
                key, i = self._where[rid]
                tree = self._groups[key].state_tree(i)
            elif rid in self._finished:
                tree = dict(self._finished[rid]["record"])
            elif rid in self._queued:
                tree = dict(self._queued[rid]["record"])
            else:
                raise KeyError(f"unknown session {rid!r}")
        pending = self._writer.submit(tree, path)
        return pending.wait() if wait else path

    def flush_checkpoints(self) -> None:
        self._writer.flush()
