"""Serving engine: batched prefill + decode with a sharded KV/state cache.

`prefill_step` and `decode_step` are the two functions the multi-pod dry-run
lowers for the inference shapes (prefill_32k / decode_32k / long_500k).  The
`Engine` class is the runnable host-side driver used by examples/serve_lm.py:
it admits a batch of requests, prefills them (right-aligned padding), then
decodes greedily/with temperature until max tokens.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist import compat, sharding  # noqa: F401  (sharding: policy API)
from repro.models import model as model_lib
from repro.serving import admission
from repro.serving.driver import ArrivalQueue, DriverStats, SlotTable


# ---------------------------------------------------------------------------
# Cache shardings
# ---------------------------------------------------------------------------
def cache_shardings(cache, cfg: ModelConfig, mesh: Mesh):
    """Batch over data/pod axes; heads (or head_dim / state channels) over
    "model" when divisible.  Cache pytrees: attn (k,v) (L,B,S,H,hd);
    ssm conv (L,B,W-1,C) + state (L,B,H,hd,N); rec conv + h (L?,B,w)."""
    model_ax = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    scanned = model_lib._homogeneous(cfg)

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(leaf):
        rank = leaf.ndim
        lead = 1 if scanned else 0           # layer-stack axis
        spec = [None] * rank
        if rank > lead:
            # batch axis: only the dp axes that divide it (long_500k has B=1)
            ok, rem = [], leaf.shape[lead]
            for a in dp_axes:
                if rem % sizes[a] == 0 and sizes[a] > 1:
                    ok.append(a)
                    rem //= sizes[a]
            spec[lead] = tuple(ok) if ok else None
        # model axis: first trailing axis (after batch) divisible
        for ax in range(rank - 1, lead, -1):
            if model_ax > 1 and leaf.shape[ax] % model_ax == 0 \
                    and leaf.shape[ax] >= 2 * model_ax:
                spec[ax] = "model"
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, cache)


# ---------------------------------------------------------------------------
# Step functions (what the dry-run lowers)
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig, *, use_kernels: bool = False):
    def prefill_step(params, tokens, frontend=None):
        out = model_lib.forward(cfg, params, tokens, frontend,
                                collect_cache=True, use_kernels=use_kernels)
        return out["logits"][:, -1:, :], out["cache"]

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, token, cache, pos):
        return model_lib.decode_step(cfg, params, token, cache, pos)

    return decode_step


# ---------------------------------------------------------------------------
# Host-side engine
# ---------------------------------------------------------------------------
class Request(NamedTuple):
    prompt: np.ndarray        # (plen,) int32
    max_new_tokens: int


class Engine:
    """Host-side LM driver, scheduled with the same primitives as the VB
    continuous-batching driver (`serving/driver.py`): requests go through
    an `ArrivalQueue` into `SlotTable` waves of at most `max_batch`
    slots, the decode loop keeps a per-slot ACTIVE mask (a request that
    has all its tokens is idle-masked while its wave-mates keep
    decoding), and `stats()` reports the same `DriverStats` counters —
    compiles, occupancy, padding waste — the VB driver reports.
    `max_batch=None` admits every request in one wave.

    `bucket` enables prompt-LENGTH bucketing through the same capacity
    ladder the VB driver uses (`admission.bucket_capacity`): each wave
    admits only prompts sharing a ladder rung and left-pads to the rung
    (not to the wave max), so a request's prefill shape — and therefore
    its greedy output, since left-padding reaches the non-longest rows'
    logits — is a function of (prompt, rung) alone, independent of which
    wave-mates it happens to batch with.  "pow2" = power-of-two rungs, a
    float > 1 = custom growth factor, None (default) = legacy wave-max
    padding."""

    def __init__(self, cfg: ModelConfig, mesh: Mesh, params, *,
                 max_seq: int = 1024, use_kernels: bool = False,
                 seed: int = 0, max_batch: Optional[int] = None,
                 bucket: Optional[str | float] = None,
                 bucket_min: int = 8):
        self.cfg, self.mesh, self.params = cfg, mesh, params
        self.max_seq = max_seq
        self.max_batch = max_batch
        if bucket is None or bucket == "pow2":
            self._bucket_growth = 2.0 if bucket == "pow2" else None
        else:
            self._bucket_growth = float(bucket)
        self.bucket = bucket
        self.bucket_min = int(bucket_min)
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(make_prefill_step(cfg,
                                                  use_kernels=use_kernels))
        self._decode = jax.jit(make_decode_step(cfg))
        self._steps = 0                 # decode steps dispatched
        self._waves = 0
        self._n_admitted = 0
        self._occ_active = 0            # sum of active slots over steps
        self._occ_slots = 0             # sum of wave widths over steps

    def generate(self, requests: list[Request], *,
                 temperature: float = 0.0) -> list[np.ndarray]:
        """Batched greedy/temperature generation.  With `max_batch` set,
        requests beyond the wave width wait in the arrival queue and run
        as follow-up waves once a wave's slots drain."""
        queue = ArrivalQueue()
        for i in range(len(requests)):
            queue.push(i)
        results: list[Optional[np.ndarray]] = [None] * len(requests)
        while len(queue):
            table = SlotTable(self.max_batch if self.max_batch is not None
                              else max(len(queue), 1))
            wave = []
            wave_rung = None
            for entry in queue.pop_ready(0.0):
                rung = self._rung(requests[entry[2]])
                if wave_rung is None and not wave:
                    wave_rung = rung            # head of queue sets the rung
                if rung != wave_rung \
                        or table.alloc(f"r{entry[2]}") is None:
                    queue.push_entry(entry)     # next wave
                else:
                    wave.append(entry[2])
            outs = self._generate_wave([requests[i] for i in wave],
                                       temperature, wave_rung)
            for i, out in zip(wave, outs):
                results[i] = out
            self._waves += 1
            self._n_admitted += len(wave)
        return results

    def _rung(self, r: Request) -> Optional[int]:
        """Prompt-length ladder rung (None with bucketing off)."""
        if self.bucket is None:
            return None
        need = max(len(r.prompt), self.cfg.frontend_len + 1)
        return admission.bucket_capacity(need,
                                         growth=self._bucket_growth,
                                         min_size=self.bucket_min)

    def _generate_wave(self, requests: list[Request],
                       temperature: float,
                       rung: Optional[int] = None) -> list[np.ndarray]:
        cfg = self.cfg
        B = len(requests)
        plen = rung if rung is not None else max(
            max(len(r.prompt) for r in requests), cfg.frontend_len + 1)
        toks = admission.right_aligned_batch(
            [r.prompt for r in requests], length=plen)
        frontend = None
        if cfg.frontend != "none":
            frontend = jnp.zeros((B, cfg.frontend_len, cfg.d_model),
                                 jnp.float32)
        max_new = max(r.max_new_tokens for r in requests)
        total = min(self.max_seq, plen + max_new)
        # per-slot active mask: slot i needs tokens until plen+max_new_i
        need = np.array([min(self.max_seq, plen + r.max_new_tokens)
                         for r in requests])

        with compat.use_mesh(self.mesh):
            logits, cache = self._prefill(self.params, jnp.asarray(toks),
                                          frontend)
            # re-home the prefill cache into a full-length decode cache
            full = model_lib.init_cache(cfg, B, total, jnp.float32)
            cache = _splice_cache(cfg, full, cache, plen)
            out = [toks]
            cur = _sample(logits, temperature, self._next_key())
            for t in range(plen, total):
                active = int((need > t).sum())
                if active == 0:         # every slot has its tokens
                    break
                self._steps += 1
                self._occ_active += active
                self._occ_slots += B
                out.append(np.asarray(cur))
                logits, cache = self._decode(self.params, cur, cache,
                                             jnp.int32(t))
                cur = _sample(logits, temperature, self._next_key())
        seq = np.concatenate(out, axis=1)
        return [seq[i, plen - len(r.prompt):plen + r.max_new_tokens]
                for i, r in enumerate(requests)]

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def stats(self) -> DriverStats:
        """The VB driver's counters, LM flavour: slices = decode steps,
        occupancy = time-averaged active/width over decode steps."""
        cache_size = lambda fn: (int(fn._cache_size())
                                 if hasattr(fn, "_cache_size") else 0)
        occ = (self._occ_active / self._occ_slots
               if self._occ_slots else 0.0)
        return DriverStats(
            slices=self._steps,
            compiles=cache_size(self._prefill) + cache_size(self._decode),
            admitted=self._n_admitted, evicted=self._n_admitted,
            queue_depth=0, active=0,
            capacity=self.max_batch or 0, occupancy=occ,
            padding_waste=(1.0 - occ) if self._occ_slots else 0.0,
            checkpoints=0)


def _sample(logits, temperature, key):
    if temperature <= 0.0:
        return jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    g = jax.random.gumbel(key, logits[:, -1, :].shape)
    return jnp.argmax(logits[:, -1, :] / temperature + g,
                      axis=-1)[:, None].astype(jnp.int32)


def _splice_cache(cfg: ModelConfig, full, prefill, plen: int):
    """Copy the prefill cache into the (longer) decode cache buffers."""
    kinds = cfg.layer_kinds()

    def splice_attn(dst, src):
        # dst (.., S_total, H, hd), src (.., S_pre, H, hd); align at offset 0
        s = src.shape[-3]
        start = (0,) * (dst.ndim - 3) + (0, 0, 0)
        pad = dst.ndim - 3
        idx = (0,) * pad + (0, 0, 0)
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), idx)

    if model_lib._homogeneous(cfg):
        kind = kinds[0]
        if kind == "attn":
            return tuple(splice_attn(d, s) for d, s in zip(full, prefill))
        return jax.tree.map(lambda d, s: s.astype(d.dtype), full, prefill)
    out = []
    for i, kind in enumerate(kinds):
        if kind == "attn":
            out.append(tuple(splice_attn(d, s)
                             for d, s in zip(full[i], prefill[i])))
        else:
            out.append(jax.tree.map(lambda d, s: s.astype(d.dtype),
                                    full[i], prefill[i]))
    return out
