"""Streaming minibatch layer for the VB engine (Algorithm 1, stochastic form).

The paper's Algorithm 1 is a *stochastic* natural-gradient method: the
Robbins-Monro schedule eta_t (Eq. 22/29) exists precisely so each node may
estimate its local optimum phi*_i from a random subsample of its data.  The
engine's full-batch path never exercised that; this module supplies the
missing sampling layer:

* `MinibatchSpec(batch_size, seed)` — the run-level request handed to
  `engine.run_vb(..., minibatch=)`.
* `node_keys(n_nodes, seed)` — one fold-in PRNG key per GLOBAL node index,
  built host-side before any executor splits the node axis.  Because the
  key is per-node data (sharded along the node axis exactly like x), the
  single-array executor, the shard_map executor and both compute backends
  draw IDENTICAL minibatches for node i at iteration t.
* `StreamState(keys, perm, epoch)` — the carried sampler state owned by
  `engine.VBState`: the per-node fold-in keys plus the CURRENT epoch's
  permutation.  `init_state` builds it, `advance(state, base_mask, t,
  batch_size)` is the per-iteration sampler used inside
  `engine._scan_steps`: it refreshes the permutation only at epoch
  boundaries (a scalar-predicate `lax.cond`, so steady-state iterations
  pay an O(B) gather instead of the old O(T log T) per-step redraw) and
  returns gather indices plus a *scaled* mask.  Because the refreshed
  permutation is the same `fold_in(key, epoch)` draw the stateless
  sampler makes, the carried path is BIT-EXACT with it — and because
  everything is keyed on the ABSOLUTE iteration t, a run split across
  `vb_run` calls (or a checkpoint restore) replays the identical stream.
* `minibatch_select(keys, base_mask, t, batch_size)` — the stateless
  reference sampler (kept as the oracle the carried path is tested
  against).

Sampling is *random reshuffling* (epoch cycling): each epoch draws a fresh
uniform permutation of the node's sample slots and the iterations of that
epoch walk through it in `batch_size` windows (wrapping at the end, so
every slot is visited at least once per epoch — exactly once when
`batch_size` divides the capacity).  Any fixed index window of a uniform
permutation is a uniform without-replacement sample, so each iteration's
statistics are unbiased exactly as with iid sampling — but batches within
an epoch are (near-)disjoint, which cancels most of the within-epoch
noise (the classic random-reshuffling advantage over iid minibatching; on
the paper's 50-node GMM it cuts the stochastic KL gap several-fold, see
benchmarks/minibatch_bench.py).

The scaled mask carries the stochastic-VB rescaling: every selected valid
point gets the constant weight T/B (slot capacity / batch size; a slot
lands in the window with probability B/T), making the sufficient
statistics — which are linear in the mask — exactly unbiased estimators
of their full-batch values even on ragged nodes, composing with the
Appendix-A `replication` factor untouched.  Since the GMM natural
parameters are linear in the sufficient statistics, E[phi*_minibatch] =
phi*_full exactly (tests/test_streaming.py asserts this by Monte Carlo).

Full-batch degeneracy is bit-exact by construction: with `batch_size` =
the per-node sample capacity there is one window per epoch, the sorted
window is the identity gather, and the T/T scale multiplies the mask by
exactly 1.0 — so `MinibatchSpec(batch_size=n_per_node)` reproduces the
full-batch run bit-for-bit on every estimator and executor.

Variance reduction (`MinibatchSpec(control_variate="svrg")`): on top of
reshuffling, `StreamState` can carry a full-batch ANCHOR — a snapshot
iterate `anchor_phi` (N, P) and its full-batch local optimum `anchor_full`
(N, P), refreshed once per epoch inside `engine._iteration`.  The engine
then uses the SVRG-corrected estimator

    phi*_svrg(t) = phi*_B(phi_t) - phi*_B(anchor_phi) + anchor_full

where phi*_B(.) is the minibatch local optimum on iteration t's window.
Both minibatch terms share the SAME window, so the correction is a classic
control variate: E_B[phi*_B(anchor_phi)] = anchor_full exactly (statistics
are linear in the scaled mask), hence the estimator stays exactly unbiased
while the shared-window correlation cancels most of the sampling noise
(Khan's information-geometry view: the natural-gradient step is linear in
the local optimum, so variance reduction on phi* is variance reduction on
the step).  The anchors ride in the engine's carried state — checkpoint /
session-split safe — and the full-batch degeneracy above is untouched:
with batch_size >= capacity the engine never materialises the correction.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.telemetry import taps


class MinibatchSpec(NamedTuple):
    """Per-node minibatch request for a streaming `run_vb` call.

    batch_size : points visited per node per iteration (static; the E-step
        then runs on a (N, batch_size, D) gather instead of the full
        (N, Ni_max, D) array — the FLOPs saving is batch_size/Ni_max).
    seed : base seed of the deterministic per-(node, epoch) reshuffling
        stream.
    control_variate : None (plain reshuffling) or "svrg" — carry a
        full-batch anchor in `StreamState` and apply the SVRG-style
        corrected estimator (module docstring); exactly unbiased, large
        variance reduction at equal iteration count.  Inert when
        batch_size >= the per-node capacity (full batch is noise-free).
    """

    batch_size: int
    seed: int = 0
    control_variate: str | None = None


def node_keys(n_nodes: int, seed: int) -> jnp.ndarray:
    """(N, 2) uint32 per-node stream keys, derived from the GLOBAL node
    index so every executor layout sees the same per-node stream."""
    base = jax.random.PRNGKey(seed)
    return jax.vmap(lambda i: jax.random.fold_in(base, i))(
        jnp.arange(n_nodes))


class StreamState(NamedTuple):
    """Carried sampler state: one epoch permutation per node.

    keys : (N, 2) uint32 per-node fold-in keys (`node_keys`); constant.
    perm : (N, T) int32 — epoch `epoch`'s reshuffling permutation of each
        node's sample slots.
    epoch : () int32 — the epoch `perm` belongs to (refreshed by `advance`
        when the absolute iteration crosses an epoch boundary).
    anchor_phi : None, or (N, P) — the SVRG anchor iterate (the phi_nodes
        snapshot taken at the last epoch boundary).  None unless
        `MinibatchSpec(control_variate="svrg")` is active.
    anchor_full : None, or (N, P) — the full-batch local optimum
        phi*_full(anchor_phi), refreshed together with `anchor_phi`.
    """

    keys: jnp.ndarray
    perm: jnp.ndarray
    epoch: jnp.ndarray
    anchor_phi: jnp.ndarray | None = None
    anchor_full: jnp.ndarray | None = None


def _epoch_perms(keys: jnp.ndarray, epoch: jnp.ndarray,
                 capacity: int) -> jnp.ndarray:
    """(N, T) epoch permutations — the same `fold_in(key, epoch)` draw as
    the stateless `_select_one`, so carried and stateless paths agree
    bit-for-bit."""
    return jax.vmap(lambda k: jax.random.permutation(
        jax.random.fold_in(k, epoch), capacity))(keys).astype(jnp.int32)


def init_state(n_nodes: int, seed: int, capacity: int) -> StreamState:
    """Stream state at t=0: per-node keys + the epoch-0 permutations."""
    keys = node_keys(n_nodes, seed)
    epoch0 = jnp.zeros((), jnp.int32)
    return StreamState(keys, _epoch_perms(keys, epoch0, capacity), epoch0)


def advance(state: StreamState, base_mask: jnp.ndarray, t: jnp.ndarray,
            batch_size: int):
    """Carried-permutation form of `minibatch_select`.

    Returns (state', idx (N, B) int32, mb_mask (N, B) scaled mask) for the
    ABSOLUTE iteration t.  The permutation refresh happens only when t
    crosses an epoch boundary (scalar-predicate `lax.cond`: epochs are
    global because every node shares the padded capacity T), and the
    refresh draw is identical to the stateless sampler's, so the
    trajectory of (idx, mb_mask) is bit-exact with `minibatch_select` —
    including across a `vb_run` split or checkpoint restore, since epoch
    and chunk are pure functions of t.
    """
    T = base_mask.shape[1]
    batch_size = min(batch_size, T)
    n_chunks = -(-T // batch_size)                    # ceil: cover everything
    epoch = (t // n_chunks).astype(state.epoch.dtype)
    chunk = t % n_chunks
    perm = jax.lax.cond(epoch != state.epoch,
                        lambda: _epoch_perms(state.keys, epoch, T),
                        lambda: state.perm)
    if taps.enabled():
        # trace-time-gated device tap (telemetry/taps.py): epoch index per
        # iteration — rollovers show as increments in the tapped series.
        # No jaxpr change when taps are off.
        taps.tap("stream/epoch", epoch, t=t)
    pos = (chunk * batch_size + jnp.arange(batch_size)) % T
    idx = jnp.sort(jnp.take(perm, pos, axis=1), axis=1).astype(jnp.int32)
    picked = jnp.take_along_axis(base_mask, idx, axis=1)  # 0 where padding
    scale = jnp.asarray(T / batch_size, base_mask.dtype)
    return state._replace(perm=perm, epoch=epoch), idx, picked * scale


def state_specs(state: StreamState, axis: str) -> StreamState:
    """Partition specs for a carried `StreamState` under a node-sharded
    mesh: per-node leaves (keys, perm, and the SVRG anchors when present)
    shard along `axis`, the scalar epoch replicates.  Mirrors the value
    tree's None structure so the specs stay a valid shard_map prefix tree
    whether or not the run carries anchors."""
    from jax.sharding import PartitionSpec as P
    return StreamState(
        keys=P(axis), perm=P(axis), epoch=P(),
        anchor_phi=None if state.anchor_phi is None else P(axis),
        anchor_full=None if state.anchor_full is None else P(axis))


def _select_one(key: jnp.ndarray, base_mask: jnp.ndarray, t: jnp.ndarray,
                batch_size: int):
    """One node's chunk at iteration t: (idx (B,) int32, scaled mask (B,)).

    Epoch e = t // n_chunks draws permutation_e of the sample slots;
    iteration t takes window (t mod n_chunks) of it — wrapping around the
    end when batch_size does not divide the capacity, so every slot is
    visited at least once per epoch (exactly once when it divides) —
    sorted ascending (with one chunk per epoch this makes the gather the
    identity permutation: the bit-exact full-batch degeneracy).

    The weight on every selected VALID point is the constant T/B
    (capacity/batch): any fixed index window of a uniform permutation is a
    uniform without-replacement draw, so each slot lands in the window
    with probability B/T and the T/B reweighting makes the statistics
    exactly unbiased — including on ragged nodes, where a window may
    contain few (or zero) valid points.  (A realized-count ratio like
    n_i/|B_i| would be biased there: it cannot compensate for the
    all-padding windows that contribute nothing.)

    Cost note: the permutation is redrawn every iteration (O(T log T) per
    node), though it only changes once per epoch — fine for sensor-sized
    buffers; a huge-buffer deployment would carry the epoch permutation in
    the scan state instead (ROADMAP follow-up).
    """
    T = base_mask.shape[0]
    n_chunks = -(-T // batch_size)                    # ceil: cover everything
    epoch = t // n_chunks
    chunk = t % n_chunks
    ke = jax.random.fold_in(key, epoch)
    perm = jax.random.permutation(ke, T)
    pos = (chunk * batch_size + jnp.arange(batch_size)) % T
    idx = jnp.sort(jnp.take(perm, pos)).astype(jnp.int32)
    picked = jnp.take(base_mask, idx)                 # 0 where padding
    scale = jnp.asarray(T / batch_size, base_mask.dtype)
    return idx, picked * scale


def minibatch_select(keys: jnp.ndarray, base_mask: jnp.ndarray,
                     t: jnp.ndarray, batch_size: int):
    """Whole-network draw at iteration t.

    keys (N, 2) from `node_keys` (or the executor's local slice of it),
    base_mask (N, T) validity mask.  Returns (idx (N, B) int32 gather
    indices into the node's sample axis, mb_mask (N, B) scaled minibatch
    mask).  Deterministic in (seed, global node index, t) only.
    """
    return jax.vmap(lambda k, m: _select_one(k, m, t, batch_size))(
        keys, base_mask)
