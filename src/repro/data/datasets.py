"""Surrogate real-data generators (container is offline — DESIGN.md §7).

Each surrogate matches the published dataset's dimensionality, cardinality
and class structure so the *relative* algorithm ordering of Tables I/II and
Fig. 13 can be validated (absolute accuracies are not comparable
digit-for-digit and are not claimed).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.data.synthetic import SensorData


def _to_sensor_data(x, labels, n_nodes, rng) -> SensorData:
    """Shuffle and deal samples uniformly to nodes (the papers' allocation
    for the real-data experiments)."""
    idx = rng.permutation(len(x))
    x, labels = x[idx], labels[idx]
    n = (len(x) // n_nodes) * n_nodes
    x, labels = x[:n], labels[:n]
    per = n // n_nodes
    xs = x.reshape(n_nodes, per, x.shape[-1])
    ls = labels.reshape(n_nodes, per)
    mask = np.ones((n_nodes, per))
    return SensorData(x=jnp.asarray(xs), mask=jnp.asarray(mask),
                      labels=jnp.asarray(ls.astype(np.int32)))


def atmosphere_surrogate(n_nodes: int = 20, *, seed: int = 0) -> SensorData:
    """1600 samples x 3 features (SO2, NO2, PM10), 2 classes (clean 830 /
    polluted 770), well-separated — the paper reports ~100% for cVB."""
    rng = np.random.default_rng(seed)
    clean = rng.multivariate_normal(
        [0.02, 0.03, 0.06], np.diag([1e-4, 2e-4, 4e-4]), 830)
    polluted = rng.multivariate_normal(
        [0.12, 0.15, 0.35], np.diag([9e-4, 1.2e-3, 4e-3]), 770)
    x = np.concatenate([clean, polluted])
    labels = np.concatenate([np.zeros(830), np.ones(770)])
    return _to_sensor_data(x, labels, n_nodes, rng)


def ionosphere_surrogate(n_nodes: int = 20, *, seed: int = 0) -> SensorData:
    """340 samples x 34 attributes, 2 overlapping classes (225 good /
    126 bad in the UCI set; the paper's cVB only reaches ~82%)."""
    rng = np.random.default_rng(seed)
    d = 34
    mu_good = rng.normal(0.4, 0.3, d)
    mu_bad = mu_good + rng.normal(0.0, 0.55, d)     # partial overlap
    a = rng.normal(size=(d, d)) * 0.12
    cov_good = a @ a.T + np.eye(d) * 0.25
    b = rng.normal(size=(d, d)) * 0.2
    cov_bad = b @ b.T + np.eye(d) * 0.45
    good = rng.multivariate_normal(mu_good, cov_good, 218)
    bad = rng.multivariate_normal(mu_bad, cov_bad, 122)
    x = np.concatenate([good, bad])
    labels = np.concatenate([np.zeros(218), np.ones(122)])
    return _to_sensor_data(x, labels, n_nodes, rng)


def coil20_surrogate(n_classes: int, n_nodes: int = 10, *,
                     seed: int = 0) -> SensorData:
    """COIL-20 after PCA: 72 images per object, 52 dims.  Rotation sweeps
    make each class an elongated low-rank cluster."""
    rng = np.random.default_rng(seed)
    d = 52
    xs, ls = [], []
    for k in range(n_classes):
        center = rng.normal(0.0, 2.2, d)
        # low-rank elongation (the turntable rotation manifold)
        basis = rng.normal(size=(d, 4)) * 0.9
        t = rng.normal(size=(72, 4))
        xs.append(center + t @ basis.T + rng.normal(0.0, 0.25, (72, d)))
        ls.append(np.full(72, k))
    x = np.concatenate(xs)
    labels = np.concatenate(ls)
    return _to_sensor_data(x, labels, n_nodes, rng)
