"""Synthetic sensor-network data — the paper's Sec. V-A generator.

Three 2-D Gaussian components; 50 nodes x 100 points with the published
*imbalanced* allocation (nodes 1-15 draw 80% from component 1, nodes 16-35
draw 90% from component 2, nodes 36-50 draw 60% from component 3).  Also the
balanced/unequal-size variants used in Sec. V-C.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax.numpy as jnp

# Paper Sec. V-A ground-truth parameters
PAPER_PI = np.array([0.32, 0.45, 0.23])
PAPER_MU = np.array([[1.5, 3.5], [4.0, 4.0], [6.5, 4.5]])
PAPER_SIGMA = np.array([
    [[0.6, 0.4], [0.4, 0.6]],
    [[0.6, -0.4], [-0.4, 0.6]],
    [[0.6, 0.4], [0.4, 0.6]],
])


class SensorData(NamedTuple):
    x: jnp.ndarray        # (N_nodes, Ni_max, D), zero-padded
    mask: jnp.ndarray     # (N_nodes, Ni_max) 1 = valid sample
    labels: jnp.ndarray   # (N_nodes, Ni_max) true component (for Eq. 46 ref)

    @property
    def flat(self):
        """(x_all, labels_all) with padding removed (host-side)."""
        m = np.asarray(self.mask).astype(bool)
        return (jnp.asarray(np.asarray(self.x)[m]),
                jnp.asarray(np.asarray(self.labels)[m]))


def _sample_component(rng, k, n):
    return rng.multivariate_normal(PAPER_MU[k], PAPER_SIGMA[k], size=n)


def _node_mixture(node: int, n_nodes: int) -> np.ndarray:
    """Per-node component mixture of Sec. V-A, rescaled to any N."""
    a, b = int(round(0.3 * n_nodes)), int(round(0.7 * n_nodes))
    if node < a:           # dominated by component 1
        return np.array([0.8, 0.1, 0.1])
    elif node < b:         # dominated by component 2
        return np.array([0.05, 0.9, 0.05])
    else:                  # dominated by component 3
        return np.array([0.2, 0.2, 0.6])


def paper_synthetic(n_nodes: int = 50, n_per_node: int = 100, *,
                    seed: int = 0, imbalanced: bool = True,
                    unequal_sizes: bool = False,
                    dtype=np.float64) -> SensorData:
    """The Sec. V-A dataset (imbalanced=True) or the Sec. V-C variants."""
    rng = np.random.default_rng(seed)
    sizes = np.full(n_nodes, n_per_node)
    if unequal_sizes:  # Sec. V-C1: 40..160 points per node
        sizes = rng.integers(40, 161, size=n_nodes)
    ni_max = int(sizes.max())
    x = np.zeros((n_nodes, ni_max, 2), dtype)
    mask = np.zeros((n_nodes, ni_max), dtype)
    labels = np.zeros((n_nodes, ni_max), np.int32)
    for i in range(n_nodes):
        p = _node_mixture(i, n_nodes) if imbalanced else PAPER_PI
        lab = rng.choice(3, size=sizes[i], p=p / p.sum())
        for k in range(3):
            idx = np.nonzero(lab == k)[0]
            if idx.size:
                x[i, idx] = _sample_component(rng, k, idx.size)
        labels[i, :sizes[i]] = lab
        mask[i, :sizes[i]] = 1.0
    return SensorData(x=jnp.asarray(x), mask=jnp.asarray(mask),
                      labels=jnp.asarray(labels))


def gmm_data(n_nodes: int, n_per_node: int, pi, mu, sigma, *, seed: int = 0,
             dtype=np.float64) -> SensorData:
    """General balanced GMM sampler (arbitrary K, D) for tests/benchmarks."""
    rng = np.random.default_rng(seed)
    pi = np.asarray(pi) / np.sum(pi)
    mu = np.asarray(mu)
    sigma = np.asarray(sigma)
    K, D = mu.shape
    x = np.zeros((n_nodes, n_per_node, D), dtype)
    labels = np.zeros((n_nodes, n_per_node), np.int32)
    for i in range(n_nodes):
        lab = rng.choice(K, size=n_per_node, p=pi)
        for k in range(K):
            idx = np.nonzero(lab == k)[0]
            if idx.size:
                x[i, idx] = rng.multivariate_normal(mu[k], sigma[k], idx.size)
        labels[i] = lab
    mask = np.ones((n_nodes, n_per_node), dtype)
    return SensorData(x=jnp.asarray(x), mask=jnp.asarray(mask),
                      labels=jnp.asarray(labels))
