"""Token data pipeline for LM training (offline container: synthetic corpus).

The corpus is a order-2 Markov chain over the vocabulary with Zipf-ish
marginals — enough structure that a ~100M model's loss drops well below the
unigram entropy within a few hundred steps (examples/train_lm.py), while
being generated on the fly with zero disk footprint.

`Batcher` yields host-side numpy batches; the trainer device_puts them with
the mesh batch sharding (the production-shaped input path).
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np


class MarkovCorpus:
    def __init__(self, vocab_size: int, seed: int = 0, branch: int = 8):
        rng = np.random.default_rng(seed)
        self.vocab = vocab_size
        self.branch = branch
        # each (prev token) maps to `branch` likely successors
        self.succ = rng.integers(0, vocab_size, size=(vocab_size, branch))
        probs = rng.dirichlet(np.ones(branch) * 0.5, size=vocab_size)
        self.probs = probs

    def sample(self, rng: np.random.Generator, batch: int, seq: int):
        out = np.empty((batch, seq), np.int32)
        cur = rng.integers(0, self.vocab, size=batch)
        out[:, 0] = cur
        for t in range(1, seq):
            choice = np.array([
                rng.choice(self.branch, p=self.probs[c]) for c in cur])
            cur = self.succ[cur, choice]
            # occasional resets keep the chain mixing
            reset = rng.random(batch) < 0.02
            cur = np.where(reset, rng.integers(0, self.vocab, batch), cur)
            out[:, t] = cur
        return out


class Batcher:
    """Deterministic, restartable batch stream."""

    def __init__(self, vocab_size: int, batch: int, seq: int, *,
                 seed: int = 0, frontend_len: int = 0, d_model: int = 0):
        self.corpus = MarkovCorpus(vocab_size, seed)
        self.batch, self.seq = batch, seq
        self.frontend_len, self.d_model = frontend_len, d_model
        self.seed = seed
        self.step = 0

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> dict:
        rng = np.random.default_rng((self.seed, self.step))
        self.step += 1
        out = {"tokens": self.corpus.sample(rng, self.batch, self.seq)}
        if self.frontend_len > 0:
            out["frontend"] = rng.standard_normal(
                (self.batch, self.frontend_len, self.d_model)).astype(
                    np.float32)
        return out
