"""Serving launcher: batched generation with the smoke configs.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2_370m \
        --requests 4 --max_new 32
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt_len", type=int, default=16)
    ap.add_argument("--max_new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--use_kernels", action="store_true")
    ap.add_argument("--max_batch", type=int, default=0,
                    help="slot-table wave width (continuous batching; "
                         "0 = one wave for all requests)")
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs.base import get_smoke_config
    from repro.models import model as model_lib
    from repro.serving import admission
    from repro.serving import engine as eng

    cfg = get_smoke_config(args.arch)
    # one data axis over whatever devices exist (a single real CPU device
    # in the smoke container, every device elsewhere)
    mesh = admission.data_axis_mesh("data")
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    e = eng.Engine(cfg, mesh, params,
                   max_seq=args.prompt_len + args.max_new + cfg.frontend_len,
                   use_kernels=args.use_kernels,
                   max_batch=args.max_batch or None)
    rng = np.random.default_rng(0)
    reqs = [eng.Request(
        rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
        args.max_new) for _ in range(args.requests)]
    outs = e.generate(reqs, temperature=args.temperature)
    for i, o in enumerate(outs):
        print(f"request {i}: {o.tolist()}")
    st = e.stats()
    print(f"engine: {st.slices} decode steps, {st.compiles} compiles, "
          f"{st.admitted} requests, occupancy {st.occupancy:.2f}")


if __name__ == "__main__":
    main()
