import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
# The dry-run (and only the dry-run) builds the 256/512-chip production mesh
# out of host-platform placeholder devices; nothing is ever allocated on
# them (ShapeDtypeStruct in, compiled artifact out).

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import warnings          # noqa: E402

warnings.filterwarnings("ignore", category=DeprecationWarning)

import jax               # noqa: E402

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config  # noqa: E402
from repro.launch import hlo_analysis, specs  # noqa: E402
from repro.launch.mesh import make_production_mesh, n_chips  # noqa: E402
from repro.models.model import param_count  # noqa: E402


def _lower_compile(cfg, shape, mesh, dp_mode, consensus_axis, use_kernels):
    fn, in_specs = specs.build_step(cfg, shape, mesh, dp_mode=dp_mode,
                                    consensus_axis=consensus_axis,
                                    use_kernels=use_kernels)
    from repro.dist import compat
    with compat.use_mesh(mesh):
        lowered = jax.jit(fn).lower(**in_specs)
        compiled = lowered.compile()
    return compiled


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            dp_mode: str = "allreduce", use_kernels: bool = False,
            verbose: bool = True, cfg_override=None) -> dict:
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    consensus_axis = None
    if dp_mode != "allreduce":
        consensus_axis = "pod" if multi_pod else "data"

    t0 = time.time()
    compiled = _lower_compile(cfg, shape, mesh, dp_mode, consensus_axis,
                              use_kernels)
    t_compile = time.time() - t0

    # model FLOPs: 6*N_active*D for train (fwd+bwd), 2*N_active*D inference
    n_active = param_count(cfg, active_only=True)
    n_tok = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                  else 1)
    mf = (6.0 if shape.kind == "train" else 2.0) * n_active * n_tok
    mem = hlo_analysis.memory_per_device(compiled)

    from repro.models.model import _homogeneous
    if _homogeneous(cfg) and cfg.n_layers > 2:
        # XLA cost analysis does not descend into while (scan) bodies;
        # recover true totals from UNSCANNED 1- and 2-layer auxiliary
        # compiles (all layer ops top-level, inner chunk loops unrolled),
        # exact for homogeneous stacks — see hlo_analysis.extrapolate_layers.
        c1 = hlo_analysis.analyze(
            _lower_compile(cfg.replace(n_layers=1, scan_layers=False),
                           shape, mesh, dp_mode, consensus_axis, use_kernels),
            n_chips(mesh), model_flops=mf)
        c2 = hlo_analysis.analyze(
            _lower_compile(cfg.replace(n_layers=2, scan_layers=False),
                           shape, mesh, dp_mode, consensus_axis, use_kernels),
            n_chips(mesh), model_flops=mf)
        roof = hlo_analysis.extrapolate_layers(c1, c2, cfg.n_layers)
    else:
        # unscanned archs (recurrentgemma): every layer is in the HLO, exact
        roof = hlo_analysis.analyze(compiled, n_chips(mesh), model_flops=mf)
    t_lower = 0.0

    report = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "dp_mode": dp_mode, "use_kernels": use_kernels,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem,
        **roof.as_dict(),
    }
    if verbose:
        gb = (mem.get("argument_size_in_bytes") or 0) / 2**30
        tmp = (mem.get("temp_size_in_bytes") or 0) / 2**30
        print(f"[dryrun] {arch:24s} {shape_name:12s} "
              f"{report['mesh']:8s} {dp_mode:9s} "
              f"args/dev {gb:8.2f} GiB  temp/dev {tmp:7.2f} GiB  "
              f"Tc {roof.t_compute*1e3:9.3f} ms  Tm {roof.t_memory*1e3:9.3f} ms"
              f"  Tcoll {roof.t_collective*1e3:9.3f} ms  "
              f"-> {roof.bottleneck:10s} useful {roof.useful_flops_ratio:.2f}"
              f"  (compile {t_compile:.0f}s)")
    return report


def main():
    ap = argparse.ArgumentParser(description="Multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="input shape name or 'all'")
    ap.add_argument("--multi_pod", action="store_true")
    ap.add_argument("--both_meshes", action="store_true",
                    help="run 16x16 AND 2x16x16 for each pair")
    ap.add_argument("--dp_mode", default="allreduce",
                    choices=["allreduce", "diffusion", "admm"])
    ap.add_argument("--use_kernels", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = (list(INPUT_SHAPES) if args.shape == "all" else [args.shape])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = (f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}"
                       f"_{args.dp_mode}"
                       + ("_kern" if args.use_kernels else ""))
                try:
                    rep = run_one(arch, shape, multi_pod=mp,
                                  dp_mode=args.dp_mode,
                                  use_kernels=args.use_kernels)
                    with open(os.path.join(args.out, tag + ".json"),
                              "w") as f:
                        json.dump(rep, f, indent=1)
                except Exception as e:  # noqa: BLE001
                    failures.append((tag, repr(e)))
                    print(f"[dryrun] FAIL {tag}: {e!r}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(" ", tag, err[:200])
        raise SystemExit(1)
    print("\nall dry-runs compiled OK")


if __name__ == "__main__":
    main()
