import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ first two lines, same contract as dryrun.py.
"""Perf-iteration driver (§Perf hillclimbing).

Runs a named (arch, shape) pair under a sequence of CONFIG VARIANTS
(sharding / remat / dtype / dp_mode / kernel knobs), re-lowers, re-analyses
and prints the roofline delta vs the baseline — the measure step of the
hypothesis -> change -> measure -> validate loop.  Results accumulate in
experiments/perf/<arch>_<shape>.json so EXPERIMENTS.md §Perf can cite them.

    PYTHONPATH=src python -m repro.launch.perf --arch yi_6b --shape train_4k \
        --variants baseline,noremat,diffusion,admm
"""

import argparse          # noqa: E402
import json              # noqa: E402
import warnings          # noqa: E402

warnings.filterwarnings("ignore", category=DeprecationWarning)

from repro.configs.base import get_config  # noqa: E402
from repro.launch import dryrun  # noqa: E402


def variant_space(cfg):
    """Named config/step variants for hillclimbing."""
    return {
        # paper-faithful baseline: allreduce DP + fsdp + remat
        "baseline": dict(cfg=cfg, dp_mode="allreduce"),
        # iteration snapshot names (same config, code-level sharding fixes;
        # the 'measure' step of hypothesis->change->measure cycles)
        "shardfix": dict(cfg=cfg, dp_mode="allreduce"),
        "shardfix2": dict(cfg=cfg, dp_mode="allreduce"),
        # activation-checkpointing OFF (memory for compute trade)
        "noremat": dict(cfg=cfg.replace(remat=False), dp_mode="allreduce"),
        # fsdp OFF (replicated weights: kills per-layer weight all-gathers,
        # costs memory)
        "nofsdp": dict(cfg=cfg.replace(fsdp=False), dp_mode="allreduce"),
        "nofsdp_noremat": dict(cfg=cfg.replace(fsdp=False, remat=False),
                               dp_mode="allreduce"),
        # the paper's technique: consensus instead of exact averaging
        "diffusion": dict(cfg=cfg, dp_mode="diffusion"),
        "admm": dict(cfg=cfg, dp_mode="admm"),
        "diffusion_noremat": dict(cfg=cfg.replace(remat=False),
                                  dp_mode="diffusion"),
        # f32 master activations (numerics-vs-bytes trade)
        "f32_compute": dict(cfg=cfg.replace(compute_dtype="float32"),
                            dp_mode="allreduce"),
        # MoE capacity trades (MoE archs only)
        "cap1": dict(cfg=cfg.replace(capacity_factor=1.0),
                     dp_mode="allreduce"),
        "cap2": dict(cfg=cfg.replace(capacity_factor=2.0),
                     dp_mode="allreduce"),
        # flat-head GQA layout: head axis shards over "model" cleanly
        "flat_heads": dict(cfg=cfg.replace(attn_flat_heads=True),
                           dp_mode="allreduce"),
        # sliding-window archs: per-chunk KV dynamic_slice instead of mask
        "windowed_kv": dict(cfg=cfg.replace(windowed_kv=True),
                            dp_mode="allreduce"),
        "flat_windowed": dict(cfg=cfg.replace(attn_flat_heads=True,
                                              windowed_kv=True),
                              dp_mode="allreduce"),
        "flat_noremat": dict(cfg=cfg.replace(attn_flat_heads=True,
                                             remat=False),
                             dp_mode="allreduce"),
        "flat_diffusion": dict(cfg=cfg.replace(attn_flat_heads=True),
                               dp_mode="diffusion"),
        # MoE per-shard dispatch (Switch per-core capacity semantics)
        "local_dispatch": dict(cfg=cfg.replace(moe_local_dispatch=True),
                               dp_mode="allreduce"),
        "local_dispatch_cap1": dict(
            cfg=cfg.replace(moe_local_dispatch=True, capacity_factor=1.0),
            dp_mode="allreduce"),
        # smaller attention q-chunks (peak-memory lever)
        "qchunk512": dict(cfg=cfg.replace(attn_q_chunk=512),
                          dp_mode="allreduce"),
        "qchunk256": dict(cfg=cfg.replace(attn_q_chunk=256),
                          dp_mode="allreduce"),
        # pad vocab to a multiple of the model axis (sharded unembed)
        "padvocab": dict(cfg=cfg.replace(
            vocab_pad=-(-cfg.vocab_size // 16) * 16), dp_mode="allreduce"),
        "padvocab_cap1": dict(cfg=cfg.replace(
            vocab_pad=-(-cfg.vocab_size // 16) * 16, capacity_factor=1.0),
            dp_mode="allreduce"),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi_pod", action="store_true")
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    space = variant_space(cfg)
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"{args.arch}_{args.shape}"
                        f"{'_2pod' if args.multi_pod else ''}.json")
    results = {}
    if os.path.exists(path):
        with open(path) as f:
            results = json.load(f)

    base = results.get("baseline")
    for name in args.variants.split(","):
        v = space[name]
        rep = dryrun.run_one(args.arch, args.shape,
                             multi_pod=args.multi_pod,
                             dp_mode=v["dp_mode"], cfg_override=v["cfg"],
                             verbose=False)
        results[name] = rep
        if name == "baseline":
            base = rep
        line = (f"[perf] {name:20s} Tc {rep['t_compute_s']*1e3:9.2f} ms  "
                f"Tm {rep['t_memory_s']*1e3:9.2f} ms  "
                f"Tcoll {rep['t_collective_s']*1e3:9.2f} ms  "
                f"-> {rep['bottleneck']}")
        if base and name != "baseline":
            for k, key in [("Tc", "t_compute_s"), ("Tm", "t_memory_s"),
                           ("Tcoll", "t_collective_s")]:
                d = (rep[key] - base[key]) / max(base[key], 1e-12) * 100
                line += f"  d{k} {d:+.1f}%"
        print(line)
        with open(path, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
