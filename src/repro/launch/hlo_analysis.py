"""Roofline-term extraction from compiled dry-run artifacts.

compute term    = HLO_FLOPs / (chips * PEAK_FLOPS)
memory term     = HLO_bytes / (chips * HBM_BW)
collective term = collective_bytes / (chips * ICI_BW)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis().  collective_bytes
is parsed from the optimised HLO text: we sum the *result-shape* bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction (per-device program; result bytes ~ wire
bytes for reduce/permute ops, an upper bound for all-gather).  Fusion-nested
occurrences are counted once (instruction granularity).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# TPU v5e-class hardware constants (per chip), from the task spec.
PEAK_FLOPS = 197e12        # bf16 FLOP/s
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.3 = bf16[2,16,512]{2,1,0} all-gather(
_INSTR_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")
# tuple-result collectives:  = (bf16[...], bf16[...]) all-reduce(
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*(" + "|".join(_COLLECTIVES) + r")(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    seen_done = set()
    for line in hlo_text.splitlines():
        line = line.strip()
        if "-done(" in line:       # async pair: count the start only
            continue
        kind = None
        nbytes = 0
        # tuple-result ops first: async starts are (operand, result) tuples;
        # the RESULT (largest element) is the wire-traffic proxy
        mt = _TUPLE_RE.search(line)
        if mt:
            kind = mt.group(2)
            sizes = [_shape_bytes(d, s)
                     for d, s in _SHAPE_RE.findall(mt.group(1))]
            nbytes = max(sizes) if sizes else 0
        else:
            m = _INSTR_RE.search(line)
            if m:
                dtype, dims, kind = m.group(1), m.group(2), m.group(3)
                nbytes = _shape_bytes(dtype, dims)
        if kind:
            stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) \
                + nbytes
            stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class Roofline:
    """All raw quantities are PER-DEVICE: the compiled artifact is the SPMD
    per-device program, so cost_analysis flops/bytes and the parsed
    collective bytes are per-chip.  The task's `X / (chips * peak)` formulas
    are therefore applied with the global `X = per_device * chips`, i.e.
    t = per_device_X / peak — identical, with sharding imbalance already
    reflected by whatever XLA replicated."""

    flops: float               # per-device HLO FLOPs
    hbm_bytes: float           # per-device bytes accessed (upper bound:
    #                            HLO cost analysis ignores fusion reuse)
    coll_bytes: float          # per-device collective wire bytes
    n_chips: int
    model_flops: float = 0.0   # 6*N*D analytic, GLOBAL
    coll_detail: dict = field(default_factory=dict)
    coll_counts: dict = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """(model FLOPs per chip) / (HLO FLOPs per chip): <1 under remat /
        redundant compute; >1 would indicate sharding that skips work."""
        if not self.flops:
            return 0.0
        return (self.model_flops / self.n_chips) / self.flops

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "n_chips": self.n_chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "coll_detail": self.coll_detail,
            "coll_counts": self.coll_counts,
        }


def analyze(compiled, n_chips: int, model_flops: float = 0.0) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    stats = collective_bytes(compiled.as_text())
    return Roofline(flops=flops, hbm_bytes=nbytes,
                    coll_bytes=float(stats.total_bytes), n_chips=n_chips,
                    model_flops=model_flops,
                    coll_detail=stats.bytes_by_kind,
                    coll_counts=stats.count_by_kind)


def extrapolate_layers(c1: Roofline, c2: Roofline, n_layers: int) -> Roofline:
    """Correct XLA's while-loop single-count: given rooflines of otherwise
    identical 1-layer and 2-layer programs, the per-layer marginal cost is
    (c2 - c1) and the L-layer total is c1 + (L-1)*(c2 - c1).  Exact for
    layer-stacked scans (the layer loop is the only differing while loop;
    inner attention/SSD chunk loops are unrolled — see layers.chunked_sdpa)."""
    def ext(a, b):
        return a + (n_layers - 1) * (b - a)

    detail = {k: ext(c1.coll_detail.get(k, 0), c2.coll_detail.get(k, 0))
              for k in set(c1.coll_detail) | set(c2.coll_detail)}
    counts = {k: ext(c1.coll_counts.get(k, 0), c2.coll_counts.get(k, 0))
              for k in set(c1.coll_counts) | set(c2.coll_counts)}
    return Roofline(
        flops=ext(c1.flops, c2.flops),
        hbm_bytes=ext(c1.hbm_bytes, c2.hbm_bytes),
        coll_bytes=ext(c1.coll_bytes, c2.coll_bytes),
        n_chips=c1.n_chips, model_flops=c1.model_flops,
        coll_detail=detail, coll_counts=counts)


def memory_per_device(compiled) -> dict:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes"):
        out[k] = getattr(ma, k, None)
    return out
