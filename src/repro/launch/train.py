"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch yi_6b --smoke \
        --dp_mode diffusion --steps 50

On this CPU container only --smoke (reduced configs) actually executes;
the full configs are exercised via the dry-run (launch/dryrun.py).  Set
--host_devices N to emulate a small mesh with host-platform devices.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global_batch", type=int, default=8)
    ap.add_argument("--seq_len", type=int, default=256)
    ap.add_argument("--dp_mode", default="allreduce",
                    choices=["allreduce", "diffusion", "admm"])
    ap.add_argument("--host_devices", type=int, default=0,
                    help="emulate N host devices (mesh data x model)")
    ap.add_argument("--data_axis", type=int, default=1)
    ap.add_argument("--model_axis", type=int, default=1)
    ap.add_argument("--peak_lr", type=float, default=3e-4)
    ap.add_argument("--use_kernels", action="store_true")
    ap.add_argument("--ckpt_dir", default=None)
    ap.add_argument("--log_every", type=int, default=10)
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}")

    import jax  # noqa: E402  (after XLA_FLAGS)

    from repro.configs.base import get_config, get_smoke_config
    from repro.training import train_step as ts
    from repro.training.trainer import Trainer

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = jax.make_mesh((args.data_axis, args.model_axis),
                         ("data", "model"))
    axis = "data" if args.dp_mode != "allreduce" else None
    hyper = ts.TrainHyper(peak_lr=args.peak_lr, total_steps=args.steps,
                          warmup=max(args.steps // 10, 5))
    trainer = Trainer(cfg, mesh, dp_mode=args.dp_mode, consensus_axis=axis,
                      hyper=hyper, global_batch=args.global_batch,
                      seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
                      use_kernels=args.use_kernels)
    trainer.run(args.steps, log_every=args.log_every)
    if args.ckpt_dir:
        print("saved:", trainer.save(args.steps))


if __name__ == "__main__":
    main()
