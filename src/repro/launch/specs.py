"""ShapeDtypeStruct input specs + step builders for the multi-pod dry-run.

Everything here is allocation-free: model/optimizer state comes from
jax.eval_shape and inputs are ShapeDtypeStructs carrying NamedShardings, so
lowering a 314B-parameter training step on 512 placeholder devices costs
only compile time.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist import sharding
from repro.launch import mesh as mesh_lib
from repro.models import model as model_lib
from repro.serving import engine
from repro.training import train_step as ts

SLIDING_WINDOW_LONG = 4096   # documented long_500k variant for full-attn archs


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _batch_axes(mesh: Mesh, batch: int):
    """Greedy batch sharding over (pod, data): only axes that divide."""
    sizes = mesh_lib.axis_sizes(mesh)
    axes = []
    rem = batch
    for a in ("pod", "data"):
        if a in sizes and rem % sizes[a] == 0:
            axes.append(a)
            rem //= sizes[a]
    return tuple(axes)


def arch_variant(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """long_500k needs sub-quadratic attention: SSM/hybrid archs are
    natively sub-quadratic; full-attention archs run the documented
    sliding-window variant (DESIGN.md §5)."""
    if shape.name == "long_500k" and cfg.window == 0 and any(
            k == "attn" for k in cfg.layer_kinds()):
        return cfg.replace(window=SLIDING_WINDOW_LONG, windowed_kv=True)
    return cfg


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                dp_mode: str = "allreduce",
                consensus_axis: Optional[str] = None) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the step being lowered.

    train  -> {"state": TrainState, "batch": {tokens[, frontend]}}
    prefill-> {"params", "tokens"[, "frontend"]}
    decode -> {"params", "token", "cache", "pos"}
    """
    cfg = arch_variant(cfg, shape)
    baxes = _batch_axes(mesh, shape.global_batch)
    B, S = shape.global_batch, shape.seq_len
    tok_dtype = jnp.int32
    scanned = model_lib._homogeneous(cfg)

    def param_specs(replica_axis=None):
        pshape = jax.eval_shape(
            functools.partial(model_lib.init_params, cfg),
            jax.random.PRNGKey(0))
        shd = sharding.param_shardings(
            pshape, mesh, fsdp=cfg.fsdp and replica_axis is None,
            scanned=scanned, replica_axis=replica_axis,
            no_fsdp_keys=("moe",) if cfg.moe_local_dispatch else ())
        return jax.tree.map(
            lambda s, h: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=h),
            pshape, shd)

    if shape.kind == "train":
        n_rep = (mesh_lib.axis_sizes(mesh).get(consensus_axis, 1)
                 if dp_mode != "allreduce" else 1)
        state_shape = jax.eval_shape(
            functools.partial(ts.init_state, cfg, dp_mode=dp_mode,
                              n_replicas=n_rep), jax.random.PRNGKey(0))
        shd = ts.state_shardings(state_shape, cfg, mesh, dp_mode=dp_mode,
                                 consensus_axis=consensus_axis)
        state = jax.tree.map(
            lambda s, h: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=h),
            state_shape, shd)
        batch = {"tokens": _sds((B, S), tok_dtype, mesh, P(baxes))}
        if cfg.frontend != "none":
            batch["frontend"] = _sds((B, cfg.frontend_len, cfg.d_model),
                                     jnp.bfloat16, mesh, P(baxes))
        return {"state": state, "batch": batch}

    params = param_specs()
    if shape.kind == "prefill":
        out = {"params": params,
               "tokens": _sds((B, S), tok_dtype, mesh, P(baxes))}
        if cfg.frontend != "none":
            out["frontend"] = _sds((B, cfg.frontend_len, cfg.d_model),
                                   jnp.bfloat16, mesh, P(baxes))
        return out

    # decode: ONE new token against a cache of seq_len
    cache_shape = jax.eval_shape(
        functools.partial(model_lib.init_cache, cfg, B, S, jnp.bfloat16))
    cache_shd = engine.cache_shardings(cache_shape, cfg, mesh)
    cache = jax.tree.map(
        lambda s, h: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=h),
        cache_shape, cache_shd)
    return {
        "params": params,
        "token": _sds((B, 1), tok_dtype, mesh, P(baxes)),
        "cache": cache,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
               dp_mode: str = "allreduce",
               consensus_axis: Optional[str] = None,
               use_kernels: bool = False):
    """Returns (fn, kwargs_specs) ready for jax.jit(fn).lower(**specs)."""
    cfg = arch_variant(cfg, shape)
    specs = input_specs(cfg, shape, mesh, dp_mode=dp_mode,
                        consensus_axis=consensus_axis)
    if shape.kind == "train":
        step = ts.make_train_step(cfg, mesh, dp_mode=dp_mode,
                                  consensus_axis=consensus_axis,
                                  use_kernels=use_kernels)

        def fn(state, batch):
            return step(state, batch)

        return fn, specs
    if shape.kind == "prefill":
        pre = engine.make_prefill_step(cfg, use_kernels=use_kernels)
        if cfg.frontend != "none":
            return (lambda params, tokens, frontend:
                    pre(params, tokens, frontend)), specs
        return (lambda params, tokens: pre(params, tokens)), specs

    dec = engine.make_decode_step(cfg)
    return (lambda params, token, cache, pos:
            dec(params, token, cache, pos)), specs
