"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required so smoke tests see 1 CPU device while
dryrun.py sees 512 host-platform placeholders).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small host-device mesh for tests (requires XLA_FLAGS host device
    count >= data*model*max(pod,1), set by the *test process*, not here)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def n_chips(mesh) -> int:
    return int(mesh.devices.size)
