"""VB serving launcher: a fleet of sensor-network sessions through
`serving.vb_service.VBService`.

    PYTHONPATH=src python -m repro.launch.vb_serve \
        --sessions 2 --budgets 30,60 --nodes 8 --per-node 20 --slice 16

Each session is an independent synthetic sensor network (the paper's
Sec. V-A generator with a different seed); `--budgets` gives the
per-session iteration budgets (cycled when shorter than `--sessions` —
heterogeneous budgets exercise the per-session gating), `--tol` enables
early stop, `--topology mixed` alternates diffusion and adaptive ADMM
fleets, `--push-at` demonstrates mid-flight data arrival, and
`--ckpt-dir` saves + restores + re-runs session 0 to demonstrate the
checkpoint path (asserting bit-exactness with the uninterrupted run).

Continuous batching (serving/driver.py): `--max-fleet` fixes the fleet
capacity — later arrivals queue until an eviction frees a slot, with
zero recompilation — and `--arrive-at` staggers session admission to
the given slice boundaries (cycled), demonstrating mid-flight join.

Bucketed admission (docs/bucketed-admission.md): `--per-node` and
`--taus` take comma-separated lists (cycled over sessions), so a MIXED
fleet — several data shapes, several Robbins-Monro taus — still lands
in one compiled fleet group per capacity rung; `--bucket` selects the
ladder ("pow2", a growth factor like 1.25, or "none" for legacy
exact-shape grouping).  The run ends by printing the `DriverStats`
counters plus the per-bucket occupancy/padding breakdown.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=2)
    ap.add_argument("--budgets", default="30,60",
                    help="comma-separated per-session iteration budgets")
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--per-node", default="20",
                    help="comma-separated per-node sample counts (cycled; "
                         "mixed values exercise bucketed admission)")
    ap.add_argument("--taus", default="",
                    help="comma-separated schedule taus (cycled over the "
                         "sessions whose topology has a natural-gradient "
                         "step; empty = the default tau)")
    ap.add_argument("--bucket", default="pow2",
                    help='admission ladder: "pow2", a growth factor '
                         '(e.g. 1.25), or "none"')
    ap.add_argument("--slice", type=int, default=16)
    ap.add_argument("--tol", type=float, default=0.0)
    ap.add_argument("--topology", default="mixed",
                    choices=["diffusion", "admm", "ring", "mixed"])
    ap.add_argument("--minibatch", type=int, default=0,
                    help="streaming minibatch size (0 = full batch)")
    ap.add_argument("--push-at", type=int, default=0,
                    help="after this many slices, append 1 fresh point "
                         "to node 0 of session 0 (0 = off)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="save/restore session 0 through this directory "
                         "and assert the resumed run is bit-exact")
    ap.add_argument("--max-fleet", type=int, default=0,
                    help="fixed fleet capacity (continuous batching; "
                         "0 = power-of-two auto-growth)")
    ap.add_argument("--arrive-at", default="",
                    help="comma-separated slice boundaries at which each "
                         "session joins (cycled; empty = all at once)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="enable telemetry and dump a Chrome trace "
                         "(chrome://tracing / Perfetto) of the run — "
                         "driver slices, compiles, checkpoint writes, "
                         "admission/eviction markers — at drain")
    ap.add_argument("--metrics", default=None, metavar="OUT.prom",
                    help="enable telemetry and dump the metrics "
                         "snapshot (Prometheus text format) at drain")
    args = ap.parse_args()

    import numpy as np

    from repro import telemetry

    if args.trace or args.metrics:
        telemetry.enable()

    from repro.core import engine, expfam, network
    from repro.core import model as model_lib
    from repro.data import stream, synthetic
    from repro.serving.vb_service import VBRequest, VBService

    expfam.enable_x64()
    K, D = 3, 2
    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
    adj, _ = network.random_geometric_graph(args.nodes, seed=0)
    W = network.nearest_neighbor_weights(adj)
    mdl = model_lib.GMMModel(prior, K, D)
    topos = {"diffusion": engine.Diffusion(W),
             "admm": engine.ADMMConsensus(adj, adaptive_rho=True),
             "ring": engine.RingDiffusion()}
    order = (["diffusion", "admm"] if args.topology == "mixed"
             else [args.topology])
    budgets = [int(b) for b in args.budgets.split(",")]
    minibatch = (stream.MinibatchSpec(args.minibatch)
                 if args.minibatch else None)

    arrivals = ([int(a) for a in args.arrive_at.split(",")]
                if args.arrive_at else [0])
    per_node = [int(p) for p in args.per_node.split(",")]
    taus = [float(t) for t in args.taus.split(",")] if args.taus else []
    bucket = (None if args.bucket == "none"
              else "pow2" if args.bucket == "pow2" else float(args.bucket))

    svc = VBService(slice_iters=args.slice,
                    max_fleet=args.max_fleet or None, bucket=bucket)
    requests = {}
    for i in range(args.sessions):
        data = synthetic.paper_synthetic(
            n_nodes=args.nodes, n_per_node=per_node[i % len(per_node)],
            seed=i)
        # leave one free slot per node so --push-at has capacity
        mask = data.mask.at[:, -1].set(0.0)
        topo = topos[order[i % len(order)]]
        sched = engine.Schedule()
        if taus and getattr(topo, "uses_schedule", True):
            sched = engine.Schedule(tau=taus[i % len(taus)])
        req = VBRequest(model=mdl, data=(data.x, mask),
                        topology=topo, schedule=sched,
                        n_iters=budgets[i % len(budgets)],
                        minibatch=minibatch, tol=args.tol)
        rid = svc.submit(req, arrive_at=arrivals[i % len(arrivals)])
        requests[rid] = req

    pushed = False
    n_slices = 0
    while True:
        left = svc.step_slice()
        n_slices += 1
        if args.push_at and n_slices == args.push_at and not pushed:
            rid0 = svc.sessions[0]
            rng = np.random.default_rng(123)
            svc.push_data(rid0, node=0, points=rng.normal(size=(1, D)))
            pushed = True
            print(f"[slice {n_slices}] pushed 1 fresh point to "
                  f"{rid0} node 0")
        if left == 0:
            break

    print(f"{'session':9s} {'topology':22s} {'iters':>6s} {'budget':>7s} "
          f"{'conv':>5s} {'final delta':>12s}")
    for rid in svc.sessions:
        st = svc.status(rid)
        topo = type(requests[rid].topology).__name__
        print(f"{rid:9s} {topo:22s} {st.t:6d} {st.budget:7d} "
              f"{str(st.converged):>5s} {st.delta:12.3e}")

    if args.ckpt_dir:
        rid0 = svc.sessions[0]
        os.makedirs(args.ckpt_dir, exist_ok=True)
        path = os.path.join(args.ckpt_dir, f"{rid0}.npz")
        svc.save_session(rid0, path)
        # resume into a FRESH service and extend the budget a little
        svc2 = VBService(slice_iters=args.slice)
        rid_r = svc2.submit(requests[rid0], restore_from=path)
        st0, st_r = svc.status(rid0), svc2.status(rid_r)
        assert st_r.t == st0.t, (st_r.t, st0.t)
        assert float(np.max(np.abs(np.asarray(st0.phi)
                                   - np.asarray(st_r.phi)))) == 0.0
        svc2.extend_budget(rid_r, args.slice)
        svc2.run()
        print(f"checkpoint: saved {rid0} at t={st0.t} -> {path}, "
              f"restored bit-exact, extended to "
              f"t={svc2.status(rid_r).t}")

    st = svc.stats()
    print(f"driver: {st.slices} slices, {st.compiles} compiles, "
          f"{st.admitted} admitted, {st.evicted} evicted, "
          f"occupancy {st.occupancy:.2f} "
          f"(padding waste {st.padding_waste:.2f}), "
          f"{st.checkpoints} background checkpoints")
    for b in st.buckets:
        print(f"  bucket {b.label}: {b.admitted} admitted over "
              f"{b.slots} slot(s), occupancy {b.occupancy:.2f}, "
              f"data padding {b.data_pad_frac:.2f}")
    print(f"served {args.sessions} session(s) in {n_slices} slice(s)")

    if args.trace:
        telemetry.export_chrome_trace(args.trace)
        names = ", ".join(telemetry.tracer().span_names())
        print(f"telemetry: wrote {len(telemetry.tracer())} trace events "
              f"to {args.trace} ({names})")
    if args.metrics:
        with open(args.metrics, "w") as f:
            f.write(telemetry.to_prometheus())
        print(f"telemetry: wrote {len(telemetry.registry())} metric "
              f"series to {args.metrics}")


if __name__ == "__main__":
    main()
