"""Blocked online-softmax (flash) attention — Pallas TPU kernel.

Target: TPU vXe MXU.  Q/K/V blocks are tiled into VMEM with hardware-aligned
(128-multiple) block shapes; the softmax running max/denominator and the
output accumulator live in VMEM scratch and persist across the sequential
kv-block grid axis.  Causal and sliding-window masking is applied per block
pair; fully-masked block pairs short-circuit (pl.when) so the sliding-window
variant does O(S * W) work, which is what makes `long_500k` tractable for
the full-attention architectures.

Layout: inputs are (BH, S, hd) — batch and heads pre-fused by ops.py (GQA kv
heads are broadcast to q heads there).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, block_q: int,
            block_k: int, seq_len: int):
    qi = pl.program_id(1)          # query-block index
    kj = pl.program_id(2)          # kv-block index (sequential, innermost)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = kj * block_k
    # block-level reachability: skip blocks that are entirely masked
    reachable = True
    if causal:
        reachable = k_start <= q_start + block_q - 1
    if window > 0:
        reachable = jnp.logical_and(
            reachable, k_start + block_k - 1 > q_start - window)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                    # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                    # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        ok = cols < seq_len
        if causal:
            ok = jnp.logical_and(ok, cols <= rows)
        if window > 0:
            ok = jnp.logical_and(ok, cols > rows - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]                                 # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(kj == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q/k/v (BH, S, hd) -> (BH, S, hd)."""
    BH, S, hd = q.shape
    scale = float(scale if scale is not None else 1.0 / (hd ** 0.5))
    bq = min(block_q, S)
    bk = min(block_k, S)
    Sp = ((S + bq - 1) // bq) * bq
    Skp = ((S + bk - 1) // bk) * bk
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0)))
    if Skp != S:
        k = jnp.pad(k, ((0, 0), (0, Skp - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skp - S), (0, 0)))
    grid = (BH, Sp // bq, Skp // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          block_q=bq, block_k=bk, seq_len=S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S, :]
