"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

Per (batch, head) the sequence is processed in chunks of length L along a
sequential grid axis; the (P, N) SSM state lives in VMEM scratch and is
carried across chunk iterations.  Inside a chunk everything is
attention-shaped MXU work:

    y_intra = ((C B^T) .* decay-gates .* dt) @ x          (L,L)@(L,P)
    y_inter = (C .* exp(cum)) @ state                     (L,N)@(N,P)
    state'  = exp(cum_L) * state + (B .* dt .* decay)^T @ x

matching mamba2.ssd_chunked / ref.ssd exactly (up to fp accumulation).
Layouts chosen 2-D-friendly for the VPU: dt enters as (..., L, 1) blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, hout_ref, state_ref,
            *, chunk: int):
    cj = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(cj == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    L = chunk
    a = a_ref[0, 0, 0]                                  # scalar decay rate A_h
    x = x_ref[0, 0, 0].astype(jnp.float32)              # (L, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)            # (L, 1)
    Bm = b_ref[0, 0].astype(jnp.float32)                # (L, N)
    Cm = c_ref[0, 0].astype(jnp.float32)                # (L, N)

    dA = dt * a                                         # (L, 1) log-decays
    cum = jnp.cumsum(dA, axis=0)                        # (L, 1) inclusive

    # intra-chunk quadratic part
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    seg = cum - cum.reshape(1, L)                       # cum_l - cum_l'
    rows = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    gates = jnp.where(cols <= rows, jnp.exp(seg), 0.0)
    M = cb * gates * dt.reshape(1, L)                   # weight by dt_{l'}
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    state = state_ref[...]                              # (N, P)
    y += jax.lax.dot_general(Cm * jnp.exp(cum), state,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    # state update
    decay_to_end = jnp.exp(cum[L - 1:L] - cum)          # (L, 1)
    wB = Bm * (dt * decay_to_end)                       # (L, N)
    s_new = jax.lax.dot_general(wB, x, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (N, P)
    state_ref[...] = jnp.exp(cum[L - 1, 0]) * state + s_new

    @pl.when(cj == nc - 1)
    def _emit_state():
        hout_ref[0, 0] = state_ref[...]


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = True):
    """x (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,N).

    Returns (y (B,S,H,P), final_state (B,H,P,N)) — final_state layout matches
    mamba2.ssd_chunked (transposed from the kernel-internal (N,P)).
    """
    Bb, S, H, P = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L
    # (B, H, nc, L, ...) layouts
    xr = jnp.moveaxis(x, 2, 1).reshape(Bb, H, nc, L, P)
    dtr = jnp.moveaxis(dt, 2, 1).reshape(Bb, H, nc, L, 1)
    Br = Bm.reshape(Bb, nc, L, N)
    Cr = Cm.reshape(Bb, nc, L, N)
    Ar = A.reshape(H, 1, 1).astype(jnp.float32)

    grid = (Bb, H, nc)
    y, hout = pl.pallas_call(
        functools.partial(_kernel, chunk=L),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1), lambda b, h, c: (h, 0, 0)),        # A
            pl.BlockSpec((1, 1, 1, L, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, L, 1), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, h, c: (b, c, 0, 0)),  # B
            pl.BlockSpec((1, 1, L, N), lambda b, h, c: (b, c, 0, 0)),  # C
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, L, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, H, nc, L, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(Ar, xr, dtr, Br, Cr)
    y = jnp.moveaxis(y.reshape(Bb, H, S, P), 1, 2)
    return y, jnp.swapaxes(hout, -1, -2)                 # (B,H,P,N)
