# The engine's compute layer (plus TPU kernels for the LM stack).
#
# gmm_estep.py is the production hot path of the VB engine: the fused
# single-pass VBE kernel (responsibilities + sufficient statistics), both
# single-node and node-batched (`gmm_estep_nodes`), selected via
# core/backends.py (`GMMModel(..., backend="fused")` / run_vb(backend=)).
# core/gmm.py keeps the naive reference implementation it is parity-tested
# against (tests/test_backends.py, tests/test_kernels.py).
#
# Kernels present (validated interpret=True vs ref.py; TPU-targeted):
#   gmm_estep.py       — fused GMM VBE responsibilities + sufficient stats
#   flash_attention.py — blocked online-softmax attention (causal/sliding)
#   ssd_scan.py        — Mamba-2 SSD chunked scan with VMEM-carried state
