# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# Kernels present (validated interpret=True vs ref.py; TPU-targeted):
#   gmm_estep.py       — fused GMM VBE responsibilities + sufficient stats
#   flash_attention.py — blocked online-softmax attention (causal/sliding)
#   ssd_scan.py        — Mamba-2 SSD chunked scan with VMEM-carried state
