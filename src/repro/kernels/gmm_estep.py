"""Fused GMM VBE step (responsibilities + sufficient statistics) — Pallas TPU.

The per-node VBE hot loop of the paper's application (Sec. IV / Appendix A)
is O(T * K * D^2): for every data point, a Mahalanobis quadratic form per
component, a row-softmax, then three accumulations (R_k, sum r x, sum r xx^T).
Done naively this makes three passes over the data in HBM.  The kernel fuses
everything into one pass: data blocks of `block_t` points stream through
VMEM, quadratic forms are (T_b, D) @ (D, D) MXU matmuls per component, and
the statistics accumulate in VMEM scratch across the sequential grid,
written out once at the end.

Inputs are the same precomputed per-component terms the oracle uses:
  log_prior (K,)  Wn (K,D,D)=nu W   b (K,D)=nu W m   c (K,)=D/beta + nu mWm

`gmm_estep_nodes` is the engine hot path: a whole sensor network at once,
x (N, T, D) with a (node, data-block) grid.  Each node has its own
per-component terms (its own current posterior), the data-block axis is the
minor (sequential) grid dimension so the VMEM accumulator carries per-node
partial statistics and is emitted once per node.  `gmm_estep` is the
single-node view (x (T, D)), a thin wrapper over the same kernel.

The engine only consumes the statistics; `return_r=False` drops the
responsibilities output entirely (no (N, T, K) write-back to HBM per
iteration — a multi-output pallas_call is opaque to XLA, so a dead output
would otherwise still be materialised).

Data may stream in a narrow dtype (bf16); quadratic forms and statistic
accumulation always run in f32 (`preferred_element_type`) — the engine's
precision-policy contract (see core/backends.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel_nodes(x_ref, mask_ref, lp_ref, wn_ref, b_ref, c_ref, rep_ref,
                  *out_refs, K: int, D: int, return_r: bool):
    """One (node, data-block) grid cell.  Every ref carries a leading
    node-block axis of 1; the accumulator is reset at the start of each
    node's (sequential, minor) data-block sweep and emitted — scaled by
    the replication factor (Appendix A) — at its end.
    out_refs = (r_ref, stats_ref, acc_ref) or (stats_ref, acc_ref).

    The per-component work runs as ROLLED `fori_loop`s over K (dynamic ref
    slices feed each (Tb, D) @ (D, D) MXU matmul): the trace/compile cost
    is O(1) in K, where the original unrolled per-component matmuls made
    compile time blow up past K ~ 16 (ROADMAP item; regression-tested by
    jaxpr size in tests/test_kernels.py)."""
    if return_r:
        r_ref, stats_ref, acc_ref = out_refs
    else:
        stats_ref, acc_ref = out_refs
    ti = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(ti == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)                     # (Tb, D)
    mask = mask_ref[0].astype(jnp.float32)               # (Tb, 1)
    lp = lp_ref[...].reshape(1, K).astype(jnp.float32)
    bmat = b_ref[0].astype(jnp.float32)                  # (K, D)
    cvec = c_ref[...].reshape(1, K).astype(jnp.float32)
    Tb = x.shape[0]

    # quadratic forms: one MXU matmul per component, rolled over K
    def quad_body(k, quad):
        Wk = wn_ref[0, pl.ds(k, 1)][0].astype(jnp.float32)   # (D, D)
        xW = jax.lax.dot_general(x, Wk, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        qk = jnp.sum(xW * x, axis=1, keepdims=True)          # (Tb, 1)
        return jax.lax.dynamic_update_slice_in_dim(quad, qk, k, axis=1)

    quad = jax.lax.fori_loop(0, K, quad_body,
                             jnp.zeros((Tb, K), jnp.float32))
    cross = jax.lax.dot_general(x, bmat, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    log_rho = lp - 0.5 * (quad - 2.0 * cross + cvec)

    m = jnp.max(log_rho, axis=1, keepdims=True)
    p = jnp.exp(log_rho - m)
    r = p / jnp.sum(p, axis=1, keepdims=True) * mask     # (Tb, K)
    if return_r:
        r_ref[0] = r.astype(r_ref.dtype)

    # accumulate sufficient statistics in VMEM scratch
    # acc layout: rows [0:K] = sum_x (K, D); row-blocks K + k*D : K+(k+1)*D
    # hold sum_xx_k (D, D); final row block holds R (K,) broadcast in col 0.
    sum_x = jax.lax.dot_general(r, x, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (K, D)
    acc_ref[0:K, :] += sum_x

    def xx_body(k, xx_all):
        rk = jax.lax.dynamic_slice_in_dim(r, k, 1, axis=1)   # (Tb, 1)
        xx = jax.lax.dot_general(x * rk, x, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        return jax.lax.dynamic_update_slice_in_dim(xx_all, xx, k * D, 0)

    xx_all = jax.lax.fori_loop(0, K, xx_body,
                               jnp.zeros((K * D, D), jnp.float32))
    acc_ref[K:K + K * D, :] += xx_all
    Rk = jnp.sum(r, axis=0)                              # (K,)
    acc_ref[K + K * D:K + K * D + K, 0:1] += Rk[:, None]

    @pl.when(ti == nt - 1)
    def _emit():
        # replication scaling lives kernel-side: the emitted statistics are
        # already the Appendix-A replicated R / sum_x / sum_xx
        stats_ref[0] = acc_ref[...] * rep_ref[0]


def gmm_estep_nodes(x, mask, log_prior, Wn, b, c, *, block_t: int = 512,
                    interpret: bool = True, return_r: bool = True,
                    replication=1.0):
    """Whole-network fused VBE step: x (N, T, D), mask (N, T), per-node
    per-component terms log_prior (N, K), Wn (N, K, D, D), b (N, K, D),
    c (N, K).  Returns (r (N, T, K), R (N, K), sum_x (N, K, D),
    sum_xx (N, K, D, D)) — `replication`-scaled stats (default 1.0 =
    unreplicated, node i matching ref.gmm_estep(x[i], ...)); the engine
    hot path passes the Appendix-A network-size factor so the scaling
    happens kernel-side at statistics-emit time instead of as a separate
    post-pass.  `replication` may be a traced scalar.  With
    `return_r=False` (the engine hot path, which only needs the
    statistics) r is None and never written to HBM.  Grid is
    (node, data-block) with the data axis minor, so each node's statistics
    accumulate sequentially in one VMEM scratch and are written out
    once."""
    N, T, D = x.shape
    K = log_prior.shape[-1]
    # The block size is a function of `block_t` ONLY — never of T.  Every
    # input is padded up to a multiple of the same block shape, so a
    # mask-zero-padded copy of the data sees bit-identical blocks (the
    # shared prefix) plus all-zero blocks whose statistics accumulate an
    # exact +0.0 through the sequential data-block grid.  That makes the
    # emitted statistics BIT-invariant to trailing padding — the serving
    # layer's bucketed-admission contract (serving/admission.py), mirroring
    # expfam.ordered_sum on the reference path.
    bt = max(8, block_t)
    Tp = ((T + bt - 1) // bt) * bt
    if Tp != T:
        x = jnp.pad(x, ((0, 0), (0, Tp - T), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, Tp - T)))
    rep = jnp.asarray(replication, jnp.float32).reshape(1)
    rows = K + K * D + K
    out_specs = [pl.BlockSpec((1, rows, D), lambda n, t: (n, 0, 0))]
    out_shape = [jax.ShapeDtypeStruct((N, rows, D), jnp.float32)]
    if return_r:
        out_specs.insert(0, pl.BlockSpec((1, bt, K), lambda n, t: (n, t, 0)))
        out_shape.insert(0, jax.ShapeDtypeStruct((N, Tp, K), jnp.float32))
    out = pl.pallas_call(
        functools.partial(_kernel_nodes, K=K, D=D, return_r=return_r),
        grid=(N, Tp // bt),
        in_specs=[
            pl.BlockSpec((1, bt, D), lambda n, t: (n, t, 0)),
            pl.BlockSpec((1, bt, 1), lambda n, t: (n, t, 0)),
            pl.BlockSpec((1, K), lambda n, t: (n, 0)),
            pl.BlockSpec((1, K, D, D), lambda n, t: (n, 0, 0, 0)),
            pl.BlockSpec((1, K, D), lambda n, t: (n, 0, 0)),
            pl.BlockSpec((1, K), lambda n, t: (n, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((rows, D), jnp.float32)],
        interpret=interpret,
    )(x, mask[..., None], log_prior, Wn, b, c, rep)
    stats = out[-1]
    r = out[0][:, :T] if return_r else None
    sum_x = stats[:, 0:K, :]
    sum_xx = stats[:, K:K + K * D, :].reshape(N, K, D, D)
    R = stats[:, K + K * D:K + K * D + K, 0]
    return r, R, sum_x, sum_xx


def gmm_estep(x, mask, log_prior, Wn, b, c, *, block_t: int = 512,
              interpret: bool = True):
    """x (T, D), mask (T,).  Returns (r (T,K), R (K,), sum_x (K,D),
    sum_xx (K,D,D)) — unreplicated stats, matching ref.gmm_estep.  The
    single-node view of `gmm_estep_nodes` (one shared kernel body)."""
    r, R, sum_x, sum_xx = gmm_estep_nodes(
        x[None], mask[None], log_prior[None], Wn[None], b[None], c[None],
        block_t=block_t, interpret=interpret)
    return r[0], R[0], sum_x[0], sum_xx[0]
