"""Fused GMM VBE step (responsibilities + sufficient statistics) — Pallas TPU.

The per-node VBE hot loop of the paper's application (Sec. IV / Appendix A)
is O(T * K * D^2): for every data point, a Mahalanobis quadratic form per
component, a row-softmax, then three accumulations (R_k, sum r x, sum r xx^T).
Done naively this makes three passes over the data in HBM.  The kernel fuses
everything into one pass: data blocks of `block_t` points stream through
VMEM, quadratic forms are (T_b, D) @ (D, D) MXU matmuls per component, and
the statistics accumulate in VMEM scratch across the sequential grid,
written out once at the end.

Inputs are the same precomputed per-component terms the oracle uses:
  log_prior (K,)  Wn (K,D,D)=nu W   b (K,D)=nu W m   c (K,)=D/beta + nu mWm
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, mask_ref, lp_ref, wn_ref, b_ref, c_ref,
            r_ref, stats_ref, acc_ref, *, K: int, D: int):
    ti = pl.program_id(0)
    nt = pl.num_programs(0)

    @pl.when(ti == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)                   # (Tb, D)
    mask = mask_ref[...].astype(jnp.float32)             # (Tb, 1)
    lp = lp_ref[...].astype(jnp.float32)                 # (1, K)
    bmat = b_ref[...].astype(jnp.float32)                # (K, D)
    cvec = c_ref[...].astype(jnp.float32)                # (1, K)

    # quadratic forms, one MXU matmul per component (K is small, static)
    quads = []
    for k in range(K):
        Wk = wn_ref[k].astype(jnp.float32)               # (D, D)
        xW = jax.lax.dot_general(x, Wk, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        quads.append(jnp.sum(xW * x, axis=1, keepdims=True))
    quad = jnp.concatenate(quads, axis=1)                # (Tb, K)
    cross = jax.lax.dot_general(x, bmat, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    log_rho = lp - 0.5 * (quad - 2.0 * cross + cvec)

    m = jnp.max(log_rho, axis=1, keepdims=True)
    p = jnp.exp(log_rho - m)
    r = p / jnp.sum(p, axis=1, keepdims=True) * mask     # (Tb, K)
    r_ref[...] = r.astype(r_ref.dtype)

    # accumulate sufficient statistics in VMEM scratch
    # acc layout: rows [0:K] = sum_x (K, D); row-blocks K + k*D : K+(k+1)*D
    # hold sum_xx_k (D, D); final row block holds R (K,) broadcast in col 0.
    sum_x = jax.lax.dot_general(r, x, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (K, D)
    acc_ref[0:K, :] += sum_x
    for k in range(K):
        rx = x * r[:, k:k + 1]
        xx = jax.lax.dot_general(rx, x, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[K + k * D:K + (k + 1) * D, :] += xx
    Rk = jnp.sum(r, axis=0)                              # (K,)
    acc_ref[K + K * D:K + K * D + K, 0:1] += Rk[:, None]

    @pl.when(ti == nt - 1)
    def _emit():
        stats_ref[...] = acc_ref[...]


def gmm_estep(x, mask, log_prior, Wn, b, c, *, block_t: int = 512,
              interpret: bool = True):
    """x (T, D), mask (T,).  Returns (r (T,K), R (K,), sum_x (K,D),
    sum_xx (K,D,D)) — unreplicated stats, matching ref.gmm_estep."""
    T, D = x.shape
    K = log_prior.shape[0]
    bt = min(block_t, max(8, T))
    Tp = ((T + bt - 1) // bt) * bt
    if Tp != T:
        x = jnp.pad(x, ((0, Tp - T), (0, 0)))
        mask = jnp.pad(mask, ((0, Tp - T),))
    rows = K + K * D + K
    r, stats = pl.pallas_call(
        functools.partial(_kernel, K=K, D=D),
        grid=(Tp // bt,),
        in_specs=[
            pl.BlockSpec((bt, D), lambda t: (t, 0)),
            pl.BlockSpec((bt, 1), lambda t: (t, 0)),
            pl.BlockSpec((1, K), lambda t: (0, 0)),
            pl.BlockSpec((K, D, D), lambda t: (0, 0, 0)),
            pl.BlockSpec((K, D), lambda t: (0, 0)),
            pl.BlockSpec((1, K), lambda t: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, K), lambda t: (t, 0)),
            pl.BlockSpec((rows, D), lambda t: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Tp, K), jnp.float32),
            jax.ShapeDtypeStruct((rows, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((rows, D), jnp.float32)],
        interpret=interpret,
    )(x, mask[:, None], log_prior[None, :], Wn, b, c[None, :])
    r = r[:T]
    sum_x = stats[0:K, :]
    sum_xx = stats[K:K + K * D, :].reshape(K, D, D)
    R = stats[K + K * D:K + K * D + K, 0]
    return r, R, sum_x, sum_xx
