"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

Deliberately *naive* implementations — independent of the blocked/fused
algorithms in the kernels — so tests/test_kernels.py exercises real
re-derivations, not shared code paths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# flash_attention oracle: materialised-logits causal/sliding attention
# ---------------------------------------------------------------------------
def attention(q, k, v, *, causal: bool = True, window: int = 0,
              scale: float | None = None):
    """q (B,H,S,hd), k/v (B,H,S,hd) (kv already broadcast to q heads)."""
    S = q.shape[-2]
    hd = q.shape[-1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(hd).astype(
        jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= j <= i
    if window > 0:
        ok &= j > i - window
    logits = jnp.where(ok[None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32)).astype(
        q.dtype)


# ---------------------------------------------------------------------------
# ssd_scan oracle: step-by-step recurrence (no chunking at all)
# ---------------------------------------------------------------------------
def ssd(x, dt, A, Bm, Cm):
    """x (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,N).

    h_t = exp(dt_t A) h_{t-1} + dt_t * (B_t outer x_t);  y_t = C_t . h_t
    Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    Bb, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp              # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt * A[None, :])  # (B,H)
        upd = dtt[..., None, None] * bt[:, None, None, :] * xt[..., None]
        h = decay[..., None, None] * h + upd
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((Bb, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt, 1, 0), jnp.moveaxis(Bm, 1, 0),
          jnp.moveaxis(Cm, 1, 0))
    h_fin, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_fin


# ---------------------------------------------------------------------------
# gmm_estep oracle: responsibilities + replicated sufficient statistics
# ---------------------------------------------------------------------------
def gmm_estep(x, mask, log_prior, Wn, b, c):
    """x (T,D), mask (T,), per-component precomputed terms:
    log_prior (K,) = E[ln pi] + 0.5 E[ln|L|] - D/2 ln 2pi
    Wn (K,D,D) = nu_k W_k ; b (K,D) = nu_k W_k m_k ;
    c (K,) = D/beta_k + nu_k m_k^T W_k m_k.
    Returns (r (T,K), R (K,), sum_x (K,D), sum_xx (K,D,D))  [no N factor]."""
    quad = jnp.einsum("td,kde,te->tk", x, Wn, x)
    cross = x @ b.T                                        # (T,K)
    e_quad = quad - 2.0 * cross + c[None, :]
    log_rho = log_prior[None, :] - 0.5 * e_quad
    r = jax.nn.softmax(log_rho, axis=-1) * mask[:, None]
    R = jnp.sum(r, axis=0)
    sum_x = r.T @ x
    sum_xx = jnp.einsum("tk,td,te->kde", r, x, x)
    return r, R, sum_x, sum_xx


def gmm_estep_nodes(x, mask, log_prior, Wn, b, c):
    """Node-batched oracle: leading N axis on every argument, node i
    matching gmm_estep(x[i], mask[i], ...)."""
    return jax.vmap(gmm_estep)(x, mask, log_prior, Wn, b, c)
