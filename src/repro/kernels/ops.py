"""jit'd public wrappers around the Pallas kernels.

`interpret` defaults to True off-TPU (this container is CPU-only: kernels
are *targeted* at TPU but *validated* by executing the kernel body in
python via pallas interpret mode).  On a real TPU backend the same calls
compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import gmm_estep as _ge
from repro.kernels import ssd_scan as _ss


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    """q (B,S,Hq,hd), k/v (B,S,Hkv,hd) GQA -> out (B,S,Hq,hd)."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    # fuse batch+heads; broadcast kv heads to q heads
    qf = jnp.moveaxis(q, 2, 1).reshape(B * Hq, S, hd)
    kf = jnp.moveaxis(jnp.repeat(k, g, axis=2), 2, 1).reshape(B * Hq, S, hd)
    vf = jnp.moveaxis(jnp.repeat(v, g, axis=2), 2, 1).reshape(B * Hq, S, hd)
    out = _fa.flash_attention(qf, kf, vf, causal=causal, window=window,
                              block_q=block_q, block_k=block_k,
                              interpret=_default_interpret())
    return jnp.moveaxis(out.reshape(B, Hq, S, hd), 1, 2)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128):
    """Mamba-2 SSD: x (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,N)."""
    return _ss.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk,
                        interpret=_default_interpret())


@functools.partial(jax.jit, static_argnames=("block_t",))
def gmm_estep(x, mask, log_prior, Wn, b, c, *, block_t: int = 512):
    return _ge.gmm_estep(x, mask, log_prior, Wn, b, c, block_t=block_t,
                         interpret=_default_interpret())


def gmm_estep_from_posterior(x, mask, q, *, block_t: int = 512):
    """Convenience: compute the kernel's precomputed terms from a
    GMMPosterior, then run the fused kernel.  Matches
    gmm.responsibilities + gmm.sufficient_stats (replication=1)."""
    from repro.core import expfam
    D = x.shape[-1]
    e_logpi = expfam.dirichlet_expected_log(q.alpha)
    e_logdet = expfam.wishart_expected_logdet(q.W, q.nu)
    log_prior = (e_logpi + 0.5 * e_logdet
                 - 0.5 * D * jnp.log(2.0 * jnp.pi)).astype(jnp.float32)
    Wn = (q.nu[:, None, None] * q.W).astype(jnp.float32)
    b = jnp.einsum("kde,ke->kd", Wn, q.m).astype(jnp.float32)
    c = (D / q.beta + jnp.einsum("kd,kd->k", q.m, b)).astype(jnp.float32)
    return gmm_estep(x.astype(jnp.float32), mask.astype(jnp.float32),
                     log_prior, Wn, b, c, block_t=block_t)
