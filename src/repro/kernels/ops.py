"""jit'd public wrappers around the Pallas kernels.

`interpret` defaults to True off-TPU (this container is CPU-only: kernels
are *targeted* at TPU but *validated* by executing the kernel body in
python via pallas interpret mode).  On a real TPU backend the same calls
compile to Mosaic.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.kernels import flash_attention as _fa
from repro.kernels import gmm_estep as _ge
from repro.kernels import ssd_scan as _ss


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _instrument(name: str):
    """Kernel wall-time telemetry: a `kernel_wall_seconds{kernel=...}`
    histogram plus a `kernel/<name>` trace span per eager call.  One bool
    check when telemetry is disabled.  Calls from inside an outer trace
    (e.g. `core.backends._fused_local_vbm` jits around `gmm_estep_nodes`)
    pass straight through — timing a trace is meaningless and
    `block_until_ready` does not apply to tracers."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not telemetry.enabled() or any(
                    isinstance(leaf, jax.core.Tracer) for leaf in
                    jax.tree_util.tree_leaves((args, kwargs))):
                return fn(*args, **kwargs)
            t0 = time.perf_counter()
            with telemetry.span(f"kernel/{name}"):
                out = fn(*args, **kwargs)
                jax.block_until_ready(out)
            telemetry.observe("kernel_wall_seconds",
                              time.perf_counter() - t0, kernel=name)
            return out
        return wrapper
    return deco


@_instrument("flash_attention")
@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    """q (B,S,Hq,hd), k/v (B,S,Hkv,hd) GQA -> out (B,S,Hq,hd)."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    # fuse batch+heads; broadcast kv heads to q heads
    qf = jnp.moveaxis(q, 2, 1).reshape(B * Hq, S, hd)
    kf = jnp.moveaxis(jnp.repeat(k, g, axis=2), 2, 1).reshape(B * Hq, S, hd)
    vf = jnp.moveaxis(jnp.repeat(v, g, axis=2), 2, 1).reshape(B * Hq, S, hd)
    out = _fa.flash_attention(qf, kf, vf, causal=causal, window=window,
                              block_q=block_q, block_k=block_k,
                              interpret=_default_interpret())
    return jnp.moveaxis(out.reshape(B, Hq, S, hd), 1, 2)


@_instrument("ssd_scan")
@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128):
    """Mamba-2 SSD: x (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,N)."""
    return _ss.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk,
                        interpret=_default_interpret())


@_instrument("gmm_estep")
@functools.partial(jax.jit, static_argnames=("block_t",))
def gmm_estep(x, mask, log_prior, Wn, b, c, *, block_t: int = 512):
    return _ge.gmm_estep(x, mask, log_prior, Wn, b, c, block_t=block_t,
                         interpret=_default_interpret())


@_instrument("gmm_estep_nodes")
@functools.partial(jax.jit, static_argnames=("block_t", "return_r"))
def gmm_estep_nodes(x, mask, log_prior, Wn, b, c, replication=1.0, *,
                    block_t: int = 512, return_r: bool = True):
    """Node-batched fused VBE step: x (N, T, D) and per-node terms; see
    gmm_estep.gmm_estep_nodes.  The engine hot path (core/backends.py)
    passes return_r=False — only the statistics leave the kernel — and the
    Appendix-A `replication` factor, applied to the statistics
    kernel-side at emit time (traced, not static)."""
    return _ge.gmm_estep_nodes(x, mask, log_prior, Wn, b, c, block_t=block_t,
                               interpret=_default_interpret(),
                               return_r=return_r, replication=replication)


@_instrument("gmm_estep_from_posterior")
@functools.partial(jax.jit, static_argnames=("block_t", "compute_dtype"))
def gmm_estep_from_posterior(x, mask, q, *, block_t: int = 512,
                             compute_dtype=None):
    """Convenience: compute the kernel's precomputed terms from a
    GMMPosterior, then run the fused kernel.  Matches
    gmm.responsibilities + gmm.sufficient_stats (replication=1).

    The per-component precompute runs INSIDE this jit in `compute_dtype`
    (default: the posterior's own dtype — the caller's precision policy
    decides; nothing is hard-cast).  `x`/`mask` stream into the kernel at
    whatever dtype they arrive in; the kernel accumulates in f32.
    """
    from repro.core import gmm
    log_prior, Wn, b, c = gmm.estep_terms(q, dtype=compute_dtype)
    return _ge.gmm_estep(x, mask, log_prior, Wn, b, c, block_t=block_t,
                         interpret=_default_interpret())
