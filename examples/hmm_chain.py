"""Distributed VB for hidden Markov chains over a sensor network.

Each sensor records a handful of Gaussian-emission HMM chains; the
network runs diffusion dSVB and dVB-ADMM through the generic engine and
recovers the shared transition matrix and emission means — the
`models/hmm.py` adapter is a three-block `blocks.BlockModel` composition
(Dirichlet initial-state + Dirichlet transition rows + the GMM
Normal-Wishart emission bank), so NO engine code knows it exists
(docs/model-zoo.md).

    PYTHONPATH=src python examples/hmm_chain.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, expfam, network
from repro.models import hmm

expfam.enable_x64()

K, D, N_NODES = 3, 2, 6

x, mask, pi_true, A_true, means_true = hmm.sample_chains(
    N_NODES, n_chains=20, length=20, K=K, D=D, seed=0)
prior = hmm.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
mdl = hmm.HMMModel(prior)
init_q = hmm.perturbed_init(prior, jnp.asarray(x), jax.random.PRNGKey(7))
phi0 = jnp.broadcast_to(mdl.pack(init_q), (N_NODES, mdl.flat_dim))

adj, _ = network.random_geometric_graph(N_NODES, seed=3)
W = network.metropolis_weights(adj)
data = (jnp.asarray(x), jnp.asarray(mask))


def transition_error(phi):
    """max |A_est - A_true| after matching labels by emission mean."""
    q = mdl.unpack(phi[0])
    est = np.asarray(q.m)
    perm = [int(np.argmin(np.sum((est - mu) ** 2, -1)))
            for mu in means_true]
    if sorted(perm) != list(range(K)):
        return float("inf")                       # label collapse
    A = np.asarray(q.trans / jnp.sum(q.trans, -1, keepdims=True))
    return float(np.max(np.abs(A[np.ix_(perm, perm)] - A_true)))


print(f"{N_NODES} sensors x {x.shape[1]} chains x {x.shape[2]} steps, "
      f"K={K} states, D={D} emissions")
for name, topo in [("dSVB (diffusion)", engine.Diffusion(W)),
                   ("dVB-ADMM", engine.ADMMConsensus(adj))]:
    out = engine.run_vb(mdl, data, topo, n_iters=80, init_phi=phi0)
    err = transition_error(out.phi)
    print(f"{name:18s} max|A_est - A_true| = {err:.4f}  "
          f"consensus err = {float(out.consensus_err[-1]):.2e}")
    assert err < 0.1, f"{name} failed to recover the transition matrix"

print("OK")
