"""Telemetry tour: metrics, spans, device taps and the bench gate.

One small script that exercises every layer of `repro.telemetry`
(docs/observability.md is the companion reference):

1. **Host telemetry** around a driver run — `telemetry.enable()` turns
   on the metrics registry and span tracer; a 4-session continuous-
   batching fleet then leaves behind scheduler counters (admissions,
   evictions, checkpoint writes), fleet-health gauges (queue depth,
   occupancy, padding waste) and a Chrome trace with `driver/slice`,
   `driver/compile`, `driver/sync` and `driver/checkpoint` spans.
2. **Diag-slot series** — a solo ADMM `vb_run` files its per-iteration
   KL / consensus / rho / residual series into the tap buffer (no jaxpr
   change: the scan emits them anyway).
3. **Device taps** — `taps.enable()` BEFORE tracing inserts
   `io_callback` taps inside the compiled step, streaming the same
   series out mid-flight; the jaxpr difference is shown.
4. **Exports** — the Chrome trace (`chrome://tracing` / Perfetto), the
   Prometheus text dump and the JSON-lines snapshot land in /tmp, and
   the perf gate (`tools/bench_gate.py`) self-checks the committed
   baseline.

    PYTHONPATH=src python examples/telemetry_tour.py
"""
import json
import os
import subprocess
import sys

import numpy as np

from repro import telemetry
from repro.core import engine, expfam, network
from repro.core import model as model_lib
from repro.data import synthetic
from repro.serving.vb_service import VBRequest, VBService
from repro.telemetry import taps

expfam.enable_x64()


def main() -> None:
    telemetry.enable()
    K, D, n_nodes = 3, 2, 8
    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
    mdl = model_lib.GMMModel(prior, K, D)
    adj, _ = network.random_geometric_graph(n_nodes, seed=0)
    W = network.nearest_neighbor_weights(adj)

    # -- 1. a traced continuous-batching fleet ---------------------------
    svc = VBService(slice_iters=8, max_fleet=2,
                    ckpt_dir="/tmp/telemetry-tour-ckpt", ckpt_every=2)
    os.makedirs("/tmp/telemetry-tour-ckpt", exist_ok=True)
    for s in range(4):
        d = synthetic.paper_synthetic(n_nodes=n_nodes, n_per_node=12,
                                      seed=s)
        svc.submit(VBRequest(model=mdl, data=(d.x, d.mask),
                             topology=engine.Diffusion(W),
                             n_iters=24 + 8 * (s % 2)))
    svc.run()
    st = svc.stats()
    print(f"driver: {st.slices} slices, {st.admitted} admitted, "
          f"{st.evicted} evicted, {st.checkpoints} checkpoints "
          f"({st.checkpoint_errors} errors), occupancy "
          f"{st.occupancy:.2f}")

    # -- 2. diag-slot series from a solo ADMM run ------------------------
    d = synthetic.paper_synthetic(n_nodes=n_nodes, n_per_node=12, seed=9)
    engine.run_vb(mdl, (d.x, d.mask),
                  engine.ADMMConsensus(adj, adaptive_rho=True),
                  n_iters=40)
    t_kl, kl = taps.series("vb_run/kl_mean")
    t_rho, rho = taps.series("vb_run/admm_rho")
    print(f"diag-slot series: kl_mean over t={t_kl[0]}..{t_kl[-1]} "
          f"(final {kl[-1]:.2f}), rho final {rho[-1]:.3f}")

    # -- 3. device taps: enabled at trace time, visible in the jaxpr -----
    import jax

    def kl_probe(phi):
        taps.tap("tour/phi_norm", (phi ** 2).sum())
        return phi * 2.0

    def kl_probe_tapped(phi):              # separate fn: fresh trace
        taps.tap("tour/phi_norm", (phi ** 2).sum())
        return phi * 2.0

    off = str(jax.make_jaxpr(kl_probe)(np.ones(3)))
    with taps.enabled_scope():
        on = str(jax.make_jaxpr(kl_probe_tapped)(np.ones(3)))
        jax.jit(kl_probe_tapped)(np.ones(3)).block_until_ready()
    print(f"device taps: io_callback in jaxpr off={'io_callback' in off} "
          f"on={'io_callback' in on}, records="
          f"{taps.counts().get('tour/phi_norm')}")

    # -- 4. exports + the bench gate -------------------------------------
    trace_path = telemetry.export_chrome_trace("/tmp/telemetry_tour.json")
    n_events = len(json.load(open(trace_path))["traceEvents"])
    with open("/tmp/telemetry_tour.prom", "w") as f:
        f.write(telemetry.to_prometheus())
    with open("/tmp/telemetry_tour.jsonl", "w") as f:
        f.write(telemetry.to_jsonl())
    print(f"exports: {n_events} trace events -> {trace_path}, "
          f"{len(telemetry.registry())} series -> "
          "/tmp/telemetry_tour.prom|.jsonl")

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    gate = os.path.join(root, "tools", "bench_gate.py")
    if os.path.exists(os.path.join(root, "BENCH_engine.json")):
        r = subprocess.run([sys.executable, gate, "--quiet"], cwd=root)
        print(f"bench gate self-check exit code: {r.returncode}")
        assert r.returncode == 0

    assert {"driver/slice", "driver/compile",
            "driver/checkpoint"} <= set(telemetry.tracer().span_names())
    print("telemetry tour OK")


if __name__ == "__main__":
    main()
