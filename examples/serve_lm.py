"""Serve a small model with batched requests (prefill + decode engine).

Trains the quick LM for a moment so generation shows the learned Markov
structure, then serves a batch of prompts through the Engine.

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokens import MarkovCorpus
from repro.serving import engine as eng
from repro.training import train_step as ts
from repro.training.trainer import Trainer


def main():
    cfg = ModelConfig(name="lm-serve", arch_type="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
                      vocab_size=512, tie_embeddings=True,
                      param_dtype="float32", compute_dtype="float32")
    mesh = jax.make_mesh((1,), ("data",))
    tr = Trainer(cfg, mesh, global_batch=8, seq_len=128,
                 hyper=ts.TrainHyper(peak_lr=3e-3, warmup=5,
                                     total_steps=40))
    tr.run(40, log_every=10)
    params = jax.tree.map(lambda p: p, tr.state.params)

    e = eng.Engine(cfg, mesh, params, max_seq=96)
    corpus = MarkovCorpus(cfg.vocab_size, seed=tr.batcher.seed)
    rng = np.random.default_rng(7)
    prompts = [corpus.sample(rng, 1, 12)[0] for _ in range(4)]
    reqs = [eng.Request(p.astype(np.int32), 24) for p in prompts]
    outs = e.generate(reqs)
    print("\nbatched generations (prompt | continuation):")
    for p, o in zip(prompts, outs):
        print(" ", p.tolist(), "|", o[len(p):].tolist())


if __name__ == "__main__":
    main()
