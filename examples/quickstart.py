"""Quickstart: the paper in ~40 lines, via the unified VB engine.

Distributed variational-Bayes estimation of a Gaussian mixture over a
50-node sensor network — dSVB (Algorithm 1) and dVB-ADMM (Algorithm 2)
against the centralised VB reference, using the paper's Sec. V-A setup.

Each estimator is one `engine.run_vb(model, data, topology, ...)` call:
the Bayesian-GMM `ConjugateExpModel` composed with a `FusionCenter`,
`Diffusion(W)` or `ADMMConsensus(adj)` topology (see README.md for the
equation -> code map).  The `algorithms.run_*` wrappers below bind that
for the GMM; swap in `model.LinRegModel` + the same topologies for the
linear-regression instance, or pass
`executor=engine.MeshExecutor(mesh, "data")` to shard the node axis over
a device mesh.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import algorithms, expfam, gmm, network, refperm
from repro.data import synthetic

expfam.enable_x64()

K, D, N_NODES = 3, 2, 50

# 1. sensor network + imbalanced per-node observations (Sec. V-A)
data = synthetic.paper_synthetic(n_nodes=N_NODES, n_per_node=100, seed=0)
adj, _ = network.random_geometric_graph(N_NODES, seed=0)
weights = network.nearest_neighbor_weights(adj)          # Eq. 47

# 2. conjugate prior + ground-truth posterior for the Eq. 46 metric
prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
x_all, labels_all = data.flat
ref = refperm.permuted_refs(gmm.ground_truth_posterior(
    x_all, labels_all, prior, K))
init_q = algorithms._perturbed_init(prior, data.x, jax.random.PRNGKey(0))

# 3. run the estimators.  Plain Algorithm 2 diverges on imbalanced
#    instances (dual wind-up — docs/admm-convergence.md); adaptive_rho=True
#    enables the adaptive-penalty consensus subsystem that fixes it.
kw = dict(n_iters=800, K=K, D=D, ref_phi=ref, init_q=init_q)
cvb = algorithms.run_cvb(data.x, data.mask, prior, **kw)
dsvb = algorithms.run_dsvb(data.x, data.mask, weights, prior, tau=0.2, **kw)
plain = algorithms.run_dvb_admm(data.x, data.mask, adj, prior, rho=0.5, **kw)
admm = algorithms.run_dvb_admm(data.x, data.mask, adj, prior, rho=0.5,
                               adaptive_rho=True, **kw)

print(f"{'algorithm':22s} {'KL to ground truth':>20s} {'node spread':>12s}")
for name, run in [("cVB", cvb), ("dSVB", dsvb), ("dVB-ADMM (plain)", plain),
                  ("dVB-ADMM (adaptive)", admm)]:
    print(f"{name:22s} {float(run.kl_mean[-1]):20.3f} "
          f"{float(run.kl_std[-1]):12.4f}")

q = expfam.unpack_natural(admm.phi[0], K, D)
print("\nestimated mixture means (node 0, adaptive dVB-ADMM):")
print(q.m)
print("ground truth:")
print(synthetic.PAPER_MU)
