"""Quickstart: the paper in ~50 lines, via the session API.

Distributed variational-Bayes estimation of a Gaussian mixture over a
50-node sensor network — dSVB (Algorithm 1) and dVB-ADMM (Algorithm 2)
against the centralised VB reference, using the paper's Sec. V-A setup.

Each estimator is an explicit SESSION: `engine.vb_init(model, data,
topology, ...)` opens it as a checkpointable `VBState` (the Bayesian-GMM
`ConjugateExpModel` composed with a `FusionCenter`, `Diffusion(W)` or
`ADMMConsensus(adj)` topology — see docs/ARCHITECTURE.md for the
equation -> code map) and `engine.vb_run(state, n)` advances it.  The
paper's algorithms are online recursions, so the run below is split into
two halves with full observability in between — the result is bit-exact
with the unsplit run (`engine.run_vb` is the one-shot wrapper).  Swap in
`model.LinRegModel` + the same topologies for the linear-regression
instance, pass `executor=engine.MeshExecutor(mesh, "data")` to shard the
node axis, or serve many such sessions at once with
`serving.vb_service.VBService` (see README).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import algorithms, engine, expfam, gmm, network, refperm
from repro.core import model as model_lib
from repro.data import synthetic

expfam.enable_x64()

K, D, N_NODES, N_ITERS = 3, 2, 50, 800

# 1. sensor network + imbalanced per-node observations (Sec. V-A)
data = synthetic.paper_synthetic(n_nodes=N_NODES, n_per_node=100, seed=0)
adj, _ = network.random_geometric_graph(N_NODES, seed=0)
weights = network.nearest_neighbor_weights(adj)          # Eq. 47

# 2. conjugate prior + ground-truth posterior for the Eq. 46 metric
prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
x_all, labels_all = data.flat
ref = refperm.permuted_refs(gmm.ground_truth_posterior(
    x_all, labels_all, prior, K))
init_q = algorithms._perturbed_init(prior, data.x, jax.random.PRNGKey(0))
mdl = model_lib.GMMModel(prior, K, D)
phi0 = jnp.broadcast_to(expfam.pack_natural(init_q), (N_NODES, mdl.flat_dim))

# 3. open one session per estimator.  Plain Algorithm 2 diverges on
#    imbalanced instances (dual wind-up — docs/admm-convergence.md);
#    adaptive_rho=True enables the consensus subsystem that fixes it.
kw = dict(init_phi=phi0, ref_phi=ref)
sessions = {
    "cVB": engine.vb_init(mdl, (data.x, data.mask), engine.FusionCenter(),
                          schedule=engine.ONE_SHOT, metric_nodes=1, **kw),
    "dSVB": engine.vb_init(mdl, (data.x, data.mask),
                           engine.Diffusion(weights),
                           schedule=engine.Schedule(tau=0.2), **kw),
    "dVB-ADMM (plain)": engine.vb_init(
        mdl, (data.x, data.mask), engine.ADMMConsensus(adj, rho=0.5), **kw),
    "dVB-ADMM (adaptive)": engine.vb_init(
        mdl, (data.x, data.mask),
        engine.ADMMConsensus(adj, rho=0.5, adaptive_rho=True), **kw),
}

# 4. run each session in two halves — pausing mid-run costs nothing and
#    changes nothing (bit-exact resume; checkpoint with ckpt.save(state))
print(f"{'algorithm':22s} {'KL to ground truth':>20s} {'node spread':>12s}")
for name, state in sessions.items():
    state, first = engine.vb_run(state, N_ITERS // 2)
    # ... a serving system would checkpoint / admit data here ...
    state, second = engine.vb_run(state, N_ITERS - N_ITERS // 2)
    assert int(state.t) == N_ITERS
    kl_std = 0.0 if name == "cVB" else float(second.kl_std[-1])
    print(f"{name:22s} {float(second.kl_mean[-1]):20.3f} {kl_std:12.4f}")
    sessions[name] = state

q = expfam.unpack_natural(sessions["dVB-ADMM (adaptive)"].phi[0], K, D)
print("\nestimated mixture means (node 0, adaptive dVB-ADMM):")
print(q.m)
print("ground truth:")
print(synthetic.PAPER_MU)
