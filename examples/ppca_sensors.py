"""Distributed Bayesian PPCA: sensors learn a shared latent subspace.

Every sensor observes noisy D-dimensional points living on the same
Q-dimensional subspace; diffusion dSVB through the generic engine
recovers the loading-matrix column space (principal-angle cosines ~ 1).
The `models/ppca.py` adapter is a ONE-block `blocks.BlockModel` — a bank
of D Normal-Gamma rows, the Bayesian-linear-regression family with
inferred latent covariates — so the whole engine/serving stack runs it
unchanged (docs/model-zoo.md), including streaming minibatches with the
SVRG control variate.

    PYTHONPATH=src python examples/ppca_sensors.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, expfam, network
from repro.data import stream
from repro.models import ppca

expfam.enable_x64()

N_NODES, N_PER, D, Q = 6, 40, 5, 2

x, mask, W_true = ppca.sample_sensors(N_NODES, N_PER, D=D, Q=Q, seed=1)
mdl = ppca.PPCAModel(ppca.prior(D, Q))
init_q = ppca.perturbed_init(mdl.prior, jax.random.PRNGKey(5))
phi0 = jnp.broadcast_to(mdl.pack(init_q), (N_NODES, mdl.flat_dim))

adj, _ = network.random_geometric_graph(N_NODES, seed=3)
W = network.metropolis_weights(adj)
data = (jnp.asarray(x), jnp.asarray(mask))


def subspace_cosines(phi):
    """Principal-angle cosines between estimated and true column spaces."""
    q = mdl.unpack(phi[0])
    u_est, _, _ = np.linalg.svd(np.asarray(q.m), full_matrices=False)
    u_true, _, _ = np.linalg.svd(np.asarray(W_true), full_matrices=False)
    return np.linalg.svd(u_est.T @ u_true, compute_uv=False)


print(f"{N_NODES} sensors x {N_PER} points, D={D} observed, "
      f"Q={Q} latent dims")

out = engine.run_vb(mdl, data, engine.Diffusion(W), n_iters=30,
                    init_phi=phi0)
cos = subspace_cosines(out.phi)
print(f"full-batch dSVB     cosines = {np.round(cos, 4)}  "
      f"consensus err = {float(out.consensus_err[-1]):.2e}")
assert np.min(cos) > 0.99, cos

# streaming: each node sees a 10-point window per iteration; the SVRG
# control variate keeps the stochastic iterates near the full-batch path
out_s = engine.run_vb(mdl, data, engine.Diffusion(W), n_iters=120,
                      init_phi=phi0,
                      minibatch=stream.MinibatchSpec(
                          10, seed=2, control_variate="svrg"))
cos_s = subspace_cosines(out_s.phi)
print(f"streaming dSVB+SVRG cosines = {np.round(cos_s, 4)}")
assert np.min(cos_s) > 0.99, cos_s

print("OK")
