"""End-to-end driver: train a ~100M-parameter decoder LM with the paper's
consensus data-parallelism (diffusion / ADMM) vs the all-reduce baseline.

    # full run (a few hundred steps; hours on this 1-core CPU container):
    PYTHONPATH=src python examples/train_lm.py --steps 300

    # quick demonstration (loss visibly decreasing in ~2 min):
    PYTHONPATH=src python examples/train_lm.py --quick

    # the paper's technique across 4 emulated replicas:
    PYTHONPATH=src python examples/train_lm.py --quick --dp_mode diffusion \
        --host_devices 4 --data_axis 4
"""
import argparse
import os


def build_config(quick: bool):
    from repro.configs.base import ModelConfig
    if quick:
        return ModelConfig(
            name="lm-20m", arch_type="dense", n_layers=4, d_model=256,
            n_heads=4, n_kv_heads=2, d_ff=1024, vocab_size=4096,
            tie_embeddings=True, param_dtype="float32",
            compute_dtype="float32")
    return ModelConfig(  # ~95M parameters
        name="lm-100m", arch_type="dense", n_layers=10, d_model=640,
        n_heads=10, n_kv_heads=2, d_ff=2560, vocab_size=16384,
        tie_embeddings=True, param_dtype="float32", compute_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--dp_mode", default="allreduce",
                    choices=["allreduce", "diffusion", "admm"])
    ap.add_argument("--host_devices", type=int, default=0)
    ap.add_argument("--data_axis", type=int, default=1)
    ap.add_argument("--global_batch", type=int, default=8)
    ap.add_argument("--seq_len", type=int, default=256)
    ap.add_argument("--ckpt_dir", default=None)
    args = ap.parse_args()
    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}")

    import jax
    from repro.models.model import param_count
    from repro.training import train_step as ts
    from repro.training.trainer import Trainer

    cfg = build_config(args.quick)
    steps = min(args.steps, 60) if args.quick else args.steps
    print(f"model {cfg.name}: {param_count(cfg)/1e6:.1f}M params, "
          f"dp_mode={args.dp_mode}, {steps} steps")
    mesh = jax.make_mesh((args.data_axis, 1), ("data", "model"))
    axis = "data" if args.dp_mode != "allreduce" else None
    hyper = ts.TrainHyper(peak_lr=1e-3, warmup=max(steps // 10, 5),
                          total_steps=steps)
    tr = Trainer(cfg, mesh, dp_mode=args.dp_mode, consensus_axis=axis,
                 hyper=hyper, global_batch=args.global_batch,
                 seq_len=args.seq_len, ckpt_dir=args.ckpt_dir)
    hist = tr.run(steps, log_every=max(steps // 20, 1))
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'PASS' if last < first else 'FAIL'}: decreasing)")
    if args.ckpt_dir:
        print("checkpoint:", tr.save(steps))


if __name__ == "__main__":
    main()
