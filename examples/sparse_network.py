"""Sparse 1000-node sensor networks: edge lists, gossip, hierarchy.

The paper's experiments stop at 50 sensors with a dense (N, N) mixing
matrix; this example runs the same Bayesian-GMM VB engine on a
1000-node random geometric graph held as a `network.SparseGraph` (edge
lists + `segment_sum` combines — O(E + N) memory, no N x N array
anywhere; see docs/sparse-topologies.md):

  * `Diffusion(sparse_nearest_neighbor_weights(g))` — Eq. 47 diffusion
    on the edge list (bit-parity with the dense oracle at small N),
  * `PairwiseGossip(g, p_activate=0.3)` — asynchronous randomized
    gossip, each link active i.i.d. per iteration, deterministic in
    (seed, t) so sessions split/resume bit-exactly,
  * `HierarchicalFusion(gateway_of, region_of)` — sensor -> gateway ->
    region fusion over a balanced two-level partition.

    PYTHONPATH=src python examples/sparse_network.py
"""
import numpy as np

from repro.core import engine, expfam, gmm, network, refperm
from repro.core import model as model_lib
from repro.data import synthetic

expfam.enable_x64()

N, K, D, ITERS = 1000, 3, 2, 60

data = synthetic.paper_synthetic(n_nodes=N, n_per_node=20, seed=0)
prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
mdl = model_lib.GMMModel(prior, K, D)
x_all, labels = data.flat
ref = refperm.permuted_refs(gmm.ground_truth_posterior(x_all, labels,
                                                       prior, K))

# edge-list graph: the N=10k-capable builder (threshold-derived radius,
# never materialises an (N, N) matrix)
g, _pos = network.random_geometric_edges(N, seed=0)
print(f"graph: {g!r}, mean degree "
      f"{2 * g.n_undirected / g.n_nodes:.1f}")

gw, rg = network.two_level_partition(N, n_gateways=64, n_regions=8)
topologies = [
    ("sparse diffusion",
     engine.Diffusion(network.sparse_nearest_neighbor_weights(g))),
    ("pairwise gossip p=0.3",
     engine.PairwiseGossip(g, p_activate=0.3, seed=5)),
    ("hierarchical 64 gw / 8 regions",
     engine.HierarchicalFusion(gw, rg)),
]

for name, topo in topologies:
    run = engine.run_vb(mdl, (data.x, data.mask), topo, n_iters=ITERS,
                        ref_phi=ref, schedule=engine.Schedule())
    print(f"{name:32s} KL {float(run.kl_mean[0]):9.0f} -> "
          f"{float(run.kl_mean[-1]):9.0f}   consensus err "
          f"{float(run.consensus_err[-1]):.3g}")

# gossip sessions resume bit-exactly: the activation pattern is a
# function of the ABSOLUTE iteration index carried in VBState.t
topo = engine.PairwiseGossip(g, p_activate=0.3, seed=5)
s = engine.vb_init(mdl, (data.x, data.mask), topo,
                   schedule=engine.Schedule())
s, _ = engine.vb_run(s, ITERS // 2)
s, _ = engine.vb_run(s, ITERS - ITERS // 2)
full = engine.run_vb(mdl, (data.x, data.mask), topo, n_iters=ITERS,
                     schedule=engine.Schedule())
assert np.array_equal(np.asarray(s.phi), np.asarray(full.phi))
print("gossip split/resume: bit-exact")
