"""Distributed clustering on the real-data surrogates (Sec. V-D).

Reproduces the Table I / Table II comparisons: cVB vs noncoop-VB vs
nsg-dVB vs dSVB vs dVB-ADMM on the atmosphere- and ionosphere-shaped
datasets (offline surrogates — DESIGN.md §7).

    PYTHONPATH=src python examples/distributed_clustering.py
"""
import jax

from repro.core import algorithms, expfam, network
from repro.data import datasets

import sys
sys.path.insert(0, ".")
from benchmarks import common  # noqa: E402

expfam.enable_x64()


def run_table(name, data, K, D, n_iters, rho, tau):
    s = common.setup_gmm(data, K, D, graph_seed=11, beta0=0.05, w0=5.0)
    kw = dict(n_iters=n_iters, K=K, D=D, init_q=s["init_q"])
    rows = {}
    rows["cVB"] = algorithms.run_cvb(data.x, data.mask, s["prior"], **kw)
    rows["noncoop-VB"] = algorithms.run_noncoop(data.x, data.mask,
                                                s["prior"], **kw)
    rows["nsg-dVB"] = algorithms.run_nsg_dvb(data.x, data.mask, s["W"],
                                             s["prior"], **kw)
    rows["dSVB"] = algorithms.run_dsvb(data.x, data.mask, s["W"],
                                       s["prior"], tau=tau, **kw)
    rows["dVB-ADMM"] = algorithms.run_dvb_admm(data.x, data.mask, s["adj"],
                                               s["prior"], rho=rho, **kw)
    print(f"\n=== {name} ===")
    print(f"{'algorithm':12s} {'accuracy':>9s}")
    for alg, run in rows.items():
        acc = common.accuracy(data, run.phi, K, D)
        print(f"{alg:12s} {acc:9.4f}")


if __name__ == "__main__":
    run_table("Table I: atmosphere (1600 x 3, 2 classes, 20 nodes)",
              datasets.atmosphere_surrogate(n_nodes=20), 2, 3, 400,
              rho=1.0, tau=0.2)
    run_table("Table II: ionosphere (340 x 34, 2 classes, 20 nodes)",
              datasets.ionosphere_surrogate(n_nodes=20), 2, 34, 300,
              rho=16.0, tau=0.2)
