"""Distributed clustering on the real-data surrogates (Sec. V-D).

Reproduces the Table I / Table II comparisons: cVB vs noncoop-VB vs
nsg-dVB vs dSVB vs dVB-ADMM on the atmosphere- and ionosphere-shaped
datasets (offline surrogates), then demos the engine API directly:
`ADMMConsensus(adaptive_rho=True)` — the adaptive-penalty consensus
subsystem — with its `ConsensusDiagnostics` summary printed (see
docs/admm-convergence.md for how to read it).

    PYTHONPATH=src python examples/distributed_clustering.py
"""
import jax
import jax.numpy as jnp

from repro.core import algorithms, engine, expfam, network
from repro.core import model as model_lib
from repro.data import datasets

import sys
sys.path.insert(0, ".")
from benchmarks import common  # noqa: E402

expfam.enable_x64()


def run_table(name, data, K, D, n_iters, rho, tau):
    s = common.setup_gmm(data, K, D, graph_seed=11, beta0=0.05, w0=5.0)
    kw = dict(n_iters=n_iters, K=K, D=D, init_q=s["init_q"])
    rows = {}
    rows["cVB"] = algorithms.run_cvb(data.x, data.mask, s["prior"], **kw)
    rows["noncoop-VB"] = algorithms.run_noncoop(data.x, data.mask,
                                                s["prior"], **kw)
    rows["nsg-dVB"] = algorithms.run_nsg_dvb(data.x, data.mask, s["W"],
                                             s["prior"], **kw)
    rows["dSVB"] = algorithms.run_dsvb(data.x, data.mask, s["W"],
                                       s["prior"], tau=tau, **kw)
    rows["dVB-ADMM"] = algorithms.run_dvb_admm(data.x, data.mask, s["adj"],
                                               s["prior"], rho=rho, **kw)
    rows["dVB-ADMM (adaptive)"] = algorithms.run_dvb_admm(
        data.x, data.mask, s["adj"], s["prior"], rho=rho,
        adaptive_rho=True, **kw)
    print(f"\n=== {name} ===")
    print(f"{'algorithm':22s} {'accuracy':>9s}")
    for alg, run in rows.items():
        acc = common.accuracy(data, run.phi, K, D)
        print(f"{alg:22s} {acc:9.4f}")
    return rows["dVB-ADMM (adaptive)"]


def print_diagnostics(run: engine.VBRun) -> None:
    """Final ConsensusDiagnostics summary of an adaptive dVB-ADMM run."""
    d = run.consensus_diag
    opened = float(d.dual_on[-1]) > 0.0
    on_at = int(jnp.argmax(d.dual_on)) if opened else -1
    print("\n--- ConsensusDiagnostics summary (adaptive dVB-ADMM) ---")
    print(f"dual warmup gate : "
          + (f"opened at iteration {on_at}" if opened else "never opened"))
    print(f"kappa (final)    : {float(d.kappa[-1]):.3f}")
    print(f"rho trajectory   : {float(jnp.mean(d.rho[0])):.3g} -> "
          f"{float(jnp.mean(d.rho[-1])):.3g}")
    print(f"primal residual  : {float(jnp.mean(d.primal_resid[-1])):.3e}")
    print(f"dual residual    : {float(jnp.mean(d.dual_resid[-1])):.3e}")
    print(f"eigen-clip fired : {int(jnp.sum(d.clip_count))} node-iterations"
          f" ({int(jnp.sum(d.reset_count))} dual resets)")


def engine_api_demo(data, K, D, n_iters=300):
    """The same run, written against engine.run_vb directly (the
    Model x Topology x Executor API from docs/ARCHITECTURE.md)."""
    s = common.setup_gmm(data, K, D, graph_seed=11, beta0=0.05, w0=5.0)
    mdl = model_lib.GMMModel(s["prior"], K, D)
    topo = engine.ADMMConsensus(s["adj"], rho=1.0, adaptive_rho=True)
    phi0 = jnp.broadcast_to(expfam.pack_natural(s["init_q"]),
                            (data.x.shape[0], mdl.flat_dim))
    run = engine.run_vb(mdl, (data.x, data.mask), topo, n_iters=n_iters,
                        init_phi=phi0)
    acc = common.accuracy(data, run.phi, K, D)
    print(f"\nengine.run_vb(GMMModel, ADMMConsensus(adaptive_rho=True)): "
          f"accuracy {acc:.4f}")
    print_diagnostics(run)


if __name__ == "__main__":
    atmosphere = datasets.atmosphere_surrogate(n_nodes=20)
    run_table("Table I: atmosphere (1600 x 3, 2 classes, 20 nodes)",
              atmosphere, 2, 3, 400, rho=1.0, tau=0.2)
    run_table("Table II: ionosphere (340 x 34, 2 classes, 20 nodes)",
              datasets.ionosphere_surrogate(n_nodes=20), 2, 34, 300,
              rho=16.0, tau=0.2)
    engine_api_demo(atmosphere, 2, 3)
