"""Streaming dSVB over a failing sensor network — minibatches + link drops.

The paper's Algorithm 1 run the way a real sensor network would: each node
estimates its local VBM optimum from a small reshuffled minibatch of its
buffer every iteration (`MinibatchSpec` — unbiased stochastic natural
gradients under the Robbins-Monro eta_t), while the communication links
independently fail with probability `--link-drop` per iteration (the
diffusion weights renormalise over whatever neighbourhood is still up,
and `ADMMConsensus` couples only live links, reporting the surviving
fraction in `ConsensusDiagnostics.link_frac`).

    PYTHONPATH=src python examples/streaming_vb.py            # CI smoke size
    PYTHONPATH=src python examples/streaming_vb.py --full     # paper size
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import algorithms, engine, expfam, gmm, network, refperm
from repro.core import model as model_lib
from repro.data import stream, synthetic

expfam.enable_x64()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-sized instance (50 nodes, 2000 iters)")
    ap.add_argument("--link-drop", type=float, default=0.2)
    args = ap.parse_args()

    n_nodes = 50 if args.full else 10
    n_per = 100 if args.full else 40
    n_iters = 2000 if args.full else 150
    batch = max(4, n_per // 5)

    K, D = 3, 2
    data = synthetic.paper_synthetic(n_nodes=n_nodes, n_per_node=n_per,
                                     seed=0)
    adj, _ = network.random_geometric_graph(n_nodes, seed=0)
    W = network.nearest_neighbor_weights(adj)
    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
    x_all, labels_all = data.flat
    ref = refperm.permuted_refs(gmm.ground_truth_posterior(
        x_all, labels_all, prior, K))
    init_q = algorithms._perturbed_init(prior, data.x, jax.random.PRNGKey(0))
    phi0 = jnp.broadcast_to(expfam.pack_natural(init_q),
                            (n_nodes, expfam.flat_dim(K, D)))
    mdl = model_lib.GMMModel(prior, K, D)
    spec = stream.MinibatchSpec(batch_size=batch, seed=0)
    kw = dict(n_iters=n_iters, init_phi=phi0, ref_phi=ref)

    print(f"{n_nodes} nodes x {n_per} pts, minibatch B={batch}, "
          f"link-drop p={args.link_drop}, {n_iters} iters\n")

    runs = {
        "dSVB full-batch, static net": engine.run_vb(
            mdl, (data.x, data.mask), engine.Diffusion(W), **kw),
        "dSVB streaming, static net": engine.run_vb(
            mdl, (data.x, data.mask), engine.Diffusion(W),
            minibatch=spec, **kw),
        "dSVB streaming, failing links": engine.run_vb(
            mdl, (data.x, data.mask),
            engine.Diffusion(W, link_drop=args.link_drop, link_seed=1),
            minibatch=spec, **kw),
    }
    admm = engine.run_vb(
        mdl, (data.x, data.mask),
        engine.ADMMConsensus(adj, adaptive_rho=True,
                             link_drop=args.link_drop, link_seed=1),
        minibatch=spec, n_iters=n_iters, init_phi=phi0, ref_phi=ref)
    runs["dVB-ADMM adaptive, streaming + failing links"] = admm

    print(f"{'run':46s} {'final KL':>10s} {'node spread':>12s}")
    for name, r in runs.items():
        print(f"{name:46s} {float(r.kl_mean[-1]):10.3f} "
              f"{float(r.kl_std[-1]):12.4f}")

    lf = admm.consensus_diag.link_frac
    print(f"\nADMM effective connectivity (link_frac): "
          f"mean {float(jnp.mean(lf)):.3f}, "
          f"min {float(jnp.min(lf)):.3f} "
          f"(nominal {1 - args.link_drop:.2f} expected)")
    assert bool(jnp.all(jnp.isfinite(runs[
        "dSVB streaming, failing links"].phi))), "streaming run diverged"
    print("\nOK: streaming + failing-link runs finished finite")


if __name__ == "__main__":
    main()
