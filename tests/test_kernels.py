"""Per-kernel shape/dtype sweeps asserting allclose against ref.py oracles
(interpret mode executes the TPU kernel bodies in python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,Hq,Hkv,hd", [
    (2, 64, 4, 2, 32),
    (1, 128, 2, 1, 64),
    (2, 96, 4, 4, 16),      # S not a multiple of block -> padding path
    (1, 256, 8, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 32])
def test_flash_attention_sweep(B, S, Hq, Hkv, hd, dtype, window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), dtype)
    out = ops.flash_attention(q, k, v, window=window)
    g = Hq // Hkv
    qr = jnp.moveaxis(q, 2, 1)
    kr = jnp.moveaxis(jnp.repeat(k, g, 2), 2, 1)
    vr = jnp.moveaxis(jnp.repeat(v, g, 2), 2, 1)
    want = jnp.moveaxis(ref.attention(qr, kr, vr, window=window), 1, 2)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_flash_attention_causality():
    """Future tokens must not influence output (hard property)."""
    ks = jax.random.split(KEY, 3)
    B, S, H, hd = 1, 64, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    out1 = ops.flash_attention(q, k, v)
    k2 = k.at[:, S // 2:].set(99.0)
    v2 = v.at[:, S // 2:].set(-99.0)
    out2 = ops.flash_attention(q, k2, v2)
    np.testing.assert_allclose(out1[:, :S // 2], out2[:, :S // 2], atol=1e-5)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 64, 4, 16, 8, 16),
    (1, 128, 2, 32, 16, 32),
    (2, 64, 2, 8, 4, 64),    # single chunk
    (1, 96, 3, 16, 8, 32),   # 3 chunks
])
def test_ssd_scan_sweep(B, S, H, P, N, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.3
    y, h = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    yr, hr = ref.ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y, yr, atol=5e-5)
    np.testing.assert_allclose(h, hr, atol=5e-5)


def test_ssd_matches_model_chunked():
    """Kernel == the model's pure-jnp chunked path (mamba2.ssd_chunked)."""
    from repro.models.mamba2 import ssd_chunked
    ks = jax.random.split(KEY, 5)
    B, S, H, P, N = 2, 128, 4, 16, 8
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.3
    y1, h1 = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=32)
    y2, h2 = ssd_chunked(x, dt, A, Bm, Cm, 32)
    np.testing.assert_allclose(y1, y2, atol=1e-5)
    np.testing.assert_allclose(h1, h2, atol=1e-5)


# ---------------------------------------------------------------------------
# gmm_estep
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("T,K,D,block", [
    (100, 3, 2, 32),
    (257, 4, 5, 64),        # padding path
    (64, 2, 8, 64),
    (500, 6, 3, 128),
])
def test_gmm_estep_sweep(T, K, D, block):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(T, D)) * 2, jnp.float32)
    mask = jnp.asarray((rng.random(T) > 0.1), jnp.float32)
    log_prior = jnp.asarray(rng.normal(size=K), jnp.float32)
    A = rng.normal(size=(K, D, D)) * 0.3
    Wn = jnp.asarray(np.einsum("kij,klj->kil", A, A) + np.eye(D),
                     jnp.float32)
    b = jnp.asarray(rng.normal(size=(K, D)), jnp.float32)
    c = jnp.asarray(rng.uniform(1, 3, K), jnp.float32)
    r, R, sx, sxx = ops.gmm_estep(x, mask, log_prior, Wn, b, c,
                                  block_t=block)
    rr, RR, sxr, sxxr = ref.gmm_estep(x, mask, log_prior, Wn, b, c)
    np.testing.assert_allclose(r, rr, atol=2e-5)
    np.testing.assert_allclose(R, RR, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(sx, sxr, rtol=1e-4, atol=5e-4)
    np.testing.assert_allclose(sxx, sxxr, rtol=1e-3, atol=5e-3)


def test_gmm_estep_matches_core_vbe():
    """Fused kernel == repro.core.gmm VBE path on a real posterior."""
    from repro.core import expfam, gmm
    rng = np.random.default_rng(1)
    K, D = 3, 4
    q = expfam.noninformative_prior(K, D, dtype=jnp.float32)
    q = q._replace(m=jnp.asarray(rng.normal(size=(K, D)), jnp.float32),
                   nu=jnp.asarray([6.0, 7.0, 8.0], jnp.float32))
    x = jnp.asarray(rng.normal(size=(200, D)) * 2, jnp.float32)
    mask = jnp.ones((200,), jnp.float32)
    r, R, sx, sxx = ops.gmm_estep_from_posterior(x, mask, q)
    r2 = gmm.responsibilities(x, q, mask)
    st = gmm.sufficient_stats(x, r2, 1.0)
    np.testing.assert_allclose(r, r2, atol=3e-5)
    np.testing.assert_allclose(R, st.R, rtol=1e-4)
    np.testing.assert_allclose(sxx, st.sum_xx, rtol=1e-3, atol=1e-3)


def _gmm_node_args(N, T, K, D, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(N, T, D)) * 2, jnp.float32)
    mask = jnp.asarray(rng.random((N, T)) > 0.2, jnp.float32)
    lp = jnp.asarray(rng.normal(size=(N, K)), jnp.float32)
    A = rng.normal(size=(N, K, D, D)) * 0.3
    Wn = jnp.asarray(np.einsum("nkij,nklj->nkil", A, A) + np.eye(D),
                     jnp.float32)
    b = jnp.asarray(rng.normal(size=(N, K, D)), jnp.float32)
    c = jnp.asarray(rng.uniform(1, 3, (N, K)), jnp.float32)
    return x, mask, lp, Wn, b, c


def test_gmm_estep_nodes_large_k_parity():
    """K=32 (the ROADMAP large-K case): the rolled-loop kernel must match
    the oracle just like the small-K sweeps."""
    args = _gmm_node_args(N=3, T=96, K=32, D=3)
    r, R, sx, sxx = ops.gmm_estep_nodes(*args, block_t=32)
    rr, RR, sxr, sxxr = ref.gmm_estep_nodes(*args)
    np.testing.assert_allclose(r, rr, atol=3e-5)
    np.testing.assert_allclose(R, RR, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(sx, sxr, rtol=1e-4, atol=5e-4)
    np.testing.assert_allclose(sxx, sxxr, rtol=1e-3, atol=5e-3)


def test_gmm_estep_kernel_replication_scaling():
    """Kernel-side replication: stats scale by the factor, r does not."""
    args = _gmm_node_args(N=2, T=50, K=3, D=2)
    from repro.kernels import gmm_estep as ge
    r1, R1, sx1, sxx1 = ge.gmm_estep_nodes(*args, replication=1.0)
    r8, R8, sx8, sxx8 = ge.gmm_estep_nodes(*args, replication=8.0)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r8))
    np.testing.assert_allclose(np.asarray(R8), 8.0 * np.asarray(R1),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(sxx8), 8.0 * np.asarray(sxx1),
                               rtol=1e-6)


def _count_eqns(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        n += 1
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):          # ClosedJaxpr
                n += _count_eqns(v.jaxpr)
            elif hasattr(v, "eqns"):         # Jaxpr
                n += _count_eqns(v)
    return n


def test_gmm_estep_trace_size_constant_in_k():
    """Compile-time regression (ROADMAP: unrolled per-component matmuls
    blew up compile time past K~16): the kernel's program must be the SAME
    SIZE at K=32 as at K=4 — the per-component work is a rolled fori_loop,
    so trace/lowering cost is O(1) in K."""
    from repro.kernels import gmm_estep as ge

    def size_at(K):
        args = _gmm_node_args(N=2, T=64, K=K, D=3)
        jaxpr = jax.make_jaxpr(
            lambda *a: ge.gmm_estep_nodes(*a, block_t=32, interpret=True,
                                          return_r=False))(*args)
        return _count_eqns(jaxpr.jaxpr)

    small, large = size_at(4), size_at(32)
    assert large == small, (small, large)
