"""The adaptive-penalty ADMM consensus subsystem (engine.ADMMConsensus
with adaptive_rho / per_block / dual_warmup / dual_reset) and its
ConsensusDiagnostics record.

Convergence itself is asserted end-to-end in
test_gmm_algorithms.test_paper_claims_ordering and
test_system.test_end_to_end_distributed_vb_recovers_mixture; this file
pins the MACHINERY: balancing direction, per-block parity, reset
triggering, the warmup gate, and the diagnostics wiring."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms, engine, expfam, linreg, network
from repro.core import model as model_lib
from repro.data import synthetic


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


K, D = 3, 2
ADJ2 = jnp.asarray([[0.0, 1.0], [1.0, 0.0]])     # the two-node graph


@pytest.fixture(scope="module")
def gmm_setup():
    data = synthetic.paper_synthetic(n_nodes=8, n_per_node=20, seed=2)
    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
    adj, _ = network.random_geometric_graph(8, seed=4)
    init_q = algorithms._perturbed_init(prior, data.x, jax.random.PRNGKey(3))
    return data, prior, adj, init_q


def _two_node_linreg_run(phi_star, topo, n_iters=40):
    mdl = model_lib.LinRegModel(linreg.prior(2))
    return engine.run_vb(mdl, phi_star, topo, n_iters=n_iters)


# ---------------------------------------------------------------------------
# residual balancing: rho moves in the expected direction
# ---------------------------------------------------------------------------
def test_balancing_rule_directions():
    rho = jnp.asarray(1.0)
    up = engine.residual_balanced_rho(rho, 100.0, 1.0)       # r >> mu s
    down = engine.residual_balanced_rho(rho, 1.0, 100.0)     # s >> mu r
    hold = engine.residual_balanced_rho(rho, 5.0, 1.0)       # balanced
    assert float(up) == 2.0 and float(down) == 0.5 and float(hold) == 1.0
    # bounds clip
    assert float(engine.residual_balanced_rho(
        jnp.asarray(900.0), 1e9, 1.0, rho_max=1e3)) == 1e3


def test_adaptive_rho_grows_on_disagreeing_two_node_instance():
    """Two linear-regression nodes with very different local optima: the
    fixed points disagree (primal residual dominates once the per-node
    subproblems settle), so residual balancing must GROW rho."""
    mdl = model_lib.LinRegModel(linreg.prior(2))
    base = mdl.init_phi()
    phi_star = jnp.stack([base + 5.0, base - 5.0])
    topo = engine.ADMMConsensus(ADJ2, rho=0.5, adaptive_rho=True,
                                dual_warmup=False, dual_reset=None,
                                adapt_every=1, project=False)
    run = _two_node_linreg_run(phi_star, topo)
    rho = np.asarray(run.consensus_diag.rho)
    assert rho[-1] > rho[0]


def test_adaptive_rho_shrinks_on_agreeing_two_node_instance():
    """Two IDENTICAL nodes: zero disagreement by symmetry, but the iterate
    still travels from the prior toward phi* (dual residual dominates), so
    residual balancing must SHRINK rho."""
    mdl = model_lib.LinRegModel(linreg.prior(2))
    base = mdl.init_phi()
    phi_star = jnp.stack([base + 5.0, base + 5.0])
    topo = engine.ADMMConsensus(ADJ2, rho=0.5, adaptive_rho=True,
                                dual_warmup=False, dual_reset=None,
                                adapt_every=1, project=False)
    run = _two_node_linreg_run(phi_star, topo)
    rho = np.asarray(run.consensus_diag.rho)
    assert rho[-1] < rho[0]
    # and the primal residual really was ~0 (symmetric consensus)
    assert float(run.consensus_diag.primal_resid[-1]) < 1e-8


# ---------------------------------------------------------------------------
# per-block dual scaling
# ---------------------------------------------------------------------------
def test_per_block_parity_when_balancing_disabled(gmm_setup):
    """per_block=True with no adaptation is a pure reparameterisation (the
    same rho in every block) — the trajectory must match the scalar path,
    which itself is golden-parity-tested against Algorithm 2."""
    data, prior, adj, init_q = gmm_setup
    kw = dict(n_iters=25, K=K, D=D, init_q=init_q, rho=0.5)
    scalar = algorithms.run_dvb_admm(data.x, data.mask, adj, prior, **kw)
    pb = algorithms.run_dvb_admm(data.x, data.mask, adj, prior,
                                 per_block=True, adaptive_rho=False,
                                 dual_warmup=False, dual_reset=None, **kw)
    np.testing.assert_allclose(np.asarray(pb.phi), np.asarray(scalar.phi),
                               rtol=1e-12, atol=1e-12)


def test_per_block_diagnostics_shapes(gmm_setup):
    data, prior, adj, init_q = gmm_setup
    run = algorithms.run_dvb_admm(data.x, data.mask, adj, prior, n_iters=10,
                                  K=K, D=D, init_q=init_q,
                                  adaptive_rho=True, per_block=True)
    d = run.consensus_diag
    n_blocks = len(expfam.BLOCK_NAMES)
    assert d.rho.shape == (10, n_blocks)
    assert d.primal_resid.shape == (10, n_blocks)
    assert d.dual_resid.shape == (10, n_blocks)
    assert d.clip_count.shape == (10,)


def test_block_labels_cover_packing():
    labels = expfam.block_labels(K, D)
    assert labels.shape == (expfam.flat_dim(K, D),)
    counts = np.bincount(labels, minlength=len(expfam.BLOCK_NAMES))
    assert counts.tolist() == [K, K, K, K * D, K * D * D]
    labels_lr = linreg.block_labels(3)
    assert labels_lr.shape == (linreg.flat_dim(3),)
    assert np.bincount(labels_lr).tolist() == [1, 1, 3, 9]


# ---------------------------------------------------------------------------
# dual reset on eigen-clip activation
# ---------------------------------------------------------------------------
def test_dual_reset_fires_iff_eigen_clip_activates(gmm_setup):
    """reset_count must equal clip_count per iteration when the feature is
    on, be all-zero when it is off, and a projection-free run never resets
    (the trigger IS the Eq. 38b projection actually moving the iterate)."""
    data, prior, adj, init_q = gmm_setup
    kw = dict(n_iters=40, K=K, D=D, init_q=init_q, rho=0.5)
    with_reset = algorithms.run_dvb_admm(
        data.x, data.mask, adj, prior, adaptive_rho=False,
        dual_warmup=False, dual_reset=0.5, **kw)
    d = with_reset.consensus_diag
    np.testing.assert_array_equal(np.asarray(d.reset_count),
                                  np.asarray(d.clip_count))
    assert int(jnp.sum(d.clip_count)) > 0      # the instance does clip
    plain = algorithms.run_dvb_admm(data.x, data.mask, adj, prior, **kw)
    assert int(jnp.sum(plain.consensus_diag.reset_count)) == 0
    no_proj = algorithms.run_dvb_admm(
        data.x, data.mask, adj, prior, project=False, adaptive_rho=False,
        dual_warmup=False, dual_reset=0.5, **kw)
    assert int(jnp.sum(no_proj.consensus_diag.reset_count)) == 0


class _ClampedLinReg(model_lib.LinRegModel):
    """LinRegModel whose Omega projection clamps every coordinate to
    [-1, 1] — a deterministic stand-in for the GMM eigen-clip, so tests
    can force the projection to activate on every iteration."""

    def project_to_domain(self, phi):
        return jnp.clip(phi, -1.0, 1.0)


def test_dual_reset_restarts_ramp_while_projection_active():
    """With an always-active projection and dual_reset on, the kappa ramp
    must restart every iteration (stay 0) and every node resets — the
    duals never get to accumulate in the invalidated geometry."""
    mdl = _ClampedLinReg(linreg.prior(2))
    base = mdl.init_phi()
    phi_star = jnp.stack([base + 5.0, base - 5.0])   # way outside the clamp
    topo = engine.ADMMConsensus(ADJ2, rho=0.5, adaptive_rho=False,
                                dual_warmup=False, dual_reset=0.0)
    run = engine.run_vb(mdl, phi_star, topo, n_iters=15)
    d = run.consensus_diag
    assert int(jnp.min(d.clip_count)) == 2           # both nodes, every iter
    np.testing.assert_array_equal(np.asarray(d.reset_count),
                                  np.asarray(d.clip_count))
    assert bool(jnp.all(d.kappa == 0.0))             # ramp never ramps


# ---------------------------------------------------------------------------
# dual warmup gate
# ---------------------------------------------------------------------------
def test_warmup_gate_holds_duals_then_opens():
    """Before the gate opens kappa is exactly 0 (pure penalty method);
    dual_on is monotone; on an easy two-node instance the gate does open
    and the duals then remove the penalty-method consensus bias."""
    mdl = model_lib.LinRegModel(linreg.prior(2))
    base = mdl.init_phi()
    phi_star = jnp.stack([base + 2.0, base - 2.0])
    topo = engine.ADMMConsensus(ADJ2, rho=0.5, dual_warmup=True,
                                warmup_window=3, project=False,
                                dual_reset=None)
    run = _two_node_linreg_run(phi_star, topo, n_iters=120)
    d = run.consensus_diag
    on = np.asarray(d.dual_on)
    kappa = np.asarray(d.kappa)
    assert bool(np.all(np.diff(on) >= 0))                  # monotone gate
    assert np.all(kappa[on == 0.0] == 0.0)                 # closed => no step
    assert on[0] == 0.0 and on[-1] == 1.0                  # it did open
    # duals alive and consensus exact-ish: both nodes at the phi* average
    want = jnp.mean(phi_star, axis=0)
    np.testing.assert_allclose(np.asarray(run.phi),
                               np.asarray(jnp.stack([want, want])),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# diagnostics wiring through run_vb
# ---------------------------------------------------------------------------
def test_diagnostics_threading(gmm_setup):
    data, prior, adj, init_q = gmm_setup
    W = network.nearest_neighbor_weights(adj)
    kw = dict(n_iters=8, K=K, D=D, init_q=init_q)
    admm = algorithms.run_dvb_admm(data.x, data.mask, adj, prior, **kw)
    d = admm.consensus_diag
    assert isinstance(d, engine.ConsensusDiagnostics)
    for f in ("primal_resid", "dual_resid", "rho", "kappa"):
        assert getattr(d, f).shape == (8,), f
    assert bool(jnp.all(d.primal_resid >= 0))
    # non-ADMM topologies emit no consensus diagnostics
    dsvb = algorithms.run_dsvb(data.x, data.mask, W, prior, **kw)
    assert dsvb.consensus_diag is None
    # run_vb(diagnostics=False) suppresses the record entirely
    mdl = model_lib.GMMModel(prior, K, D)
    run = engine.run_vb(mdl, (data.x, data.mask),
                        engine.ADMMConsensus(adj), n_iters=4,
                        diagnostics=False)
    assert run.consensus_diag is None and run.consensus_err is None


# ---------------------------------------------------------------------------
# training-layer lift (optim/consensus.py) shares the same balancing rule
# ---------------------------------------------------------------------------
def test_training_layer_adapt_rho_alias():
    from repro.optim import consensus as oc
    assert float(oc.adapt_rho(jnp.asarray(2.0), 100.0, 1.0)) == 4.0
    assert float(oc.adapt_rho(jnp.asarray(2.0), 1.0, 100.0)) == 1.0


CODE_RING_RESIDUALS = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.dist import compat
from repro.optim import consensus as oc

mesh = jax.make_mesh((4,), ("data",))
params_new = {"w": jnp.arange(4.0).reshape(4, 1) * 10.0}   # disagreeing ring
params_prev = jax.tree.map(lambda p: p + 1.0, params_new)

def f(p_new, p_prev):
    return oc.admm_residual_norms(p_new, p_prev, "data", rho=2.0)

fn = compat.shard_map(f, mesh=mesh,
                      in_specs=(P("data"), P("data")),
                      out_specs=(P(), P()), check_vma=False)
r, s = fn(params_new["w"], params_prev["w"])
# r: node values 10*[0..3], ring disagreement 2p_i - p_{i-1} - p_{i+1}
# -> [-20, 0, 0, 20] up to wraparound; rms = sqrt(mean([400,0,0,400]*100))
import numpy as np
want_r = np.sqrt(np.mean(np.asarray([40.0, 0.0, 0.0, -40.0]) ** 2))
assert abs(float(r) - want_r) < 1e-5, (float(r), want_r)
assert abs(float(s) - 2.0) < 1e-6, float(s)   # rho * |delta|, delta=1

# admm_step(return_residuals=True): the ride-along norms must equal the
# standalone helper evaluated on the step's own (new_params, params_prev)
def g(p_star, p_prev, lam):
    p_new, d_new, (r2, s2) = oc.admm_step(
        {"w": p_star}, {"w": p_prev}, {"w": lam}, "data", rho=2.0,
        kappa=0.3, return_residuals=True)
    r3, s3 = oc.admm_residual_norms(p_new, {"w": p_prev}, "data", rho=2.0)
    return r2, s2, r3, s3

gn = compat.shard_map(g, mesh=mesh,
                      in_specs=(P("data"), P("data"), P("data")),
                      out_specs=(P(), P(), P(), P()), check_vma=False)
r2, s2, r3, s3 = gn(params_new["w"], params_prev["w"],
                    jnp.zeros_like(params_new["w"]))
assert abs(float(r2) - float(r3)) < 1e-5, (float(r2), float(r3))
assert abs(float(s2) - float(s3)) < 1e-5, (float(s2), float(s3))
print("OK")
"""


def test_training_layer_residual_norms_on_ring(subproc):
    out = subproc(CODE_RING_RESIDUALS, n_devices=4)
    assert "OK" in out
