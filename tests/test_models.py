"""Per-architecture smoke tests (task deliverable f) + model invariants.

Each assigned architecture instantiates its REDUCED same-family variant
(<=2-3 layers, d_model<=512, <=4 experts), runs one forward and one train
step on CPU, asserting output shapes and no NaNs; decode-capable archs also
run a cached decode step and the decode-vs-forward consistency check.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.dist import compat
from repro.models import model
from repro.training import train_step as ts

B, S = 2, 32


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.frontend != "none":
        batch["frontend"] = jnp.ones((B, cfg.frontend_len, cfg.d_model),
                                     jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    assert cfg.d_model <= 512 and cfg.n_layers <= 3
    assert cfg.n_experts <= 4
    key = jax.random.PRNGKey(0)
    params = model.init_params(cfg, key)
    batch = _batch(cfg, key)
    out = model.forward(cfg, params, batch["tokens"], batch.get("frontend"))
    assert out["logits"].shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(out["logits"])))

    # one training step (warmup=0 so the step actually moves parameters)
    mesh = jax.make_mesh((1,), ("data",))
    state = ts.init_state(cfg, key)
    hyper = ts.TrainHyper(warmup=0, peak_lr=1e-3)
    step = jax.jit(ts.make_train_step(cfg, mesh, hyper=hyper))
    with compat.use_mesh(mesh):
        state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert not bool(jnp.any(jnp.isnan(
        jax.tree.leaves(state2.params)[0])))
    # params actually changed
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        state.params, state2.params)
    assert max(jax.tree.leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = model.init_params(cfg, key)
    cache = model.init_cache(cfg, B, 64, jnp.float32)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    logits, cache2 = model.decode_step(cfg, params, tok, cache,
                                       jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", ["yi_6b", "mamba2_370m",
                                  "recurrentgemma_2b",
                                  "granite_moe_3b_a800m", "chatglm3_6b",
                                  "qwen2_vl_2b"])
def test_decode_matches_forward(arch):
    """Teacher-forced forward logits == step-by-step decode logits."""
    cfg = get_smoke_config(arch)
    if cfg.frontend != "none":
        cfg = cfg.replace(frontend="none", frontend_len=0)
    if cfg.is_moe:
        # capacity-based token dropping depends on how many tokens route
        # together; use a capacity that never drops so prefill == decode
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    key = jax.random.PRNGKey(1)
    params = model.init_params(cfg, key)
    Sd = 16
    toks = jax.random.randint(key, (B, Sd), 0, cfg.vocab_size)
    want = model.forward(cfg, params, toks)["logits"]
    cache = model.init_cache(cfg, B, Sd, jnp.float32)
    step = jax.jit(model.decode_step, static_argnums=0)
    outs = []
    for t in range(Sd):
        lg, cache = step(cfg, params, toks[:, t:t + 1], cache, jnp.int32(t))
        outs.append(lg)
    got = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(got, want, atol=5e-3)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_formula(arch):
    """Analytic param_count (used for 6ND roofline FLOPs) matches the real
    initialised tree to <1% (small bias/scale terms tolerated)."""
    cfg = get_smoke_config(arch)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    actual = sum(l.size for l in jax.tree.leaves(params))
    predicted = model.param_count(cfg)
    assert abs(actual - predicted) / actual < 0.01, (actual, predicted)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= 1 and balanced routing, most tokens keep all
    their expert slots."""
    from repro.models import moe as moe_lib
    cfg = get_smoke_config("granite_moe_3b_a800m").replace(
        capacity_factor=2.0)
    key = jax.random.PRNGKey(0)
    p = moe_lib.moe_params(key, cfg, jnp.float32)
    x = jax.random.normal(key, (4, 64, cfg.d_model))
    out, aux = moe_lib.moe_block(x, p, cfg)
    assert out.shape == x.shape
    assert float(aux) == pytest.approx(1.0, rel=0.5)  # ~1 when balanced


def test_sliding_window_blocks_long_range():
    cfg = get_smoke_config("yi_6b").replace(window=8)
    key = jax.random.PRNGKey(0)
    params = model.init_params(cfg, key)
    toks = jax.random.randint(key, (1, 32), 0, cfg.vocab_size)
    base = model.forward(cfg, params, toks)["logits"]
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    pert = model.forward(cfg, params, toks2)["logits"]
    # token 0 is outside the window of position 31 (31 - 0 >= 8 + margin)
    np.testing.assert_allclose(base[0, -1], pert[0, -1], atol=1e-4)
