"""Graph / combination-weight properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not available in this environment")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import network


@st.composite
def connected_graphs(draw, min_n=4, max_n=24):
    """Arbitrary connected graph: random spanning tree + random extra
    edges — far wider coverage than the geometric ensemble alone."""
    n = draw(st.integers(min_n, max_n))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    adj = np.zeros((n, n))
    for i in range(1, n):                      # spanning tree: connected
        j = int(rng.integers(0, i))
        adj[i, j] = adj[j, i] = 1.0
    for _ in range(draw(st.integers(0, 2 * n))):
        i, j = (int(v) for v in rng.integers(0, n, 2))
        if i != j:
            adj[i, j] = adj[j, i] = 1.0
    return adj


@st.composite
def arbitrary_graphs(draw, min_n=4, max_n=20):
    """Symmetric zero-diagonal graph, connected or not."""
    n = draw(st.integers(min_n, max_n))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    p = draw(st.floats(0.0, 0.6))
    u = np.triu(rng.random((n, n)) < p, 1).astype(float)
    return u + u.T


@settings(max_examples=10, deadline=None)
@given(st.integers(8, 40), st.integers(0, 1000))
def test_geometric_graph_connected_symmetric(n, seed):
    adj, pos = network.random_geometric_graph(n, seed=seed)
    a = np.asarray(adj)
    assert a.shape == (n, n)
    np.testing.assert_array_equal(a, a.T)
    assert np.all(np.diag(a) == 0)
    assert network._is_connected(a)


@settings(max_examples=10, deadline=None)
@given(st.integers(8, 30), st.integers(0, 1000))
def test_nearest_neighbor_weights_row_stochastic(n, seed):
    adj, _ = network.random_geometric_graph(n, seed=seed)
    W = np.asarray(network.nearest_neighbor_weights(adj))
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-6)
    assert np.all(W >= 0)
    # support = N_i u {i} only (Eq. 23 / 47)
    mask = np.asarray(adj) + np.eye(n)
    assert np.all(W[mask == 0] == 0)


@settings(max_examples=10, deadline=None)
@given(st.integers(8, 30), st.integers(0, 1000))
def test_metropolis_doubly_stochastic(n, seed):
    adj, _ = network.random_geometric_graph(n, seed=seed)
    W = np.asarray(network.metropolis_weights(adj))
    np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-6)
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(connected_graphs())
def test_metropolis_arbitrary_connected(adj):
    """Metropolis weights (Eq. 48) on ARBITRARY connected graphs — not
    just the geometric ensemble: symmetric, doubly stochastic,
    nonnegative, supported on N_i u {i} only."""
    W = np.asarray(network.metropolis_weights(jnp.asarray(adj)))
    np.testing.assert_allclose(W, W.T, atol=1e-6)
    np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-6)
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-6)
    assert np.all(W >= 0)
    mask = adj + np.eye(adj.shape[0])
    assert np.all(W[mask == 0] == 0)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 24), st.integers(0, 10_000), st.integers(0, 500),
       st.floats(0.0, 1.0))
def test_link_keep_matrix_symmetric_deterministic(n, seed, t, drop):
    key = jax.random.PRNGKey(seed)
    keep = np.asarray(network.link_keep_matrix(key, t, n, drop))
    np.testing.assert_array_equal(keep, keep.T)       # one coin per pair
    np.testing.assert_array_equal(np.diag(keep), 1.0)
    assert set(np.unique(keep)) <= {0.0, 1.0}
    again = np.asarray(network.link_keep_matrix(key, t, n, drop))
    np.testing.assert_array_equal(keep, again)        # deterministic (key,t)


@settings(max_examples=25, deadline=None)
@given(st.integers(3, 40), st.integers(0, 10_000), st.integers(0, 500),
       st.floats(0.0, 1.0))
def test_ring_link_keep_degree_bounds(n, seed, t, drop):
    key = jax.random.PRNGKey(seed)
    e = np.asarray(network.ring_link_keep(key, t, n, drop))
    assert e.shape == (n,)
    assert set(np.unique(e)) <= {0.0, 1.0}
    # effective degree of ring node i is e[i-1] + e[i]: never above the
    # nominal ring degree 2, never negative
    deg = np.roll(e, 1) + e
    assert np.all(deg <= 2) and np.all(deg >= 0)
    np.testing.assert_array_equal(
        e, np.asarray(network.ring_link_keep(key, t, n, drop)))


@settings(max_examples=25, deadline=None)
@given(arbitrary_graphs())
def test_algebraic_connectivity_iff_connected(adj):
    lam2 = network.algebraic_connectivity(jnp.asarray(adj))
    if network._is_connected(adj):
        assert lam2 > 1e-4
    else:
        assert abs(lam2) < 1e-4


def test_ring_graph():
    adj = np.asarray(network.ring_graph(6))
    assert adj.sum() == 12
    assert network.algebraic_connectivity(jnp.asarray(adj)) > 0


def test_consensus_contraction():
    """Row-stochastic diffusion must contract disagreement (the mechanism
    behind Eq. 27b): repeated averaging converges to consensus."""
    adj, _ = network.random_geometric_graph(12, seed=0)
    W = np.asarray(network.nearest_neighbor_weights(adj))
    x = np.random.default_rng(0).normal(size=(12, 5))
    for _ in range(400):
        x = W @ x
    assert np.abs(x - x.mean(0, keepdims=True)).max() < 1e-6


# ---------------------------------------------------------------------------
# Sparse representation properties
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(connected_graphs())
def test_sparse_graph_round_trip(adj):
    g = network.SparseGraph.from_dense(adj)
    np.testing.assert_array_equal(np.asarray(g.to_dense()), adj)
    n = adj.shape[0]
    assert g.n_undirected == int(adj.sum()) // 2
    assert g.senders.shape == g.receivers.shape == g.edge_id.shape
    np.testing.assert_array_equal(np.asarray(g.deg), adj.sum(1))
    # receiver-sorted (the segment_sum contract) and both directions of
    # an undirected link share one edge_id
    r = np.asarray(g.receivers)
    assert np.all(r[:-1] <= r[1:])
    ids = {}
    for s, rr, e in zip(np.asarray(g.senders), r, np.asarray(g.edge_id)):
        ids.setdefault(frozenset((int(s), int(rr))), set()).add(int(e))
    assert all(len(v) == 1 for v in ids.values())
    assert len(ids) == g.n_undirected


@settings(max_examples=25, deadline=None)
@given(connected_graphs())
def test_sparse_weights_match_dense_rows(adj):
    """sparse_{nearest_neighbor,metropolis}_weights scatter back to the
    exact dense Eq. 47 / Eq. 48 matrices."""
    g = network.SparseGraph.from_dense(adj)
    for dense_fn, sparse_fn in [
            (network.nearest_neighbor_weights,
             network.sparse_nearest_neighbor_weights),
            (network.metropolis_weights,
             network.sparse_metropolis_weights)]:
        W = np.asarray(dense_fn(jnp.asarray(adj)))
        sw = sparse_fn(g)
        dense = np.diag(np.asarray(sw.w_self, np.float64))
        dense[np.asarray(sw.graph.senders),
              np.asarray(sw.graph.receivers)] = 0.0
        # scatter w_edge at (receiver, sender): row i holds node i's weights
        dense[np.asarray(sw.graph.receivers),
              np.asarray(sw.graph.senders)] = np.asarray(sw.w_edge)
        np.testing.assert_allclose(dense, W, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(3, 40), st.integers(0, 10_000), st.integers(0, 500),
       st.floats(0.0, 1.0))
def test_sparse_link_keep_matches_ring_coins(n, seed, t, drop):
    """On a ring, sparse_link_keep IS ring_link_keep bit-for-bit: link k
    of SparseGraph.ring is (k, k+1 mod N), the ring coin order."""
    key = jax.random.PRNGKey(seed)
    np.testing.assert_array_equal(
        np.asarray(network.sparse_link_keep(key, t, n, drop)),
        np.asarray(network.ring_link_keep(key, t, n, drop)))


@settings(max_examples=25, deadline=None)
@given(arbitrary_graphs())
def test_edges_connected_matches_dense(adj):
    u, v = np.nonzero(np.triu(adj, 1))
    assert network._edges_connected(u, v, adj.shape[0]) == \
        bool(network._is_connected(adj))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 60), st.data())
def test_two_level_partition_properties(n, data):
    g = data.draw(st.integers(1, n))
    r = data.draw(st.integers(1, g))
    gateway_of, region_of = network.two_level_partition(n, g, r)
    gw, rg = np.asarray(gateway_of), np.asarray(region_of)
    assert gw.shape == (n,) and rg.shape == (g,)
    # surjective and balanced at both levels (sizes differ by <= 1)
    for ids, count in [(gw, g), (rg, r)]:
        sizes = np.bincount(ids, minlength=count)
        assert sizes.min() >= 1
        assert sizes.max() - sizes.min() <= 1
