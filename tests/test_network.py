"""Graph / combination-weight properties."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not available in this environment")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import network


@settings(max_examples=10, deadline=None)
@given(st.integers(8, 40), st.integers(0, 1000))
def test_geometric_graph_connected_symmetric(n, seed):
    adj, pos = network.random_geometric_graph(n, seed=seed)
    a = np.asarray(adj)
    assert a.shape == (n, n)
    np.testing.assert_array_equal(a, a.T)
    assert np.all(np.diag(a) == 0)
    assert network._is_connected(a)


@settings(max_examples=10, deadline=None)
@given(st.integers(8, 30), st.integers(0, 1000))
def test_nearest_neighbor_weights_row_stochastic(n, seed):
    adj, _ = network.random_geometric_graph(n, seed=seed)
    W = np.asarray(network.nearest_neighbor_weights(adj))
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-6)
    assert np.all(W >= 0)
    # support = N_i u {i} only (Eq. 23 / 47)
    mask = np.asarray(adj) + np.eye(n)
    assert np.all(W[mask == 0] == 0)


@settings(max_examples=10, deadline=None)
@given(st.integers(8, 30), st.integers(0, 1000))
def test_metropolis_doubly_stochastic(n, seed):
    adj, _ = network.random_geometric_graph(n, seed=seed)
    W = np.asarray(network.metropolis_weights(adj))
    np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-6)
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-6)


def test_ring_graph():
    adj = np.asarray(network.ring_graph(6))
    assert adj.sum() == 12
    assert network.algebraic_connectivity(jnp.asarray(adj)) > 0


def test_consensus_contraction():
    """Row-stochastic diffusion must contract disagreement (the mechanism
    behind Eq. 27b): repeated averaging converges to consensus."""
    adj, _ = network.random_geometric_graph(12, seed=0)
    W = np.asarray(network.nearest_neighbor_weights(adj))
    x = np.random.default_rng(0).normal(size=(12, 5))
    for _ in range(400):
        x = W @ x
    assert np.abs(x - x.mean(0, keepdims=True)).max() < 1e-6
