# NOTE: deliberately NO --xla_force_host_platform_device_count here — smoke
# tests and benches must see the single real CPU device.  Tests that need a
# multi-device mesh spawn a subprocess with XLA_FLAGS set (see helpers).
import os
import subprocess
import sys

import pytest


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(code: str, n_devices: int = 4) -> str:
    """Run python `code` in a fresh process with N host-platform devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=REPO,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess
