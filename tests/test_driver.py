"""Continuous-batching driver tests (serving/driver.py).

The two acceptance properties:

* **no mid-flight recompilation** — sessions join and leave a
  partially-full fixed-capacity fleet and the slice function traces
  exactly once per group configuration (`DriverStats.compiles`);
* **driver scheduling is invisible to the numerics** — a session's
  trajectory is a pure function of its own absolute `t` (the engine's
  resumability contract), so driver-scheduled sessions are bit-equal to
  a solo `vb_run` of the same length for elementwise-combine topologies
  (Ring/Fusion/Isolated), and bit-INVARIANT to the arrival/eviction
  pattern for every topology (matmul combines differ from the solo
  single-session GEMM shape by ~1 ulp — see docs/continuous-batching.md
  — so Diffusion/ADMM get a 1e-9 closeness check instead).

Plus the scheduler mechanics: arrival staggering, fleet-full queueing,
the background thread, background checkpoint writes, eviction lifecycle
edges, and the LM engine sharing the same primitives.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.core import engine, expfam, network
from repro.core import model as model_lib
from repro.data import synthetic
from repro.serving import driver as drv
from repro.serving.vb_service import VBRequest, VBService


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


K, D, N_NODES = 3, 2, 8


@pytest.fixture(scope="module")
def setup():
    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
    mdl = model_lib.GMMModel(prior, K, D)
    adj, _ = network.random_geometric_graph(N_NODES, seed=4)
    W = network.nearest_neighbor_weights(adj)
    datasets = [synthetic.paper_synthetic(n_nodes=N_NODES, n_per_node=10,
                                          seed=s) for s in range(5)]
    return mdl, adj, W, datasets


# ---------------------------------------------------------------------------
# Scheduling primitives
# ---------------------------------------------------------------------------
def test_arrival_queue_fifo_and_readiness():
    q = drv.ArrivalQueue()
    q.push("a", 0)
    q.push("b", 2)
    q.push("c", 0)
    assert len(q) == 3 and q.next_arrival() == 0
    ready = q.pop_ready(0)
    assert [e[2] for e in ready] == ["a", "c"]     # FIFO within a tick
    assert q.pop_ready(1) == []
    q.push_entry(ready[0])                          # requeue keeps position
    assert [e[2] for e in q.pop_ready(2)] == ["a", "b"]


def test_slot_table_reuse_lowest_first():
    t = drv.SlotTable(3)
    assert [t.alloc(r) for r in "xyz"] == [0, 1, 2]
    assert t.alloc("w") is None and t.n_occupied == 3
    assert t.free(1) == "y"
    assert t.alloc("w") == 1                        # lowest free slot
    assert sorted(t.occupied()) == [(0, "x"), (1, "w"), (2, "z")]
    t.grow(5)
    assert t.capacity == 5 and t.alloc("v") == 3


# ---------------------------------------------------------------------------
# Acceptance: join/leave without recompilation, bit-equal to solo
# ---------------------------------------------------------------------------
def test_join_leave_no_recompile_and_bit_equal_solo(setup):
    """5 ring sessions with mixed budgets flow through a 3-slot fleet:
    the slice fn traces ONCE, and every session's final phi is
    bit-identical to a solo vb_run of its own length."""
    mdl, adj, W, datasets = setup
    topo = engine.RingDiffusion()
    budgets = [16, 24, 40, 16, 24]
    svc = VBService(slice_iters=8, max_fleet=3)
    rids = [svc.submit(VBRequest(model=mdl, data=(d.x, d.mask),
                                 topology=topo, n_iters=n),
                       arrive_at=2 if i == 4 else 0)
            for i, (d, n) in enumerate(zip(datasets, budgets))]
    st = svc.stats()
    assert st.admitted == 3 and st.queue_depth == 2   # fleet full
    out = svc.run()
    st = svc.stats()
    assert st.compiles == 1, st                        # ONE trace, ever
    assert st.admitted == 5 and st.evicted == 5
    assert st.queue_depth == 0 and st.active == 0
    for d, n, rid in zip(datasets, budgets, rids):
        s = out[rid]
        assert s.done and s.evicted and s.t == n
        solo = engine.run_vb(mdl, (d.x, d.mask), topo, n_iters=n)
        np.testing.assert_array_equal(np.asarray(solo.phi),
                                      np.asarray(s.phi), err_msg=rid)


def test_one_trace_per_group_config(setup):
    """Two topology groups while sessions join/leave: one trace each."""
    mdl, adj, W, datasets = setup
    svc = VBService(slice_iters=6, max_fleet=2)
    for i, d in enumerate(datasets[:4]):
        svc.submit(VBRequest(
            model=mdl, data=(d.x, d.mask),
            topology=engine.RingDiffusion() if i % 2 else
            engine.FusionCenter(),
            n_iters=10 + 6 * i,
            schedule=engine.Schedule() if i % 2 else engine.ONE_SHOT))
    svc.run()
    assert len(svc._groups) == 2
    assert svc.stats().compiles == 2, svc.stats()


def test_scheduling_invariance_matmul_topologies(setup):
    """Diffusion/ADMM (matmul combines): the scheduling QUANTUM is
    bit-invisible — the same admission into the same slots driven with
    different slice lengths (different eviction boundaries, with slots
    going idle at different ticks) gives bit-identical phi — and the
    result stays 1e-9-close to solo.  (Literal bit-equality to solo is a
    slot-position property of the batched GEMM: remainder-column
    micro-kernels differ by global column index, a ~1-ulp/step effect —
    see docs/continuous-batching.md.  Elementwise-combine topologies ARE
    bit-equal to solo: test_join_leave_no_recompile_and_bit_equal_solo.)"""
    mdl, adj, W, datasets = setup
    budgets = [12, 18, 24]
    for topo_fn in (lambda: engine.Diffusion(W),
                    lambda: engine.ADMMConsensus(adj, adaptive_rho=True)):
        runs = []
        for slice_iters in (6, 9):
            svc = VBService(slice_iters=slice_iters, max_fleet=3)
            topo = topo_fn()
            rids = [svc.submit(VBRequest(model=mdl, data=(d.x, d.mask),
                                         topology=topo, n_iters=n))
                    for d, n in zip(datasets, budgets)]
            out = svc.run()
            assert svc.stats().compiles == 1
            runs.append([np.asarray(out[r].phi) for r in rids])
        for a, b in zip(*runs):
            np.testing.assert_array_equal(a, b)
        for d, n, a in zip(datasets, budgets, runs[0]):
            solo = engine.run_vb(mdl, (d.x, d.mask), topo_fn(), n_iters=n)
            assert float(jnp.max(jnp.abs(solo.phi - a))) < 1e-9


# ---------------------------------------------------------------------------
# Eviction lifecycle edges (what VBService must preserve forever)
# ---------------------------------------------------------------------------
def test_extend_budget_on_converged_evicted_session(setup):
    mdl, adj, W, datasets = setup
    d = datasets[0]
    svc = VBService(slice_iters=5, max_fleet=2)
    rid = svc.submit(VBRequest(model=mdl, data=(d.x, d.mask),
                               topology=engine.RingDiffusion(),
                               n_iters=400, tol=1e-2))
    out = svc.run()
    assert out[rid].converged and out[rid].evicted
    t_conv = out[rid].t
    svc.extend_budget(rid, 10)          # un-latch + re-queue + re-admit
    st = svc.status(rid)
    assert not st.converged and not st.done and st.budget == 410
    out = svc.run()
    # converges again at the same delta (state was frozen bit-exactly)
    assert out[rid].converged and out[rid].t >= t_conv


def test_push_data_unlatches_finished_session(setup):
    mdl, adj, W, datasets = setup
    d = datasets[1]
    mask = d.mask.at[:, -4:].set(0.0)           # room for arrivals
    svc = VBService(slice_iters=5, max_fleet=2)
    rid = svc.submit(VBRequest(model=mdl, data=(d.x, mask),
                               topology=engine.RingDiffusion(),
                               n_iters=300, tol=1e-2))
    out = svc.run()
    assert out[rid].converged and out[rid].evicted
    phi_before = np.asarray(out[rid].phi)
    svc.push_data(rid, node=1,
                  points=np.random.default_rng(0).normal(size=(3, D)))
    st = svc.status(rid)
    assert not st.converged and not st.done      # back in the queue
    out = svc.run()
    assert out[rid].done
    assert not np.allclose(phi_before, np.asarray(out[rid].phi))


def test_status_and_save_on_evicted_slot(setup, tmp_path):
    """An evicted session stays fully observable and checkpointable,
    and its slot is already recycled by a later arrival."""
    mdl, adj, W, datasets = setup
    svc = VBService(slice_iters=4, max_fleet=1)
    topo = engine.RingDiffusion()
    r0 = svc.submit(VBRequest(model=mdl, data=(datasets[0].x,
                                               datasets[0].mask),
                              topology=topo, n_iters=8))
    r1 = svc.submit(VBRequest(model=mdl, data=(datasets[1].x,
                                               datasets[1].mask),
                              topology=topo, n_iters=8))
    svc.step_slice()
    svc.step_slice()                    # r0 done+evicted, r1 admitted
    st0 = svc.status(r0)
    assert st0.evicted and st0.done and st0.t == 8
    path = svc.save_session(r0, os.path.join(tmp_path, "evicted.npz"))
    svc_b = VBService(slice_iters=4)
    rb = svc_b.submit(VBRequest(model=mdl,
                                data=(datasets[0].x, datasets[0].mask),
                                topology=topo, n_iters=8),
                      restore_from=path)
    stb = svc_b.status(rb)              # restored-finished: retired as-is
    assert stb.done and stb.t == 8 and stb.evicted
    np.testing.assert_array_equal(np.asarray(st0.phi), np.asarray(stb.phi))
    out = svc.run()
    assert out[r1].done and out[r1].t == 8


def test_async_checkpoints_and_background_thread(setup, tmp_path):
    mdl, adj, W, datasets = setup
    ckpt_dir = os.path.join(tmp_path, "auto")
    svc = VBService(slice_iters=5, max_fleet=2, ckpt_dir=ckpt_dir,
                    ckpt_every=1)
    svc.start()                         # background scheduler thread
    rids = [svc.submit(VBRequest(model=mdl, data=(d.x, d.mask),
                                 topology=engine.RingDiffusion(),
                                 n_iters=20)) for d in datasets[:3]]
    svc.drain()
    svc.stop()
    stats = svc.stats()
    assert stats.checkpoints > 0
    for rid in rids:
        st = svc.status(rid)
        assert st.done and st.t == 20 and st.latency_s > 0.0
        assert os.path.exists(os.path.join(ckpt_dir, f"{rid}.npz"))
    # an explicitly-async save lands after flush and restores bit-exactly
    path = svc.save_session(rids[0], os.path.join(tmp_path, "a.npz"),
                            wait=False)
    svc.driver.flush_checkpoints()
    restored = ckpt.restore(path, svc.driver._finished[rids[0]]["record"])
    np.testing.assert_array_equal(np.asarray(restored["phi"]),
                                  np.asarray(svc.status(rids[0]).phi))


def test_padding_waste_accounting(setup):
    """ROADMAP item 1 groundwork: a half-empty fixed fleet reports its
    idle-masked slot fraction."""
    mdl, adj, W, datasets = setup
    svc = VBService(slice_iters=5, max_fleet=4)
    d = datasets[0]
    svc.submit(VBRequest(model=mdl, data=(d.x, d.mask),
                         topology=engine.RingDiffusion(), n_iters=10))
    svc.run()
    st = svc.stats()
    assert st.occupancy == pytest.approx(0.25)      # 1 of 4 slots working
    assert st.padding_waste == pytest.approx(0.75)
    assert st.padding_waste == pytest.approx(1.0 - st.occupancy)
