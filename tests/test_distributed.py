"""Mesh-parallel faithfulness: sharded runners == single-array runners.

These need >1 device, so they run in a subprocess with host-platform
devices (conftest.run_subprocess) — the main pytest process keeps 1 device.
"""
import pytest


CODE_FAITHFUL = r"""
import jax
from repro.core import expfam
expfam.enable_x64()
import jax.numpy as jnp
from repro.core import algorithms, distributed, network
from repro.data import synthetic

data = synthetic.paper_synthetic(n_nodes=8, n_per_node=40, seed=1)
K, D = 3, 2
prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
adj, _ = network.random_geometric_graph(8, seed=3)
W = network.nearest_neighbor_weights(adj)
mesh = jax.make_mesh((4,), ("data",))

phi = distributed.run_dsvb_sharded(mesh, data.x, data.mask, W, prior,
                                   n_iters=40, K=K, D=D)
ref = algorithms.run_dsvb(data.x, data.mask, W, prior, n_iters=40, K=K, D=D)
err = float(jnp.max(jnp.abs(phi - ref.phi)))
assert err < 1e-8, f"dsvb sharded err {err}"

phi = distributed.run_admm_sharded(mesh, data.x, data.mask, adj, prior,
                                   n_iters=40, K=K, D=D)
ref = algorithms.run_dvb_admm(data.x, data.mask, adj, prior, n_iters=40,
                              K=K, D=D)
err = float(jnp.max(jnp.abs(phi - ref.phi)))
assert err < 1e-8, f"admm sharded err {err}"

phi = distributed.run_dsvb_ring_sharded(mesh, data.x, data.mask, prior,
                                        n_iters=40, K=K, D=D)
Wr = network.nearest_neighbor_weights(network.ring_graph(8))
ref = algorithms.run_dsvb(data.x, data.mask, Wr, prior, n_iters=40, K=K, D=D)
err = float(jnp.max(jnp.abs(phi - ref.phi)))
assert err < 1e-8, f"ring sharded err {err}"
print("OK")
"""


def test_sharded_runners_match_dense(subproc):
    out = subproc(CODE_FAITHFUL, n_devices=4)
    assert "OK" in out


CODE_CONSENSUS = r"""
import jax, jax.numpy as jnp, numpy as np, functools
from jax.sharding import PartitionSpec as P
from repro.dist import compat
from repro.optim import consensus
from repro.core import network

mesh = jax.make_mesh((8,), ("data",))
n = 8
params = {"w": jnp.arange(8.0 * 3).reshape(8, 3),
          "b": jnp.linspace(0, 1, 8)[:, None] * jnp.ones((8, 2))}

@functools.partial(compat.shard_map, mesh=mesh, in_specs=P("data"),
                   out_specs=P("data"))
def combine(p):
    local = jax.tree.map(lambda a: a[0], p)
    out = consensus.diffusion_combine(local, "data")
    return jax.tree.map(lambda a: a[None], out)

got = combine(params)
W = np.asarray(network.nearest_neighbor_weights(network.ring_graph(8)))
for k in params:
    want = W @ np.asarray(params[k])
    np.testing.assert_allclose(np.asarray(got[k]), want, atol=1e-6)

# ADMM duals: lambda stays antisymmetric-aggregated => sum_i lambda_i == 0
@functools.partial(compat.shard_map, mesh=mesh,
                   in_specs=(P("data"), P("data")),
                   out_specs=(P("data"), P("data")))
def admm(p_star, p_prev):
    ps = jax.tree.map(lambda a: a[0], p_star)
    pp = jax.tree.map(lambda a: a[0], p_prev)
    duals = consensus.admm_init_duals(ps)
    pn, dn = consensus.admm_step(ps, pp, duals, "data", rho=0.5, kappa=1.0)
    return (jax.tree.map(lambda a: a[None], pn),
            jax.tree.map(lambda a: a[None], dn))

pn, dn = admm(params, params)
for k in params:
    s = np.asarray(dn[k]).sum(0)
    np.testing.assert_allclose(s, 0.0, atol=1e-5)
print("OK")
"""


def test_consensus_optim_ring_math(subproc):
    out = subproc(CODE_CONSENSUS, n_devices=8)
    assert "OK" in out


CODE_TRAIN_MODES = r"""
import jax, jax.numpy as jnp
from repro.configs.base import ModelConfig
from repro.dist import compat
from repro.training import train_step as ts

cfg = ModelConfig(name="tiny", arch_type="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  param_dtype="float32", compute_dtype="float32")
mesh = jax.make_mesh((4, 2), ("data", "model"))
key = jax.random.PRNGKey(0)
batch = {"tokens": jax.random.randint(key, (8, 32), 0, 128)}
losses = {}
with compat.use_mesh(mesh):
    for mode in ["allreduce", "diffusion", "admm"]:
        axis = "data" if mode != "allreduce" else None
        state = ts.init_state(cfg, key, dp_mode=mode, n_replicas=4)
        shd = ts.state_shardings(state, cfg, mesh, dp_mode=mode,
                                 consensus_axis=axis)
        state = jax.device_put(state, shd)
        b = jax.device_put(batch, ts.batch_sharding(mesh))
        fn = jax.jit(ts.make_train_step(cfg, mesh, dp_mode=mode,
                                        consensus_axis=axis))
        for _ in range(3):
            state, m = fn(state, b)
        losses[mode] = float(m["loss"])
        if mode != "allreduce":
            assert float(m["consensus_residual"]) < 1e-6  # identical replicas
# same data, same init => initial dynamics nearly identical across modes
assert abs(losses["allreduce"] - losses["diffusion"]) < 0.05
assert abs(losses["allreduce"] - losses["admm"]) < 0.05
print("OK", losses)
"""


def test_train_modes_on_mesh(subproc):
    out = subproc(CODE_TRAIN_MODES, n_devices=8)
    assert "OK" in out


CODE_ADAPTIVE_RHO = r"""
import jax, jax.numpy as jnp
from repro.configs.base import ModelConfig
from repro.dist import compat
from repro.training import train_step as ts

cfg = ModelConfig(name="tiny", arch_type="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  param_dtype="float32", compute_dtype="float32")
mesh = jax.make_mesh((4,), ("data",))
key = jax.random.PRNGKey(0)
batch = {"tokens": jax.random.randint(key, (8, 32), 0, 128)}
# rho_mu < 1 makes the balancing rule fire on ANY nonzero residual
# imbalance, so the adaptation is observable within a few steps
hyper = ts.TrainHyper(adaptive_rho=True, rho_mu=0.5, rho=0.5)
with compat.use_mesh(mesh):
    state = ts.init_state(cfg, key, dp_mode="admm", n_replicas=4,
                          hyper=hyper)
    assert state.rho is not None and float(state.rho) == 0.5
    shd = ts.state_shardings(state, cfg, mesh, dp_mode="admm",
                             consensus_axis="data")
    state = jax.device_put(state, shd)
    b = jax.device_put(batch, ts.batch_sharding(mesh))
    fn = jax.jit(ts.make_train_step(cfg, mesh, dp_mode="admm",
                                    consensus_axis="data", hyper=hyper))
    rhos = [float(state.rho)]
    for _ in range(4):
        state, m = fn(state, b)
        rhos.append(float(state.rho))
        assert float(m["admm_rho"]) == rhos[-1]
# rho is DYNAMIC state: the balancing rule moved it across steps
assert any(r != rhos[0] for r in rhos[1:]), rhos

# without adaptive_rho the dynamic rho must stay put
with compat.use_mesh(mesh):
    hyper2 = ts.TrainHyper(rho=0.7)
    state = ts.init_state(cfg, key, dp_mode="admm", n_replicas=4,
                          hyper=hyper2)
    shd = ts.state_shardings(state, cfg, mesh, dp_mode="admm",
                             consensus_axis="data")
    state = jax.device_put(state, shd)
    b = jax.device_put(batch, ts.batch_sharding(mesh))
    fn = jax.jit(ts.make_train_step(cfg, mesh, dp_mode="admm",
                                    consensus_axis="data", hyper=hyper2))
    rho0 = float(state.rho)
    for _ in range(3):
        state, m = fn(state, b)
    assert float(state.rho) == rho0, (float(state.rho), rho0)
# non-ADMM modes carry no rho state
state = ts.init_state(cfg, key, dp_mode="diffusion", n_replicas=4)
assert state.rho is None
print("OK", rhos)
"""


def test_admm_adaptive_rho_is_dynamic_state(subproc):
    out = subproc(CODE_ADAPTIVE_RHO, n_devices=4)
    assert "OK" in out


CODE_SHARDING_RULES = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.dist import sharding

mesh = jax.make_mesh((4, 2), ("data", "model"))
# model axis picks a divisible dim; fsdp picks another
s = sharding.spec_for((64, 32), mesh, fsdp=True)
assert "model" in s and "data" in s, s
# indivisible dims replicate
s = sharding.spec_for((7, 5), mesh, fsdp=True)
assert s == P(None, None), s
# scan axis never sharded
s = sharding.spec_for((10, 64, 32), mesh, fsdp=False, n_scan_axes=1)
assert s[0] is None, s
# replica axis leads
s = sharding.spec_for((4, 64, 32), mesh, fsdp=False, replica_axis="data")
assert s[0] == "data", s
print("OK")
"""


def test_sharding_rules(subproc):
    out = subproc(CODE_SHARDING_RULES, n_devices=8)
    assert "OK" in out
