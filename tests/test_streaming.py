"""Streaming minibatch stochastic VB + time-varying networks.

Pins the two contracts of the streaming subsystem (data/stream.py +
engine.run_vb(minibatch=) + the topologies' link schedules):

1. *Full-batch degeneracy is bit-exact*: `MinibatchSpec(batch_size =
   n_per_node)` reproduces the full-batch run bit-for-bit on all five
   estimators and both executors — streaming off the golden path costs
   nothing, not even a ulp.
2. *Minibatch natural gradients are unbiased*: the GMM natural parameters
   are linear in the sufficient statistics, and the scaled-mask rescaling
   (n_i/|B_i|) makes the minibatch statistics unbiased, so the
   seed-averaged minibatch phi* must converge to the full-batch phi*.

Plus the time-varying-network laws: all-links-down == Isolated,
keep-everything == static, determinism in the link seed, effective
connectivity observable via ConsensusDiagnostics.link_frac, and
executor equivalence with both features on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms, engine, expfam, linreg, network
from repro.core import model as model_lib
from repro.data import stream, synthetic


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


K, D, N_NODES, N_PER, N_ITERS = 3, 2, 8, 20, 15


@pytest.fixture(scope="module")
def setup():
    data = synthetic.paper_synthetic(n_nodes=N_NODES, n_per_node=N_PER,
                                     seed=2)
    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
    adj, _ = network.random_geometric_graph(N_NODES, seed=4)
    W = network.nearest_neighbor_weights(adj)
    init_q = algorithms._perturbed_init(prior, data.x, jax.random.PRNGKey(3))
    phi0 = jnp.broadcast_to(expfam.pack_natural(init_q),
                            (N_NODES, expfam.flat_dim(K, D)))
    mdl = model_lib.GMMModel(prior, K, D)
    return data, prior, adj, W, phi0, mdl


def _estimators(adj, W):
    return [
        ("cvb", engine.FusionCenter(), dict(schedule=engine.ONE_SHOT)),
        ("noncoop", engine.Isolated(),
         dict(schedule=engine.ONE_SHOT, replication=1.0)),
        ("nsg_dvb", engine.Diffusion(W), dict(schedule=engine.ONE_SHOT)),
        ("dsvb", engine.Diffusion(W), dict(schedule=engine.Schedule())),
        ("dvb_admm", engine.ADMMConsensus(adj), {}),
    ]


# ---------------------------------------------------------------------------
# 1. bit-exact full-batch degeneracy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("est", ["cvb", "noncoop", "nsg_dvb", "dsvb",
                                 "dvb_admm"])
def test_full_batch_spec_is_bit_identical(setup, est):
    data, prior, adj, W, phi0, mdl = setup
    name, topo, kw = next(e for e in _estimators(adj, W) if e[0] == est)
    a = engine.run_vb(mdl, (data.x, data.mask), topo, n_iters=N_ITERS,
                      init_phi=phi0, **kw)
    b = engine.run_vb(mdl, (data.x, data.mask), topo, n_iters=N_ITERS,
                      init_phi=phi0,
                      minibatch=stream.MinibatchSpec(batch_size=N_PER),
                      **kw)
    np.testing.assert_array_equal(np.asarray(a.phi), np.asarray(b.phi))
    np.testing.assert_array_equal(np.asarray(a.kl_nodes),
                                  np.asarray(b.kl_nodes))


def test_full_batch_degeneracy_is_identity_gather(setup):
    """With batch_size covering the node, the sorted selection is the
    identity permutation and the scaled mask IS the base mask."""
    data, *_ = setup
    keys = stream.node_keys(N_NODES, seed=7)
    idx, mb = stream.minibatch_select(keys, data.mask, jnp.asarray(5),
                                      N_PER)
    np.testing.assert_array_equal(
        np.asarray(idx), np.broadcast_to(np.arange(N_PER), (N_NODES, N_PER)))
    np.testing.assert_array_equal(np.asarray(mb), np.asarray(data.mask))


# ---------------------------------------------------------------------------
# 2. unbiasedness of the stochastic natural-gradient direction
# ---------------------------------------------------------------------------
def test_minibatch_phi_star_is_unbiased(setup):
    """E_seeds[phi*_minibatch] -> phi*_full: the natural parameters are
    linear in the sufficient statistics and the n_i/|B| mask scaling makes
    the statistics unbiased under without-replacement sampling.  The
    seed-averaged deviation must sit inside the Monte-Carlo confidence
    band (5 standard errors) on EVERY coordinate — a missing or wrong
    rescale (e.g. forgetting n_i/|B|) shifts coordinates by O(phi*),
    hundreds of standard errors."""
    data, prior, adj, W, phi0, mdl = setup
    rep = float(N_NODES)
    full = np.asarray(mdl.local_optimum((data.x, data.mask), phi0, rep))

    B, n_seeds = 10, 400
    acc = jnp.zeros_like(jnp.asarray(full))
    acc2 = jnp.zeros_like(acc)

    @jax.jit
    def one(seed):
        keys = stream.node_keys(N_NODES, seed)
        idx, mb = stream.minibatch_select(keys, data.mask, jnp.asarray(0), B)
        data_t = mdl.take_minibatch((data.x, data.mask), idx, mb)
        return mdl.local_optimum(data_t, phi0, rep)

    for s in range(n_seeds):
        p = one(s)
        acc = acc + p
        acc2 = acc2 + p * p
    mean_mb = np.asarray(acc) / n_seeds
    var = np.maximum(np.asarray(acc2) / n_seeds - mean_mb ** 2, 0.0)
    se = np.sqrt(var / n_seeds)
    dev = np.abs(mean_mb - full)
    # 5-sigma band, plus a tiny absolute floor for zero-variance coords
    assert np.all(dev <= 5.0 * se + 1e-9 * (np.abs(full) + 1.0)), \
        float(np.max(dev / (se + 1e-12)))
    # sanity: the band itself is tight relative to phi* on average, so the
    # check above has teeth
    assert np.median(se / (np.abs(full) + 1.0)) < 0.05


def test_selection_scaling_on_ragged_nodes():
    """Ragged nodes under epoch reshuffling: windows of one epoch cover
    the sample slots (exactly once when B divides the capacity T), indices
    are sorted, and every selected valid point carries the constant
    unbiased weight T/B — a slot lands in a window with probability B/T,
    so T/B is the importance weight that keeps the statistics unbiased
    even for windows that hit few (or zero) valid points."""
    n, T, B = 5, 12, 4
    n_chunks = T // B
    mask = np.zeros((n, T))
    n_valid = [3, 8, 12, 1, 10]
    for i, v in enumerate(n_valid):
        mask[i, :v] = 1.0
    mask = jnp.asarray(mask)
    keys = stream.node_keys(n, seed=1)
    seen = [[] for _ in range(n)]
    for t in range(n_chunks):                     # one full epoch
        idx, mb = stream.minibatch_select(keys, mask, jnp.asarray(t), B)
        assert idx.shape == (n, B) and mb.shape == (n, B)
        mb_np, idx_np = np.asarray(mb), np.asarray(idx)
        for i, v in enumerate(n_valid):
            assert (np.diff(idx_np[i]) >= 0).all()          # sorted
            picked = idx_np[i][mb_np[i] > 0]
            assert (picked < v).all()                       # valid only
            np.testing.assert_allclose(mb_np[i][mb_np[i] > 0], T / B)
            seen[i].extend(idx_np[i].tolist())
    for i, v in enumerate(n_valid):                # epoch = exact cover
        assert sorted(seen[i]) == list(range(T))
    # B does not divide T: the wrapped windows still cover every slot at
    # least once per epoch (overlap instead of a silently-dropped tail)
    B2 = 5
    n_chunks2 = -(-T // B2)
    seen2 = set()
    for t in range(n_chunks2):
        idx, _ = stream.minibatch_select(keys, mask, jnp.asarray(t), B2)
        seen2.update(np.asarray(idx)[0].tolist())
    assert seen2 == set(range(T))


def test_minibatch_determinism_and_variation(setup):
    data, prior, adj, W, phi0, mdl = setup
    spec = stream.MinibatchSpec(batch_size=6, seed=11)
    kw = dict(n_iters=N_ITERS, init_phi=phi0, schedule=engine.Schedule())
    a = engine.run_vb(mdl, (data.x, data.mask), engine.Diffusion(W),
                      minibatch=spec, **kw)
    b = engine.run_vb(mdl, (data.x, data.mask), engine.Diffusion(W),
                      minibatch=spec, **kw)
    np.testing.assert_array_equal(np.asarray(a.phi), np.asarray(b.phi))
    c = engine.run_vb(mdl, (data.x, data.mask), engine.Diffusion(W),
                      minibatch=stream.MinibatchSpec(batch_size=6, seed=12),
                      **kw)
    assert float(jnp.max(jnp.abs(a.phi - c.phi))) > 0.0
    # successive iterations draw different batches
    keys = stream.node_keys(N_NODES, 11)
    i0, _ = stream.minibatch_select(keys, data.mask, jnp.asarray(0), 6)
    i1, _ = stream.minibatch_select(keys, data.mask, jnp.asarray(1), 6)
    assert bool(jnp.any(i0 != i1))


def test_minibatch_api_validation(setup):
    data, prior, adj, W, phi0, mdl = setup
    with pytest.raises(ValueError, match="batch_size"):
        engine.run_vb(mdl, (data.x, data.mask), engine.Diffusion(W),
                      n_iters=2, minibatch=stream.MinibatchSpec(0))
    # LinRegModel streams raw data but refuses a precomputed phi* stack
    lr = model_lib.LinRegModel(linreg.prior(2))
    phi_star = jnp.stack([lr.init_phi() + 1.0, lr.init_phi() - 1.0])
    with pytest.raises(ValueError, match="phi\\* stack"):
        engine.run_vb(lr, phi_star, engine.FusionCenter(), n_iters=2,
                      schedule=engine.ONE_SHOT,
                      minibatch=stream.MinibatchSpec(4))


def test_linreg_streaming_full_batch_parity():
    rng = np.random.default_rng(1)
    Dl, n, ni = 3, 6, 15
    X = jnp.asarray(rng.normal(size=(n, ni, Dl)))
    y = jnp.asarray(X @ rng.normal(size=Dl) + rng.normal(size=(n, ni)) * 0.3)
    mask = jnp.ones((n, ni))
    lr = model_lib.LinRegModel(linreg.prior(Dl))
    a = engine.run_vb(lr, (X, y, mask), engine.FusionCenter(), n_iters=5,
                      schedule=engine.ONE_SHOT)
    b = engine.run_vb(lr, (X, y, mask), engine.FusionCenter(), n_iters=5,
                      schedule=engine.ONE_SHOT,
                      minibatch=stream.MinibatchSpec(batch_size=ni))
    np.testing.assert_array_equal(np.asarray(a.phi), np.asarray(b.phi))
    c = engine.run_vb(lr, (X, y, mask), engine.FusionCenter(), n_iters=5,
                      schedule=engine.ONE_SHOT,
                      minibatch=stream.MinibatchSpec(batch_size=5))
    assert bool(jnp.all(jnp.isfinite(c.phi)))


# ---------------------------------------------------------------------------
# 3. time-varying networks
# ---------------------------------------------------------------------------
def test_all_links_down_is_isolated(setup):
    """link_drop=1.0 (or an identity keep mask) disconnects everyone:
    diffusion renormalises to the identity combine, ADMM's neighbour sums
    and degrees vanish."""
    data, prior, adj, W, phi0, mdl = setup
    kw = dict(n_iters=N_ITERS, init_phi=phi0, schedule=engine.Schedule())
    iso = engine.run_vb(mdl, (data.x, data.mask), engine.Isolated(), **kw)
    dead = engine.run_vb(mdl, (data.x, data.mask),
                         engine.Diffusion(W, link_drop=1.0), **kw)
    np.testing.assert_allclose(np.asarray(dead.phi), np.asarray(iso.phi),
                               rtol=1e-12, atol=1e-12)
    ring_dead = engine.run_vb(
        mdl, (data.x, data.mask),
        engine.RingDiffusion(link_mask_fn=lambda t: jnp.zeros(N_NODES)),
        **kw)
    np.testing.assert_allclose(np.asarray(ring_dead.phi),
                               np.asarray(iso.phi), rtol=1e-12, atol=1e-12)
    admm_dead = engine.run_vb(mdl, (data.x, data.mask),
                              engine.ADMMConsensus(adj, link_drop=1.0),
                              n_iters=N_ITERS, init_phi=phi0)
    assert bool(jnp.all(admm_dead.consensus_diag.link_frac == 0.0))
    assert bool(jnp.all(jnp.isfinite(admm_dead.phi)))


def test_keep_everything_matches_static(setup):
    data, prior, adj, W, phi0, mdl = setup
    n = N_NODES
    kw = dict(n_iters=N_ITERS, init_phi=phi0, schedule=engine.Schedule())
    static = engine.run_vb(mdl, (data.x, data.mask), engine.Diffusion(W),
                           **kw)
    keep_all = engine.run_vb(
        mdl, (data.x, data.mask),
        engine.Diffusion(W, link_mask_fn=lambda t: jnp.ones((n, n))), **kw)
    np.testing.assert_allclose(np.asarray(keep_all.phi),
                               np.asarray(static.phi), rtol=1e-12)
    ring_static = engine.run_vb(mdl, (data.x, data.mask),
                                engine.RingDiffusion(), **kw)
    ring_keep = engine.run_vb(
        mdl, (data.x, data.mask),
        engine.RingDiffusion(link_mask_fn=lambda t: jnp.ones(n)), **kw)
    np.testing.assert_allclose(np.asarray(ring_keep.phi),
                               np.asarray(ring_static.phi), rtol=1e-12)
    admm_static = engine.run_vb(mdl, (data.x, data.mask),
                                engine.ADMMConsensus(adj), n_iters=N_ITERS,
                                init_phi=phi0)
    admm_keep = engine.run_vb(
        mdl, (data.x, data.mask),
        engine.ADMMConsensus(adj, link_mask_fn=lambda t: jnp.ones((n, n))),
        n_iters=N_ITERS, init_phi=phi0)
    np.testing.assert_allclose(np.asarray(admm_keep.phi),
                               np.asarray(admm_static.phi), rtol=1e-12)


def test_link_drop_determinism_and_diagnostics(setup):
    data, prior, adj, W, phi0, mdl = setup
    topo = lambda: engine.ADMMConsensus(adj, link_drop=0.3, link_seed=5)
    a = engine.run_vb(mdl, (data.x, data.mask), topo(), n_iters=25,
                      init_phi=phi0)
    b = engine.run_vb(mdl, (data.x, data.mask), topo(), n_iters=25,
                      init_phi=phi0)
    np.testing.assert_array_equal(np.asarray(a.phi), np.asarray(b.phi))
    lf = np.asarray(a.consensus_diag.link_frac)
    assert lf.shape == (25,)
    assert (lf >= 0.0).all() and (lf <= 1.0).all()
    assert 0.45 < lf.mean() < 0.9        # ~70% of links up on average
    assert lf.std() > 0.0                # genuinely time-varying
    c = engine.run_vb(mdl, (data.x, data.mask),
                      engine.ADMMConsensus(adj, link_drop=0.3, link_seed=6),
                      n_iters=25, init_phi=phi0)
    assert float(jnp.max(jnp.abs(a.phi - c.phi))) > 0.0


def test_link_keep_matrix_properties():
    key = jax.random.PRNGKey(0)
    keep = network.link_keep_matrix(key, jnp.asarray(3), 10, 0.4)
    keep_np = np.asarray(keep)
    np.testing.assert_array_equal(keep_np, keep_np.T)       # symmetric
    np.testing.assert_array_equal(np.diag(keep_np), 1.0)    # self always up
    assert set(np.unique(keep_np)) <= {0.0, 1.0}
    # deterministic in (key, t); varies across t
    again = network.link_keep_matrix(key, jnp.asarray(3), 10, 0.4)
    np.testing.assert_array_equal(np.asarray(again), keep_np)
    other = network.link_keep_matrix(key, jnp.asarray(4), 10, 0.4)
    assert (np.asarray(other) != keep_np).any()


def test_link_schedule_validation():
    with pytest.raises(ValueError, match="not both"):
        engine.RingDiffusion(link_drop=0.5, link_mask_fn=lambda t: None)
    with pytest.raises(ValueError, match="probability"):
        engine.ADMMConsensus(jnp.eye(2), link_drop=1.5)


# ---------------------------------------------------------------------------
# 4. executor equivalence with streaming + failing links on
# ---------------------------------------------------------------------------
CODE_STREAMING_EXEC = r"""
import jax
from repro.core import expfam
expfam.enable_x64()
import jax.numpy as jnp
import numpy as np
from repro.core import engine, network
from repro.core import model as model_lib
from repro.data import stream, synthetic

data = synthetic.paper_synthetic(n_nodes=8, n_per_node=24, seed=9)
K, D = 3, 2
prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
adj, _ = network.random_geometric_graph(8, seed=5)
W = network.nearest_neighbor_weights(adj)
mesh = jax.make_mesh((4,), ("data",))
mexec = engine.MeshExecutor(mesh, "data")
mdl = model_lib.GMMModel(prior, K, D)
mb = stream.MinibatchSpec(batch_size=8, seed=3)

# bit-exact full-batch degeneracy holds under the mesh executor too
full = engine.run_vb(mdl, (data.x, data.mask), engine.Diffusion(W),
                     n_iters=15, executor=mexec)
fb = engine.run_vb(mdl, (data.x, data.mask), engine.Diffusion(W),
                   n_iters=15, executor=mexec,
                   minibatch=stream.MinibatchSpec(batch_size=24))
assert bool(jnp.all(full.phi == fb.phi)), "mesh full-batch spec not bit-exact"

# ADMM runs with the Eq. 38b projection use SHORT horizons: the eigen-clip
# is a discontinuous branch, and once the noisy streaming trajectory grazes
# it, the executors' inherent ulp-level reassociation differences flip the
# branch and the trajectories split chaotically (pre-existing sensitivity,
# not a layout bug — the projection-free ADMM below runs the full horizon).
for name, topo, n_it, kw in [
    ("dsvb-mb", engine.Diffusion(W), 20, dict(schedule=engine.Schedule())),
    ("dsvb-mb-drop", engine.Diffusion(W, link_drop=0.3, link_seed=2), 20,
     dict(schedule=engine.Schedule())),
    ("ring-mb-drop", engine.RingDiffusion(link_drop=0.3, link_seed=2), 20,
     dict(schedule=engine.Schedule())),
    ("admm-mb-drop", engine.ADMMConsensus(adj, link_drop=0.3, link_seed=2),
     8, {}),
    ("admm-mb-drop-noproj",
     engine.ADMMConsensus(adj, link_drop=0.3, link_seed=2, project=False),
     25, {}),
    ("admm-adaptive-mb-drop",
     engine.ADMMConsensus(adj, adaptive_rho=True, link_drop=0.3,
                          link_seed=2), 8, {}),
]:
    a = engine.run_vb(mdl, (data.x, data.mask), topo, n_iters=n_it,
                      minibatch=mb, **kw)
    b = engine.run_vb(mdl, (data.x, data.mask), topo, n_iters=n_it,
                      minibatch=mb, executor=mexec, **kw)
    err = float(jnp.max(jnp.abs(a.phi - b.phi)))
    assert err < 1e-8, f"{name} phi err {err}"
    if a.consensus_diag is not None:
        for f in engine.ConsensusDiagnostics._fields:
            da = getattr(a.consensus_diag, f).astype(jnp.float64)
            db = getattr(b.consensus_diag, f).astype(jnp.float64)
            derr = float(jnp.max(jnp.abs(da - db)))
            assert derr < 1e-8, f"{name} diag {f} err {derr}"
print("OK")
"""


def test_streaming_executor_equivalence(subproc):
    out = subproc(CODE_STREAMING_EXEC, n_devices=4)
    assert "OK" in out
