"""Model-zoo acceptance suite: HMM + PPCA through every stack layer.

The two PR-9 adapters (`models/hmm.py`, `models/ppca.py`) are plain
`blocks.BlockModel` compositions, so they must drop into the whole stack
with zero engine/serving special-casing:

* **golden parity** — the engine-backed runs reproduce the paper loops
  (Eqs. 27a/27b diffusion, Eqs. 38a/39/40 ADMM) written out longhand over
  `model.local_optimum`, to 1e-10;
* **every topology** — bucketed-admission padding is bit-invisible under
  all six dense topologies plus the sparse gossip/hierarchical ones;
* **both executors** — a subprocess run pins MeshExecutor == single-array;
* **streaming + SVRG** — full-batch minibatch specs are bit-identical;
  `control_variate="svrg"` stays finite, degenerates bit-exactly at full
  batch, and survives session split/resume bit-exactly;
* **sessions / checkpoints** — vb_init/vb_run split and ckpt round-trips
  are bit-exact;
* **serving** — mixed-capacity HMM/PPCA sessions bucket into shared
  VBService fleets, each bit-equal to its solo run;
* **backend capability** — `backend="fused"` on a non-GMM model warns and
  falls back to the reference backend (same numbers), instead of crashing
  inside the kernel.
"""
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.core import engine, network
from repro.data import stream
from repro.models import hmm as hmm_lib
from repro.models import ppca as ppca_lib
from repro.serving.vb_service import VBRequest, VBService


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


K, D_HMM, N_NODES = 3, 2, 6
D_PPCA, Q = 5, 2


@pytest.fixture(scope="module")
def hmm_setup():
    x, mask, _, A_true, _ = hmm_lib.sample_chains(N_NODES, 10, 8, K=K,
                                                  D=D_HMM, seed=0)
    prior = hmm_lib.noninformative_prior(K, D_HMM, beta0=0.1, w0_scale=10.0)
    init_q = hmm_lib.perturbed_init(prior, jnp.asarray(x),
                                    jax.random.PRNGKey(7))
    mdl = hmm_lib.HMMModel(prior)
    adj, _ = network.random_geometric_graph(N_NODES, seed=3)
    W = network.metropolis_weights(adj)
    phi0 = jnp.broadcast_to(mdl.pack(init_q), (N_NODES, mdl.flat_dim))
    return mdl, (jnp.asarray(x), jnp.asarray(mask)), adj, W, phi0, A_true


@pytest.fixture(scope="module")
def ppca_setup():
    x, mask, W_true = ppca_lib.sample_sensors(N_NODES, 24, D=D_PPCA, Q=Q,
                                              seed=1)
    mdl = ppca_lib.PPCAModel(ppca_lib.prior(D_PPCA, Q))
    init_q = ppca_lib.perturbed_init(mdl.prior, jax.random.PRNGKey(5))
    adj, _ = network.random_geometric_graph(N_NODES, seed=3)
    W = network.metropolis_weights(adj)
    phi0 = jnp.broadcast_to(mdl.pack(init_q), (N_NODES, mdl.flat_dim))
    return mdl, (jnp.asarray(x), jnp.asarray(mask)), adj, W, phi0, W_true


# ---------------------------------------------------------------------------
# Golden parity: paper loops longhand over model.local_optimum
# ---------------------------------------------------------------------------
def _legacy_dsvb(mdl, data, W, phi0, *, n_iters, tau=0.2, d0=1.0):
    phi = phi0
    for t in range(n_iters):
        phi_star = mdl.local_optimum(data, phi, float(phi.shape[0]))
        eta = 1.0 / (d0 + tau * (t + 1.0))                       # Eq. 29
        varphi = phi + eta * (phi_star - phi)                    # Eq. 27a
        phi = W @ varphi                                         # Eq. 27b
    return phi


def _legacy_admm(mdl, data, adj, phi0, *, n_iters, rho=0.5, xi=0.05):
    deg = jnp.sum(adj, axis=1)
    phi, lam = phi0, jnp.zeros_like(phi0)
    for t in range(n_iters):
        phi_star = mdl.local_optimum(data, phi, float(phi.shape[0]))
        neigh = adj @ phi
        phi_hat = (phi_star - 2.0 * lam
                   + rho * (deg[:, None] * phi + neigh))         # Eq. 38a
        phi_hat = phi_hat / (1.0 + 2.0 * rho * deg)[:, None]
        phi_new = jax.vmap(mdl.project_to_domain)(phi_hat)       # Eq. 38b
        kappa = 1.0 - 1.0 / (1.0 + xi * (t + 1.0)) ** 2          # Eq. 40
        resid = deg[:, None] * phi_new - adj @ phi_new
        lam = lam + kappa * rho / 2.0 * resid                    # Eq. 39
        phi = phi_new
    return phi


def _parity_case(setup):
    return setup[0], setup[1], setup[2], setup[3], setup[4]


@pytest.mark.parametrize("which", ["hmm", "ppca"])
def test_diffusion_matches_legacy_loop(which, hmm_setup, ppca_setup):
    mdl, data, adj, W, phi0 = _parity_case(
        hmm_setup if which == "hmm" else ppca_setup)
    want = _legacy_dsvb(mdl, data, W, phi0, n_iters=8)
    got = engine.run_vb(mdl, data, engine.Diffusion(W), n_iters=8,
                        init_phi=phi0).phi
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("which", ["hmm", "ppca"])
def test_admm_matches_legacy_loop(which, hmm_setup, ppca_setup):
    mdl, data, adj, W, phi0 = _parity_case(
        hmm_setup if which == "hmm" else ppca_setup)
    want = _legacy_admm(mdl, data, adj, phi0, n_iters=8)
    got = engine.run_vb(mdl, data, engine.ADMMConsensus(adj), n_iters=8,
                        init_phi=phi0).phi
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-10, atol=1e-10)


# ---------------------------------------------------------------------------
# Convergence sanity on ground truth
# ---------------------------------------------------------------------------
def test_hmm_recovers_transitions():
    """Diffusion VB on sticky ground-truth chains recovers the transition
    matrix (up to the label permutation the seeds pin to identity)."""
    x, mask, _, A_true, means = hmm_lib.sample_chains(
        N_NODES, 20, 20, K=K, D=D_HMM, seed=0)
    prior = hmm_lib.noninformative_prior(K, D_HMM, beta0=0.1, w0_scale=10.0)
    mdl = hmm_lib.HMMModel(prior)
    init_q = hmm_lib.perturbed_init(prior, jnp.asarray(x),
                                    jax.random.PRNGKey(7))
    adj, _ = network.random_geometric_graph(N_NODES, seed=3)
    W = network.metropolis_weights(adj)
    phi0 = jnp.broadcast_to(mdl.pack(init_q), (N_NODES, mdl.flat_dim))
    out = engine.run_vb(mdl, (jnp.asarray(x), jnp.asarray(mask)),
                        engine.Diffusion(W), n_iters=80, init_phi=phi0)
    q = mdl.unpack(out.phi[0])
    # match estimated components to truth by emission means
    est_means = np.asarray(q.m)
    perm = [int(np.argmin(np.sum((est_means - mu) ** 2, -1)))
            for mu in means]
    assert sorted(perm) == list(range(K)), "label collapse"
    A_est = np.asarray(q.trans / jnp.sum(q.trans, -1, keepdims=True))
    assert np.max(np.abs(A_est[np.ix_(perm, perm)] - A_true)) < 0.1


def test_ppca_recovers_subspace(ppca_setup):
    mdl, data, adj, W, phi0, W_true = ppca_setup
    out = engine.run_vb(mdl, data, engine.Diffusion(W), n_iters=30,
                        init_phi=phi0)
    q = mdl.unpack(out.phi[0])
    # column spaces align: principal angles between estimated and true
    # loading subspaces are ~0
    u_est, _, _ = np.linalg.svd(np.asarray(q.m), full_matrices=False)
    u_true, _, _ = np.linalg.svd(np.asarray(W_true), full_matrices=False)
    cos = np.linalg.svd(u_est.T @ u_true, compute_uv=False)
    assert np.min(cos) > 0.99, cos


# ---------------------------------------------------------------------------
# Every topology: padding bit-invisibility (the bucketed contract)
# ---------------------------------------------------------------------------
def _dense_topologies(adj, W):
    return [
        ("fusion", engine.FusionCenter(), engine.ONE_SHOT),
        ("isolated", engine.Isolated(), engine.Schedule()),
        ("ring", engine.RingDiffusion(), engine.Schedule(tau=0.1)),
        ("diffusion", engine.Diffusion(W), engine.Schedule()),
        ("admm", engine.ADMMConsensus(adj), engine.Schedule()),
        ("admm-adaptive", engine.ADMMConsensus(adj, adaptive_rho=True),
         engine.Schedule()),
    ]


def _sparse_topologies(adj):
    g = network.SparseGraph.from_dense(adj)
    gw, rg = network.two_level_partition(N_NODES, 3, 1)
    return [
        ("gossip", engine.PairwiseGossip(g, p_activate=0.5, seed=2),
         engine.Schedule()),
        ("hierarchical", engine.HierarchicalFusion(gw, rg),
         engine.Schedule()),
    ]


@pytest.mark.parametrize("which", ["hmm", "ppca"])
def test_padding_bit_equal_every_topology(which, hmm_setup, ppca_setup):
    setup = hmm_setup if which == "hmm" else ppca_setup
    mdl, data, adj, W, phi0 = _parity_case(setup)
    cap = data[0].shape[1]
    padded = mdl.pad_to_capacity(data, cap + 5)
    assert padded[0].shape[1] == cap + 5
    assert padded[-1].shape == (N_NODES, cap + 5)
    for name, topo, sched in (_dense_topologies(adj, W)
                              + _sparse_topologies(adj)):
        a = engine.run_vb(mdl, data, topo, n_iters=6, schedule=sched,
                          init_phi=phi0)
        b = engine.run_vb(mdl, padded, topo, n_iters=6, schedule=sched,
                          init_phi=phi0)
        np.testing.assert_array_equal(np.asarray(a.phi), np.asarray(b.phi),
                                      err_msg=f"{which}/{name}")


# ---------------------------------------------------------------------------
# Streaming + SVRG
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("which", ["hmm", "ppca"])
@pytest.mark.parametrize("cv", [None, "svrg"])
def test_full_batch_spec_is_bit_identical(which, cv, hmm_setup, ppca_setup):
    """batch_size >= capacity reproduces the batch run bit-for-bit — with
    SVRG requested too: the anchor machinery must be structurally absent
    in the degenerate case, not approximately cancelling."""
    setup = hmm_setup if which == "hmm" else ppca_setup
    mdl, data, adj, W, phi0 = _parity_case(setup)
    cap = data[0].shape[1]
    a = engine.run_vb(mdl, data, engine.Diffusion(W), n_iters=6,
                      init_phi=phi0)
    b = engine.run_vb(mdl, data, engine.Diffusion(W), n_iters=6,
                      init_phi=phi0,
                      minibatch=stream.MinibatchSpec(cap, seed=0,
                                                     control_variate=cv))
    np.testing.assert_array_equal(np.asarray(a.phi), np.asarray(b.phi))


@pytest.mark.parametrize("which", ["hmm", "ppca"])
def test_svrg_minibatch_runs_finite(which, hmm_setup, ppca_setup):
    setup = hmm_setup if which == "hmm" else ppca_setup
    mdl, data, adj, W, phi0 = _parity_case(setup)
    cap = data[0].shape[1]
    out = engine.run_vb(mdl, data, engine.Diffusion(W), n_iters=2 * cap,
                        init_phi=phi0,
                        minibatch=stream.MinibatchSpec(
                            cap // 2, seed=1, control_variate="svrg"))
    assert np.all(np.isfinite(np.asarray(out.phi)))
    assert np.all(np.isfinite(np.asarray(out.kl_nodes)))


def test_svrg_split_resume_bit_exact(hmm_setup):
    """Anchors ride in StreamState, so an SVRG session split across
    vb_run calls (crossing an epoch boundary = anchor refresh) matches
    the unsplit run bit-for-bit."""
    mdl, data, adj, W, phi0 = _parity_case(hmm_setup)
    cap = data[0].shape[1]
    spec = stream.MinibatchSpec(cap // 2, seed=3, control_variate="svrg")
    n = cap + 3      # crosses the first epoch boundary
    whole = engine.vb_init(mdl, data, engine.Diffusion(W), minibatch=spec,
                           init_phi=phi0)
    whole, _ = engine.vb_run(whole, n)
    split = engine.vb_init(mdl, data, engine.Diffusion(W), minibatch=spec,
                           init_phi=phi0)
    split, _ = engine.vb_run(split, n // 2)
    split, _ = engine.vb_run(split, n - n // 2)
    np.testing.assert_array_equal(np.asarray(whole.phi),
                                  np.asarray(split.phi))
    np.testing.assert_array_equal(np.asarray(whole.stream.anchor_phi),
                                  np.asarray(split.stream.anchor_phi))
    np.testing.assert_array_equal(np.asarray(whole.stream.anchor_full),
                                  np.asarray(split.stream.anchor_full))


def test_svrg_unknown_control_variate_rejected(hmm_setup):
    mdl, data, adj, W, _ = _parity_case(hmm_setup)
    with pytest.raises(ValueError, match="control_variate"):
        engine.vb_init(mdl, data, engine.Diffusion(W),
                       minibatch=stream.MinibatchSpec(
                           4, control_variate="saga"))


# ---------------------------------------------------------------------------
# Sessions + checkpoints
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("which", ["hmm", "ppca"])
def test_split_resume_bit_exact(which, hmm_setup, ppca_setup):
    setup = hmm_setup if which == "hmm" else ppca_setup
    mdl, data, adj, W, phi0 = _parity_case(setup)
    whole = engine.run_vb(mdl, data, engine.ADMMConsensus(adj), n_iters=8,
                          init_phi=phi0)
    s = engine.vb_init(mdl, data, engine.ADMMConsensus(adj), init_phi=phi0)
    s, _ = engine.vb_run(s, 5)
    s, _ = engine.vb_run(s, 3)
    np.testing.assert_array_equal(np.asarray(whole.phi), np.asarray(s.phi))


def test_checkpoint_roundtrip_hmm(hmm_setup, tmp_path):
    mdl, data, adj, W, phi0 = _parity_case(hmm_setup)
    cap = data[0].shape[1]
    mk = lambda: engine.vb_init(
        mdl, data, engine.Diffusion(W), init_phi=phi0,
        minibatch=stream.MinibatchSpec(cap // 2, seed=2,
                                       control_variate="svrg"))
    s = mk()
    s, _ = engine.vb_run(s, 5)
    path = os.path.join(tmp_path, "hmm.npz")
    ckpt.save(path, s)
    restored = ckpt.restore(path, mk())
    assert int(restored.t) == 5
    s, _ = engine.vb_run(s, 7)
    restored, _ = engine.vb_run(restored, 7)
    np.testing.assert_array_equal(np.asarray(s.phi),
                                  np.asarray(restored.phi))


# ---------------------------------------------------------------------------
# Serving: bucketed fleets, model-generic
# ---------------------------------------------------------------------------
def test_mixed_capacity_hmm_sessions_share_fleet(hmm_setup):
    """HMM sessions with per-node chain counts 9/10 round to one rung:
    one group, one compiled slice, each bit-equal to its solo run —
    proving the serving stack needed zero model-specific code."""
    mdl, _, adj, W, _ = _parity_case(hmm_setup)
    datasets = []
    for i, s_chains in enumerate([9, 10]):
        x, mask, _, _, _ = hmm_lib.sample_chains(N_NODES, s_chains, 8, K=K,
                                                 D=D_HMM, seed=10 + i)
        datasets.append((jnp.asarray(x), jnp.asarray(mask)))
    topo = engine.Diffusion(W)
    svc = VBService(slice_iters=4, max_fleet=4)
    rids = [svc.submit(VBRequest(model=mdl, data=d, topology=topo,
                                 n_iters=8)) for d in datasets]
    out = svc.run()
    assert len(svc._groups) == 1 and svc.stats().compiles == 1
    for d, rid in zip(datasets, rids):
        solo = engine.run_vb(mdl, d, topo, n_iters=8)
        np.testing.assert_array_equal(np.asarray(solo.phi),
                                      np.asarray(out[rid].phi), err_msg=rid)


def test_mixed_capacity_ppca_sessions_share_fleet(ppca_setup):
    mdl, _, adj, W, phi0 = _parity_case(ppca_setup)
    datasets = []
    for i, t in enumerate([21, 29]):        # both round to rung 32
        x, mask, _ = ppca_lib.sample_sensors(N_NODES, t, D=D_PPCA, Q=Q,
                                             seed=20 + i)
        datasets.append((jnp.asarray(x), jnp.asarray(mask)))
    topo = engine.RingDiffusion()
    svc = VBService(slice_iters=4, max_fleet=4)
    rids = [svc.submit(VBRequest(model=mdl, data=d, topology=topo,
                                 n_iters=8, init_phi=phi0))
            for d in datasets]
    out = svc.run()
    assert len(svc._groups) == 1 and svc.stats().compiles == 1
    for d, rid in zip(datasets, rids):
        solo = engine.run_vb(mdl, d, topo, n_iters=8, init_phi=phi0)
        # the fleet axis turns the per-row jnp.linalg.solve into a batched
        # kernel, so (unlike the elementwise-combine GMM/HMM cases) the
        # fleet run is 1e-9-close rather than bit-equal to solo — the
        # PR-6 matmul-combine contract
        np.testing.assert_allclose(np.asarray(solo.phi),
                                   np.asarray(out[rid].phi),
                                   rtol=1e-9, atol=1e-9, err_msg=rid)


# ---------------------------------------------------------------------------
# Backend capability check
# ---------------------------------------------------------------------------
def test_fused_backend_falls_back_for_non_gmm(hmm_setup):
    """The Pallas GMM kernel cannot serve an HMM: `Backend.supports`
    catches the mismatch and the session degrades to the reference
    backend with a warning — results equal the plain run."""
    mdl, data, adj, W, phi0 = _parity_case(hmm_setup)
    plain = engine.run_vb(mdl, data, engine.Diffusion(W), n_iters=4,
                          init_phi=phi0)
    with pytest.warns(UserWarning, match="falling back to the reference"):
        fb = engine.run_vb(mdl, data, engine.Diffusion(W), n_iters=4,
                           backend="fused", init_phi=phi0)
    np.testing.assert_array_equal(np.asarray(plain.phi),
                                  np.asarray(fb.phi))


def test_gmm_fused_backend_still_supported():
    from repro.core import backends
    from repro.core import model as model_lib
    from repro.core.expfam import noninformative_prior
    mdl = model_lib.GMMModel(noninformative_prior(3, 2), 3, 2)
    assert backends.FusedBackend().supports(mdl)
    assert not backends.FusedBackend().supports(object())
    assert backends.ReferenceBackend().supports(object())


# ---------------------------------------------------------------------------
# Both executors: shard_map == single-array, whole zoo (subprocess)
# ---------------------------------------------------------------------------
CODE_ZOO_EXECUTOR_EQUIV = r"""
import jax
from repro.core import expfam
expfam.enable_x64()
import jax.numpy as jnp
from repro.core import engine, network
from repro.data import stream
from repro.models import hmm as hmm_lib
from repro.models import ppca as ppca_lib

adj, _ = network.random_geometric_graph(8, seed=3)
W = network.metropolis_weights(adj)
mesh = jax.make_mesh((4,), ("data",))
mexec = engine.MeshExecutor(mesh, "data")

x, mask, _, _, _ = hmm_lib.sample_chains(8, 8, 8, K=3, D=2, seed=0)
hmm = hmm_lib.HMMModel(
    hmm_lib.noninformative_prior(3, 2, beta0=0.1, w0_scale=10.0))
hdata = (jnp.asarray(x), jnp.asarray(mask))
hq = hmm_lib.perturbed_init(hmm.prior, jnp.asarray(x),
                            jax.random.PRNGKey(7))
hphi0 = jnp.broadcast_to(hmm.pack(hq), (8, hmm.flat_dim))

px, pmask, _ = ppca_lib.sample_sensors(8, 16, D=5, Q=2, seed=1)
ppca = ppca_lib.PPCAModel(ppca_lib.prior(5, 2))
pdata = (jnp.asarray(px), jnp.asarray(pmask))
pq = ppca_lib.perturbed_init(ppca.prior, jax.random.PRNGKey(5))
pphi0 = jnp.broadcast_to(ppca.pack(pq), (8, ppca.flat_dim))

cases = [("hmm", hmm, hdata, hphi0), ("ppca", ppca, pdata, pphi0)]
topos = [("diffusion", engine.Diffusion(W), {}),
         ("ring", engine.RingDiffusion(), {}),
         ("admm", engine.ADMMConsensus(adj), {}),
         ("fusion", engine.FusionCenter(),
          dict(schedule=engine.ONE_SHOT))]
for mname, mdl, data, phi0 in cases:
    cap = data[0].shape[1]
    for tname, topo, kw in topos:
        a = engine.run_vb(mdl, data, topo, n_iters=8, init_phi=phi0, **kw)
        b = engine.run_vb(mdl, data, topo, n_iters=8, init_phi=phi0,
                          executor=mexec, **kw)
        err = float(jnp.max(jnp.abs(a.phi - b.phi)))
        assert err < 1e-8, f"{mname}/{tname} phi err {err}"
    # streaming SVRG path through shard_map (anchor specs included)
    spec = stream.MinibatchSpec(cap // 2, seed=4, control_variate="svrg")
    a = engine.run_vb(mdl, data, engine.Diffusion(W), n_iters=cap + 2,
                      init_phi=phi0, minibatch=spec)
    b = engine.run_vb(mdl, data, engine.Diffusion(W), n_iters=cap + 2,
                      init_phi=phi0, minibatch=spec, executor=mexec)
    err = float(jnp.max(jnp.abs(a.phi - b.phi)))
    assert err < 1e-8, f"{mname}/svrg phi err {err}"
print("OK")
"""


def test_zoo_mesh_executor_matches_single_array(subproc):
    out = subproc(CODE_ZOO_EXECUTOR_EQUIV, n_devices=4)
    assert "OK" in out
