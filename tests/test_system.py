"""End-to-end behaviour of the paper's system: distributed VB on the sensor
network reaches centralised-quality estimates and recovers the mixture."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms, expfam, gmm, network
from repro.data import synthetic


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


def test_end_to_end_distributed_vb_recovers_mixture():
    """Full pipeline: sample sensor data -> run dVB-ADMM -> the recovered
    mixture means match the ground-truth components (modulo permutation).

    dVB-ADMM runs the adaptive-penalty consensus subsystem
    (`adaptive_rho=True`); plain Algorithm 2 diverges on this instance
    (dual wind-up — docs/admm-convergence.md).  The restart key is 0:
    PRNGKey(2)'s initialisation sends even centralised VB (the fusion
    centre this test's consensus target equals) to a degenerate two-
    component optimum, so it cannot discriminate consensus quality."""
    data = synthetic.paper_synthetic(n_nodes=20, n_per_node=80, seed=7)
    K, D = 3, 2
    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
    adj, _ = network.random_geometric_graph(20, seed=7)
    init_q = algorithms._perturbed_init(prior, data.x, jax.random.PRNGKey(0))
    run = algorithms.run_dvb_admm(data.x, data.mask, adj, prior,
                                  n_iters=400, K=K, D=D, rho=0.5,
                                  adaptive_rho=True, init_q=init_q)
    q = expfam.unpack_natural(run.phi[0], K, D)
    got = np.asarray(q.m)
    want = synthetic.PAPER_MU
    used = set()
    for k in range(K):
        d = np.linalg.norm(want - got[k], axis=1)
        j = int(np.argmin(d))
        assert d[j] < 0.35, (k, got[k], d)
        assert j not in used
        used.add(j)


def test_end_to_end_clustering_accuracy():
    """Hard-assignment clustering with the learned posterior separates the
    synthetic components well (Table I-style evaluation)."""
    data = synthetic.paper_synthetic(n_nodes=10, n_per_node=80, seed=3)
    K, D = 3, 2
    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
    adj, _ = network.random_geometric_graph(10, seed=3)
    W = network.nearest_neighbor_weights(adj)
    init_q = algorithms._perturbed_init(prior, data.x, jax.random.PRNGKey(0))
    run = algorithms.run_dsvb(data.x, data.mask, W, prior, n_iters=900,
                              K=K, D=D, tau=0.2, init_q=init_q)
    q = expfam.unpack_natural(run.phi[3], K, D)   # any node
    x_all, labels = data.flat
    pred = np.asarray(gmm.predict_labels(x_all, q))
    labels = np.asarray(labels)
    import itertools
    acc = max(np.mean(np.asarray([p[i] for i in pred]) == labels)
              for p in itertools.permutations(range(K)))
    assert acc > 0.85, acc
