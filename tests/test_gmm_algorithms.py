"""Bayesian-GMM mechanics + the paper's algorithm-level claims (Sec. V)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms, expfam, gmm, network, refperm
from repro.data import synthetic


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


K, D = 3, 2


@pytest.fixture(scope="module")
def setup():
    data = synthetic.paper_synthetic(n_nodes=20, n_per_node=60, seed=1)
    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
    x_all, labels_all = data.flat
    ref = gmm.ground_truth_posterior(x_all, labels_all, prior, K)
    ref_phis = refperm.permuted_refs(ref)
    adj, _ = network.random_geometric_graph(20, seed=3)
    W = network.nearest_neighbor_weights(adj)
    init_q = algorithms._perturbed_init(prior, data.x, jax.random.PRNGKey(0))
    return data, prior, ref_phis, adj, W, init_q


def test_responsibilities_normalised(setup):
    data, prior, *_ = setup
    r = gmm.responsibilities(data.x[0], prior)
    np.testing.assert_allclose(np.asarray(jnp.sum(r, -1)), 1.0, atol=1e-10)


def test_elbo_monotone_under_vb(setup):
    """Classical VB guarantee: the local ELBO is non-decreasing."""
    data, prior, *_ = setup
    x = data.x[0]
    q = prior
    prev = -np.inf
    for _ in range(25):
        r = gmm.responsibilities(x, q)
        stats = gmm.sufficient_stats(x, r, 1.0)
        q = gmm.posterior_from_stats(stats, prior)
        e = float(gmm.elbo(x, q, prior))
        assert e >= prev - 1e-6, (e, prev)
        prev = e


def test_vbm_average_identity(setup):
    """Eq. 20: the centralised VBM optimum is the average of the local
    natural-parameter optima (what makes consensus solve the VBM step)."""
    data, prior, *_ = setup
    n = data.x.shape[0]
    phi0 = expfam.pack_natural(prior)
    phis = jnp.broadcast_to(phi0, (n,) + phi0.shape)
    phi_star = gmm.local_vbm_optimum_nodes(data.x, phis, prior, float(n),
                                           K, D, data.mask)
    # average of naturals == posterior from pooled replicated stats
    avg = jnp.mean(phi_star, 0)
    q_avg = expfam.unpack_natural(avg, K, D)
    # pooled direct computation
    q_prior = expfam.unpack_natural(phi0, K, D)
    r_all = [gmm.responsibilities(data.x[i], q_prior, data.mask[i])
             for i in range(n)]
    stats = [gmm.sufficient_stats(data.x[i], r_all[i], float(n))
             for i in range(n)]
    pooled = gmm.SuffStats(
        R=sum(s.R for s in stats) / n,
        sum_x=sum(s.sum_x for s in stats) / n,
        sum_xx=sum(s.sum_xx for s in stats) / n)
    q_pool = gmm.posterior_from_stats(pooled, prior)
    np.testing.assert_allclose(q_avg.alpha, q_pool.alpha, rtol=1e-6)
    np.testing.assert_allclose(q_avg.m, q_pool.m, rtol=1e-5, atol=1e-8)
    np.testing.assert_allclose(q_avg.beta, q_pool.beta, rtol=1e-6)


def test_paper_claims_ordering(setup):
    """Fig. 4 / Fig. 8 qualitative claims on a reduced instance:
    dVB-ADMM ~ cVB  <<  nsg-dVB; dSVB well below nsg-dVB; noncoop worst;
    dVB-ADMM faster than dSVB at equal iteration count.

    dVB-ADMM runs the adaptive-penalty consensus subsystem
    (`adaptive_rho=True`: residual balancing + residual-gated dual warmup
    + dual reset on eigen-clip) — plain Algorithm 2 genuinely diverges on
    this reduced instance (dual wind-up; docs/admm-convergence.md)."""
    data, prior, ref_phis, adj, W, init_q = setup
    kw = dict(K=K, D=D, ref_phi=ref_phis, init_q=init_q)
    cvb = algorithms.run_cvb(data.x, data.mask, prior, n_iters=300, **kw)
    admm = algorithms.run_dvb_admm(data.x, data.mask, adj, prior, rho=0.5,
                                   adaptive_rho=True, n_iters=300, **kw)
    # dSVB's Robbins-Monro schedule needs more iterations to overtake the
    # one-shot nsg-dVB plateau on this reduced instance (crossover ~t=430;
    # the paper's Fig. 4 runs 2000+) — compare those two at 600, and ADMM
    # against dSVB's 300-iteration mark of the same trajectory.
    dsvb = algorithms.run_dsvb(data.x, data.mask, W, prior, tau=0.2,
                               n_iters=600, **kw)
    nsg = algorithms.run_nsg_dvb(data.x, data.mask, W, prior, n_iters=600,
                                 **kw)

    c = float(cvb.kl_mean[-1])
    assert float(admm.kl_mean[-1]) < c * 1.2 + 1.0          # ADMM ~ cVB
    assert float(admm.kl_mean[-1]) < 2.0 * c                # within 2x cVB
    assert float(admm.kl_mean[-1]) < float(dsvb.kl_mean[299])  # ADMM faster
    assert float(dsvb.kl_mean[-1]) < float(nsg.kl_mean[-1])   # dSVB > nsg
    # consensus: ADMM node spread tiny, nsg spread large
    assert float(admm.kl_std[-1]) < 0.05 * float(nsg.kl_std[-1]) + 1e-3
    # the diagnostics tell the convergence story: the dual warmup gate
    # opened (and stayed open), and no eigen-clip fired afterwards
    diag = admm.consensus_diag
    assert float(diag.dual_on[-1]) == 1.0
    assert float(diag.kappa[-1]) > 0.9


def test_admm_dual_clipping_damps_windup(setup):
    """ADMMConsensus(lam_max=...): clipping the duals to a multiple of
    |phi*| must damp the wind-up divergence by orders of magnitude on the
    instance where plain Algorithm 2 explodes (ROADMAP 'dVB-ADMM
    numerics').  Not a convergence claim — see the xfailed
    test_paper_claims_ordering for that."""
    data, prior, ref_phis, adj, W, init_q = setup
    kw = dict(n_iters=150, K=K, D=D, ref_phi=ref_phis, init_q=init_q)
    plain = algorithms.run_dvb_admm(data.x, data.mask, adj, prior, rho=0.5,
                                    **kw)
    clipped = algorithms.run_dvb_admm(data.x, data.mask, adj, prior, rho=0.5,
                                      lam_max=0.05, **kw)
    assert float(clipped.kl_mean[-1]) < 1e-2 * float(plain.kl_mean[-1])
    assert float(clipped.kl_mean[-1]) < 500.0
    # lam_max=None must stay bit-identical to Algorithm 2 (golden parity
    # for the default path lives in test_engine.py)
    plain2 = algorithms.run_dvb_admm(data.x, data.mask, adj, prior, rho=0.5,
                                     lam_max=None, **kw)
    np.testing.assert_array_equal(np.asarray(plain.phi),
                                  np.asarray(plain2.phi))


def test_dsvb_robust_to_unequal_sizes():
    """Sec. V-C1 (Fig. 9): unequal per-node sample sizes (40..160), samples
    drawn from the whole mixture — dVB-ADMM still matches cVB.  (The
    doubly-imbalanced variant — sizes AND mixture composition — destabilises
    dVB-ADMM; documented in EXPERIMENTS.md §Beyond.)"""
    data = synthetic.paper_synthetic(n_nodes=16, n_per_node=60, seed=5,
                                     unequal_sizes=True, imbalanced=False)
    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
    x_all, labels_all = data.flat
    ref = gmm.ground_truth_posterior(x_all, labels_all, prior, K)
    ref_phis = refperm.permuted_refs(ref)
    adj, _ = network.random_geometric_graph(16, seed=2)
    W = network.nearest_neighbor_weights(adj)
    init_q = algorithms._perturbed_init(prior, data.x, jax.random.PRNGKey(1))
    kw = dict(n_iters=300, K=K, D=D, ref_phi=ref_phis, init_q=init_q)
    cvb = algorithms.run_cvb(data.x, data.mask, prior, **kw)
    admm = algorithms.run_dvb_admm(data.x, data.mask, adj, prior, rho=0.5,
                                   **kw)
    assert float(admm.kl_mean[-1]) < float(cvb.kl_mean[-1]) * 1.3 + 2.0


def test_schedules():
    t = jnp.arange(1.0, 2000.0)
    eta = algorithms.eta_schedule(t, tau=0.2)
    assert float(eta[0]) <= 1.0 and float(eta[-1]) < 0.01
    # Robbins-Monro: sum eta -> inf (log growth), sum eta^2 bounded
    assert float(jnp.sum(eta ** 2)) < 30.0
    kap = algorithms.kappa_schedule(t, xi=0.05)
    assert float(kap[0]) < 0.2 and float(kap[-1]) > 0.99
    assert bool(jnp.all(jnp.diff(kap) >= 0))


def test_cvb_equals_fusion_center_batch_vb(setup):
    """cVB over nodes == textbook VB on the pooled dataset."""
    data, prior, *_ = setup
    run = algorithms.run_cvb(data.x, data.mask, prior, n_iters=40, K=K, D=D)
    q_dist = expfam.unpack_natural(run.phi[0], K, D)
    # textbook VB on pooled data, same #iterations, same init
    x_all, _ = data.flat
    q = prior
    for _ in range(40):
        r = gmm.responsibilities(x_all, q)
        stats = gmm.sufficient_stats(x_all, r, 1.0)
        q = gmm.posterior_from_stats(stats, prior)
    np.testing.assert_allclose(q_dist.m, q.m, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(q_dist.alpha, q.alpha, rtol=1e-4)
