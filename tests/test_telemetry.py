"""Telemetry subsystem tests (src/repro/telemetry/ + tools/bench_gate.py).

The two tentpole acceptance pins:

* **disabled is invisible** — with telemetry off (and with host
  telemetry ON but device taps off) the engine step jaxpr is
  byte-identical to the uninstrumented trace, and a driver run keeps
  its compiles==1 contract;
* **enabled is complete** — a traced driver run yields a loadable
  Chrome trace with slice/compile/checkpoint spans and a metrics
  snapshot carrying occupancy / queue-depth / padding-waste gauges and
  eviction counters.

Plus the satellites: CheckpointWriter failure isolation (a failing
write must not kill the scheduler; it increments an error counter that
surfaces in DriverStats), the backend-fallback warn-once bugfix, and
the bench gate's pass-on-baseline / fail-on-degraded behavior.
"""
import importlib.util
import json
import os
import threading
import warnings

import jax
import numpy as np
import pytest

from repro import telemetry
from repro.core import engine, expfam, network
from repro.core import model as model_lib
from repro.data import stream as stream_lib
from repro.data import synthetic
from repro.serving import driver as drv
from repro.serving.vb_service import VBRequest, VBService
from repro.telemetry import taps

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True)
def _clean_telemetry():
    """Telemetry state is process-global: every test starts and ends
    disabled and empty so nothing leaks across tests (or suites)."""
    telemetry.disable()
    taps.disable()
    telemetry.reset()
    yield
    telemetry.disable()
    taps.disable()
    telemetry.reset()


K, D, N_NODES = 3, 2, 8


@pytest.fixture(scope="module")
def setup():
    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
    mdl = model_lib.GMMModel(prior, K, D)
    adj, _ = network.random_geometric_graph(N_NODES, seed=4)
    W = network.nearest_neighbor_weights(adj)
    data = synthetic.paper_synthetic(n_nodes=N_NODES, n_per_node=10,
                                     seed=0)
    return mdl, adj, W, data


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------
def test_registry_counter_gauge_histogram():
    reg = telemetry.MetricsRegistry()
    reg.counter("req_total", route="vb").inc()
    reg.counter("req_total", route="vb").inc(2)
    reg.counter("req_total", route="lm").inc()
    reg.gauge("depth").set(7)
    reg.histogram("lat_s", bounds=(0.1, 1.0)).observe(0.05)
    reg.histogram("lat_s", bounds=(0.1, 1.0)).observe(5.0)
    rows = {(r["name"], tuple(sorted(r["labels"].items()))): r
            for r in reg.snapshot()}
    assert rows[("req_total", (("route", "vb"),))]["value"] == 3.0
    assert rows[("req_total", (("route", "lm"),))]["value"] == 1.0
    assert rows[("depth", ())]["value"] == 7.0
    hist = rows[("lat_s", ())]
    assert hist["count"] == 2 and hist["buckets"]["+Inf"] == 1

    # JSON-lines: one parseable object per series
    lines = [json.loads(line) for line in reg.to_jsonl().splitlines()]
    assert len(lines) == len(reg.snapshot())

    prom = reg.to_prometheus()
    assert 'req_total{route="vb"} 3' in prom
    assert "# TYPE lat_s histogram" in prom
    assert 'lat_s_bucket{le="+Inf"} 2' in prom

    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("req_total", route="vb")


def test_registry_thread_safety():
    reg = telemetry.MetricsRegistry()

    def work():
        for _ in range(1000):
            reg.counter("n").inc()

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.counter("n").value == 8000.0


def test_module_helpers_noop_when_disabled():
    telemetry.inc("x_total")
    telemetry.set_gauge("g", 1.0)
    telemetry.observe("h", 0.5)
    telemetry.instant("ev")
    with telemetry.span("s"):
        pass
    assert len(telemetry.registry()) == 0
    assert len(telemetry.tracer()) == 0
    with telemetry.enabled_scope():
        telemetry.inc("x_total")
        with telemetry.span("s"):
            pass
    assert len(telemetry.registry()) == 1
    assert telemetry.tracer().span_names() == ["s"]


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------
def test_tracer_chrome_export_nesting(tmp_path):
    tr = telemetry.Tracer()
    with tr.span("outer", k=8):
        with tr.span("inner"):
            tr.instant("mark", rid="s0")
    path = tr.export_chrome_trace(str(tmp_path / "trace.json"))
    doc = json.load(open(path))
    events = doc["traceEvents"]
    assert {e["name"] for e in events} == {"outer", "inner", "mark"}
    by_name = {e["name"]: e for e in events}
    outer, inner = by_name["outer"], by_name["inner"]
    assert outer["ph"] == "X" and by_name["mark"]["ph"] == "i"
    # nesting = time containment on one tid
    assert outer["tid"] == inner["tid"]
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    assert outer["args"] == {"k": 8}


# ---------------------------------------------------------------------------
# Device taps + the jaxpr pin (tentpole acceptance)
# ---------------------------------------------------------------------------
def _step_jaxpr(mdl, data, topo, **kw):
    """The session-step jaxpr string.  `session_step_fn` returns a fresh
    closure per call, so each invocation is a fresh trace — no trace
    cache can mask a gating bug."""
    state = engine.vb_init(mdl, data, topo, **kw)
    fn = engine.session_step_fn(state.session)
    return str(jax.make_jaxpr(fn)(state.session.data, state.phi,
                                  state.carry, state.stream, state.t))


def _scan_jaxpr(mdl, data, topo, n_iters=3, **kw):
    """The vb_run scan jaxpr (the path carrying the kl/msd/rho taps);
    a fresh closure per call, same cache-safety argument as above."""
    state = engine.vb_init(mdl, data, topo, **kw)
    ses = state.session

    def fn(phi, carry, st, t):
        return engine._scan_steps(
            ses.model, ses.data, ses.topology, ses.schedule,
            ses.replication, ses.ref_phi, n_iters, phi, carry, t0=t,
            stream0=st, diagnostics=ses.diagnostics,
            metric_nodes=ses.metric_nodes, minibatch=ses.minibatch)

    return str(jax.make_jaxpr(fn)(state.phi, state.carry, state.stream,
                                  state.t))


def test_disabled_and_host_enabled_jaxprs_identical(setup):
    """The pin: neither the default-off state nor host-only telemetry
    may change the compiled program; only taps.enable() may (and then
    io_callback must actually appear where a tap site exists)."""
    mdl, adj, W, data = setup
    spec = stream_lib.MinibatchSpec(4, seed=1, control_variate="svrg")
    for jaxpr_of, topo, kw in (
            (_step_jaxpr, engine.Diffusion(W), {"minibatch": spec}),
            (_step_jaxpr, engine.ADMMConsensus(adj), {}),
            (_scan_jaxpr, engine.ADMMConsensus(adj), {}),
            (_scan_jaxpr, engine.Diffusion(W), {})):
        base = jaxpr_of(mdl, (data.x, data.mask), topo, **kw)
        with telemetry.enabled_scope():
            host_on = jaxpr_of(mdl, (data.x, data.mask), topo, **kw)
        assert host_on == base          # byte-identical
        assert "io_callback" not in base


def test_taps_insert_io_callback_where_sites_exist(setup):
    """taps.enable() inserts io_callback in every path with a tap site:
    the vb_run scan (kl/msd/rho taps) and the streaming session step
    (epoch + SVRG-anchor taps).  A plain full-batch session step has no
    tap sites, so its jaxpr stays untouched even with taps on."""
    mdl, adj, W, data = setup
    spec = stream_lib.MinibatchSpec(4, seed=1, control_variate="svrg")
    plain = _step_jaxpr(mdl, (data.x, data.mask),
                        engine.ADMMConsensus(adj))
    with taps.enabled_scope():
        assert "io_callback" in _scan_jaxpr(
            mdl, (data.x, data.mask), engine.ADMMConsensus(adj))
        assert "io_callback" in _scan_jaxpr(
            mdl, (data.x, data.mask), engine.Diffusion(W))
        assert "io_callback" in _step_jaxpr(
            mdl, (data.x, data.mask), engine.Diffusion(W),
            minibatch=spec)
        assert _step_jaxpr(mdl, (data.x, data.mask),
                           engine.ADMMConsensus(adj)) == plain


def test_tap_series_from_scan(setup):
    """Taps inside the engine scan stream per-iteration series out in
    absolute-t order (unordered io_callback + t-indexed records)."""
    mdl, adj, W, data = setup
    with taps.enabled_scope():
        state = engine.vb_init(mdl, (data.x, data.mask),
                               engine.ADMMConsensus(adj))
        state, _ = engine.vb_run(state, 6)
        state, _ = engine.vb_run(state, 6)       # resumed: absolute t
        jax.block_until_ready(state.phi)
    ts, kl = taps.series("vb/kl_mean")
    assert ts.tolist() == list(range(12))
    assert kl.shape == (12,) and np.all(np.isfinite(kl))
    ts_r, rho = taps.series("vb/admm_rho")
    assert ts_r.tolist() == list(range(12)) and np.all(rho > 0)


def test_vb_run_diag_slot_series(setup):
    """Host telemetry alone (no device taps) files the scan's own
    outputs as vb_run/* series — no recompilation, absolute-t indexed."""
    mdl, adj, W, data = setup
    with telemetry.enabled_scope():
        state = engine.vb_init(mdl, (data.x, data.mask),
                               engine.ADMMConsensus(adj, adaptive_rho=True))
        state, _ = engine.vb_run(state, 10)
        state, _ = engine.vb_run(state, 5)
    ts, kl = taps.series("vb_run/kl_mean")
    assert ts.tolist() == list(range(15)) and kl.shape == (15,)
    for name in ("vb_run/consensus_msd", "vb_run/admm_rho",
                 "vb_run/admm_primal_resid", "vb_run/admm_dual_resid"):
        ts_n, vals = taps.series(name)
        assert ts_n.tolist() == list(range(15)), name
        assert np.all(np.isfinite(vals)), name


def test_taps_record_series_ordering():
    taps.record_series("s", np.arange(6.0).reshape(3, 2),
                       ts=np.array([7, 5, 6]))
    ts, vals = taps.series("s")
    assert ts.tolist() == [5, 6, 7]
    assert vals[0].tolist() == [2.0, 3.0]        # sorted by t
    assert sorted(taps.names()) == ["s"]
    taps.clear()
    assert taps.names() == []


# ---------------------------------------------------------------------------
# Driver integration (enabled path + compile-count pin)
# ---------------------------------------------------------------------------
def _run_fleet(mdl, W, tmp_path, n_sessions=3, ckpt=True):
    svc = VBService(slice_iters=8, max_fleet=2,
                    ckpt_dir=str(tmp_path) if ckpt else None,
                    ckpt_every=2 if ckpt else 0)
    for s in range(n_sessions):
        d = synthetic.paper_synthetic(n_nodes=N_NODES, n_per_node=10,
                                      seed=s)
        svc.submit(VBRequest(model=mdl, data=(d.x, d.mask),
                             topology=engine.RingDiffusion(),
                             n_iters=16 + 8 * (s % 2)))
    svc.run()
    return svc.stats()


def test_traced_driver_run_spans_and_metrics(setup, tmp_path):
    """Enabled-path acceptance: slice/compile/checkpoint spans on the
    timeline; occupancy/queue-depth/padding-waste gauges and eviction
    counters in the snapshot; compiles stays 1 (telemetry does not
    perturb the no-recompilation contract)."""
    mdl, adj, W, data = setup
    telemetry.enable()
    st = _run_fleet(mdl, W, tmp_path)
    assert st.compiles == 1 and st.evicted == 3
    assert st.checkpoints > 0 and st.checkpoint_errors == 0

    names = set(telemetry.tracer().span_names())
    assert {"driver/slice", "driver/compile", "driver/sync",
            "driver/checkpoint", "driver/admit",
            "driver/evict"} <= names

    trace = telemetry.tracer().to_chrome()
    assert json.dumps(trace)                     # loadable
    slice_evs = [e for e in trace["traceEvents"]
                 if e["name"] == "driver/slice"]
    assert len(slice_evs) == st.slices
    assert all(e["ph"] == "X" and e["dur"] >= 0 for e in slice_evs)

    rows = {r["name"]: r for r in telemetry.snapshot()}
    for gauge in ("driver_occupancy", "driver_queue_depth",
                  "driver_padding_waste", "driver_active",
                  "driver_capacity"):
        assert gauge in rows, gauge
    assert rows["driver_evicted_total"]["value"] == 3.0
    assert rows["driver_admitted_total"]["value"] == 3.0
    assert rows["driver_checkpoints_total"]["value"] == st.checkpoints
    assert 0.0 <= rows["driver_occupancy"]["value"] <= 1.0
    prom = telemetry.to_prometheus()
    assert "driver_checkpoint_write_seconds_bucket" in prom


def test_disabled_driver_leaves_no_telemetry(setup, tmp_path):
    mdl, adj, W, data = setup
    st = _run_fleet(mdl, W, tmp_path, ckpt=False)
    assert st.compiles == 1
    assert len(telemetry.registry()) == 0
    assert len(telemetry.tracer()) == 0


# ---------------------------------------------------------------------------
# CheckpointWriter failure handling (satellite)
# ---------------------------------------------------------------------------
def _blocked_dir(tmp_path) -> str:
    """A checkpoint 'directory' that is actually a regular file, so
    ckpt.save's makedirs raises deterministically on every write."""
    blocker = tmp_path / "blocker"
    blocker.write_text("not a directory")
    return str(blocker / "sub")


def test_checkpoint_writer_failure_counts_and_survives(setup, tmp_path):
    mdl, adj, W, data = setup
    w = drv.CheckpointWriter()
    bad = os.path.join(_blocked_dir(tmp_path), "x.npz")
    pending = w.submit({"t": np.int64(3)}, bad)
    with pytest.raises(OSError):
        pending.wait()
    assert w.errors == 1 and w.completed == 0
    # the daemon thread survived: a good write still lands
    good = str(tmp_path / "ok.npz")
    assert w.submit({"t": np.int64(3)}, good).wait() == good
    assert w.completed == 1 and w.errors == 1
    assert os.path.exists(good)


def test_driver_autosave_failure_does_not_kill_scheduler(setup, tmp_path):
    """Every periodic autosave fails, yet the fleet drains normally and
    the failures surface in DriverStats.checkpoint_errors (previously
    they vanished: autosaves never wait() on their futures)."""
    mdl, adj, W, data = setup
    svc = VBService(slice_iters=8, max_fleet=2,
                    ckpt_dir=_blocked_dir(tmp_path), ckpt_every=1)
    rids = []
    for s in range(3):
        d = synthetic.paper_synthetic(n_nodes=N_NODES, n_per_node=10,
                                      seed=s)
        rids.append(svc.submit(VBRequest(
            model=mdl, data=(d.x, d.mask),
            topology=engine.RingDiffusion(), n_iters=16)))
    out = svc.run()
    st = svc.stats()
    assert all(out[r].done for r in rids)        # scheduler survived
    assert st.checkpoint_errors > 0
    assert st.checkpoints == 0
    # explicit save_session(wait=True) still raises to the caller
    with pytest.raises(OSError):
        svc.save_session(rids[0],
                         os.path.join(_blocked_dir(tmp_path), "s.npz"))


def test_driver_stats_has_checkpoint_errors_default():
    """LM Engine.stats() builds DriverStats without the new field — the
    appended default must keep that call site valid."""
    st = drv.DriverStats(slices=1, compiles=1, admitted=1, evicted=0,
                         queue_depth=0, active=1, capacity=2,
                         occupancy=0.5, padding_waste=0.5, checkpoints=0)
    assert st.checkpoint_errors == 0


# ---------------------------------------------------------------------------
# Backend-fallback warn-once (satellite bugfix)
# ---------------------------------------------------------------------------
def test_backend_fallback_warns_once_and_counts():
    from repro.core import linreg

    mdl = model_lib.LinRegModel(linreg.prior(2))
    phi_star = np.stack([np.asarray(mdl.init_phi()) + 1.0,
                         np.asarray(mdl.init_phi()) - 1.0])
    telemetry.enable()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        for _ in range(3):
            engine.vb_init(mdl, phi_star, engine.FusionCenter(),
                           backend="fused")
    fallback = [w for w in caught
                if "falling back to the reference backend"
                in str(w.message)]
    assert len(fallback) == 1                    # once per session...
    rows = {r["name"]: r for r in telemetry.snapshot()}
    assert rows["backend_fallback_total"]["value"] == 3.0  # ...all counted
    assert rows["backend_fallback_total"]["labels"]["backend"] == "fused"

    telemetry.reset()                            # new session: warns again
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        engine.vb_init(mdl, phi_star, engine.FusionCenter(),
                       backend="fused")
    assert any("falling back" in str(w.message) for w in caught)


# ---------------------------------------------------------------------------
# Bench gate
# ---------------------------------------------------------------------------
def _load_gate():
    spec = importlib.util.spec_from_file_location(
        "bench_gate", os.path.join(ROOT, "tools", "bench_gate.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_bench_gate_passes_on_committed_baseline():
    gate = _load_gate()
    baseline = gate.load(os.path.join(ROOT, "BENCH_engine.json"))
    failures, checks = gate.gate(baseline, baseline,
                                 max_ratio=gate.DEFAULT_MAX_RATIO)
    assert failures == []
    assert checks                                # something was checked


def test_bench_gate_fails_on_degraded_rows():
    gate = _load_gate()
    baseline = gate.load(os.path.join(ROOT, "BENCH_engine.json"))

    slow = json.loads(json.dumps(baseline))
    slow["results"]["vb_driver_poisson"]["us_per_call"] *= 100
    failures, _ = gate.gate(baseline, slow,
                            max_ratio=gate.DEFAULT_MAX_RATIO)
    assert any("TIMING" in f and "vb_driver_poisson" in f
               for f in failures)

    broken = json.loads(json.dumps(baseline))
    broken["results"]["vb_driver_poisson"]["derived"] = (
        broken["results"]["vb_driver_poisson"]["derived"]
        .replace("compiles=1", "compiles=5")
        .replace("speedup_vs_sync=2.4x", "speedup_vs_sync=0.9x"))
    failures, _ = gate.gate(baseline, broken,
                            max_ratio=gate.DEFAULT_MAX_RATIO)
    assert sum("DERIVED" in f for f in failures) == 2

    failed = json.loads(json.dumps(baseline))
    failed["failed"] = ["svrg_vb"]
    failures, _ = gate.gate(baseline, failed,
                            max_ratio=gate.DEFAULT_MAX_RATIO)
    assert any("bench FAILED" in f for f in failures)


def test_bench_gate_parse_derived():
    gate = _load_gate()
    d = gate.parse_derived(
        "speedup_vs_sync=2.4x compiles=1 degen_bitexact=True "
        "p50_latency_s=0.05 label=GMM/N8 bare x=")
    assert d["speedup_vs_sync"] == 2.4
    assert d["compiles"] == 1.0
    assert d["degen_bitexact"] is True
    assert d["label"] == "GMM/N8"
    assert "bare" not in d and "x" not in d
    assert gate._check_rule(2.4, ">=", 2.0)
    assert not gate._check_rule(5, "<=", 1)
    assert gate._check_rule(True, "==", True)


def test_bench_gate_empty_fresh_fails():
    gate = _load_gate()
    failures, _ = gate.gate({"results": {}}, {"results": {}},
                            max_ratio=4.0)
    assert any("nothing was gated" in f for f in failures)
