"""Session/state API + serving layer tests.

The resumability contract: `VBState.t` is the ABSOLUTE iteration count and
every per-iteration source of randomness (minibatch reshuffling epochs,
link-failure schedules, the eta_t/kappa_t ramps) is a function of it, so

    vb_run(s, a + b)  ==  vb_run(vb_run(s, a)[0], b)      (bit-exact)

for every topology — including the ADMM adaptive-rho dual/gate state and
link-drop schedules — plus checkpoint save -> restore -> continue parity
through checkpoint/ckpt.py, the carried epoch-permutation stream state
matching the stateless sampler, and the `VBService` fleet semantics
(same-shape batching == solo runs, per-session budgets/early-stop,
mid-flight data arrival, checkpoint restore).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.core import engine, expfam, network
from repro.core import model as model_lib
from repro.data import stream, synthetic
from repro.serving.vb_service import VBRequest, VBService


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


K, D, N_NODES = 3, 2, 8


@pytest.fixture(scope="module")
def setup():
    data = synthetic.paper_synthetic(n_nodes=N_NODES, n_per_node=20, seed=2)
    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
    adj, _ = network.random_geometric_graph(N_NODES, seed=4)
    W = network.nearest_neighbor_weights(adj)
    mdl = model_lib.GMMModel(prior, K, D)
    return data, mdl, adj, W


def _assert_trees_bitequal(a, b, what):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), what
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=what)


def _topo_configs(adj, W):
    """(name, topology factory, vb_init kwargs) — every topology, with
    link-drop / minibatch riding along where they apply."""
    return [
        ("fusion", lambda: engine.FusionCenter(),
         dict(schedule=engine.ONE_SHOT)),
        ("isolated", lambda: engine.Isolated(),
         dict(schedule=engine.ONE_SHOT, replication=1.0)),
        ("diffusion-mb-drop",
         lambda: engine.Diffusion(W, link_drop=0.3, link_seed=5),
         dict(minibatch=stream.MinibatchSpec(7, seed=3))),
        ("ring-drop",
         lambda: engine.RingDiffusion(link_drop=0.25, link_seed=6), {}),
        ("admm-plain", lambda: engine.ADMMConsensus(adj), {}),
        ("admm-adaptive-pb",
         lambda: engine.ADMMConsensus(adj, adaptive_rho=True,
                                      per_block=True), {}),
    ]


# ---------------------------------------------------------------------------
# Split-resume bit-exactness: vb_run(s, a+b) == vb_run(vb_run(s, a), b)
# ---------------------------------------------------------------------------
def test_split_resume_bit_exact_every_topology(setup):
    data, mdl, adj, W = setup
    a, b = 37, 63
    for name, topo, kw in _topo_configs(adj, W):
        full = engine.vb_init(mdl, (data.x, data.mask), topo(), **kw)
        full, run_full = engine.vb_run(full, a + b)

        split = engine.vb_init(mdl, (data.x, data.mask), topo(), **kw)
        split, run_a = engine.vb_run(split, a)
        assert int(split.t) == a, name
        split, run_b = engine.vb_run(split, b)
        assert int(split.t) == a + b, name

        _assert_trees_bitequal(full.phi, split.phi, f"{name}: phi")
        _assert_trees_bitequal(full.carry, split.carry, f"{name}: carry")
        _assert_trees_bitequal(full.stream, split.stream,
                               f"{name}: stream")
        _assert_trees_bitequal(full.diag, split.diag, f"{name}: diag")
        # the per-iteration trajectories also tile exactly
        _assert_trees_bitequal(
            run_full.kl_nodes,
            jnp.concatenate([run_a.kl_nodes, run_b.kl_nodes]),
            f"{name}: kl trajectory")
        _assert_trees_bitequal(
            run_full.consensus_err,
            jnp.concatenate([run_a.consensus_err, run_b.consensus_err]),
            f"{name}: consensus trajectory")


def test_single_stepping_matches_scan(setup):
    """vb_step x n == vb_run(s, n) bit-exactly (the serving quantum)."""
    data, mdl, adj, W = setup
    topo = engine.ADMMConsensus(adj, adaptive_rho=True)
    s_scan = engine.vb_init(mdl, (data.x, data.mask), topo,
                            minibatch=stream.MinibatchSpec(9, seed=1))
    s_scan, _ = engine.vb_run(s_scan, 5)
    s_step = engine.vb_init(mdl, (data.x, data.mask), topo,
                            minibatch=stream.MinibatchSpec(9, seed=1))
    for _ in range(5):
        s_step = engine.vb_step(s_step)
    _assert_trees_bitequal(s_scan.phi, s_step.phi, "phi")
    _assert_trees_bitequal(s_scan.carry, s_step.carry, "carry")
    _assert_trees_bitequal(s_scan.stream, s_step.stream, "stream")
    assert int(s_step.t) == 5


def test_run_vb_wrapper_is_session_path(setup):
    data, mdl, adj, W = setup
    run_w = engine.run_vb(mdl, (data.x, data.mask), engine.Diffusion(W),
                          n_iters=20)
    state = engine.vb_init(mdl, (data.x, data.mask), engine.Diffusion(W))
    _, run_s = engine.vb_run(state, 20)
    _assert_trees_bitequal(run_w.phi, run_s.phi, "phi")
    _assert_trees_bitequal(run_w.kl_nodes, run_s.kl_nodes, "kl")


def test_carried_stream_state_matches_stateless_sampler(setup):
    """The epoch-permutation carry (ROADMAP follow-up: no per-iteration
    O(T log T) redraw) is bit-exact with the stateless oracle."""
    data, mdl, *_ = setup
    B = 6
    keys = stream.node_keys(N_NODES, seed=11)
    st = stream.init_state(N_NODES, 11, data.mask.shape[1])
    for t in range(25):
        ta = jnp.asarray(t)
        i_ref, m_ref = stream.minibatch_select(keys, data.mask, ta, B)
        st, i_new, m_new = stream.advance(st, data.mask, ta, B)
        np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_new))
        np.testing.assert_array_equal(np.asarray(m_ref), np.asarray(m_new))
    assert int(st.epoch) == 24 // -(-data.mask.shape[1] // B)


# ---------------------------------------------------------------------------
# Checkpointing: save -> restore -> continue == uninterrupted
# ---------------------------------------------------------------------------
def test_checkpoint_restore_continue_parity(setup, tmp_path):
    data, mdl, adj, W = setup
    mk = lambda: engine.vb_init(
        mdl, (data.x, data.mask),
        engine.ADMMConsensus(adj, adaptive_rho=True),
        minibatch=stream.MinibatchSpec(7, seed=1))
    s = mk()
    s, _ = engine.vb_run(s, 9)
    path = os.path.join(tmp_path, "state.npz")
    ckpt.save(path, s)
    restored = ckpt.restore(path, mk())     # fresh session re-attached
    assert int(restored.t) == 9
    s, _ = engine.vb_run(s, 11)
    restored, _ = engine.vb_run(restored, 11)
    _assert_trees_bitequal(s.phi, restored.phi, "phi")
    _assert_trees_bitequal(s.carry, restored.carry, "carry")
    _assert_trees_bitequal(s.stream, restored.stream, "stream")


def test_vbstate_validation(setup):
    data, mdl, adj, W = setup
    state = engine.vb_init(mdl, (data.x, data.mask), engine.Diffusion(W))
    with pytest.raises(ValueError, match="shapes/dtypes"):
        state.with_data((data.x[:, :5], data.mask))
    # with_data round-trips and keeps the evolving state
    state2 = state.with_data((data.x + 1.0, data.mask))
    assert state2.session.data[0].shape == data.x.shape
    with pytest.raises(ValueError, match="session"):
        engine.vb_run(engine.VBState(state.phi, state.t), 1)


# ---------------------------------------------------------------------------
# VBService: fleets
# ---------------------------------------------------------------------------
def test_service_fleet_matches_solo_with_heterogeneous_budgets(setup):
    data, mdl, adj, W = setup
    datasets = [synthetic.paper_synthetic(n_nodes=N_NODES, n_per_node=20,
                                          seed=s) for s in range(3)]
    budgets = [17, 26, 40]
    svc = VBService(slice_iters=10)
    # a FRESH (but structurally equal, same W array) topology per request
    # must still land every tenant in ONE fleet group
    rids = [svc.submit(VBRequest(model=mdl, data=(d.x, d.mask),
                                 topology=engine.Diffusion(W), n_iters=n))
            for d, n in zip(datasets, budgets)]
    assert len(svc._groups) == 1
    topo = engine.Diffusion(W)
    out = svc.run()
    for d, n, rid in zip(datasets, budgets, rids):
        st = out[rid]
        assert st.done and st.t == n and st.budget == n
        solo = engine.run_vb(mdl, (d.x, d.mask), topo, n_iters=n)
        err = float(jnp.max(jnp.abs(solo.phi - st.phi)))
        assert err < 1e-8, (rid, err)


def test_service_16_session_mixed_topology_fleet(setup):
    """The acceptance scenario: a 16-session mixed-topology fleet with
    per-session early stop, mid-flight data arrival and checkpoint
    restore, all in one service."""
    data, mdl, adj, W = setup
    topos = [engine.Diffusion(W),
             engine.ADMMConsensus(adj, adaptive_rho=True)]
    svc = VBService(slice_iters=6)
    rids = []
    for i in range(16):
        d = synthetic.paper_synthetic(n_nodes=N_NODES, n_per_node=10,
                                      seed=i)
        mask = d.mask.at[:, -2:].set(0.0)       # free slots for arrival
        # session 4 gets a long budget + loose tol: it must EARLY-stop
        # inside its fleet while its fleet-mates run to their budgets
        rids.append(svc.submit(VBRequest(
            model=mdl, data=(d.x, mask), topology=topos[i % 2],
            n_iters=300 if i == 4 else 12 + (i % 4) * 6,
            tol=5e-2 if i == 4 else 0.0)))
    assert len(svc._groups) == 2                # one fleet per topology
    svc.step_slice()
    svc.push_data(rids[3], node=1,
                  points=np.random.default_rng(0).normal(size=(2, D)))
    out = svc.run()
    for i, rid in enumerate(rids):
        st = out[rid]
        assert st.done, rid
        if i != 4:
            assert st.t == st.budget == 12 + (i % 4) * 6
    assert out[rids[4]].converged and out[rids[4]].t < 300


def test_service_early_stop_freezes_state(setup):
    data, mdl, adj, W = setup
    svc = VBService(slice_iters=5)
    rid = svc.submit(VBRequest(model=mdl, data=(data.x, data.mask),
                               topology=engine.Diffusion(W),
                               n_iters=400, tol=1e-2))
    out = svc.run()
    st = out[rid]
    assert st.converged and st.done and st.t < 400
    assert st.delta < 1e-2
    # the frozen state equals a solo run of exactly st.t iterations
    solo = engine.run_vb(mdl, (data.x, data.mask), engine.Diffusion(W),
                         n_iters=st.t)
    assert float(jnp.max(jnp.abs(solo.phi - st.phi))) < 1e-8


def test_service_push_and_replace_data(setup):
    data, mdl, adj, W = setup
    mask = data.mask.at[:, 15:].set(0.0)        # free capacity everywhere
    svc = VBService(slice_iters=4)
    rid = svc.submit(VBRequest(model=mdl, data=(data.x, mask),
                               topology=engine.Diffusion(W), n_iters=8))
    svc.step_slice()
    before = np.asarray(svc.status(rid).phi)
    svc.push_data(rid, node=2,
                  points=np.random.default_rng(1).normal(size=(3, D)))
    out = svc.run()
    # the appended points changed the remaining trajectory
    assert not np.allclose(before, np.asarray(out[rid].phi))
    # overflowing a node's buffer is no longer an error: the bucketed
    # driver regrows the session to a larger ladder rung (the buffer-full
    # ValueError still surfaces with bucket=None — tests/test_bucketed.py)
    svc.push_data(rid, node=2, points=np.zeros((100, D)))
    with pytest.raises(ValueError, match="signature mismatch"):
        svc.replace_data(rid, (data.x[:3], mask[:3]))   # wrong node count
    svc.replace_data(rid, (data.x, mask))
    svc.extend_budget(rid, 4)
    out = svc.run()
    assert out[rid].t == 12


def test_service_checkpoint_restore_bit_exact(setup, tmp_path):
    data, mdl, adj, W = setup
    req = VBRequest(model=mdl, data=(data.x, data.mask),
                    topology=engine.Diffusion(W), n_iters=30,
                    minibatch=stream.MinibatchSpec(7, seed=1))
    svc_a = VBService(slice_iters=10)
    rid_a = svc_a.submit(req)
    svc_a.step_slice()
    path = os.path.join(tmp_path, "sess.npz")
    svc_a.save_session(rid_a, path)
    svc_b = VBService(slice_iters=10)
    rid_b = svc_b.submit(req, restore_from=path)
    assert svc_b.status(rid_b).t == 10
    out_a, out_b = svc_a.run(), svc_b.run()
    _assert_trees_bitequal(out_a[rid_a].phi, out_b[rid_b].phi, "phi")


def test_service_rejects_bad_requests(setup):
    data, mdl, adj, W = setup
    svc = VBService(slice_iters=4)
    with pytest.raises(ValueError, match="n_iters"):
        svc.submit(VBRequest(model=mdl, data=(data.x, data.mask),
                             topology=engine.Diffusion(W), n_iters=0))
    with pytest.raises(KeyError):
        svc.status("nope")
    with pytest.raises(ValueError, match="slice_iters"):
        VBService(slice_iters=0)


# ---------------------------------------------------------------------------
# Mesh executor: session resume + fleet service under shard_map
# ---------------------------------------------------------------------------
CODE_MESH_SESSION = r"""
import jax
from repro.core import expfam
expfam.enable_x64()
import jax.numpy as jnp
from repro.core import engine, network
from repro.core import model as model_lib
from repro.data import synthetic, stream
from repro.serving.vb_service import VBRequest, VBService

K, D, N = 3, 2, 8
data = synthetic.paper_synthetic(n_nodes=N, n_per_node=20, seed=9)
prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
adj, _ = network.random_geometric_graph(N, seed=5)
W = network.nearest_neighbor_weights(adj)
mdl = model_lib.GMMModel(prior, K, D)
mesh = jax.make_mesh((4,), ("data",))
mexec = engine.MeshExecutor(mesh, "data")

# 1. vb_run split-resume under the mesh executor == unsplit single-array
for topo_fn, mb in [
    (lambda: engine.Diffusion(W, link_drop=0.3, link_seed=5),
     stream.MinibatchSpec(7, seed=3)),
    (lambda: engine.ADMMConsensus(adj, adaptive_rho=True), None),
]:
    s = engine.vb_init(mdl, (data.x, data.mask), topo_fn(), executor=mexec,
                       minibatch=mb)
    s, _ = engine.vb_run(s, 11)
    s, _ = engine.vb_run(s, 14)
    solo = engine.run_vb(mdl, (data.x, data.mask), topo_fn(), n_iters=25,
                         minibatch=mb)
    err = float(jnp.max(jnp.abs(solo.phi - s.phi)))
    assert err < 1e-8, err

# 2. VBService fleet with the node axis sharded (vmap inside shard_map)
svc = VBService(slice_iters=9, executor=mexec)
datasets = [synthetic.paper_synthetic(n_nodes=N, n_per_node=20, seed=s)
            for s in range(3)]
topo = engine.RingDiffusion()
rids = [svc.submit(VBRequest(model=mdl, data=(d.x, d.mask), topology=topo,
                             n_iters=20)) for d in datasets]
out = svc.run()
for d, r in zip(datasets, rids):
    solo = engine.run_vb(mdl, (d.x, d.mask), topo, n_iters=20)
    err = float(jnp.max(jnp.abs(solo.phi - out[r].phi)))
    assert err < 1e-8, (r, err)
print("OK")
"""


def test_mesh_session_and_service(subproc):
    out = subproc(CODE_MESH_SESSION, n_devices=4)
    assert "OK" in out
