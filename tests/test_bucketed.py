"""Bucketed ragged-fleet admission (PR 7 acceptance suite).

The contract under test, end to end:

* **ladder** — `admission.bucket_capacity` / `bucket_for` round per-node
  data capacities up to geometric rungs;
* **padding is invisible** — `model.pad_to_capacity` adds mask-zero
  slots and the engine's ordered reductions keep the padded session
  BIT-EQUAL to the unpadded solo `vb_run`, on every topology, both
  executors and both GMM compute backends;
* **mixed shapes share a fleet** — sessions whose capacities round to
  one rung land in ONE fleet group (one compiled slice fn), each still
  bit-equal (elementwise combines) / 1e-9-close (matmul combines, the
  PR-6 contract) to its solo run;
* **mixed hyper share a fleet** — tau/rho become per-slot fleet arrays
  (`engine.hyper_names`), so sessions differing only in those schedule
  knobs also share the group;
* **overflow re-buckets** — `push_data` beyond the rung evicts, regrows
  to the next rung and re-admits under the absolute-t resume contract
  (trajectory replayable with vb_init/vb_run);
* `static_signature` signs small arrays by content (regression: it used
  to sign by object identity, splitting equal-config groups).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, expfam, linreg, network
from repro.core import model as model_lib
from repro.data import stream, synthetic
from repro.serving import admission
from repro.serving.vb_service import VBRequest, VBService


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


K, D, N_NODES = 3, 2, 8


@pytest.fixture(scope="module")
def setup():
    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
    mdl = model_lib.GMMModel(prior, K, D)
    adj, _ = network.random_geometric_graph(N_NODES, seed=4)
    W = network.nearest_neighbor_weights(adj)
    return mdl, adj, W


def _gmm_data(n_per_node, seed=0):
    d = synthetic.paper_synthetic(n_nodes=N_NODES, n_per_node=n_per_node,
                                  seed=seed)
    return d.x, d.mask


# ---------------------------------------------------------------------------
# The ladder
# ---------------------------------------------------------------------------
def test_bucket_capacity_ladder():
    assert [admission.bucket_capacity(n) for n in (1, 8, 9, 25, 64, 65)] \
        == [8, 8, 16, 32, 64, 128]
    # finer tensor2tensor-style rungs: at most ~25% padded slots (above
    # the min_size floor, where everything rounds up to the first rung)
    caps = {n: admission.bucket_capacity(n, growth=1.25)
            for n in range(8, 200)}
    assert all(c >= n and (c - n) / c < 0.25 + 1e-9
               for n, c in caps.items())
    assert admission.bucket_capacity(25, growth=1.25) == 28
    # tiny growth factors still make a strictly increasing ladder
    assert admission.bucket_capacity(9, growth=1.01) > 8
    with pytest.raises(ValueError):
        admission.bucket_capacity(0)
    with pytest.raises(ValueError):
        admission.bucket_capacity(5, growth=1.0)


def test_bucket_for_rounds_capacity_axis_only():
    a = admission.shape_signature((jnp.zeros((4, 25, 2)),
                                   jnp.zeros((4, 25))))
    b = admission.shape_signature((jnp.zeros((4, 32, 2)),
                                   jnp.zeros((4, 32))))
    c = admission.shape_signature((jnp.zeros((4, 33, 2)),
                                   jnp.zeros((4, 33))))
    assert admission.bucket_for(a) == admission.bucket_for(b)
    assert admission.bucket_for(a) != admission.bucket_for(c)   # next rung
    # node axis (axis 0) and trailing axes are never bucketed
    d = admission.shape_signature((jnp.zeros((5, 25, 2)),
                                   jnp.zeros((5, 25))))
    assert admission.bucket_for(a) != admission.bucket_for(d)
    # 1-D leaves (e.g. a phi* row) pass through untouched
    e = admission.shape_signature(jnp.zeros((25,)))
    assert admission.bucket_for(e) == e


# ---------------------------------------------------------------------------
# static_signature: content digest for small arrays (id() regression)
# ---------------------------------------------------------------------------
def test_static_signature_small_arrays_by_content(setup):
    mdl, adj, W = setup
    # two separately-built equal-valued topologies sign EQUAL
    assert admission.static_signature(engine.Diffusion(W.copy())) \
        == admission.static_signature(engine.Diffusion(W.copy()))
    W2 = np.asarray(W).copy()
    W2[0, 0] += 1e-3
    assert admission.static_signature(engine.Diffusion(W)) \
        != admission.static_signature(engine.Diffusion(W2))


def test_static_signature_large_arrays_by_identity():
    big = np.zeros((1 << 14, 3))        # > DIGEST_MAX_BYTES
    assert big.nbytes > admission.DIGEST_MAX_BYTES
    assert admission.static_signature(big) \
        != admission.static_signature(big.copy())   # conservative split
    assert admission.static_signature(big) == admission.static_signature(big)
    small = big[:4].copy()
    assert admission.static_signature(small) \
        == admission.static_signature(small.copy())


def test_static_signature_ignore_lifted_attrs(setup):
    mdl, adj, W = setup
    a = engine.ADMMConsensus(adj, rho=0.3)
    b = engine.ADMMConsensus(adj, rho=0.9)
    lifted = engine.lifted_attr_names(a)
    assert "rho" in lifted
    assert admission.static_signature(a) != admission.static_signature(b)
    assert admission.static_signature(a, ignore=lifted) \
        == admission.static_signature(b, ignore=lifted)


# ---------------------------------------------------------------------------
# pad_to_capacity: padded solo run bit-equal to unpadded solo run
# ---------------------------------------------------------------------------
def _topologies(adj, W):
    return [
        ("fusion", engine.FusionCenter(), engine.ONE_SHOT),
        ("isolated", engine.Isolated(), engine.Schedule()),
        ("ring", engine.RingDiffusion(), engine.Schedule(tau=0.1)),
        ("diffusion", engine.Diffusion(W), engine.Schedule()),
        ("admm", engine.ADMMConsensus(adj), engine.Schedule()),
        ("admm-adaptive", engine.ADMMConsensus(adj, adaptive_rho=True),
         engine.Schedule()),
    ]


def test_gmm_padding_bit_equal_every_topology(setup):
    """The tentpole numerics contract: padding a session's data buffers
    to the ladder rung with mask-zero slots changes NO bit of phi, for
    every estimator (ordered within-node reductions make the zero slots
    exact no-ops)."""
    mdl, adj, W = setup
    data = _gmm_data(25)
    padded = mdl.pad_to_capacity(data, admission.bucket_capacity(25))
    assert padded[0].shape == (N_NODES, 32, D)
    for name, topo, sched in _topologies(adj, W):
        a = engine.run_vb(mdl, data, topo, n_iters=12, schedule=sched)
        b = engine.run_vb(mdl, padded, topo, n_iters=12, schedule=sched)
        np.testing.assert_array_equal(np.asarray(a.phi), np.asarray(b.phi),
                                      err_msg=name)


def test_gmm_padding_bit_equal_fused_backend(setup):
    """The Pallas backend blocks the sample axis T-independently, so the
    fused estimator keeps the same guarantee."""
    mdl, adj, W = setup
    data = _gmm_data(25)
    padded = mdl.pad_to_capacity(data, 32)
    for backend in ("reference", "fused"):
        a = engine.run_vb(mdl, data, engine.RingDiffusion(), n_iters=8,
                          backend=backend)
        b = engine.run_vb(mdl, padded, engine.RingDiffusion(), n_iters=8,
                          backend=backend)
        np.testing.assert_array_equal(np.asarray(a.phi), np.asarray(b.phi),
                                      err_msg=backend)


def test_linreg_padding_bit_equal(setup):
    mdl, adj, W = setup
    rng = np.random.default_rng(3)
    Dl, ni = 3, 13
    X = jnp.asarray(rng.normal(size=(N_NODES, ni, Dl)))
    y = jnp.asarray(X @ rng.normal(size=Dl)
                    + rng.normal(size=(N_NODES, ni)) * 0.3)
    mask = jnp.ones((N_NODES, ni), X.dtype)
    lr = model_lib.LinRegModel(linreg.prior(Dl))
    padded = lr.pad_to_capacity((X, y, mask), 16)
    assert padded[0].shape == (N_NODES, 16, Dl)
    a = engine.run_vb(lr, (X, y, mask), engine.RingDiffusion(), n_iters=10)
    b = engine.run_vb(lr, padded, engine.RingDiffusion(), n_iters=10)
    np.testing.assert_array_equal(np.asarray(a.phi), np.asarray(b.phi))


def test_linreg_phi_star_stack_not_padddable(setup):
    """A precomputed phi* stack has no sample axis: pad_to_capacity must
    refuse (the driver then falls back to exact-signature grouping)."""
    lr = model_lib.LinRegModel(linreg.prior(2))
    phi_star = jnp.stack([lr.init_phi() + 1.0, lr.init_phi() - 1.0])
    with pytest.raises(ValueError):
        lr.pad_to_capacity(phi_star, 16)
    with pytest.raises(ValueError):
        lr.pad_to_capacity((jnp.zeros((2, 5, 2)), jnp.zeros((2, 5)),
                            jnp.ones((2, 5))), 4)   # capacity < T


# ---------------------------------------------------------------------------
# Driver: mixed shapes / mixed hyper share one compiled fleet
# ---------------------------------------------------------------------------
def test_mixed_shapes_share_one_fleet_bit_equal_solo(setup):
    """Four sessions with per-node capacities 9/10/13/16 all round to
    rung 16: ONE group, ONE trace, every result bit-equal to the solo
    run on its own unpadded data."""
    mdl, adj, W = setup
    sizes = [9, 10, 13, 16]
    datasets = [_gmm_data(n, seed=i) for i, n in enumerate(sizes)]
    topo = engine.RingDiffusion()
    svc = VBService(slice_iters=6, max_fleet=4)
    rids = [svc.submit(VBRequest(model=mdl, data=d, topology=topo,
                                 n_iters=18)) for d in datasets]
    out = svc.run()
    st = svc.stats()
    assert len(svc._groups) == 1 and st.compiles == 1, st
    assert len(st.buckets) == 1
    b = st.buckets[0]
    assert b.bucket_capacity == 16 and b.label.endswith("/cap16")
    assert b.admitted == 4
    # mean mask-zero padding fraction: ((16-9)+(16-10)+(16-13)+0)/16/4
    assert b.data_pad_frac == pytest.approx((7 + 6 + 3 + 0) / 16 / 4)
    for d, rid in zip(datasets, rids):
        solo = engine.run_vb(mdl, d, topo, n_iters=18)
        np.testing.assert_array_equal(np.asarray(solo.phi),
                                      np.asarray(out[rid].phi), err_msg=rid)


def test_mixed_tau_share_one_fleet_bit_equal_solo(setup):
    """Sessions differing only in the schedule's tau (lifted to a
    per-slot fleet array) share the group and still match their solo
    runs bit-for-bit."""
    mdl, adj, W = setup
    data = _gmm_data(12)
    taus = [0.2, 0.05, 1.0]
    topo = engine.RingDiffusion()
    svc = VBService(slice_iters=5, max_fleet=4)
    rids = [svc.submit(VBRequest(model=mdl, data=data, topology=topo,
                                 n_iters=15,
                                 schedule=engine.Schedule(tau=tau)))
            for tau in taus]
    out = svc.run()
    assert len(svc._groups) == 1 and svc.stats().compiles == 1
    for tau, rid in zip(taus, rids):
        solo = engine.run_vb(mdl, data, topo, n_iters=15,
                             schedule=engine.Schedule(tau=tau))
        np.testing.assert_array_equal(np.asarray(solo.phi),
                                      np.asarray(out[rid].phi),
                                      err_msg=f"tau={tau}")


def test_mixed_rho_admm_share_one_fleet(setup):
    """ADMM sessions differing only in rho (and shape, via the ladder)
    share one group; matmul combines inherit the PR-6 1e-9 contract."""
    mdl, adj, W = setup
    cases = [(10, 0.3), (13, 0.8), (16, 0.5)]
    svc = VBService(slice_iters=5, max_fleet=4)
    rids = [svc.submit(VBRequest(model=mdl, data=_gmm_data(n, seed=n),
                                 topology=engine.ADMMConsensus(adj, rho=r),
                                 n_iters=12))
            for n, r in cases]
    out = svc.run()
    assert len(svc._groups) == 1 and svc.stats().compiles == 1
    for (n, r), rid in zip(cases, rids):
        solo = engine.run_vb(mdl, _gmm_data(n, seed=n),
                             engine.ADMMConsensus(adj, rho=r), n_iters=12)
        err = float(jnp.max(jnp.abs(solo.phi - out[rid].phi)))
        assert err < 1e-9, (n, r, err)


def test_eta_fixed_never_shares_with_scheduled(setup):
    """ONE_SHOT (eta_fixed=1.0) compiles a different step than the
    Robbins-Monro ramp — those sessions must NOT share a group."""
    mdl, adj, W = setup
    data = _gmm_data(12)
    svc = VBService(slice_iters=5, max_fleet=2)
    svc.submit(VBRequest(model=mdl, data=data, topology=engine.Isolated(),
                         n_iters=8))
    svc.submit(VBRequest(model=mdl, data=data, topology=engine.Isolated(),
                         n_iters=8, schedule=engine.ONE_SHOT))
    svc.run()
    assert len(svc._groups) == 2


def test_minibatch_sessions_not_bucketed(setup):
    """Streaming sessions key epoch permutations on the TRUE capacity, so
    they keep exact-shape grouping (and different capacities stay in
    different groups) — still bit-equal to their solo streaming runs."""
    mdl, adj, W = setup
    sizes = [10, 13]
    mb = stream.MinibatchSpec(5, seed=2)
    svc = VBService(slice_iters=5, max_fleet=2)
    rids = [svc.submit(VBRequest(model=mdl, data=_gmm_data(n, seed=n),
                                 topology=engine.RingDiffusion(),
                                 n_iters=10, minibatch=mb))
            for n in sizes]
    out = svc.run()
    assert len(svc._groups) == 2
    labels = [b.label for b in svc.stats().buckets]
    assert all(lab.endswith("/exact") for lab in labels), labels
    for n, rid in zip(sizes, rids):
        solo = engine.run_vb(mdl, _gmm_data(n, seed=n),
                             engine.RingDiffusion(), n_iters=10,
                             minibatch=mb)
        np.testing.assert_array_equal(np.asarray(solo.phi),
                                      np.asarray(out[rid].phi))


def test_bucket_none_keeps_exact_grouping_and_buffer_full(setup):
    """Legacy mode: bucket=None groups by exact signature and push_data
    overflow is still a hard error."""
    mdl, adj, W = setup
    svc = VBService(slice_iters=5, max_fleet=2, bucket=None)
    rids = [svc.submit(VBRequest(model=mdl, data=_gmm_data(n, seed=n),
                                 topology=engine.RingDiffusion(),
                                 n_iters=8)) for n in (10, 13)]
    svc.run()
    assert len(svc._groups) == 2
    with pytest.raises(ValueError, match="buffer full"):
        svc.push_data(rids[0], node=0,
                      points=np.zeros((100, D)))


# ---------------------------------------------------------------------------
# Overflow -> eviction -> re-admission into the next rung
# ---------------------------------------------------------------------------
def test_push_data_overflow_rebuckets_with_exact_replay(setup):
    """A full rung-8 session receives 3 points mid-flight: the driver
    evicts it, regrows the buffers to rung 16, re-admits, and the final
    phi is BIT-EQUAL to the replayed vb_init/vb_run trajectory (run 5
    iters on the old buffers, regrow, run the remaining 15)."""
    mdl, adj, W = setup
    data = _gmm_data(8)                       # rung 8, zero padding slots
    topo = engine.RingDiffusion()
    pts = np.asarray(
        np.random.default_rng(7).normal(size=(3, D)), np.float64)

    svc = VBService(slice_iters=5, max_fleet=2)
    rid = svc.submit(VBRequest(model=mdl, data=data, topology=topo,
                               n_iters=20))
    assert svc.step_slice() == 1              # t=5, mid-flight
    svc.push_data(rid, node=1, points=pts)    # overflow -> re-bucket
    out = svc.run()
    assert out[rid].done and out[rid].t == 20
    st = svc.stats()
    assert st.evicted >= 2                    # overflow eviction + final
    assert any(b.bucket_capacity == 16 for b in st.buckets), st.buckets

    # replay the exact trajectory through the public session API
    s = engine.vb_init(mdl, data, topo)
    s, _ = engine.vb_run(s, 5)
    grown = mdl.append_node_data(mdl.pad_to_capacity(data, 16), 1, pts)
    s2 = engine.vb_init(mdl, grown, topo)
    s2 = s2.replace(phi=s.phi, t=s.t, carry=s.carry)
    s2, _ = engine.vb_run(s2, 15)
    np.testing.assert_array_equal(np.asarray(s2.phi),
                                  np.asarray(out[rid].phi))


def test_replace_data_pads_to_rung(setup):
    """replace_data on a bucketed session accepts any data that pads to
    the session's rung (here: fewer true samples than the original)."""
    mdl, adj, W = setup
    x, mask = _gmm_data(13)                   # rung 16
    svc = VBService(slice_iters=5, max_fleet=2)
    rid = svc.submit(VBRequest(model=mdl, data=(x, mask),
                               topology=engine.RingDiffusion(), n_iters=10))
    svc.run()
    svc.replace_data(rid, (x[:, :9], mask[:, :9]))      # pads 9 -> 16
    out = svc.run()
    solo = engine.run_vb(mdl, (x[:, :9], mask[:, :9]),
                         engine.RingDiffusion(), n_iters=10)
    # the replayed tail ran on the replaced buffers from the old phi, so
    # only shapes/convergence are asserted here; numerics are covered by
    # the padding-invariance tests above
    assert out[rid].done and np.asarray(out[rid].phi).shape == solo.phi.shape


# ---------------------------------------------------------------------------
# Mesh executor: the bucketed fleet composes with shard_map
# ---------------------------------------------------------------------------
CODE_MESH_BUCKETED = r"""
import jax
from repro.core import expfam
expfam.enable_x64()
import jax.numpy as jnp
import numpy as np
from repro.core import engine, network
from repro.core import model as model_lib
from repro.data import synthetic
from repro.serving.vb_service import VBRequest, VBService

K, D, N = 3, 2, 8
prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
mdl = model_lib.GMMModel(prior, K, D)
mesh = jax.make_mesh((4,), ("data",))
mexec = engine.MeshExecutor(mesh, "data")
topo = engine.RingDiffusion()

sizes = [9, 10, 13, 16]
datasets = [synthetic.paper_synthetic(n_nodes=N, n_per_node=n, seed=i)
            for i, n in enumerate(sizes)]
taus = [0.2, 0.1, 0.2, 0.1]
svc = VBService(slice_iters=6, max_fleet=4, executor=mexec)
rids = [svc.submit(VBRequest(model=mdl, data=(d.x, d.mask), topology=topo,
                             n_iters=18, schedule=engine.Schedule(tau=tau)))
        for d, tau in zip(datasets, taus)]
out = svc.run()
assert len(svc._groups) == 1 and svc.stats().compiles == 1, svc.stats()
for d, tau, rid in zip(datasets, taus, rids):
    solo = engine.run_vb(mdl, (d.x, d.mask), topo, n_iters=18,
                         schedule=engine.Schedule(tau=tau))
    np.testing.assert_array_equal(np.asarray(solo.phi),
                                  np.asarray(out[rid].phi), err_msg=rid)
print("MESH-BUCKETED-OK")
"""


def test_bucketed_fleet_on_mesh_executor(subproc):
    """Mixed shapes AND mixed tau in one shard_mapped fleet: one trace,
    bit-equal to solo single-array runs."""
    assert "MESH-BUCKETED-OK" in subproc(CODE_MESH_BUCKETED, n_devices=4)
