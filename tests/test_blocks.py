"""Property suite for the composable exponential-family block layer.

Four shipped block configurations (Dirichlet single-row, Dirichlet bank,
Normal-Wishart bank, Normal-Gamma single-row + bank) must satisfy the
`ExpFamBlock` contract: pack/unpack identity, KL >= 0 and = 0 at self,
projection idempotence and domain landing, label partitions covering every
flat coordinate, and consistency of the hand-tuned KLs with the generic
exp-family identity (`blocks.default_kl`).  A composition section pins the
refactor bit-invisibility: `GMMModel`/`LinRegModel` over blocks reproduce
the legacy `expfam`/`linreg` monolith paths BIT-for-bit.

Runs under hypothesis when available; otherwise the same properties run as
seed-parametrised deterministic draws.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import blocks, expfam, linreg
from repro.core import model as model_lib
from repro.core.linreg import NGPosterior
from repro.models import hmm as hmm_lib
from repro.models import ppca as ppca_lib


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


def seeded(test):
    """Hypothesis `@given(seed)` when available, else 8 fixed seeds."""
    if HAVE_HYPOTHESIS:
        return settings(max_examples=20, deadline=None)(
            given(seed=st.integers(0, 10_000))(test))
    return pytest.mark.parametrize("seed", range(8))(test)


# ---------------------------------------------------------------------------
# Random valid hypers per block configuration
# ---------------------------------------------------------------------------
def _dirichlet_hyper(rng, rows, K):
    return jnp.asarray(rng.uniform(0.5, 30, (rows, K)))


def _nw_hyper(rng, K, D):
    A = rng.normal(size=(K, D, D)) * 0.3
    return expfam.NWParams(
        m=jnp.asarray(rng.normal(size=(K, D)) * 3),
        beta=jnp.asarray(rng.uniform(0.5, 20, K)),
        W=jnp.asarray(np.einsum("kij,klj->kil", A, A) + np.eye(D) * 0.5),
        nu=jnp.asarray(rng.uniform(D + 1.0, D + 50, K)))


def _ng_hyper(rng, rows, D):
    A = rng.normal(size=(rows, D, D)) * 0.4
    return NGPosterior(
        m=jnp.asarray(rng.normal(size=(rows, D))),
        V=jnp.asarray(np.einsum("rij,rlj->ril", A, A) + np.eye(D) * 0.3),
        a=jnp.asarray(rng.uniform(0.5, 20, rows)),
        b=jnp.asarray(rng.uniform(0.5, 20, rows)))


#: (name, block, random-hyper draw) — the four shipped block types, with
#: both single-row and bank configurations of the row-generic families.
BLOCK_CASES = [
    ("dirichlet", blocks.DirichletBlock(4),
     lambda rng: _dirichlet_hyper(rng, 1, 4)),
    ("dirichlet-bank", blocks.DirichletBlock(3, rows=3, name="trans"),
     lambda rng: _dirichlet_hyper(rng, 3, 3)),
    ("normal-wishart", blocks.NormalWishartBlock(3, 2),
     lambda rng: _nw_hyper(rng, 3, 2)),
    ("normal-gamma", blocks.NormalGammaBlock(3),
     lambda rng: _ng_hyper(rng, 1, 3)),
    ("normal-gamma-bank", blocks.NormalGammaBlock(2, rows=4),
     lambda rng: _ng_hyper(rng, 4, 2)),
]

CASE_IDS = [c[0] for c in BLOCK_CASES]


def _leaves(h):
    return jax.tree_util.tree_leaves(h)


@pytest.mark.parametrize("name,block,draw", BLOCK_CASES, ids=CASE_IDS)
class TestBlockContract:

    @seeded
    def test_pack_unpack_identity(self, name, block, draw, seed):
        h = draw(np.random.default_rng(seed))
        x = block.pack(h)
        assert x.shape == (block.dim,)
        h2 = block.unpack(x)
        for a, b in zip(_leaves(h), _leaves(h2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-8, atol=1e-8)
        np.testing.assert_allclose(np.asarray(block.pack(h2)),
                                   np.asarray(x), rtol=1e-10, atol=1e-10)

    @seeded
    def test_kl_nonneg_and_zero_at_self(self, name, block, draw, seed):
        rng = np.random.default_rng(seed)
        x = block.pack(draw(rng))
        y = block.pack(draw(rng))
        assert abs(float(block.kl(x, x))) < 1e-6
        assert float(block.kl(x, y)) > -1e-8

    @seeded
    def test_projection_idempotent_and_identity_in_domain(
            self, name, block, draw, seed):
        rng = np.random.default_rng(seed)
        x = block.pack(draw(rng))
        # in-domain points are (near-)fixed
        np.testing.assert_allclose(np.asarray(block.project(x)),
                                   np.asarray(x), rtol=1e-6, atol=1e-8)
        # off-domain points land on a fixed point of the projection
        x_off = x + jnp.asarray(rng.normal(size=x.shape)) * 0.3
        p1 = block.project(x_off)
        p2 = block.project(p1)
        np.testing.assert_allclose(np.asarray(p2), np.asarray(p1),
                                   rtol=1e-6, atol=1e-8)

    @seeded
    def test_kl_matches_expfam_identity(self, name, block, draw, seed):
        """The hand-ordered KLs equal the generic default_kl — ties
        pack/log_partition/expected_stats into one consistent family."""
        rng = np.random.default_rng(seed)
        x = block.pack(draw(rng))
        y = block.pack(draw(rng))
        np.testing.assert_allclose(
            float(block.kl(x, y)),
            float(blocks.default_kl(block, x, y)), rtol=1e-7, atol=1e-7)

    @seeded
    def test_expected_stats_is_grad_log_partition(
            self, name, block, draw, seed):
        """E[u] = grad_phi A(phi) on the flat coordinates — pins the
        segment layout of every block type."""
        h = draw(np.random.default_rng(seed))
        x = block.pack(h)
        gA = jax.grad(lambda p: block.log_partition(block.unpack(p)))(x)
        np.testing.assert_allclose(np.asarray(gA),
                                   np.asarray(block.expected_stats(h)),
                                   rtol=1e-6, atol=1e-8)

    def test_labels_partition_segment(self, name, block, draw):
        lab = block.labels()
        assert lab.shape == (block.dim,)
        assert lab.dtype == np.int32
        used = set(np.unique(lab).tolist())
        assert used == set(range(len(block.label_names)))


# ---------------------------------------------------------------------------
# Model-level label partitions: every P coordinate covered, once
# ---------------------------------------------------------------------------
ZOO = {
    "gmm": lambda: model_lib.GMMModel(
        expfam.noninformative_prior(3, 2), K=3, D=2),
    "linreg": lambda: model_lib.LinRegModel(linreg.prior(3)),
    "hmm": lambda: hmm_lib.HMMModel(hmm_lib.noninformative_prior(3, 2)),
    "ppca": lambda: ppca_lib.PPCAModel(ppca_lib.prior(4, 2)),
}


@pytest.mark.parametrize("name", sorted(ZOO))
def test_zoo_conforms_to_protocol(name):
    mdl = ZOO[name]()
    assert isinstance(mdl, model_lib.ConjugateExpModel)
    assert isinstance(mdl, blocks.BlockModel)
    assert mdl.flat_dim == sum(b.dim for b in mdl.blocks)
    # pack/unpack through split_hyper/join_hyper round-trips the prior
    phi = mdl.init_phi()
    np.testing.assert_array_equal(np.asarray(mdl.pack(mdl.unpack(phi))),
                                  np.asarray(phi))


@pytest.mark.parametrize("name", sorted(ZOO))
def test_block_labels_cover_flat_dim(name):
    mdl = ZOO[name]()
    lab = np.asarray(mdl.block_labels())
    assert lab.shape == (mdl.flat_dim,)
    assert set(np.unique(lab).tolist()) == set(range(len(mdl.BLOCK_NAMES)))
    # labels are a partition by construction: every coordinate has exactly
    # one label, and segment offsets make model labels the concatenation
    # of per-block labels
    off, base = 0, 0
    for b in mdl.blocks:
        np.testing.assert_array_equal(
            lab[off:off + b.dim], b.labels().astype(np.int32) + base)
        off += b.dim
        base += len(b.label_names)
    assert off == mdl.flat_dim


@pytest.mark.parametrize("name", sorted(ZOO))
def test_model_kl_and_projection_compose(name):
    mdl = ZOO[name]()
    rng = np.random.default_rng(3)
    phi = mdl.init_phi()
    pert = phi + jnp.asarray(rng.normal(size=phi.shape)) * 0.05
    proj = mdl.project_to_domain(pert)
    assert abs(float(mdl.kl(phi, phi))) < 1e-8
    assert np.isfinite(float(mdl.kl(proj, phi)))
    np.testing.assert_allclose(np.asarray(mdl.project_to_domain(proj)),
                               np.asarray(proj), rtol=1e-6, atol=1e-8)


# ---------------------------------------------------------------------------
# Refactor bit-invisibility: composed models == legacy monolith paths
# ---------------------------------------------------------------------------
def test_gmm_composition_bit_equal_legacy():
    K, D = 3, 2
    rng = np.random.default_rng(0)
    q = expfam.GMMPosterior(alpha=_dirichlet_hyper(rng, 1, K)[0],
                            **_nw_hyper(rng, K, D)._asdict())
    mdl = model_lib.GMMModel(expfam.noninformative_prior(K, D), K=K, D=D)
    phi = mdl.pack(q)
    np.testing.assert_array_equal(np.asarray(phi),
                                  np.asarray(expfam.pack_natural(q)))
    pert = phi + jnp.asarray(rng.normal(size=phi.shape)) * 0.1
    np.testing.assert_array_equal(
        np.asarray(mdl.project_to_domain(pert)),
        np.asarray(expfam.project_to_domain(pert, K, D)))
    np.testing.assert_array_equal(
        np.asarray(mdl.kl(mdl.project_to_domain(pert), phi)),
        np.asarray(expfam.gmm_kl_flat(mdl.project_to_domain(pert), phi,
                                      K, D)))
    np.testing.assert_array_equal(np.asarray(mdl.block_labels()),
                                  np.asarray(expfam.block_labels(K, D)))
    assert mdl.BLOCK_NAMES == expfam.BLOCK_NAMES
    q2 = mdl.unpack(phi)
    assert isinstance(q2, expfam.GMMPosterior)
    for a, b in zip(_leaves(q2), _leaves(expfam.unpack_natural(phi, K, D))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_linreg_composition_bit_equal_legacy():
    D = 3
    rng = np.random.default_rng(1)
    q0 = linreg.prior(D)
    mdl = model_lib.LinRegModel(q0)
    phi = mdl.init_phi()
    np.testing.assert_array_equal(np.asarray(phi),
                                  np.asarray(linreg.pack(q0)))
    pert = phi + jnp.asarray(rng.normal(size=phi.shape)) * 0.05
    np.testing.assert_array_equal(
        np.asarray(mdl.kl(pert, phi)),
        np.asarray(linreg.kl(linreg.unpack(pert, D),
                             linreg.unpack(phi, D))))
    np.testing.assert_array_equal(np.asarray(mdl.block_labels()),
                                  np.asarray(linreg.block_labels(D)))
    assert mdl.BLOCK_NAMES == linreg.BLOCK_NAMES
    assert isinstance(mdl.unpack(phi), NGPosterior)


def test_expfam_nw_helpers_roundtrip():
    """The extracted nw_pack/nw_unpack pair is its own inverse and agrees
    with the full GMM packing on the NW segment."""
    K, D = 3, 2
    q = _nw_hyper(np.random.default_rng(2), K, D)
    seg = expfam.nw_pack(q)
    assert seg.shape == (K * (2 + D + D * D),)
    q2 = expfam.nw_unpack(seg, K, D)
    for a, b in zip(_leaves(q), _leaves(q2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-9, atol=1e-9)
    full = expfam.pack_natural(expfam.GMMPosterior(
        alpha=jnp.ones(K), **q._asdict()))
    np.testing.assert_array_equal(np.asarray(full[K:]), np.asarray(seg))
