"""Engine-level parity of the compute backends (core/backends.py).

`run_vb(..., backend="fused")` — the node-batched single-pass Pallas VBE
kernel + jitted VBM post-stage — must reproduce the reference einsum path
(core/gmm.py) across every topology, masked (ragged Ni) node data, both
executors, and the bf16-storage/f32-accum precision policy.  Everything
here runs in f32: that is the precision the fused kernel owns (the
acceptance bar is KL-trajectory agreement at rtol <= 1e-4 in f32).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backends, engine, expfam, gmm, network, refperm
from repro.core import model as model_lib
from repro.data import synthetic
from repro.kernels import ops, ref

K, D, N_NODES, N_ITERS = 3, 2, 8, 25


@pytest.fixture(scope="module")
def setup():
    # ragged Ni: unequal per-node sample sizes -> zero-padded rows + mask
    data = synthetic.paper_synthetic(n_nodes=N_NODES, n_per_node=30, seed=9,
                                     unequal_sizes=True, imbalanced=False,
                                     dtype=np.float32)
    assert float(jnp.min(jnp.sum(data.mask, 1))) \
        < float(jnp.max(jnp.sum(data.mask, 1)))          # genuinely ragged
    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0,
                                        dtype=jnp.float32)
    adj, _ = network.random_geometric_graph(N_NODES, seed=4)
    adj = adj.astype(jnp.float32)
    W = network.nearest_neighbor_weights(adj).astype(jnp.float32)
    x_all, labels = data.flat
    ref_q = gmm.ground_truth_posterior(x_all, labels, prior, K)
    ref_phis = refperm.permuted_refs(ref_q)
    mdl = model_lib.GMMModel(prior, K, D)
    return data, prior, adj, W, ref_phis, mdl


def _topologies(adj, W):
    """The five estimators of the paper as (name, topology, run_vb kwargs)."""
    return [
        ("cvb", engine.FusionCenter(), dict(schedule=engine.ONE_SHOT)),
        ("noncoop", engine.Isolated(),
         dict(schedule=engine.ONE_SHOT, replication=1.0)),
        ("nsg_dvb", engine.Diffusion(W), dict(schedule=engine.ONE_SHOT)),
        ("dsvb", engine.Diffusion(W), dict(schedule=engine.Schedule())),
        ("dvb_admm", engine.ADMMConsensus(adj), {}),
    ]


@pytest.mark.parametrize("est", ["cvb", "noncoop", "nsg_dvb", "dsvb",
                                 "dvb_admm"])
def test_fused_matches_reference_all_estimators(setup, est):
    """KL trajectories + final phi: fused == reference, rtol 1e-4 in f32."""
    data, prior, adj, W, ref_phis, mdl = setup
    name, topo, kw = next(t for t in _topologies(adj, W) if t[0] == est)
    a = engine.run_vb(mdl, (data.x, data.mask), topo, n_iters=N_ITERS,
                      ref_phi=ref_phis, backend="reference", **kw)
    b = engine.run_vb(mdl, (data.x, data.mask), topo, n_iters=N_ITERS,
                      ref_phi=ref_phis, backend="fused", **kw)
    np.testing.assert_allclose(np.asarray(b.kl_mean), np.asarray(a.kl_mean),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(b.kl_nodes), np.asarray(a.kl_nodes),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(b.phi), np.asarray(a.phi),
                               rtol=2e-4, atol=2e-3)


def test_node_batched_kernel_matches_oracle():
    """gmm_estep_nodes == vmapped naive oracle on ragged masked data."""
    rng = np.random.default_rng(0)
    N, T, Kk, Dd = 5, 137, 4, 3
    x = jnp.asarray(rng.normal(size=(N, T, Dd)) * 2, jnp.float32)
    mask = jnp.asarray(rng.random((N, T)) > 0.2, jnp.float32)
    lp = jnp.asarray(rng.normal(size=(N, Kk)), jnp.float32)
    A = rng.normal(size=(N, Kk, Dd, Dd)) * 0.3
    Wn = jnp.asarray(np.einsum("nkij,nklj->nkil", A, A) + np.eye(Dd),
                     jnp.float32)
    b = jnp.asarray(rng.normal(size=(N, Kk, Dd)), jnp.float32)
    c = jnp.asarray(rng.uniform(1, 3, (N, Kk)), jnp.float32)
    r, R, sx, sxx = ops.gmm_estep_nodes(x, mask, lp, Wn, b, c, block_t=32)
    rr, RR, sxr, sxxr = ref.gmm_estep_nodes(x, mask, lp, Wn, b, c)
    np.testing.assert_allclose(r, rr, atol=2e-5)
    np.testing.assert_allclose(R, RR, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(sx, sxr, rtol=1e-4, atol=5e-4)
    np.testing.assert_allclose(sxx, sxxr, rtol=1e-3, atol=5e-3)


def test_bf16_storage_f32_accum(setup):
    """PrecisionPolicy(data_dtype=bf16): wire/stream dtype narrows, the
    f32-accumulated result stays within bf16-commensurate tolerance."""
    data, prior, adj, W, ref_phis, mdl = setup
    bf16 = backends.FusedBackend(
        precision=backends.PrecisionPolicy(data_dtype=jnp.bfloat16))
    a = engine.run_vb(mdl, (data.x, data.mask), engine.Diffusion(W),
                      n_iters=N_ITERS, ref_phi=ref_phis)
    b = engine.run_vb(mdl, (data.x, data.mask), engine.Diffusion(W),
                      n_iters=N_ITERS, ref_phi=ref_phis, backend=bf16)
    rel = np.max(np.abs(np.asarray(b.phi) - np.asarray(a.phi))
                 / (np.abs(np.asarray(a.phi)) + 1.0))
    assert rel < 3e-2, rel
    np.testing.assert_allclose(np.asarray(b.kl_mean), np.asarray(a.kl_mean),
                               rtol=5e-2, atol=5e-2)


def test_backend_selection_api(setup):
    """Resolution rules: names, instances, model- vs run-level, errors."""
    data, prior, adj, W, ref_phis, mdl = setup
    assert backends.resolve(None).name == "reference"
    assert backends.resolve("fused").name == "fused"
    fb = backends.FusedBackend(block_t=128)
    assert backends.resolve(fb) is fb
    with pytest.raises(ValueError, match="unknown backend"):
        backends.resolve("mosaic")
    # model-level selection == run-level override
    mdl_f = model_lib.GMMModel(prior, K, D, backend="fused")
    a = engine.run_vb(mdl_f, (data.x, data.mask), engine.Diffusion(W),
                      n_iters=5)
    b = engine.run_vb(mdl, (data.x, data.mask), engine.Diffusion(W),
                      n_iters=5, backend="fused")
    np.testing.assert_allclose(np.asarray(a.phi), np.asarray(b.phi))
    # LinRegModel: reference passes through, fused refuses
    lr = model_lib.LinRegModel(D=3)
    assert lr.with_backend("reference") is lr
    with pytest.raises(ValueError, match="no 'fused' compute backend"):
        lr.with_backend("fused")


def test_wrapper_backend_passthrough(setup):
    """algorithms.run_* accept backend= (static under their jit)."""
    from repro.core import algorithms
    data, prior, adj, W, ref_phis, mdl = setup
    a = algorithms.run_dsvb(data.x, data.mask, W, prior, n_iters=10,
                            K=K, D=D)
    b = algorithms.run_dsvb(data.x, data.mask, W, prior, n_iters=10,
                            K=K, D=D, backend="fused")
    np.testing.assert_allclose(np.asarray(b.phi), np.asarray(a.phi),
                               rtol=2e-4, atol=2e-3)


# ---------------------------------------------------------------------------
# Mesh executor x fused backend (subprocess: forced multi-device host)
# ---------------------------------------------------------------------------
CODE_MESH_FUSED = r"""
import jax
import jax.numpy as jnp
import numpy as np
from repro.core import backends, engine, expfam, network
from repro.core import model as model_lib
from repro.data import synthetic

K, D = 3, 2
data = synthetic.paper_synthetic(n_nodes=8, n_per_node=30, seed=9,
                                 unequal_sizes=True, imbalanced=False,
                                 dtype=np.float32)
prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0,
                                    dtype=jnp.float32)
adj, _ = network.random_geometric_graph(8, seed=5)
adj = adj.astype(jnp.float32)
W = network.nearest_neighbor_weights(adj).astype(jnp.float32)
mesh = jax.make_mesh((4,), ("data",))
mexec = engine.MeshExecutor(mesh, "data")
mdl = model_lib.GMMModel(prior, K, D)

for name, topo, kw in [
    ("dsvb", engine.Diffusion(W), dict(schedule=engine.Schedule())),
    ("ring", engine.RingDiffusion(), dict(schedule=engine.Schedule())),
    ("admm", engine.ADMMConsensus(adj), {}),
    ("cvb", engine.FusionCenter(), dict(schedule=engine.ONE_SHOT)),
]:
    single = engine.run_vb(mdl, (data.x, data.mask), topo, n_iters=15,
                           backend="fused", **kw)
    sharded = engine.run_vb(mdl, (data.x, data.mask), topo, n_iters=15,
                            backend="fused", executor=mexec, **kw)
    reference = engine.run_vb(mdl, (data.x, data.mask), topo, n_iters=15,
                              backend="reference", executor=mexec, **kw)
    err = float(jnp.max(jnp.abs(single.phi - sharded.phi)
                        / (jnp.abs(single.phi) + 1.0)))
    assert err < 1e-5, f"{name} fused mesh-vs-single rel err {err}"
    err = float(jnp.max(jnp.abs(reference.phi - sharded.phi)
                        / (jnp.abs(reference.phi) + 1.0)))
    assert err < 1e-4, f"{name} mesh fused-vs-reference rel err {err}"
print("OK")
"""


def test_mesh_executor_fused_backend(subproc):
    out = subproc(CODE_MESH_FUSED, n_devices=4)
    assert "OK" in out
