"""Dense/sparse topology parity + the sparse-scale contracts.

The dense (N, N) path is the parity ORACLE: every sparse edge-list
combine (`Diffusion`/`RingDiffusion`/`ADMMConsensus` over
`network.SparseGraph`) must reproduce it to <= 1e-9 at N=50 in f64
across all five paper estimators and both executors; the fused Pallas
backend is f32-only so its bar is the KL-trajectory rtol<=1e-4
convention of tests/test_backends.py.  The new scenario topologies pin
their anchor limits (gossip with every edge active == dense diffusion;
a single-region hierarchy with zero self/gateway weight == fusion
centre) and the absolute-t resume contract
(vb_run(s, a+b) == vb_run(vb_run(s, a), b), bit-exact).  Finally the
scale contract itself: the sparse combine must lower WITHOUT any (N, N)
intermediate, and the 10k-node geometric builder must connect in
bounded attempts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, expfam, gmm, network, refperm
from repro.core import model as model_lib
from repro.data import synthetic
from repro.serving.vb_service import VBRequest, VBService


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


K, D, N = 3, 2, 50
N_ITERS = 25
TOL = 1e-9                 # the dense-oracle bar (f64, reference backend)


@pytest.fixture(scope="module")
def setup():
    data = synthetic.paper_synthetic(n_nodes=N, n_per_node=20, seed=2)
    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
    adj, _ = network.random_geometric_graph(N, seed=4)
    g = network.SparseGraph.from_dense(np.asarray(adj))
    mdl = model_lib.GMMModel(prior, K, D)
    return data, mdl, np.asarray(adj, np.float64), g


def _estimators(adj, g):
    """The five paper estimators as (name, dense topology, sparse
    topology, run_vb kwargs).  cvb/noncoop have no graph — dense ==
    sparse there by construction, kept so the suite literally covers
    all five."""
    W = network.nearest_neighbor_weights(jnp.asarray(adj))
    sw = network.sparse_nearest_neighbor_weights(g)
    return [
        ("cvb", engine.FusionCenter(), engine.FusionCenter(),
         dict(schedule=engine.ONE_SHOT)),
        ("noncoop", engine.Isolated(), engine.Isolated(),
         dict(schedule=engine.ONE_SHOT, replication=1.0)),
        ("nsg_dvb", engine.Diffusion(W), engine.Diffusion(sw),
         dict(schedule=engine.ONE_SHOT)),
        ("dsvb", engine.Diffusion(W), engine.Diffusion(sw),
         dict(schedule=engine.Schedule())),
        ("dvb_admm", engine.ADMMConsensus(jnp.asarray(adj)),
         engine.ADMMConsensus(g), {}),
    ]


ESTIMATORS = ["cvb", "noncoop", "nsg_dvb", "dsvb", "dvb_admm"]


@pytest.mark.parametrize("est", ESTIMATORS)
def test_sparse_matches_dense_oracle(setup, est):
    data, mdl, adj, g = setup
    _, dense, sparse, kw = next(e for e in _estimators(adj, g)
                                if e[0] == est)
    a = engine.run_vb(mdl, (data.x, data.mask), dense,
                      n_iters=N_ITERS, **kw)
    b = engine.run_vb(mdl, (data.x, data.mask), sparse,
                      n_iters=N_ITERS, **kw)
    np.testing.assert_allclose(np.asarray(b.phi), np.asarray(a.phi),
                               rtol=TOL, atol=TOL)


def test_sparse_matches_dense_metropolis_and_adaptive(setup):
    """The weight variants not in the 5-estimator list: Metropolis
    diffusion and the adaptive-rho ADMM subsystem."""
    data, mdl, adj, g = setup
    pairs = [
        (engine.Diffusion(network.metropolis_weights(jnp.asarray(adj))),
         engine.Diffusion(network.sparse_metropolis_weights(g)),
         dict(schedule=engine.Schedule())),
        (engine.ADMMConsensus(jnp.asarray(adj), adaptive_rho=True,
                              per_block=True),
         engine.ADMMConsensus(g, adaptive_rho=True, per_block=True), {}),
    ]
    for dense, sparse, kw in pairs:
        a = engine.run_vb(mdl, (data.x, data.mask), dense,
                          n_iters=N_ITERS, **kw)
        b = engine.run_vb(mdl, (data.x, data.mask), sparse,
                          n_iters=N_ITERS, **kw)
        np.testing.assert_allclose(np.asarray(b.phi), np.asarray(a.phi),
                                   rtol=TOL, atol=TOL)


def test_ring_sparse_matches_dense_with_link_drop(setup):
    """SparseGraph.ring orders link k as (k, k+1 mod N) — the
    ring_link_keep coin order — so the edge-list ring replays the
    IDENTICAL failure sequence as the roll-based ring, not just the
    same distribution."""
    data, mdl, _, _ = setup
    for drop in (0.0, 0.4):
        a = engine.run_vb(mdl, (data.x, data.mask),
                          engine.RingDiffusion(link_drop=drop, link_seed=3),
                          n_iters=N_ITERS, schedule=engine.Schedule())
        b = engine.run_vb(mdl, (data.x, data.mask),
                          engine.RingDiffusion(
                              graph=network.SparseGraph.ring(N),
                              link_drop=drop, link_seed=3),
                          n_iters=N_ITERS, schedule=engine.Schedule())
        np.testing.assert_allclose(np.asarray(b.phi), np.asarray(a.phi),
                                   rtol=TOL, atol=TOL)


def test_gossip_all_edges_active_is_dense_diffusion(setup):
    """PairwiseGossip with p_activate=1 averages over the FULL
    neighbourhood with Eq. 47 weights == dense nearest-neighbour
    Diffusion on the same graph."""
    data, mdl, adj, g = setup
    W = network.nearest_neighbor_weights(jnp.asarray(adj))
    a = engine.run_vb(mdl, (data.x, data.mask), engine.Diffusion(W),
                      n_iters=N_ITERS, schedule=engine.Schedule())
    b = engine.run_vb(mdl, (data.x, data.mask),
                      engine.PairwiseGossip(g, p_activate=1.0),
                      n_iters=N_ITERS, schedule=engine.Schedule())
    np.testing.assert_allclose(np.asarray(b.phi), np.asarray(a.phi),
                               rtol=TOL, atol=TOL)


def test_hierarchy_degenerates_to_fusion_center(setup):
    """One region, w_self = w_gateway = 0: every node gets the global
    mean — exactly FusionCenter."""
    data, mdl, _, _ = setup
    gw, rg = network.two_level_partition(N, 1, 1)
    a = engine.run_vb(mdl, (data.x, data.mask), engine.FusionCenter(),
                      n_iters=N_ITERS, schedule=engine.ONE_SHOT)
    b = engine.run_vb(mdl, (data.x, data.mask),
                      engine.HierarchicalFusion(gw, rg, w_self=0.0,
                                                w_gateway=0.0),
                      n_iters=N_ITERS, schedule=engine.ONE_SHOT)
    np.testing.assert_allclose(np.asarray(b.phi), np.asarray(a.phi),
                               rtol=TOL, atol=TOL)


def test_gossip_contracts_disagreement(setup):
    """Repeated randomized gossip averaging reaches consensus (the
    mechanism behind the combine), and — every row being a convex
    combination of the active neighbourhood — the consensus point stays
    inside the convex hull of the starting iterates."""
    _, _, _, g = setup
    topo = engine.PairwiseGossip(g, p_activate=0.3, seed=5)
    x0 = np.random.default_rng(0).normal(size=(N, 5))
    x = jnp.asarray(x0)
    for t in range(600):
        x = topo.combine(x, t=t)
    x = np.asarray(x)
    assert np.abs(x - x.mean(0, keepdims=True)).max() < 1e-5
    # every combine row is a convex combination, so the consensus value
    # must lie inside the convex hull of the starting iterates
    assert np.all(x.min(0) >= x0.min(0) - 1e-9)
    assert np.all(x.max(0) <= x0.max(0) + 1e-9)


# ---------------------------------------------------------------------------
# Absolute-t resume contract: bit-exact split/resume for the new
# topologies (gossip activation + link schedules key on VBState.t)
# ---------------------------------------------------------------------------
def _bitequal(a, b, what):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), what
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=what)


def test_split_resume_bitexact_new_topologies(setup):
    data, mdl, adj, g = setup
    sw = network.sparse_nearest_neighbor_weights(g)
    gw, rg = network.two_level_partition(N, 8, 2)
    a, b = 17, 23
    for name, topo_fn in [
        ("gossip", lambda: engine.PairwiseGossip(g, p_activate=0.4,
                                                 seed=11)),
        ("hier", lambda: engine.HierarchicalFusion(gw, rg)),
        ("sparse-diffusion-drop",
         lambda: engine.Diffusion(sw, link_drop=0.3, link_seed=7)),
        ("sparse-admm-drop",
         lambda: engine.ADMMConsensus(g, adaptive_rho=True,
                                      link_drop=0.2)),
    ]:
        full = engine.vb_init(mdl, (data.x, data.mask), topo_fn(),
                              schedule=engine.Schedule())
        full, _ = engine.vb_run(full, a + b)
        split = engine.vb_init(mdl, (data.x, data.mask), topo_fn(),
                               schedule=engine.Schedule())
        split, _ = engine.vb_run(split, a)
        split, _ = engine.vb_run(split, b)
        _bitequal(full.phi, split.phi, f"{name}: phi")
        _bitequal(full.carry, split.carry, f"{name}: carry")


# ---------------------------------------------------------------------------
# Mesh executor: sparse combines under shard_map == single-array
# ---------------------------------------------------------------------------
CODE_MESH_SPARSE = r"""
import jax
from repro.core import expfam
expfam.enable_x64()
import numpy as np, jax.numpy as jnp
from repro.core import engine, network
from repro.core import model as model_lib
from repro.data import synthetic

N = 50
data = synthetic.paper_synthetic(n_nodes=N, n_per_node=20, seed=2)
prior = expfam.noninformative_prior(3, 2, beta0=0.1, w0_scale=10.0)
mdl = model_lib.GMMModel(prior, 3, 2)
adj, _ = network.random_geometric_graph(N, seed=4)
g = network.SparseGraph.from_dense(np.asarray(adj))
sw = network.sparse_nearest_neighbor_weights(g)
gw, rg = network.two_level_partition(N, 8, 2)
mesh = jax.make_mesh((2,), ("data",))
mexec = engine.MeshExecutor(mesh, "data")

for name, topo, kw in [
    ("sparse-diffusion", engine.Diffusion(sw),
     dict(schedule=engine.Schedule())),
    ("sparse-diffusion-drop", engine.Diffusion(sw, link_drop=0.3,
                                               link_seed=7),
     dict(schedule=engine.Schedule())),
    ("sparse-ring", engine.RingDiffusion(
        graph=network.SparseGraph.ring(N), link_drop=0.2),
     dict(schedule=engine.Schedule())),
    ("sparse-admm", engine.ADMMConsensus(g, adaptive_rho=True,
                                         per_block=True), {}),
    ("gossip", engine.PairwiseGossip(g, p_activate=0.4, seed=5),
     dict(schedule=engine.Schedule())),
    ("hier", engine.HierarchicalFusion(gw, rg),
     dict(schedule=engine.Schedule())),
]:
    a = engine.run_vb(mdl, (data.x, data.mask), topo, n_iters=20, **kw)
    b = engine.run_vb(mdl, (data.x, data.mask), topo, n_iters=20,
                      executor=mexec, **kw)
    np.testing.assert_allclose(np.asarray(b.phi), np.asarray(a.phi),
                               rtol=1e-9, atol=1e-9, err_msg=name)
print("OK")
"""


def test_mesh_executor_matches_single_array_sparse(subproc):
    out = subproc(CODE_MESH_SPARSE, n_devices=2)
    assert "OK" in out


# ---------------------------------------------------------------------------
# Fused Pallas backend on sparse topologies (f32; the kernel owns the
# estep, the combine is dtype-generic) — tests/test_backends.py bar
# ---------------------------------------------------------------------------
def test_fused_backend_sparse_parity():
    jax.config.update("jax_enable_x64", False)
    try:
        data = synthetic.paper_synthetic(n_nodes=16, n_per_node=30, seed=9,
                                         dtype=np.float32)
        prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0,
                                            dtype=jnp.float32)
        mdl = model_lib.GMMModel(prior, K, D)
        adj, _ = network.random_geometric_graph(16, seed=4)
        g = network.SparseGraph.from_dense(np.asarray(adj))
        sw = network.sparse_nearest_neighbor_weights(g)
        x_all, labels = data.flat
        ref_q = gmm.ground_truth_posterior(x_all, labels, prior, K)
        ref_phis = refperm.permuted_refs(ref_q)
        gw, rg = network.two_level_partition(16, 4, 2)
        for topo in (engine.Diffusion(sw),
                     engine.PairwiseGossip(g, p_activate=0.5, seed=3),
                     engine.HierarchicalFusion(gw, rg)):
            a = engine.run_vb(mdl, (data.x, data.mask), topo, n_iters=20,
                              ref_phi=ref_phis, backend="reference",
                              schedule=engine.Schedule())
            b = engine.run_vb(mdl, (data.x, data.mask), topo, n_iters=20,
                              ref_phi=ref_phis, backend="fused",
                              schedule=engine.Schedule())
            np.testing.assert_allclose(np.asarray(b.kl_mean),
                                       np.asarray(a.kl_mean),
                                       rtol=1e-4, atol=1e-4)
    finally:
        jax.config.update("jax_enable_x64", True)


# ---------------------------------------------------------------------------
# The scale contract: no (N, N) intermediate on the sparse path
# ---------------------------------------------------------------------------
def test_sparse_combine_lowering_has_no_dense_matrix():
    """Lower a sparse-diffusion iterate combine at N=2048 and assert no
    2048x2048 tensor appears anywhere in the StableHLO; the dense
    combine (the oracle) of course has one — proving the probe bites."""
    n = 2048
    g = network.SparseGraph.ring(n)
    sw = network.sparse_nearest_neighbor_weights(g)
    topo = engine.Diffusion(sw, link_drop=0.1)
    sds = jax.ShapeDtypeStruct((n, 8), jnp.float64)
    txt = jax.jit(lambda v: topo.combine(v, t=3)).lower(sds).as_text()
    assert f"{n}x{n}" not in txt

    dense = engine.Diffusion(jnp.eye(n, dtype=jnp.float64))
    txt_d = jax.jit(lambda v: dense.combine(v, t=3)).lower(sds).as_text()
    assert f"{n}x{n}" in txt_d


def test_gossip_and_hier_lowering_has_no_dense_matrix():
    n = 2048
    g = network.SparseGraph.ring(n)
    gw, rg = network.two_level_partition(n, 64, 8)
    sds = jax.ShapeDtypeStruct((n, 8), jnp.float64)
    for topo in (engine.PairwiseGossip(g, p_activate=0.3),
                 engine.HierarchicalFusion(gw, rg)):
        txt = jax.jit(lambda v: topo.combine(v, t=0)).lower(sds).as_text()
        assert f"{n}x{n}" not in txt


# ---------------------------------------------------------------------------
# Large-N geometric builders: threshold radius, bounded retries
# ---------------------------------------------------------------------------
def test_geometric_edges_match_dense_small():
    for n, seed in [(16, 3), (50, 0), (50, 7), (100, 1)]:
        adj, pos = network.random_geometric_graph(n, seed=seed)
        g, pos_e = network.random_geometric_edges(n, seed=seed)
        np.testing.assert_array_equal(np.asarray(pos), np.asarray(pos_e))
        np.testing.assert_array_equal(np.asarray(g.to_dense()),
                                      np.asarray(adj, np.float64))


def test_default_radius_unchanged_at_paper_scale():
    """The threshold-derived default must NOT change the paper-scale
    graphs: below the crossover (N ~ 128) the legacy 0.8 still wins."""
    for n in (8, 16, 50, 100):
        side = network._paper_side(n, None)
        assert network._resolve_radius(n, side, None) == 0.8


def test_geometric_10k_builds_in_bounded_attempts():
    """Regression for the N=10k connectivity stall: the threshold-derived
    radius (~sqrt(log n / n) scaling) must connect on the FIRST attempt —
    the old constant 0.8 sat below the connectivity threshold there and
    the rejection loop re-sampled forever."""
    n = 10_000
    side = network._paper_side(n, None)
    assert network._resolve_radius(n, side, None) > 0.8  # threshold active
    g, pos = network.random_geometric_edges(n, seed=0, max_tries=1)
    assert g.n_nodes == n and pos.shape == (n, 2)
    assert int(np.asarray(g.deg).min()) >= 1


# ---------------------------------------------------------------------------
# Serving composition: sparse/gossip sessions through VBService
# ---------------------------------------------------------------------------
def test_vb_service_batches_sparse_sessions(setup):
    """Gossip + sparse-diffusion sessions run through `VBService` and
    match solo runs — `SparseGraph` rides through the structural
    signature, and structurally-equal fresh topologies still share one
    fleet group."""
    data, mdl, _, g = setup
    sched = engine.Schedule()
    svc = VBService(slice_iters=5)
    rids = [svc.submit(VBRequest(
        model=mdl, data=(data.x, data.mask),
        topology=engine.PairwiseGossip(g, p_activate=0.5, seed=7),
        n_iters=10, schedule=sched)) for _ in range(2)]
    rids.append(svc.submit(VBRequest(
        model=mdl, data=(data.x, data.mask),
        topology=engine.Diffusion(
            network.sparse_nearest_neighbor_weights(g)),
        n_iters=10, schedule=sched)))
    assert len(svc._groups) == 2        # 2 gossip tenants batch into one
    out = svc.run()
    for rid, topo in zip(rids, [
            engine.PairwiseGossip(g, p_activate=0.5, seed=7),
            engine.PairwiseGossip(g, p_activate=0.5, seed=7),
            engine.Diffusion(network.sparse_nearest_neighbor_weights(g))]):
        st = out[rid]
        assert st.done and st.t == 10
        solo = engine.run_vb(mdl, (data.x, data.mask), topo, n_iters=10,
                             schedule=sched)
        np.testing.assert_allclose(np.asarray(st.phi),
                                   np.asarray(solo.phi),
                                   rtol=TOL, atol=TOL, err_msg=rid)
