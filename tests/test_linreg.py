"""Second conjugate-exponential instance: distributed Bayesian linear
regression recovers the exact pooled posterior via the paper's machinery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import linreg, network


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


D, N_NODES, NI = 4, 12, 30
W_TRUE = np.array([1.5, -2.0, 0.5, 3.0])


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(N_NODES, NI, D))
    noise = rng.normal(size=(N_NODES, NI)) * 0.5      # lambda_true = 4
    y = X @ W_TRUE + noise
    return jnp.asarray(X), jnp.asarray(y)


@pytest.fixture(scope="module")
def setup(data):
    X, y = data
    q0 = linreg.prior(D)
    mask = jnp.ones((NI,), X.dtype)
    phi_star = jnp.stack([
        linreg.local_optimum(X[i], y[i], mask, q0, float(N_NODES))
        for i in range(N_NODES)])
    ref = linreg.pooled_posterior(X.reshape(-1, D), y.reshape(-1), q0)
    adj, _ = network.random_geometric_graph(N_NODES, seed=1)
    return q0, phi_star, ref, adj


def test_pack_unpack_roundtrip(setup):
    _, phi_star, ref, _ = setup
    q2 = linreg.unpack(linreg.pack(ref), D)
    for a, b in zip(ref, q2):
        np.testing.assert_allclose(a, b, rtol=1e-9)


def test_grad_log_partition_is_expected_stats(setup):
    """Eq. 10a for the Normal-Gamma family — pins the packing layout."""
    _, _, ref, _ = setup
    phi = linreg.pack(ref)
    gA = jax.grad(lambda p: linreg.log_partition(linreg.unpack(p, D)))(phi)
    e1, e2, e3, e4 = linreg.expected_stats(ref)
    want = jnp.concatenate([e1[None], e2[None], e3, e4.reshape(-1)])
    np.testing.assert_allclose(gA, want, rtol=1e-6, atol=1e-9)


def test_cvb_average_is_exact_pooled_posterior(setup):
    """Eq. 20 for this model: averaging local naturals == pooled Bayes."""
    _, phi_star, ref, _ = setup
    q = linreg.unpack(linreg.run_cvb(phi_star), D)
    np.testing.assert_allclose(q.m, ref.m, rtol=1e-8)
    np.testing.assert_allclose(q.a, ref.a, rtol=1e-8)
    np.testing.assert_allclose(q.b, ref.b, rtol=1e-6)


def test_dsvb_converges_to_pooled(setup):
    _, phi_star, ref, adj = setup
    W = network.nearest_neighbor_weights(adj)
    phi = linreg.run_dsvb(phi_star, W, n_iters=800, tau=0.1)
    kls = [float(linreg.kl(linreg.unpack(phi[i], D), ref))
           for i in range(N_NODES)]
    assert max(kls) < 0.5, kls
    # estimates recover w
    q = linreg.unpack(phi[0], D)
    np.testing.assert_allclose(q.m, W_TRUE, atol=0.15)


def test_admm_converges_to_pooled_faster(setup):
    _, phi_star, ref, adj = setup
    W = network.nearest_neighbor_weights(adj)
    phi_a = linreg.run_admm(phi_star, adj, n_iters=200, rho=0.5)
    kl_a = max(float(linreg.kl(linreg.unpack(phi_a[i], D), ref))
               for i in range(N_NODES))
    phi_d = linreg.run_dsvb(phi_star, W, n_iters=200, tau=0.1)
    kl_d = max(float(linreg.kl(linreg.unpack(phi_d[i], D), ref))
               for i in range(N_NODES))
    assert kl_a < 0.05, kl_a             # ADMM: consensus to pooled Bayes
    assert kl_a < kl_d                   # and faster than dSVB (Fig. 8 analogue)


def test_noise_precision_recovered(setup):
    _, phi_star, ref, _ = setup
    assert abs(float(ref.a / ref.b) - 4.0) < 1.0   # lambda_true = 1/0.25
