"""Training loop, checkpointing and serving-engine behaviour."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.configs.base import ModelConfig
from repro.models import model
from repro.serving import engine as eng
from repro.training import train_step as ts
from repro.training.trainer import Trainer

CFG = ModelConfig(name="tiny", arch_type="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  param_dtype="float32", compute_dtype="float32")


def test_loss_decreases():
    mesh = jax.make_mesh((1,), ("data",))
    tr = Trainer(CFG, mesh, global_batch=8, seq_len=64,
                 hyper=ts.TrainHyper(peak_lr=3e-3, warmup=5,
                                     total_steps=60))
    hist = tr.run(60, log_every=20)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.5, hist


def test_checkpoint_roundtrip_exact():
    key = jax.random.PRNGKey(0)
    state = ts.init_state(CFG, key)
    with tempfile.TemporaryDirectory() as td:
        path = checkpoint.save(td, state, step=7)
        assert os.path.exists(path)
        assert checkpoint.ckpt.latest_step(td) == 7
        restored = checkpoint.restore(td, state, step=7)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_bf16_preserved():
    tree = {"w": jnp.ones((3, 4), jnp.bfloat16) * 1.5,
            "n": jnp.arange(5, dtype=jnp.int32)}
    with tempfile.TemporaryDirectory() as td:
        p = checkpoint.save(os.path.join(td, "x.npz"), tree)
        back = checkpoint.restore(p, tree)
    assert back["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(back["w"], np.float32),
                                  np.asarray(tree["w"], np.float32))


def test_engine_greedy_matches_teacher_forcing():
    mesh = jax.make_mesh((1,), ("data",))
    params = model.init_params(CFG, jax.random.PRNGKey(0))
    e = eng.Engine(CFG, mesh, params, max_seq=64)
    reqs = [eng.Request(np.array([3, 5, 7], np.int32), 8),
            eng.Request(np.array([10, 20, 30, 40, 50], np.int32), 8)]
    outs = e.generate(reqs)
    # feeding the generated sequence back through forward must reproduce it
    seq = jnp.asarray(outs[1][None, :])
    ref = model.forward(CFG, params, seq)["logits"]
    plen = 5
    pred = jnp.argmax(ref[0, plen - 1:-1], -1)
    np.testing.assert_array_equal(np.asarray(pred), np.asarray(seq[0, plen:]))


def test_engine_batch_right_alignment():
    """Different prompt lengths in one batch decode correctly."""
    mesh = jax.make_mesh((1,), ("data",))
    params = model.init_params(CFG, jax.random.PRNGKey(0))
    e = eng.Engine(CFG, mesh, params, max_seq=64)
    single = e.generate([eng.Request(np.array([9, 9, 9], np.int32), 6)])[0]
    batched = e.generate([
        eng.Request(np.array([9, 9, 9], np.int32), 6),
        eng.Request(np.array([1, 2, 3, 4, 5, 6, 7], np.int32), 6),
    ])[0]
    # note: right-aligned padding means the short prompt sees leading zeros
    # in the batched case; outputs match when the prompt is the batch max
    assert single.shape == batched.shape


def test_engine_waves_match_single_batch():
    """The LM engine on the shared continuous-batching primitives: with
    `max_batch=1` every request runs as its own SlotTable wave and the
    outputs are bit-identical to solo generation; DriverStats reports
    the decode-step occupancy of the wave widths actually used."""
    mesh = jax.make_mesh((1,), ("data",))
    params = model.init_params(CFG, jax.random.PRNGKey(0))
    reqs = [eng.Request(np.array([3, 5, 7], np.int32), 6),
            eng.Request(np.array([11, 13], np.int32), 4),
            eng.Request(np.array([2, 4, 6, 8], np.int32), 6)]
    solo = [eng.Engine(CFG, mesh, params, max_seq=64).generate([r])[0]
            for r in reqs]
    waved = eng.Engine(CFG, mesh, params, max_seq=64,
                       max_batch=1).generate(reqs)
    for a, b in zip(solo, waved):
        np.testing.assert_array_equal(a, b)
    e = eng.Engine(CFG, mesh, params, max_seq=64, max_batch=2)
    outs = e.generate(reqs)
    assert [len(o) for o in outs] == [len(s) for s in solo]
    st = e.stats()
    assert st.admitted == 3 and st.compiles >= 2
    assert 0.0 < st.occupancy <= 1.0
    assert st.padding_waste == pytest.approx(1.0 - st.occupancy)


def test_engine_prompt_bucketing_bit_identical():
    """With `bucket` on, waves only mix same-rung prompts and pad to the
    rung, so a request's greedy output is a function of (prompt, rung)
    alone — the mixed-length batch must match bucketed solo runs bitwise."""
    mesh = jax.make_mesh((1,), ("data",))
    params = model.init_params(CFG, jax.random.PRNGKey(0))
    reqs = [eng.Request(np.array([3, 5, 7], np.int32), 6),            # rung 8
            eng.Request(np.arange(1, 13, dtype=np.int32), 6),         # rung 16
            eng.Request(np.array([9, 9, 9, 9, 9], np.int32), 6),      # rung 8
            eng.Request(np.arange(20, 30, dtype=np.int32), 6)]        # rung 16
    kw = dict(max_seq=64, bucket="pow2", bucket_min=8)
    solo = [eng.Engine(CFG, mesh, params, **kw).generate([r])[0]
            for r in reqs]
    e = eng.Engine(CFG, mesh, params, **kw)
    outs = e.generate(reqs)
    for a, b in zip(solo, outs):
        np.testing.assert_array_equal(a, b)
    assert e._waves == 2          # one wave per rung, not per request


def test_data_pipeline_determinism():
    from repro.data.tokens import Batcher
    b1 = Batcher(128, 4, 32, seed=3)
    b2 = Batcher(128, 4, 32, seed=3)
    np.testing.assert_array_equal(b1.next_batch()["tokens"],
                                  b2.next_batch()["tokens"])
    x1 = b1.next_batch()["tokens"]
    assert x1.shape == (4, 32) and x1.dtype == np.int32


def test_markov_corpus_learnable_structure():
    """The synthetic corpus must have sub-uniform conditional entropy
    (otherwise the 100M example can't show learning)."""
    from repro.data.tokens import MarkovCorpus
    c = MarkovCorpus(64, seed=0)
    rng = np.random.default_rng(0)
    x = c.sample(rng, 64, 128)
    # bigram statistics: successors are concentrated on `branch` options
    from collections import Counter, defaultdict
    succ = defaultdict(Counter)
    for row in x:
        for a, b in zip(row[:-1], row[1:]):
            succ[a][b] += 1
    top = np.mean([max(v.values()) / sum(v.values())
                   for v in succ.values() if sum(v.values()) > 20])
    assert top > 0.3      # uniform would be ~1/64
