"""Exponential-family invariants (unit + hypothesis property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not available in this environment")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import expfam


@pytest.fixture(autouse=True, scope="module")
def _x64():
    """x64 for the VB numerics in THIS module only (restored afterwards so
    the float32 framework-layer tests aren't affected)."""
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


def random_posterior(rng, K, D):
    m = rng.normal(size=(K, D)) * 3
    beta = rng.uniform(0.5, 20, K)
    nu = rng.uniform(D + 1.0, D + 50, K)
    A = rng.normal(size=(K, D, D)) * 0.3
    W = np.einsum("kij,klj->kil", A, A) + np.eye(D) * 0.5
    alpha = rng.uniform(0.5, 30, K)
    return expfam.GMMPosterior(alpha=jnp.asarray(alpha), m=jnp.asarray(m),
                               beta=jnp.asarray(beta), W=jnp.asarray(W),
                               nu=jnp.asarray(nu))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 5), st.integers(1, 4), st.integers(0, 10_000))
def test_pack_unpack_roundtrip(K, D, seed):
    q = random_posterior(np.random.default_rng(seed), K, D)
    q2 = expfam.unpack_natural(expfam.pack_natural(q), K, D)
    for a, b in zip(q, q2):
        np.testing.assert_allclose(a, b, rtol=1e-8, atol=1e-8)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 4), st.integers(1, 3), st.integers(0, 10_000))
def test_grad_log_partition_is_expected_stats(K, D, seed):
    """Eq. 10a: grad_phi A(phi) == E[u(z)] — pins the packing layout."""
    q = random_posterior(np.random.default_rng(seed), K, D)
    phi = expfam.pack_natural(q)
    gA = jax.grad(lambda p: expfam.gmm_log_partition(
        expfam.unpack_natural(p, K, D)))(phi)
    es = expfam.expected_sufficient_stats(q)
    np.testing.assert_allclose(gA, es, rtol=1e-6, atol=1e-8)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 4), st.integers(1, 3), st.integers(0, 10_000),
       st.integers(0, 10_000))
def test_kl_properties(K, D, s1, s2):
    q = random_posterior(np.random.default_rng(s1), K, D)
    p = random_posterior(np.random.default_rng(s2), K, D)
    klqq = float(expfam.gmm_kl(q, q))
    klqp = float(expfam.gmm_kl(q, p))
    assert abs(klqq) < 1e-6
    assert klqp > -1e-8


def test_kl_zero_iff_equal_and_positive_when_not():
    q = random_posterior(np.random.default_rng(0), 3, 2)
    p = q._replace(m=q.m + 0.5)
    assert float(expfam.gmm_kl(q, p)) > 1e-3


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 4), st.integers(1, 3), st.integers(0, 10_000))
def test_projection_lands_in_domain(K, D, seed):
    """Eq. 38b: after projection the point is in Omega, and projecting a
    point already in Omega is (near) identity."""
    rng = np.random.default_rng(seed)
    q = random_posterior(rng, K, D)
    phi = expfam.pack_natural(q)
    assert bool(expfam.in_domain(phi, K, D))
    proj = expfam.project_to_domain(phi, K, D)
    np.testing.assert_allclose(proj, phi, rtol=1e-6, atol=1e-8)
    # corrupt mildly (the ADMM scenario, Sec. III-B): nu below D-1 and a
    # W^{-1} pushed indefinite via its n2 block
    bad = np.asarray(phi).copy()
    bad[K] = -(D + 1.0) / 2.0                # n1 => nu = -1 < D - 1
    blk = 2 + D + D * D
    n2_start = K + 2 + D
    bad[n2_start:n2_start + D * D] += np.eye(D).reshape(-1) * 10.0
    bad = jnp.asarray(bad)
    assert not bool(expfam.in_domain(bad, K, D))
    fixed = expfam.project_to_domain(bad, K, D)
    assert bool(expfam.in_domain(fixed, K, D))


def test_dirichlet_expected_log_matches_mc():
    alpha = jnp.asarray([2.0, 5.0, 1.0])
    rng = np.random.default_rng(0)
    samples = rng.dirichlet(np.asarray(alpha), size=200_000)
    mc = np.log(samples).mean(0)
    np.testing.assert_allclose(expfam.dirichlet_expected_log(alpha), mc,
                               atol=5e-3)


def test_flat_dim():
    for K, D in [(3, 2), (2, 5), (10, 52)]:
        q = random_posterior(np.random.default_rng(0), K, D)
        assert expfam.pack_natural(q).shape == (expfam.flat_dim(K, D),)
