"""Roofline extraction unit tests (regex over synthetic HLO text) plus a
real end-to-end lower/compile on a tiny mesh."""
import numpy as np

from repro.launch import hlo_analysis as ha


HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[16,1024]{1,0} parameter(0)
  %ag = bf16[16,16384]{1,0} all-gather(%p0), dimensions={1}
  %ar = f32[256,512]{1,0} all-reduce(%x), to_apply=%add
  %rs = f32[16,32]{1,0} reduce-scatter(%y), dimensions={0}
  %cp = bf16[8,8]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %a2a = f32[4,4]{1,0} all-to-all(%w), dimensions={0}
  %ags = (bf16[2,4]{1,0}, bf16[2,4]{1,0}) all-gather-start(%q), dimensions={0}
  %agd = bf16[2,4]{1,0} all-gather-done(%ags)
  ROOT %r = f32[1]{0} constant(0)
}
"""


def test_collective_bytes_parsing():
    stats = ha.collective_bytes(HLO)
    assert stats.count_by_kind["all-gather"] == 2      # plain + start
    assert stats.bytes_by_kind["all-reduce"] == 256 * 512 * 4
    assert stats.bytes_by_kind["reduce-scatter"] == 16 * 32 * 4
    assert stats.bytes_by_kind["collective-permute"] == 8 * 8 * 2
    assert stats.bytes_by_kind["all-to-all"] == 4 * 4 * 4
    # tuple start: max element only (the gathered result, not the operand)
    ag = 16 * 16384 * 2 + (2 * 4 * 2)
    assert stats.bytes_by_kind["all-gather"] == ag
    assert stats.total_bytes == sum(stats.bytes_by_kind.values())


def test_roofline_terms_and_bottleneck():
    r = ha.Roofline(flops=1.97e14, hbm_bytes=819e9 * 2, coll_bytes=50e9 / 2,
                    n_chips=256, model_flops=1.97e14 * 128)
    assert np.isclose(r.t_compute, 1.0)
    assert np.isclose(r.t_memory, 2.0)
    assert np.isclose(r.t_collective, 0.5)
    assert r.bottleneck == "memory"
    assert np.isclose(r.useful_flops_ratio, 0.5)


def test_extrapolation_linear():
    c1 = ha.Roofline(flops=10.0, hbm_bytes=100.0, coll_bytes=4.0, n_chips=4,
                     model_flops=1.0, coll_detail={"all-reduce": 4.0},
                     coll_counts={"all-reduce": 2})
    c2 = ha.Roofline(flops=16.0, hbm_bytes=150.0, coll_bytes=6.0, n_chips=4,
                     model_flops=1.0, coll_detail={"all-reduce": 6.0},
                     coll_counts={"all-reduce": 3})
    r = ha.extrapolate_layers(c1, c2, 10)
    assert r.flops == 10 + 9 * 6
    assert r.hbm_bytes == 100 + 9 * 50
    assert r.coll_detail["all-reduce"] == 4 + 9 * 2
    assert r.coll_counts["all-reduce"] == 2 + 9 * 1


CODE_TINY_DRYRUN = r"""
import jax, jax.numpy as jnp, functools
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.dist import compat
from repro.launch import hlo_analysis as ha

mesh = jax.make_mesh((2, 2), ("data", "model"))
w_sds = jax.ShapeDtypeStruct((64, 64), jnp.float32,
                             sharding=NamedSharding(mesh, P(None, "model")))
x_sds = jax.ShapeDtypeStruct((8, 64), jnp.float32,
                             sharding=NamedSharding(mesh, P("data", None)))

def f(x, w):
    return jnp.sum(x @ w)

with compat.use_mesh(mesh):
    compiled = jax.jit(f).lower(x_sds, w_sds).compile()
r = ha.analyze(compiled, 4, model_flops=2 * 8 * 64 * 64)
assert r.flops > 0
assert r.coll_bytes > 0          # the sum over model shards needs a reduce
mem = ha.memory_per_device(compiled)
assert mem["argument_size_in_bytes"] > 0
print("OK")
"""


def test_real_lower_compile_roundtrip(subproc):
    out = subproc(CODE_TINY_DRYRUN, n_devices=4)
    assert "OK" in out
