"""Golden-parity tests for the unified Model x Topology x Executor engine.

The legacy per-algorithm loops (the seed's core/algorithms.py and
core/linreg.py) are re-implemented INLINE here, straight from the paper's
equations, and the engine-backed `run_*` wrappers must reproduce them to
tight tolerance on both conjugate-exponential instances.  A subprocess test
asserts the shard_map executor matches the single-array executor through
the same step function.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms, engine, expfam, gmm, linreg, network
from repro.core import model as model_lib
from repro.data import synthetic


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


K, D, N_NODES, N_ITERS = 3, 2, 8, 15


@pytest.fixture(scope="module")
def setup():
    data = synthetic.paper_synthetic(n_nodes=N_NODES, n_per_node=20, seed=2)
    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
    adj, _ = network.random_geometric_graph(N_NODES, seed=4)
    W = network.nearest_neighbor_weights(adj)
    init_q = algorithms._perturbed_init(prior, data.x, jax.random.PRNGKey(3))
    return data, prior, adj, W, init_q


def _legacy_init(prior, init_q, n_nodes):
    phi0 = expfam.pack_natural(init_q)
    return jnp.broadcast_to(phi0, (n_nodes,) + phi0.shape)


# ---------------------------------------------------------------------------
# GMM goldens: the seed's loops, written out longhand
# ---------------------------------------------------------------------------
def _legacy_dsvb(x, mask, weights, prior, init_q, *, n_iters, tau=0.2,
                 d0=1.0):
    n = x.shape[0]
    phi = _legacy_init(prior, init_q, n)
    for t in range(n_iters):
        phi_star = gmm.local_vbm_optimum_nodes(x, phi, prior, float(n),
                                               K, D, mask)
        eta = 1.0 / (d0 + tau * (t + 1.0))                       # Eq. 29
        varphi = phi + eta * (phi_star - phi)                    # Eq. 27a
        phi = weights @ varphi                                   # Eq. 27b
    return phi


def _legacy_admm(x, mask, adj, prior, init_q, *, n_iters, rho=0.5, xi=0.05,
                 project=True):
    n = x.shape[0]
    deg = jnp.sum(adj, axis=1)
    phi = _legacy_init(prior, init_q, n)
    lam = jnp.zeros_like(phi)
    for t in range(n_iters):
        phi_star = gmm.local_vbm_optimum_nodes(x, phi, prior, float(n),
                                               K, D, mask)
        neigh = adj @ phi
        phi_hat = (phi_star - 2.0 * lam
                   + rho * (deg[:, None] * phi + neigh))         # Eq. 38a
        phi_hat = phi_hat / (1.0 + 2.0 * rho * deg)[:, None]
        if project:                                              # Eq. 38b
            phi_new = jax.vmap(
                lambda p: expfam.project_to_domain(p, K, D))(phi_hat)
        else:
            phi_new = phi_hat
        kappa = 1.0 - 1.0 / (1.0 + xi * (t + 1.0)) ** 2          # Eq. 40
        resid = deg[:, None] * phi_new - adj @ phi_new
        lam = lam + kappa * rho / 2.0 * resid                    # Eq. 39
        phi = phi_new
    return phi


def test_dsvb_matches_legacy_loop(setup):
    data, prior, adj, W, init_q = setup
    want = _legacy_dsvb(data.x, data.mask, W, prior, init_q,
                        n_iters=N_ITERS)
    got = algorithms.run_dsvb(data.x, data.mask, W, prior, n_iters=N_ITERS,
                              K=K, D=D, init_q=init_q).phi
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-10, atol=1e-10)


def test_admm_matches_legacy_loop(setup):
    data, prior, adj, W, init_q = setup
    want = _legacy_admm(data.x, data.mask, adj, prior, init_q,
                        n_iters=N_ITERS)
    got = algorithms.run_dvb_admm(data.x, data.mask, adj, prior,
                                  n_iters=N_ITERS, K=K, D=D,
                                  init_q=init_q).phi
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-10, atol=1e-10)


def test_cvb_noncoop_nsg_match_legacy_loops(setup):
    data, prior, adj, W, init_q = setup
    n = data.x.shape[0]

    # cVB: phi <- mean_i phi*_i (Eq. 20), single shared iterate
    phi = _legacy_init(prior, init_q, n)
    for _ in range(N_ITERS):
        phi_star = gmm.local_vbm_optimum_nodes(data.x, phi, prior, float(n),
                                               K, D, data.mask)
        phi = jnp.broadcast_to(jnp.mean(phi_star, 0), phi.shape)
    got = algorithms.run_cvb(data.x, data.mask, prior, n_iters=N_ITERS,
                             K=K, D=D, init_q=init_q).phi
    np.testing.assert_allclose(np.asarray(got), np.asarray(phi),
                               rtol=1e-10, atol=1e-10)

    # noncoop: phi_i <- phi*_i with UNreplicated data
    phi = _legacy_init(prior, init_q, n)
    for _ in range(N_ITERS):
        phi = gmm.local_vbm_optimum_nodes(data.x, phi, prior, 1.0, K, D,
                                          data.mask)
    got = algorithms.run_noncoop(data.x, data.mask, prior, n_iters=N_ITERS,
                                 K=K, D=D, init_q=init_q).phi
    np.testing.assert_allclose(np.asarray(got), np.asarray(phi),
                               rtol=1e-10, atol=1e-10)

    # nsg-dVB: phi <- W phi*
    phi = _legacy_init(prior, init_q, n)
    for _ in range(N_ITERS):
        phi_star = gmm.local_vbm_optimum_nodes(data.x, phi, prior, float(n),
                                               K, D, data.mask)
        phi = W @ phi_star
    got = algorithms.run_nsg_dvb(data.x, data.mask, W, prior,
                                 n_iters=N_ITERS, K=K, D=D, init_q=init_q).phi
    np.testing.assert_allclose(np.asarray(got), np.asarray(phi),
                               rtol=1e-10, atol=1e-10)


def test_run_metrics_match_direct_engine_call(setup):
    """The wrapper's VBRun metrics == a direct engine.run_vb call."""
    data, prior, adj, W, init_q = setup
    from repro.core import refperm
    x_all, labels = data.flat
    ref = refperm.permuted_refs(gmm.ground_truth_posterior(
        x_all, labels, prior, K))
    run_w = algorithms.run_dsvb(data.x, data.mask, W, prior,
                                n_iters=N_ITERS, K=K, D=D, ref_phi=ref,
                                init_q=init_q)
    mdl = model_lib.GMMModel(prior, K, D)
    phi0 = _legacy_init(prior, init_q, data.x.shape[0])
    run_e = engine.run_vb(mdl, (data.x, data.mask), engine.Diffusion(W),
                          n_iters=N_ITERS, init_phi=phi0, ref_phi=ref)
    np.testing.assert_allclose(run_w.phi, run_e.phi, rtol=1e-12)
    np.testing.assert_allclose(run_w.kl_nodes, run_e.kl_nodes, rtol=1e-10)
    np.testing.assert_allclose(run_w.kl_mean, run_e.kl_mean, rtol=1e-10)
    assert run_e.consensus_err.shape == (N_ITERS,)
    assert bool(jnp.all(run_e.consensus_err >= 0))


# ---------------------------------------------------------------------------
# Linear-regression goldens (the seed's fixed-point consensus loops)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def linreg_setup():
    rng = np.random.default_rng(1)
    Dl, n_nodes, ni = 3, 10, 25
    w_true = rng.normal(size=Dl)
    X = rng.normal(size=(n_nodes, ni, Dl))
    y = X @ w_true + rng.normal(size=(n_nodes, ni)) * 0.3
    X, y = jnp.asarray(X), jnp.asarray(y)
    q0 = linreg.prior(Dl)
    mask = jnp.ones((ni,), X.dtype)
    phi_star = jnp.stack([
        linreg.local_optimum(X[i], y[i], mask, q0, float(n_nodes))
        for i in range(n_nodes)])
    adj, _ = network.random_geometric_graph(n_nodes, seed=6)
    return phi_star, adj, network.nearest_neighbor_weights(adj)


def test_linreg_dsvb_matches_legacy_loop(linreg_setup):
    phi_star, adj, W = linreg_setup
    tau, d0, T = 0.1, 1.0, 50
    phi = phi_star
    for t in range(T):
        eta = 1.0 / (d0 + tau * (t + 1.0))
        varphi = phi + eta * (phi_star - phi)
        phi = W @ varphi
    got = linreg.run_dsvb(phi_star, W, n_iters=T, tau=tau)
    np.testing.assert_allclose(np.asarray(got), np.asarray(phi),
                               rtol=1e-10, atol=1e-12)


def test_linreg_admm_matches_legacy_loop(linreg_setup):
    phi_star, adj, W = linreg_setup
    rho, xi, T = 0.5, 0.05, 50
    deg = jnp.sum(adj, axis=1)
    phi, lam = phi_star, jnp.zeros_like(phi_star)
    for t in range(T):
        neigh = adj @ phi
        phi_new = (phi_star - 2.0 * lam
                   + rho * (deg[:, None] * phi + neigh))
        phi_new = phi_new / (1.0 + 2.0 * rho * deg)[:, None]
        kap = 1.0 - 1.0 / (1.0 + xi * (t + 1.0)) ** 2
        resid = deg[:, None] * phi_new - adj @ phi_new
        lam = lam + kap * rho / 2.0 * resid
        phi = phi_new
    got = linreg.run_admm(phi_star, adj, n_iters=T, rho=rho, xi=xi)
    np.testing.assert_allclose(np.asarray(got), np.asarray(phi),
                               rtol=1e-10, atol=1e-12)


def test_linreg_cvb_is_fusion_mean(linreg_setup):
    phi_star, *_ = linreg_setup
    np.testing.assert_allclose(np.asarray(linreg.run_cvb(phi_star)),
                               np.asarray(jnp.mean(phi_star, 0)), rtol=1e-14)


def test_linreg_model_from_raw_data(linreg_setup):
    """LinRegModel also accepts raw (X, y, mask) node data."""
    rng = np.random.default_rng(0)
    Dl, n_nodes, ni = 3, 6, 20
    X = jnp.asarray(rng.normal(size=(n_nodes, ni, Dl)))
    y = jnp.asarray(X @ rng.normal(size=Dl)
                    + rng.normal(size=(n_nodes, ni)) * 0.3)
    mask = jnp.ones((n_nodes, ni), X.dtype)
    q0 = linreg.prior(Dl)
    mdl = model_lib.LinRegModel(q0)
    phi_star = mdl.local_optimum((X, y, mask), None, float(n_nodes))
    want = jnp.stack([
        linreg.local_optimum(X[i], y[i], mask[i], q0, float(n_nodes))
        for i in range(n_nodes)])
    np.testing.assert_allclose(np.asarray(phi_star), np.asarray(want),
                               rtol=1e-12)


# ---------------------------------------------------------------------------
# Sharded executor == single-array executor (same step function)
# ---------------------------------------------------------------------------
CODE_EXECUTOR_EQUIV = r"""
import jax
from repro.core import expfam
expfam.enable_x64()
import jax.numpy as jnp
from repro.core import engine, network
from repro.core import model as model_lib
from repro.data import synthetic

data = synthetic.paper_synthetic(n_nodes=8, n_per_node=30, seed=9)
K, D = 3, 2
prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
adj, _ = network.random_geometric_graph(8, seed=5)
W = network.nearest_neighbor_weights(adj)
mesh = jax.make_mesh((4,), ("data",))
mdl = model_lib.GMMModel(prior, K, D)
mexec = engine.MeshExecutor(mesh, "data")

for name, topo, kw in [
    ("diffusion", engine.Diffusion(W), dict(schedule=engine.Schedule())),
    ("ring", engine.RingDiffusion(), dict(schedule=engine.Schedule())),
    ("admm", engine.ADMMConsensus(adj), {}),
    ("admm-adaptive", engine.ADMMConsensus(adj, adaptive_rho=True), {}),
    ("admm-adaptive-pb",
     engine.ADMMConsensus(adj, adaptive_rho=True, per_block=True), {}),
    ("fusion", engine.FusionCenter(), dict(schedule=engine.ONE_SHOT)),
]:
    a = engine.run_vb(mdl, (data.x, data.mask), topo, n_iters=25, **kw)
    b = engine.run_vb(mdl, (data.x, data.mask), topo, n_iters=25,
                      executor=mexec, **kw)
    err = float(jnp.max(jnp.abs(a.phi - b.phi)))
    assert err < 1e-8, f"{name} phi err {err}"
    cerr = float(jnp.max(jnp.abs(a.consensus_err - b.consensus_err)))
    assert cerr < 1e-8, f"{name} consensus err {cerr}"
    if a.consensus_diag is not None:
        for f in engine.ConsensusDiagnostics._fields:
            da = getattr(a.consensus_diag, f).astype(jnp.float64)
            db = getattr(b.consensus_diag, f).astype(jnp.float64)
            derr = float(jnp.max(jnp.abs(da - db)))
            assert derr < 1e-8, f"{name} diag {f} err {derr}"
print("OK")
"""


def test_mesh_executor_matches_single_array(subproc):
    out = subproc(CODE_EXECUTOR_EQUIV, n_devices=4)
    assert "OK" in out
