"""SVRG-controlled streaming dSVB vs plain streaming — variance at equal t.

PR 4's minibatch bench records the price of stochasticity at EQUAL
iteration count: with B=20 of 100 points, plain streaming lands at
kl_ratio_equal_iters ~= 1.7x the full-batch KL — pure minibatch noise,
since both runs take the same number of steps.  The SVRG control variate
(`MinibatchSpec(control_variate="svrg")`) re-centres every minibatch
estimate on a full-batch anchor refreshed each epoch,

    phi*_svrg = phi*_B(phi_t) - phi*_B(anchor) + phi*_full(anchor),

which cancels the window's sampling noise while staying exactly unbiased.
The acceptance bar: the same equal-iteration ratio drops to <= 1.3, and
the full-batch degeneracy (batch_size = capacity, where the correction is
structurally absent) stays BIT-exact with the plain full-batch run.

Cost note: each epoch's anchor refresh is one full-batch phi* evaluation
amortised over N_PER/BATCH minibatch steps, so the per-iteration E-step
cost is (1 + BATCH/N_PER)x plain streaming — recorded as us_per_iter.

Everything is seeded; the committed BENCH_engine.json row reproduces
bit-for-bit on the same stack.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import engine, expfam
from repro.core import model as model_lib
from repro.data import stream, synthetic

from benchmarks import common

K, D = 3, 2
N_NODES, N_PER, BATCH = 50, 100, 20


def run(full=False):
    n_iters = 1200 if full else 400
    data = synthetic.paper_synthetic(n_nodes=N_NODES, n_per_node=N_PER,
                                     seed=0)
    setup = common.setup_gmm(data, K, D, seed=0, graph_seed=0)
    prior, W, ref = setup["prior"], setup["W"], setup["ref_phis"]
    phi0 = jnp.broadcast_to(
        expfam.pack_natural(setup["init_q"]),
        (N_NODES, expfam.flat_dim(K, D)))
    mdl = model_lib.GMMModel(prior, K, D)
    topo = engine.Diffusion(W)

    def go(minibatch, want_phi=False):
        fn = jax.jit(lambda x, m: (lambda r: (r.kl_mean, r.phi))(
            engine.run_vb(mdl, (x, m), topo, n_iters=n_iters,
                          init_phi=phi0, ref_phi=ref,
                          minibatch=minibatch)))
        fn(data.x, data.mask)                    # compile
        (kl, phi), wall = common.timed(fn, data.x, data.mask)
        return float(kl[-1]), phi, common.us_per_iter(wall, n_iters)

    kl_full, phi_full, us_full = go(None)
    kl_plain, _, us_plain = go(stream.MinibatchSpec(BATCH, seed=0))
    kl_svrg, _, us_svrg = go(stream.MinibatchSpec(
        BATCH, seed=0, control_variate="svrg"))

    # degeneracy pin: svrg at batch_size = capacity is the full-batch run,
    # bit for bit (the anchor machinery is structurally absent)
    _, phi_degen, _ = go(stream.MinibatchSpec(
        N_PER, seed=0, control_variate="svrg"))
    degen_bitexact = bool(jnp.all(phi_degen == phi_full))

    ratio_plain = kl_plain / kl_full
    ratio_svrg = kl_svrg / kl_full
    common.save("svrg_bench", {
        "n_nodes": N_NODES, "n_per_node": N_PER, "batch_size": BATCH,
        "n_iters": n_iters, "final_kl_full": kl_full,
        "final_kl_stream_plain": kl_plain, "final_kl_stream_svrg": kl_svrg,
        "kl_ratio_equal_iters_plain": ratio_plain,
        "kl_ratio_equal_iters_svrg": ratio_svrg,
        "full_batch_degeneracy_bitexact": degen_bitexact,
        "us_per_iter_full": us_full, "us_per_iter_plain": us_plain,
        "us_per_iter_svrg": us_svrg,
    })
    # acceptance: the control variate buys back most of the equal-t noise
    # penalty (PR 4 recorded ~1.7x plain), without touching the full-batch
    # degeneracy
    assert degen_bitexact
    assert ratio_svrg <= 1.3, ratio_svrg
    assert ratio_svrg <= ratio_plain, (ratio_svrg, ratio_plain)
    return [
        ("svrg_vb_plain", us_plain,
         f"B={BATCH} n_iters={n_iters} "
         f"kl_ratio_equal_iters={ratio_plain:.3f}"),
        ("svrg_vb", us_svrg,
         f"B={BATCH} n_iters={n_iters} "
         f"kl_ratio_equal_iters={ratio_svrg:.3f} "
         f"degen_bitexact={degen_bitexact}"),
    ]
