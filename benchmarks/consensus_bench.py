"""Beyond-paper benchmark: the paper's consensus algorithms as training
data-parallelism, measured on ACTUAL training (not just lowered HLO).

Trains the same tiny LM for N steps under allreduce / diffusion / admm on
an emulated 4-replica mesh (subprocess with host devices) and reports final
losses + replica disagreement.  Validates that the dSVB/dVB-ADMM update
rules train comparably to exact averaging at matched step counts — the
LM-training analogue of the paper's "distributed ~= centralised" claim.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks import common

_CODE = r"""
import jax, json
from repro.configs.base import ModelConfig
from repro.training import train_step as ts
from repro.training.trainer import Trainer

cfg = ModelConfig(name="tiny", arch_type="dense", n_layers=2, d_model=128,
                  n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=512,
                  tie_embeddings=True, param_dtype="float32",
                  compute_dtype="float32")
out = {}
for mode in ["allreduce", "diffusion", "admm"]:
    mesh = jax.make_mesh((4, 1), ("data", "model"))
    axis = "data" if mode != "allreduce" else None
    tr = Trainer(cfg, mesh, dp_mode=mode, consensus_axis=axis,
                 hyper=ts.TrainHyper(peak_lr=3e-3, warmup=5, total_steps=60),
                 global_batch=8, seq_len=128, seed=0)
    hist = tr.run(60, log_every=60)
    out[mode] = {"first": hist[0]["loss"], "final": hist[-1]["loss"],
                 "resid": hist[-1].get("consensus_residual")}
print("RESULT" + json.dumps(out))
"""


def run(full=False):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(here, "src")
    proc = subprocess.run([sys.executable, "-c", _CODE], env=env, cwd=here,
                          capture_output=True, text=True, timeout=1800)
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")]
    if not line:
        raise RuntimeError(proc.stdout[-2000:] + proc.stderr[-2000:])
    res = json.loads(line[0][len("RESULT"):])
    common.save("consensus_lm", res)
    ar, df, ad = (res[m]["final"] for m in ("allreduce", "diffusion", "admm"))
    return [("consensus_lm_training", 0.0,
             f"final_loss ar={ar:.3f} diffusion={df:.3f} admm={ad:.3f} "
             f"resid_diff={res['diffusion']['resid']:.1e}")]
