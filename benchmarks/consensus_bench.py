"""Consensus benchmarks, both layers of the stack:

* `run` (group "consensus_lm") — beyond-paper: the paper's consensus
  algorithms as training data-parallelism, measured on ACTUAL training.
  Trains the same tiny LM for N steps under allreduce / diffusion / admm
  on an emulated 4-replica mesh (subprocess with host devices) and reports
  final losses + replica disagreement.
* `vb_run` (group "consensus_vb") — the adaptive-penalty dVB-ADMM
  subsystem on the paper's GMM instance: plain Algorithm 2 vs
  `ADMMConsensus(adaptive_rho=True)`, with the `ConsensusDiagnostics`
  summary (dual-activation iteration, final rho, clip/reset totals) in the
  derived column and the --json snapshot.  This is the benchmark-level
  guard on the docs/admm-convergence.md convergence story.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from benchmarks import common

_CODE = r"""
import jax, json
from repro.configs.base import ModelConfig
from repro.training import train_step as ts
from repro.training.trainer import Trainer

cfg = ModelConfig(name="tiny", arch_type="dense", n_layers=2, d_model=128,
                  n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=512,
                  tie_embeddings=True, param_dtype="float32",
                  compute_dtype="float32")
out = {}
for mode in ["allreduce", "diffusion", "admm"]:
    mesh = jax.make_mesh((4, 1), ("data", "model"))
    axis = "data" if mode != "allreduce" else None
    tr = Trainer(cfg, mesh, dp_mode=mode, consensus_axis=axis,
                 hyper=ts.TrainHyper(peak_lr=3e-3, warmup=5, total_steps=60),
                 global_batch=8, seq_len=128, seed=0)
    hist = tr.run(60, log_every=60)
    out[mode] = {"first": hist[0]["loss"], "final": hist[-1]["loss"],
                 "resid": hist[-1].get("consensus_residual")}
print("RESULT" + json.dumps(out))
"""


def vb_run(full=False):
    """Adaptive-penalty dVB-ADMM vs plain Algorithm 2 + diagnostics row."""
    import jax
    import jax.numpy as jnp
    from repro.core import algorithms
    from repro.data import synthetic

    x64_before = jax.config.jax_enable_x64
    try:
        K, D = 3, 2
        n_nodes, n_per, n_iters = (50, 100, 1500) if full else (20, 60, 300)
        data = synthetic.paper_synthetic(n_nodes=n_nodes, n_per_node=n_per,
                                         seed=1)
        s = common.setup_gmm(data, K, D, seed=0, graph_seed=3)  # enables x64
        kw = dict(n_iters=n_iters, K=K, D=D, ref_phi=s["ref_phis"],
                  init_q=s["init_q"])

        cvb = algorithms.run_cvb(data.x, data.mask, s["prior"], **kw)

        def run_adaptive():
            return algorithms.run_dvb_admm(data.x, data.mask, s["adj"],
                                           s["prior"], rho=0.5,
                                           adaptive_rho=True, **kw)

        adaptive = run_adaptive()
        jax.block_until_ready(adaptive.phi)          # warm the whole-run jit
        t0 = time.perf_counter()
        adaptive = run_adaptive()
        jax.block_until_ready(adaptive.phi)
        us = (time.perf_counter() - t0) / n_iters * 1e6
        plain = algorithms.run_dvb_admm(data.x, data.mask, s["adj"],
                                        s["prior"], rho=0.5, **kw)

        d = adaptive.consensus_diag
        dual_on_at = (int(jnp.argmax(d.dual_on))
                      if float(d.dual_on[-1]) else -1)
        summary = dict(
            kl_cvb=float(cvb.kl_mean[-1]),
            kl_adaptive=float(adaptive.kl_mean[-1]),
            kl_plain=float(plain.kl_mean[-1]),
            dual_on_at=dual_on_at,
            rho_final=float(jnp.mean(d.rho[-1])),
            clips=int(jnp.sum(d.clip_count)),
            resets=int(jnp.sum(d.reset_count)),
            primal_resid_final=float(jnp.mean(d.primal_resid[-1])),
            dual_resid_final=float(jnp.mean(d.dual_resid[-1])))
        common.save("consensus_vb_adaptive", summary)
        return [("consensus_vb_adaptive", us,
                 f"kl adaptive={summary['kl_adaptive']:.2f} "
                 f"cvb={summary['kl_cvb']:.2f} "
                 f"plain={summary['kl_plain']:.1e} "
                 f"dual_on@{dual_on_at} rho={summary['rho_final']:.2f} "
                 f"clips={summary['clips']}")]
    finally:
        jax.config.update("jax_enable_x64", x64_before)


def run(full=False):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(here, "src")
    proc = subprocess.run([sys.executable, "-c", _CODE], env=env, cwd=here,
                          capture_output=True, text=True, timeout=1800)
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")]
    if not line:
        raise RuntimeError(proc.stdout[-2000:] + proc.stderr[-2000:])
    res = json.loads(line[0][len("RESULT"):])
    common.save("consensus_lm", res)
    ar, df, ad = (res[m]["final"] for m in ("allreduce", "diffusion", "admm"))
    return [("consensus_lm_training", 0.0,
             f"final_loss ar={ar:.3f} diffusion={df:.3f} admm={ad:.3f} "
             f"resid_diff={res['diffusion']['resid']:.1e}")]
