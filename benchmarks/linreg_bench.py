"""Generality benchmark: the framework on a SECOND conjugate-exponential
model (Bayesian linear regression, Normal-Gamma) — paper contribution 1.

Reports the max-over-nodes KL to the exact pooled Bayesian posterior for
dSVB and dVB-ADMM at matched iteration budgets.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import linreg, network


def run(full=False):
    jax.config.update("jax_enable_x64", True)
    D, n_nodes, ni = 6, 50 if full else 20, 40
    rng = np.random.default_rng(0)
    w_true = rng.normal(size=D)
    X = rng.normal(size=(n_nodes, ni, D))
    y = X @ w_true + rng.normal(size=(n_nodes, ni)) * 0.4
    X, y = jnp.asarray(X), jnp.asarray(y)
    q0 = linreg.prior(D)
    mask = jnp.ones((ni,), X.dtype)
    phi_star = jnp.stack([
        linreg.local_optimum(X[i], y[i], mask, q0, float(n_nodes))
        for i in range(n_nodes)])
    ref = linreg.pooled_posterior(X.reshape(-1, D), y.reshape(-1), q0)
    adj, _ = network.random_geometric_graph(n_nodes, seed=1)
    W = network.nearest_neighbor_weights(adj)

    n_iters = 2000 if full else 400
    t0 = time.time()
    phi_d = linreg.run_dsvb(phi_star, W, n_iters=n_iters, tau=0.1)
    phi_a = linreg.run_admm(phi_star, adj, n_iters=n_iters, rho=0.5)
    jax.block_until_ready((phi_d, phi_a))
    wall = time.time() - t0

    kl_d = max(float(linreg.kl(linreg.unpack(phi_d[i], D), ref))
               for i in range(n_nodes))
    kl_a = max(float(linreg.kl(linreg.unpack(phi_a[i], D), ref))
               for i in range(n_nodes))
    common.save("linreg_generality", {"kl_dsvb": kl_d, "kl_admm": kl_a,
                                      "n_iters": n_iters})
    return [("linreg_generality", common.us_per_iter(wall, 2 * n_iters),
             f"maxKL_to_pooled dsvb={kl_d:.2e} admm={kl_a:.2e}")]
