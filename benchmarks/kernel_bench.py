"""Micro-benchmarks of the Pallas kernels vs their XLA/jnp references.

On this CPU container the kernels execute in interpret mode, so absolute
wall-times are NOT TPU-representative — what's meaningful here is (a) the
oracle-vs-kernel numerical agreement (asserted) and (b) the XLA-reference
wall-times as a CPU sanity signal.  The TPU roofline claims come from the
dry-run (benchmarks/roofline.py), not from these timings.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, n=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6


def run(full=False):
    key = jax.random.PRNGKey(0)
    rows = []
    f32 = jnp.float32  # pin f32: earlier benches may have enabled x64
    # flash attention (XLA ref timing; kernel checked vs oracle)
    B, S, H, hd = 2, 256, 4, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), f32)
    k = jax.random.normal(ks[1], (B, S, H, hd), f32)
    v = jax.random.normal(ks[2], (B, S, H, hd), f32)
    ref_fn = jax.jit(lambda q, k, v: ref.attention(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1)))
    us = _time(ref_fn, q, k, v)
    out = ops.flash_attention(q, k, v)
    want = jnp.moveaxis(ref_fn(q, k, v), 1, 2)
    err = float(jnp.max(jnp.abs(out - want)))
    rows.append(("kernel_flash_attention", us, f"max_err_vs_oracle={err:.1e}"))

    # ssd scan
    Bb, S2, H2, P, N = 1, 256, 4, 32, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (Bb, S2, H2, P), f32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, S2, H2), f32))
    A = -jnp.exp(jax.random.normal(ks[2], (H2,), f32) * 0.5)
    Bm = jax.random.normal(ks[3], (Bb, S2, N), f32) * 0.3
    Cm = jax.random.normal(ks[4], (Bb, S2, N), f32) * 0.3
    ref_fn = jax.jit(ref.ssd)
    us = _time(ref_fn, x, dt, A, Bm, Cm)
    y, h = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=64)
    yr, hr = ref_fn(x, dt, A, Bm, Cm)
    err = float(jnp.max(jnp.abs(y - yr)))
    rows.append(("kernel_ssd_scan", us, f"max_err_vs_oracle={err:.1e}"))

    # gmm estep
    rng = np.random.default_rng(0)
    T, K, D = 2000, 3, 4
    xg = jnp.asarray(rng.normal(size=(T, D)), jnp.float32)
    mask = jnp.ones((T,), jnp.float32)
    lp = jnp.asarray(rng.normal(size=K), jnp.float32)
    Aw = rng.normal(size=(K, D, D)) * 0.3
    Wn = jnp.asarray(np.einsum("kij,klj->kil", Aw, Aw) + np.eye(D),
                     jnp.float32)
    b = jnp.asarray(rng.normal(size=(K, D)), jnp.float32)
    c = jnp.asarray(rng.uniform(1, 3, K), jnp.float32)
    ref_fn = jax.jit(ref.gmm_estep)
    us = _time(ref_fn, xg, mask, lp, Wn, b, c)
    r, R, sx, sxx = ops.gmm_estep(xg, mask, lp, Wn, b, c)
    rr = ref_fn(xg, mask, lp, Wn, b, c)
    err = float(jnp.max(jnp.abs(r - rr[0])))
    rows.append(("kernel_gmm_estep", us, f"max_err_vs_oracle={err:.1e}"))
    return rows
