"""VBService fleet-batching throughput vs sequential `run_vb` calls.

The serving claim: admitting 16 same-shape sensor-network sessions into
one vmapped fleet and stepping them in slices beats 16 back-to-back
`run_vb` calls — the fleet pays ONE trace/compile and runs vectorised,
while sequential serving pays per-session dispatch.  The bench row
asserts fleet-batched >= 2x sequential wall-clock (the acceptance
criterion) and reports sessions/sec + fleet steps/sec.
"""
import time

import jax

from benchmarks import common


def run(full: bool = False):
    from repro.core import engine, expfam, network
    from repro.core import model as model_lib
    from repro.data import synthetic
    from repro.serving.vb_service import VBRequest, VBService

    expfam.enable_x64()
    K, D = 3, 2
    n_sessions = 16
    n_nodes = 16 if full else 8
    n_per_node = 50 if full else 25
    n_iters = 200 if full else 120

    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
    adj, _ = network.random_geometric_graph(n_nodes, seed=0)
    W = network.nearest_neighbor_weights(adj)
    mdl = model_lib.GMMModel(prior, K, D)
    topo = engine.Diffusion(W)
    datasets = [synthetic.paper_synthetic(n_nodes=n_nodes,
                                          n_per_node=n_per_node, seed=s)
                for s in range(n_sessions)]

    # sequential serving: one run_vb call per session, back to back
    t0 = time.time()
    seq_phis = []
    for d in datasets:
        r = engine.run_vb(mdl, (d.x, d.mask), topo, n_iters=n_iters,
                          diagnostics=False)
        seq_phis.append(jax.block_until_ready(r.phi))
    t_seq = time.time() - t0

    # fleet serving: one VBService batch, sliced
    t0 = time.time()
    svc = VBService(slice_iters=40)
    rids = [svc.submit(VBRequest(model=mdl, data=(d.x, d.mask),
                                 topology=topo, n_iters=n_iters))
            for d in datasets]
    out = svc.run()
    jax.block_until_ready([out[r].phi for r in rids])
    t_fleet = time.time() - t0

    # fidelity guard: the fleet must be serving the same answers
    import numpy as np
    for d_phi, rid in zip(seq_phis, rids):
        err = float(np.max(np.abs(np.asarray(d_phi)
                                  - np.asarray(out[rid].phi))))
        assert err < 1e-8, f"fleet diverged from sequential: {err}"

    speedup = t_seq / t_fleet
    sessions_per_s = n_sessions / t_fleet
    steps_per_s = n_sessions * n_iters / t_fleet
    derived = (f"speedup_vs_sequential={speedup:.1f}x "
               f"sessions_per_s={sessions_per_s:.2f} "
               f"fleet_steps_per_s={steps_per_s:.0f} "
               f"n_sessions={n_sessions} n_iters={n_iters}")
    assert speedup >= 2.0, (
        f"fleet-batched serving must be >= 2x sequential run_vb "
        f"(got {speedup:.2f}x: fleet {t_fleet:.2f}s vs "
        f"sequential {t_seq:.2f}s)")
    yield ("vb_service_throughput",
           common.us_per_iter(t_fleet, n_iters * n_sessions), derived)
