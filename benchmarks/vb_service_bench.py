"""VBService fleet-batching + continuous-batching driver benchmarks.

`run`: admitting 16 same-shape sensor-network sessions into one vmapped
fleet and stepping them in slices beats 16 back-to-back `run_vb` calls —
the fleet pays ONE trace/compile and runs vectorised, while sequential
serving pays per-session dispatch.  Asserts fleet >= 2x sequential.

`run_poisson`: the continuous-batching claim (ISSUE 6).  Same-shape
sessions with MIXED budgets arrive as a Poisson process in wall-clock
time.  The synchronous baseline is the pre-driver serving loop: admit
whatever has arrived, `run()` the fleet to FULL drain, then look at the
queue again — short sessions wait out the longest budget in their batch
and arrivals pile up behind the drain barrier (and every admission wave
regrows the fleet, recompiling).  The driver serves the same schedule
through one fixed-capacity fleet with mid-flight join/leave: one
compile, evictions free slots for queued arrivals at slice boundaries.
Reports p50/p99 session latency (submit -> finished) and sessions/s for
both, asserting driver >= 2x the synchronous baseline's sessions/s.

`run_mixed_fleet`: the bucketed-admission claim (ISSUE 7,
docs/bucketed-admission.md).  64 sessions with 5 distinct data shapes
and 2 Robbins-Monro taus share ONE compiled fleet through the capacity
ladder + hyper lifting, instead of one group (one trace, one
mostly-empty fleet) per distinct (shape, tau) — 10 groups pre-
bucketing.  Asserts the ragged mix holds >= 0.5x the sessions/s of an
all-same-shape fleet of the same size, and that the solo answers are
preserved.
"""
import time

import jax

from benchmarks import common


def run(full: bool = False):
    from repro.core import engine, expfam, network
    from repro.core import model as model_lib
    from repro.data import synthetic
    from repro.serving.vb_service import VBRequest, VBService

    expfam.enable_x64()
    K, D = 3, 2
    n_sessions = 16
    n_nodes = 16 if full else 8
    n_per_node = 50 if full else 25
    n_iters = 200 if full else 120

    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
    adj, _ = network.random_geometric_graph(n_nodes, seed=0)
    W = network.nearest_neighbor_weights(adj)
    mdl = model_lib.GMMModel(prior, K, D)
    topo = engine.Diffusion(W)
    datasets = [synthetic.paper_synthetic(n_nodes=n_nodes,
                                          n_per_node=n_per_node, seed=s)
                for s in range(n_sessions)]

    # sequential serving: one run_vb call per session, back to back
    t0 = time.time()
    seq_phis = []
    for d in datasets:
        r = engine.run_vb(mdl, (d.x, d.mask), topo, n_iters=n_iters,
                          diagnostics=False)
        seq_phis.append(jax.block_until_ready(r.phi))
    t_seq = time.time() - t0

    # fleet serving: one VBService batch, sliced
    t0 = time.time()
    svc = VBService(slice_iters=40)
    rids = [svc.submit(VBRequest(model=mdl, data=(d.x, d.mask),
                                 topology=topo, n_iters=n_iters))
            for d in datasets]
    out = svc.run()
    jax.block_until_ready([out[r].phi for r in rids])
    t_fleet = time.time() - t0

    # fidelity guard: the fleet must be serving the same answers
    import numpy as np
    for d_phi, rid in zip(seq_phis, rids):
        err = float(np.max(np.abs(np.asarray(d_phi)
                                  - np.asarray(out[rid].phi))))
        assert err < 1e-8, f"fleet diverged from sequential: {err}"

    speedup = t_seq / t_fleet
    sessions_per_s = n_sessions / t_fleet
    steps_per_s = n_sessions * n_iters / t_fleet
    derived = (f"speedup_vs_sequential={speedup:.1f}x "
               f"sessions_per_s={sessions_per_s:.2f} "
               f"fleet_steps_per_s={steps_per_s:.0f} "
               f"n_sessions={n_sessions} n_iters={n_iters}")
    assert speedup >= 2.0, (
        f"fleet-batched serving must be >= 2x sequential run_vb "
        f"(got {speedup:.2f}x: fleet {t_fleet:.2f}s vs "
        f"sequential {t_seq:.2f}s)")
    yield ("vb_service_throughput",
           common.us_per_iter(t_fleet, n_iters * n_sessions), derived)


def run_poisson(full: bool = False):
    import numpy as np

    from repro.core import engine, expfam, network
    from repro.core import model as model_lib
    from repro.data import synthetic
    from repro.serving.vb_service import VBRequest, VBService

    expfam.enable_x64()
    K, D = 3, 2
    n_sessions = 24 if full else 12
    n_nodes = 16 if full else 8
    n_per_node = 50 if full else 25
    budgets = [40, 80, 160]             # mixed: the drain barrier's worst case
    max_fleet = 8 if full else 6
    slice_iters = 10

    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
    adj, _ = network.random_geometric_graph(n_nodes, seed=0)
    W = network.nearest_neighbor_weights(adj)
    mdl = model_lib.GMMModel(prior, K, D)
    topo = engine.Diffusion(W)
    reqs = []
    for s in range(n_sessions):
        d = synthetic.paper_synthetic(n_nodes=n_nodes,
                                      n_per_node=n_per_node, seed=s)
        reqs.append(VBRequest(model=mdl, data=(d.x, d.mask), topology=topo,
                              n_iters=budgets[s % len(budgets)]))

    # one Poisson arrival schedule (wall-clock), shared by both systems
    rng = np.random.default_rng(7)
    gaps = rng.exponential(scale=0.08, size=n_sessions)
    arrive = np.cumsum(gaps) - gaps[0]  # first session arrives at t=0

    def wait_until(t0, t):
        now = time.time() - t0
        if t > now:
            time.sleep(t - now)

    # -- synchronous baseline: admit arrivals, run() to FULL drain, repeat
    svc = VBService(slice_iters=slice_iters)
    submitted, finish = {}, {}
    t0 = time.time()
    i = 0
    while i < n_sessions:
        wait_until(t0, arrive[i])
        while i < n_sessions and arrive[i] <= time.time() - t0:
            submitted[svc.submit(reqs[i])] = i
            i += 1
        svc.run()                       # the drain barrier
        now = time.time() - t0
        for j in submitted.values():
            finish.setdefault(j, now)
    sync_makespan = max(finish.values())
    sync_lat = np.array([finish[j] - arrive[j] for j in range(n_sessions)])
    sync_sessions_per_s = n_sessions / sync_makespan

    # -- continuous-batching driver: background scheduler, real-time joins
    svc2 = VBService(slice_iters=slice_iters, max_fleet=max_fleet)
    svc2.start()
    t0 = time.time()
    rid_of = {}
    for j in range(n_sessions):
        wait_until(t0, arrive[j])
        rid_of[j] = svc2.submit(reqs[j])
    svc2.drain()
    drv_makespan = time.time() - t0
    svc2.stop()
    stats = svc2.stats()
    drv_lat = np.array([svc2.status(rid_of[j]).latency_s
                        for j in range(n_sessions)])
    drv_sessions_per_s = n_sessions / drv_makespan

    # fidelity guard: the driver must be serving the right answers
    j0 = int(np.argmin([r.n_iters for r in reqs]))
    solo = engine.run_vb(mdl, reqs[j0].data, topo,
                         n_iters=reqs[j0].n_iters, diagnostics=False)
    err = float(np.max(np.abs(np.asarray(solo.phi)
                              - np.asarray(svc2.status(rid_of[j0]).phi))))
    assert err < 1e-8, f"driver diverged from solo run_vb: {err}"

    speedup = drv_sessions_per_s / sync_sessions_per_s
    derived = (f"sessions_per_s={drv_sessions_per_s:.2f} "
               f"sync_sessions_per_s={sync_sessions_per_s:.2f} "
               f"speedup_vs_sync={speedup:.1f}x "
               f"p50_latency_s={np.percentile(drv_lat, 50):.2f} "
               f"p99_latency_s={np.percentile(drv_lat, 99):.2f} "
               f"sync_p50_latency_s={np.percentile(sync_lat, 50):.2f} "
               f"sync_p99_latency_s={np.percentile(sync_lat, 99):.2f} "
               f"occupancy={stats.occupancy:.2f} "
               f"compiles={stats.compiles} evictions={stats.evicted} "
               f"n_sessions={n_sessions} max_fleet={max_fleet}")
    assert speedup >= 2.0, (
        f"continuous batching must serve >= 2x the synchronous drain-loop "
        f"sessions/s (got {speedup:.2f}x: driver {drv_makespan:.2f}s vs "
        f"sync {sync_makespan:.2f}s for {n_sessions} sessions)")
    total_iters = sum(r.n_iters for r in reqs)
    yield ("vb_driver_poisson",
           common.us_per_iter(drv_makespan, total_iters), derived)


def run_mixed_fleet(full: bool = False):
    import numpy as np

    from repro.core import engine, expfam, network
    from repro.core import model as model_lib
    from repro.data import synthetic
    from repro.serving.vb_service import VBRequest, VBService

    expfam.enable_x64()
    K, D = 3, 2
    n_sessions = 64
    n_nodes = 16 if full else 8
    n_iters = 200 if full else 100
    # 5 distinct shapes, all rounding to rung 32 — the pre-bucketing
    # driver would split this mix 5 (shapes) x 2 (taus) = 10 ways, each
    # paying its own trace over a mostly-empty fleet.  (Multi-rung
    # admission and its padding accounting are pinned functionally in
    # tests/test_bucketed.py; here one rung keeps the device work
    # comparable to the same-shape reference so the ratio measures the
    # bucketing machinery, not the ladder's padding policy.)
    shapes = [17, 20, 24, 28, 32]
    taus = [0.2, 0.1]

    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
    adj, _ = network.random_geometric_graph(n_nodes, seed=0)
    W = network.nearest_neighbor_weights(adj)
    mdl = model_lib.GMMModel(prior, K, D)
    topo = engine.Diffusion(W)

    def serve(reqs):
        t0 = time.time()
        svc = VBService(slice_iters=25)
        rids = [svc.submit(r) for r in reqs]
        out = svc.run()
        jax.block_until_ready([out[r].phi for r in rids])
        return svc, rids, out, time.time() - t0

    mixed_reqs, solo_cfg = [], []
    for s in range(n_sessions):
        n = shapes[s % len(shapes)]
        tau = taus[s % len(taus)]
        d = synthetic.paper_synthetic(n_nodes=n_nodes, n_per_node=n,
                                      seed=s)
        mixed_reqs.append(VBRequest(
            model=mdl, data=(d.x, d.mask), topology=topo, n_iters=n_iters,
            schedule=engine.Schedule(tau=tau)))
        solo_cfg.append(((d.x, d.mask), tau))

    # same-shape reference fleet: identical session count/iters, every
    # session on the big rung's exact capacity, one tau
    same_reqs = []
    for s in range(n_sessions):
        d = synthetic.paper_synthetic(n_nodes=n_nodes, n_per_node=32,
                                      seed=s)
        same_reqs.append(VBRequest(
            model=mdl, data=(d.x, d.mask), topology=topo,
            n_iters=n_iters, schedule=engine.Schedule(tau=taus[0])))

    # untimed one-slice warmup of BOTH fleet configurations, so neither
    # timed run is charged the process's first-touch traces
    for reqs in (same_reqs, mixed_reqs):
        serve([r._replace(n_iters=25) for r in reqs])

    svc, rids, out, t_mixed = serve(mixed_reqs)
    t_mixed = min(t_mixed, serve(mixed_reqs)[3])    # best-of-2: the ratio
    #                       guards a CI floor, so damp scheduler noise
    st = svc.stats()
    n_groups = len(st.buckets)
    assert n_groups == 1, st.buckets          # the whole point: 10 -> 1
    assert st.compiles <= n_groups + 1, st    # one trace per rung group

    # fidelity guard: bucketing + hyper lifting must preserve the answers
    for s in (0, 1, 4):                       # one per rung x tau corner
        (data, tau), rid = solo_cfg[s], rids[s]
        solo = engine.run_vb(mdl, data, topo, n_iters=n_iters,
                             schedule=engine.Schedule(tau=tau),
                             diagnostics=False)
        err = float(np.max(np.abs(np.asarray(solo.phi)
                                  - np.asarray(out[rid].phi))))
        assert err < 1e-8, f"mixed fleet diverged from solo: {err}"

    t_same = min(serve(same_reqs)[3], serve(same_reqs)[3])

    mixed_sessions_per_s = n_sessions / t_mixed
    same_sessions_per_s = n_sessions / t_same
    ratio = mixed_sessions_per_s / same_sessions_per_s
    pad = {b.label: round(b.data_pad_frac, 3) for b in st.buckets}
    derived = (f"sessions_per_s={mixed_sessions_per_s:.2f} "
               f"same_shape_sessions_per_s={same_sessions_per_s:.2f} "
               f"ratio_vs_same_shape={ratio:.2f} "
               f"n_sessions={n_sessions} n_shapes={len(shapes)} "
               f"n_taus={len(taus)} groups={n_groups} "
               f"compiles={st.compiles} "
               f"padding={pad}")
    assert ratio >= 0.5, (
        f"bucketed mixed-shape fleet must hold >= 0.5x the same-shape "
        f"fleet's sessions/s (got {ratio:.2f}x: mixed {t_mixed:.2f}s vs "
        f"same-shape {t_same:.2f}s for {n_sessions} sessions)")
    yield ("vb_service_mixed",
           common.us_per_iter(t_mixed, n_iters * n_sessions), derived)
