"""Streaming minibatch dSVB vs full-batch dSVB — the paper's 50-node GMM.

The acceptance bar of the streaming subsystem: with `batch_size=20` (20%
of each node's 100 points, so <= 25% of the per-iteration E-step FLOPs)
the streaming run must reach a final KL within 10% of the full-batch run.
The comparison is at EQUAL E-STEP FLOPs — the full-batch run gets T_full
iterations, the streaming run gets T_full * (100/20) iterations, i.e. the
same number of data passes — which is the deployment-relevant question
("what does a FLOP buy me"): random-reshuffling minibatches take five
cheap steps per data pass where full batch takes one expensive one, and
on this instance that lands the streaming run several times BELOW the
full-batch KL, not merely within 10% of it.  The equal-iteration ratio
(streaming noise penalty at the same t) is recorded alongside.

Everything is seeded (data, graph, init, reshuffling stream), so the
committed BENCH_engine.json row is reproducible bit-for-bit across runs
on the same stack.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import engine, expfam
from repro.core import model as model_lib
from repro.data import stream, synthetic

from benchmarks import common

K, D = 3, 2
N_NODES, N_PER, BATCH = 50, 100, 20


def run(full=False):
    iters_full = 1200 if full else 400
    ratio = N_PER // BATCH                       # data passes per iteration
    iters_stream = iters_full * ratio            # equal E-step FLOPs
    data = synthetic.paper_synthetic(n_nodes=N_NODES, n_per_node=N_PER,
                                     seed=0)
    setup = common.setup_gmm(data, K, D, seed=0, graph_seed=0)
    prior, W, ref = setup["prior"], setup["W"], setup["ref_phis"]
    phi0 = jnp.broadcast_to(
        expfam.pack_natural(setup["init_q"]),
        (N_NODES, expfam.flat_dim(K, D)))
    mdl = model_lib.GMMModel(prior, K, D)
    topo = engine.Diffusion(W)

    def go(n_iters, minibatch):
        fn = jax.jit(lambda x, m: engine.run_vb(
            mdl, (x, m), topo, n_iters=n_iters, init_phi=phi0, ref_phi=ref,
            minibatch=minibatch).kl_mean)
        fn(data.x, data.mask)                    # compile
        kl, wall = common.timed(fn, data.x, data.mask)
        return float(kl[-1]), common.us_per_iter(wall, n_iters)

    kl_full, us_full = go(iters_full, None)
    spec = stream.MinibatchSpec(batch_size=BATCH, seed=0)
    kl_stream, us_stream = go(iters_stream, spec)
    kl_stream_eqiter, _ = go(iters_full, spec)

    flops_frac = BATCH / N_PER
    ratio_eqflops = kl_stream / kl_full
    ratio_eqiter = kl_stream_eqiter / kl_full
    common.save("minibatch_bench", {
        "n_nodes": N_NODES, "n_per_node": N_PER, "batch_size": BATCH,
        "iters_full": iters_full, "iters_stream": iters_stream,
        "final_kl_full": kl_full, "final_kl_stream": kl_stream,
        "final_kl_stream_equal_iters": kl_stream_eqiter,
        "kl_ratio_equal_flops": ratio_eqflops,
        "kl_ratio_equal_iters": ratio_eqiter,
        "estep_flops_frac_per_iter": flops_frac,
        "us_per_iter_full": us_full, "us_per_iter_stream": us_stream,
    })
    # the ISSUE acceptance bar: within 10% of full batch at <= 25% of the
    # per-iteration E-step FLOPs (deterministic: everything is seeded)
    assert flops_frac <= 0.25, flops_frac
    assert ratio_eqflops <= 1.10, ratio_eqflops
    return [
        ("minibatch_vb_full", us_full,
         f"n_iters={iters_full} final_kl={kl_full:.2f}"),
        ("minibatch_vb_stream", us_stream,
         f"B={BATCH} n_iters={iters_stream} final_kl={kl_stream:.2f}"),
        ("minibatch_vb", us_stream,
         f"kl_ratio_equal_flops={ratio_eqflops:.3f} "
         f"flops_frac={flops_frac:.2f} "
         f"kl_ratio_equal_iters={ratio_eqiter:.2f}"),
    ]
