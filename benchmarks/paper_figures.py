"""One benchmark per paper figure/table (Sec. V).  Reduced-but-faithful
settings by default (CPU budget); --full restores the paper's exact sizes.

Each function returns rows of (name, us_per_call, derived-metric) and saves
full curves to experiments/benchmarks/*.json for EXPERIMENTS.md.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import algorithms
from repro.data import datasets, synthetic

K, D = 3, 2


def _paper_data(full):
    n_nodes = 50 if full else 20
    n_per = 100 if full else 80
    return synthetic.paper_synthetic(n_nodes=n_nodes, n_per_node=n_per,
                                     seed=1), n_nodes


def fig3_tau_sweep(full=False):
    """Fig. 3: dSVB cost vs forgetting rate tau — optimum in [0.1, 0.3]."""
    data, n = _paper_data(full)
    s = common.setup_gmm(data, K, D, graph_seed=3)
    n_iters = 2000 if full else 500
    taus = [0.02, 0.05, 0.1, 0.2, 0.3, 0.5, 0.8]
    rows, curve = [], {}
    for tau in taus:
        run, wall = common.timed(
            algorithms.run_dsvb, data.x, data.mask, s["W"], s["prior"],
            n_iters=n_iters, K=K, D=D, tau=tau, ref_phi=s["ref_phis"],
            init_q=s["init_q"])
        curve[tau] = {"kl_mean": float(run.kl_mean[-1]),
                      "kl_std": float(run.kl_std[-1])}
    cvb, _ = common.timed(algorithms.run_cvb, data.x, data.mask, s["prior"],
                          n_iters=min(300, n_iters), K=K, D=D,
                          ref_phi=s["ref_phis"], init_q=s["init_q"])
    best_tau = min(curve, key=lambda t: curve[t]["kl_mean"])
    common.save("fig3_tau_sweep", {"curve": curve, "n_iters": n_iters,
                                   "cvb_kl": float(cvb.kl_mean[-1]),
                                   "best_tau": best_tau})
    rows.append(("fig3_tau_sweep", common.us_per_iter(wall, n_iters),
                 f"best_tau={best_tau}"))
    return rows


def fig4_convergence(full=False):
    """Fig. 4: dSVB converges to ~cVB; nsg-dVB biased."""
    data, n = _paper_data(full)
    s = common.setup_gmm(data, K, D, graph_seed=3)
    n_iters = 3000 if full else 1500
    kw = dict(n_iters=n_iters, K=K, D=D, ref_phi=s["ref_phis"],
              init_q=s["init_q"])
    # dSVB runs at fig3's swept optimum (tau=0.2 on this instance stalls
    # the RM ramp 3 decades above cVB); fall back to 0.05 when fig3's
    # snapshot isn't on disk yet
    fig3 = common.load("fig3_tau_sweep") or {}
    tau = float(fig3.get("best_tau", 0.05))
    dsvb, wall = common.timed(algorithms.run_dsvb, data.x, data.mask,
                              s["W"], s["prior"], tau=tau, **kw)
    cvb, _ = common.timed(algorithms.run_cvb, data.x, data.mask, s["prior"],
                          **kw)
    nsg, _ = common.timed(algorithms.run_nsg_dvb, data.x, data.mask, s["W"],
                          s["prior"], **kw)
    nonc, _ = common.timed(algorithms.run_noncoop, data.x, data.mask,
                           s["prior"], **kw)
    sub = slice(0, n_iters, max(1, n_iters // 200))
    common.save("fig4_convergence", {
        "iters": list(range(n_iters))[sub],
        "dsvb": np.asarray(dsvb.kl_mean)[sub].tolist(),
        "cvb": np.asarray(cvb.kl_mean)[sub].tolist(),
        "nsg_dvb": np.asarray(nsg.kl_mean)[sub].tolist(),
        "noncoop": np.asarray(nonc.kl_mean)[sub].tolist(),
        "tau": tau,
        "final": {"dsvb": float(dsvb.kl_mean[-1]),
                  "cvb": float(cvb.kl_mean[-1]),
                  "nsg_dvb": float(nsg.kl_mean[-1]),
                  "noncoop": float(nonc.kl_mean[-1])}})
    ratio = float(dsvb.kl_mean[-1]) / max(float(cvb.kl_mean[-1]), 1e-9)
    return [("fig4_convergence", common.us_per_iter(wall, n_iters),
             f"dsvb/cvb_kl_ratio={ratio:.2f} tau={tau}")]


def fig7_rho_sweep(full=False):
    """Fig. 7: small rho converges faster; too small risks leaving Omega."""
    data, n = _paper_data(full)
    s = common.setup_gmm(data, K, D, graph_seed=3)
    n_iters = 1000 if full else 300
    rhos = [0.25, 0.5, 1.0, 2.0, 8.0]
    curve = {}
    for rho in rhos:
        run, wall = common.timed(
            algorithms.run_dvb_admm, data.x, data.mask, s["adj"], s["prior"],
            n_iters=n_iters, K=K, D=D, rho=rho, ref_phi=s["ref_phis"],
            init_q=s["init_q"])
        tr = np.asarray(run.kl_mean)
        # iterations to reach 1.5x the final cVB-quality level
        target = float(tr[-1]) * 1.5 + 0.5
        t_hit = int(np.argmax(tr < target)) if np.any(tr < target) else -1
        curve[rho] = {"kl_final": float(tr[-1]), "iters_to_1p5x": t_hit,
                      "kl_std": float(run.kl_std[-1])}
    common.save("fig7_rho_sweep", {"curve": curve, "n_iters": n_iters})
    fastest = min(curve, key=lambda r: curve[r]["iters_to_1p5x"]
                  if curve[r]["iters_to_1p5x"] >= 0 else 1e9)
    return [("fig7_rho_sweep", common.us_per_iter(wall, n_iters),
             f"fastest_rho={fastest}")]


def fig8_admm_vs_dsvb(full=False):
    """Fig. 8: dVB-ADMM converges ~5x faster than dSVB to the same KL."""
    data, n = _paper_data(full)
    s = common.setup_gmm(data, K, D, graph_seed=3)
    n_iters = 1500 if full else 600
    kw = dict(n_iters=n_iters, K=K, D=D, ref_phi=s["ref_phis"],
              init_q=s["init_q"])
    dsvb, _ = common.timed(algorithms.run_dsvb, data.x, data.mask, s["W"],
                           s["prior"], tau=0.2, **kw)
    # adaptive rho: plain Algorithm-2 ADMM diverges on the reduced
    # instance, leaving a[-1] so large that BOTH curves cross the target
    # at iteration 0 and the speedup degenerates to 0.0x
    admm, wall = common.timed(algorithms.run_dvb_admm, data.x, data.mask,
                              s["adj"], s["prior"], rho=0.5,
                              adaptive_rho=True, **kw)
    a, d = np.asarray(admm.kl_mean), np.asarray(dsvb.kl_mean)
    target = float(a[-1]) * 1.2 + 0.5
    t_admm = int(np.argmax(a < target)) if np.any(a < target) else n_iters
    t_dsvb = int(np.argmax(d < target)) if np.any(d < target) else n_iters
    speedup = max(t_dsvb, 1) / max(t_admm, 1)
    common.save("fig8_admm_vs_dsvb", {
        "kl_admm_final": float(a[-1]), "kl_dsvb_final": float(d[-1]),
        "iters_admm": t_admm, "iters_dsvb": t_dsvb, "speedup": speedup,
        "std_admm": float(admm.kl_std[-1]), "std_dsvb": float(dsvb.kl_std[-1])})
    return [("fig8_admm_vs_dsvb", common.us_per_iter(wall, n_iters),
             f"admm_speedup={speedup:.1f}x")]


def fig9_imbalance(full=False):
    """Fig. 9: unequal per-node data sizes (40..160) — performance holds."""
    n_nodes = 50 if full else 20
    # paper Fig. 9: sizes 40..160, samples from the WHOLE mixture
    data = synthetic.paper_synthetic(n_nodes=n_nodes, n_per_node=100,
                                     seed=2, unequal_sizes=True,
                                     imbalanced=False)
    s = common.setup_gmm(data, K, D, graph_seed=4)
    n_iters = 1500 if full else 500
    kw = dict(n_iters=n_iters, K=K, D=D, ref_phi=s["ref_phis"],
              init_q=s["init_q"])
    cvb, _ = common.timed(algorithms.run_cvb, data.x, data.mask, s["prior"],
                          **kw)
    dsvb, _ = common.timed(algorithms.run_dsvb, data.x, data.mask, s["W"],
                           s["prior"], tau=0.2, **kw)
    admm, wall = common.timed(algorithms.run_dvb_admm, data.x, data.mask,
                              s["adj"], s["prior"], rho=0.5, **kw)
    common.save("fig9_imbalance", {
        "cvb": float(cvb.kl_mean[-1]), "dsvb": float(dsvb.kl_mean[-1]),
        "admm": float(admm.kl_mean[-1])})
    ratio = float(admm.kl_mean[-1]) / max(float(cvb.kl_mean[-1]), 1e-9)
    return [("fig9_imbalance", common.us_per_iter(wall, n_iters),
             f"admm/cvb_kl_ratio={ratio:.2f}")]


def fig10_network_size(full=False):
    """Fig. 10: N=30/80/100 (reduced: 15/30/45) — converges at any size,
    more slowly for larger networks."""
    sizes = [30, 80, 100] if full else [15, 30, 45]
    n_iters = 2000 if full else 600
    out = {}
    for n in sizes:
        data = synthetic.paper_synthetic(n_nodes=n, n_per_node=60, seed=3)
        s = common.setup_gmm(data, K, D, graph_seed=5)
        run, wall = common.timed(
            algorithms.run_dvb_admm, data.x, data.mask, s["adj"], s["prior"],
            n_iters=n_iters, K=K, D=D, rho=0.5, ref_phi=s["ref_phis"],
            init_q=s["init_q"])
        tr = np.asarray(run.kl_mean)
        target = float(tr[-1]) * 1.5 + 0.5
        out[n] = {"kl_final": float(tr[-1]),
                  "iters_to_1p5x": int(np.argmax(tr < target))}
    common.save("fig10_network_size", out)
    return [("fig10_network_size", common.us_per_iter(wall, n_iters),
             "iters_to_conv=" + "/".join(
                 str(out[n]["iters_to_1p5x"]) for n in sizes))]


def _clustering_table(name, data, Kc, Dc, n_iters, rho, tau, graph_seed):
    s = common.setup_gmm(data, Kc, Dc, graph_seed=graph_seed, beta0=0.05,
                         w0=5.0)
    kw = dict(n_iters=n_iters, K=Kc, D=Dc, init_q=s["init_q"])
    results, wall = {}, 0.0
    cvb, w = common.timed(algorithms.run_cvb, data.x, data.mask, s["prior"],
                          **kw)
    results["cvb"] = common.accuracy(data, cvb.phi, Kc, Dc)
    nonc, _ = common.timed(algorithms.run_noncoop, data.x, data.mask,
                           s["prior"], **kw)
    results["noncoop"] = common.accuracy(data, nonc.phi, Kc, Dc)
    nsg, _ = common.timed(algorithms.run_nsg_dvb, data.x, data.mask, s["W"],
                          s["prior"], **kw)
    results["nsg_dvb"] = common.accuracy(data, nsg.phi, Kc, Dc)
    dsvb, _ = common.timed(algorithms.run_dsvb, data.x, data.mask, s["W"],
                           s["prior"], tau=tau, **kw)
    results["dsvb"] = common.accuracy(data, dsvb.phi, Kc, Dc)
    admm, wall = common.timed(algorithms.run_dvb_admm, data.x, data.mask,
                              s["adj"], s["prior"], rho=rho, **kw)
    results["dvb_admm"] = common.accuracy(data, admm.phi, Kc, Dc)
    common.save(name, results)
    return results, wall, n_iters


def table1_atmosphere(full=False):
    """Table I: atmosphere surrogate (1600 x 3, 2 classes, 20 nodes)."""
    data = datasets.atmosphere_surrogate(n_nodes=20, seed=0)
    res, wall, n_iters = _clustering_table(
        "table1_atmosphere", data, 2, 3, 400 if not full else 1000,
        rho=1.0, tau=0.2, graph_seed=11)
    return [("table1_atmosphere", common.us_per_iter(wall, n_iters),
             f"acc cvb={res['cvb']:.3f} admm={res['dvb_admm']:.3f} "
             f"dsvb={res['dsvb']:.3f} nsg={res['nsg_dvb']:.3f} "
             f"noncoop={res['noncoop']:.3f}")]


def table2_ionosphere(full=False):
    """Table II: ionosphere surrogate (340 x 34, 2 classes, 20 nodes)."""
    data = datasets.ionosphere_surrogate(n_nodes=20, seed=0)
    res, wall, n_iters = _clustering_table(
        "table2_ionosphere", data, 2, 34, 300 if not full else 800,
        rho=16.0, tau=0.2, graph_seed=12)
    return [("table2_ionosphere", common.us_per_iter(wall, n_iters),
             f"acc cvb={res['cvb']:.3f} admm={res['dvb_admm']:.3f} "
             f"dsvb={res['dsvb']:.3f} nsg={res['nsg_dvb']:.3f} "
             f"noncoop={res['noncoop']:.3f}")]


def fig13_coil20(full=False):
    """Fig. 13: accuracy vs number of clusters K on the COIL-20 surrogate."""
    Ks = list(range(2, 11, 2)) if full else [2, 4, 6]
    out = {}
    for Kc in Ks:
        data = datasets.coil20_surrogate(Kc, n_nodes=10, seed=Kc)
        res, wall, n_iters = _clustering_table(
            f"fig13_coil20_K{Kc}", data, Kc, 52,
            250 if not full else 600, rho=16.0, tau=0.2, graph_seed=13)
        out[Kc] = res
    common.save("fig13_coil20", out)
    last = out[Ks[-1]]
    return [("fig13_coil20", common.us_per_iter(wall, n_iters),
             f"K={Ks[-1]} acc admm={last['dvb_admm']:.3f} "
             f"cvb={last['cvb']:.3f} noncoop={last['noncoop']:.3f}")]


ALL = [fig3_tau_sweep, fig4_convergence, fig7_rho_sweep, fig8_admm_vs_dsvb,
       fig9_imbalance, fig10_network_size, table1_atmosphere,
       table2_ionosphere, fig13_coil20]
