"""End-to-end GMM engine-iteration benchmark: reference vs fused backend.

Runs the SAME `engine.run_vb` dSVB loop on the paper's sensor config
(reduced sizes by default) with each compute backend and reports
us/iteration plus the reference/fused speedup and the final-phi parity.

On this CPU container the fused path executes the Pallas kernel body in
interpret mode, so the speedup number here is a *parity + plumbing* signal
(interpret-mode timings are not TPU-representative in either direction);
on a TPU backend the same call compiles to Mosaic and the row becomes the
real hot-path speedup.  The JSON emitted via `run.py --json` keeps both
rows so the perf trajectory is tracked either way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, expfam, network
from repro.core import model as model_lib
from repro.data import synthetic

from benchmarks import common

K, D = 3, 2


def run(full=False):
    n_nodes = 50 if full else 16
    n_per = 100 if full else 60
    n_iters = 200 if full else 60
    data = synthetic.paper_synthetic(n_nodes=n_nodes, n_per_node=n_per,
                                     seed=1, dtype=np.float32)
    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0,
                                        dtype=jnp.float32)
    adj, _ = network.random_geometric_graph(n_nodes, seed=3)
    W = network.nearest_neighbor_weights(adj).astype(jnp.float32)
    mdl = model_lib.GMMModel(prior, K, D)
    topo = engine.Diffusion(W)

    runs, rows = {}, []
    for backend in ("reference", "fused"):
        fn = jax.jit(lambda x, m, b=backend: engine.run_vb(
            mdl, (x, m), topo, n_iters=n_iters, backend=b).phi)
        fn(data.x, data.mask)                       # compile
        out, wall = common.timed(fn, data.x, data.mask)
        runs[backend] = out
        rows.append((f"backend_{backend}_engine",
                     common.us_per_iter(wall, n_iters),
                     f"n_nodes={n_nodes} n_iters={n_iters}"))
    err = float(jnp.max(jnp.abs(runs["reference"] - runs["fused"])
                        / (jnp.abs(runs["reference"]) + 1.0)))
    speedup = rows[0][1] / max(rows[1][1], 1e-9)
    interp = jax.default_backend() != "tpu"
    rows.append(("backend_speedup", rows[1][1],
                 f"ref/fused={speedup:.2f}x interpret={interp} "
                 f"max_rel_phi_err={err:.1e}"))
    common.save("gmm_backend_bench", {
        "us_per_iter_reference": rows[0][1], "us_per_iter_fused": rows[1][1],
        "speedup_ref_over_fused": speedup, "interpret_mode": interp,
        "max_rel_phi_err": err, "n_nodes": n_nodes, "n_iters": n_iters})
    assert err < 1e-3, f"backend parity broken: {err}"
    return rows
