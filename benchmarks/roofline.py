"""Aggregate the dry-run JSON reports into the §Roofline table."""
from __future__ import annotations

import glob
import json
import os

HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRYRUN_DIR = os.path.join(HERE, "experiments", "dryrun")


def load_reports(pattern: str = "*.json") -> list[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def table(reports=None, mesh: str = "16x16", dp_mode: str = "allreduce",
          kernels=False) -> str:
    reports = reports or load_reports()
    rows = [r for r in reports if r["mesh"] == mesh
            and r["dp_mode"] == dp_mode and r.get("use_kernels", False) ==
            kernels]
    hdr = (f"{'arch':24s} {'shape':12s} {'Tc_ms':>9s} {'Tm_ms':>9s} "
           f"{'Tcoll_ms':>9s} {'bound':>10s} {'useful':>6s} "
           f"{'args_GiB':>8s} {'temp_GiB':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        ma = r.get("memory_analysis", {})
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} "
            f"{r['t_compute_s']*1e3:9.2f} {r['t_memory_s']*1e3:9.2f} "
            f"{r['t_collective_s']*1e3:9.2f} {r['bottleneck']:>10s} "
            f"{r['useful_flops_ratio']:6.2f} "
            f"{(ma.get('argument_size_in_bytes') or 0)/2**30:8.2f} "
            f"{(ma.get('temp_size_in_bytes') or 0)/2**30:8.2f}")
    return "\n".join(lines)


def run(full=False):
    reports = load_reports()
    if not reports:
        return [("roofline", 0.0, "no dryrun reports — run "
                 "`python -m repro.launch.dryrun --arch all --shape all "
                 "--both_meshes` first")]
    n16 = sum(r["mesh"] == "16x16" for r in reports)
    n512 = sum(r["mesh"] == "2x16x16" for r in reports)
    bounds = {}
    for r in reports:
        if r["mesh"] == "16x16" and r["dp_mode"] == "allreduce":
            bounds[r["bottleneck"]] = bounds.get(r["bottleneck"], 0) + 1
    print(table(reports))
    return [("roofline", 0.0,
             f"baselines 16x16={n16} 2x16x16={n512} bound:{bounds}")]


if __name__ == "__main__":
    print(table())
    print()
    print(table(mesh="2x16x16"))
