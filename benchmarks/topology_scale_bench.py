"""Topology scale sweep: dense oracle vs sparse edge-list combines at
N in {50, 1k, 10k} (ROADMAP item 3).

For each network size this times one VB iteration (us/iter, compiled,
KL metric included) and records the KL-vs-iterations trajectory for the
sparse diffusion, pairwise-gossip, and hierarchical-fusion topologies —
plus the dense-matrix diffusion oracle where it still fits (50, 1k; at
10k the dense mixing matrix alone would be 800 MB, which is the point
of the sparse path).  The committed 10k row carries the scale contract
itself: the lowered sparse step contains NO (N, N) tensor — per-
iteration memory is O(E + N), independent of N^2 — asserted against the
StableHLO text, not inferred.

Everything is seeded (data, graph, gossip activation), so the committed
BENCH_engine.json rows are reproducible bit-for-bit on the same stack.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, expfam, gmm, network, refperm
from repro.core import model as model_lib
from repro.data import synthetic

from benchmarks import common

K, D = 3, 2
N_PER = 20
N_SWEEP = (50, 1_000, 10_000)
DENSE_MAX = 1_000            # largest N the dense oracle still runs at


def _iters(n: int, full: bool) -> int:
    if n <= 50:
        return 400 if full else 100
    if n <= 1_000:
        return 120 if full else 40
    return 60 if full else 16


def _setup(n: int):
    data = synthetic.paper_synthetic(n_nodes=n, n_per_node=N_PER, seed=0)
    prior = expfam.noninformative_prior(K, D, beta0=0.1, w0_scale=10.0)
    mdl = model_lib.GMMModel(prior, K, D)
    x_all, labels = data.flat
    ref_q = gmm.ground_truth_posterior(x_all, labels, prior, K)
    ref_phis = refperm.permuted_refs(ref_q)
    g, _pos = network.random_geometric_edges(n, seed=0)
    return data, mdl, ref_phis, g


def _time_run(mdl, data, topo, n_iters, ref_phis):
    fn = jax.jit(lambda x, m: engine.run_vb(
        mdl, (x, m), topo, n_iters=n_iters, ref_phi=ref_phis,
        schedule=engine.Schedule()).kl_mean)
    fn(data.x, data.mask)                        # compile
    kl, wall = common.timed(fn, data.x, data.mask)
    kl = np.asarray(kl)
    return kl, common.us_per_iter(wall, n_iters)


def _no_dense_matrix_in_hlo(topo, n: int) -> bool:
    """The memory contract: the lowered combine has no (N, N) tensor."""
    sds = jax.ShapeDtypeStruct((n, expfam.flat_dim(K, D)), jnp.float64)
    txt = jax.jit(lambda v: topo.combine(v, t=1)).lower(sds).as_text()
    return f"{n}x{n}" not in txt


def run(full=False):
    expfam.enable_x64()
    rows, payload = [], {}
    for n in N_SWEEP:
        n_iters = _iters(n, full)
        data, mdl, ref_phis, g = _setup(n)
        sw = network.sparse_nearest_neighbor_weights(g)
        n_gw = max(1, n // 16)
        gw, rg = network.two_level_partition(n, n_gw, max(1, n_gw // 8))
        topos = [
            ("sparse_diffusion", engine.Diffusion(sw)),
            ("gossip", engine.PairwiseGossip(g, p_activate=0.3, seed=5)),
            ("hierarchical", engine.HierarchicalFusion(gw, rg)),
        ]
        if n <= DENSE_MAX:
            W = network.nearest_neighbor_weights(
                jnp.asarray(g.to_dense()))
            topos.insert(0, ("dense_diffusion", engine.Diffusion(W)))
        for tname, topo in topos:
            kl, us = _time_run(mdl, data, topo, n_iters, ref_phis)
            name = f"topology_scale_{tname}_n{n}"
            derived = (f"edges={g.n_undirected} n_iters={n_iters} "
                       f"kl0={kl[0]:.1f} kl_final={kl[-1]:.2f}")
            if tname != "dense_diffusion":
                no_nxn = _no_dense_matrix_in_hlo(topo, n)
                assert no_nxn, f"{name}: (N,N) tensor leaked into HLO"
                if n > DENSE_MAX:
                    derived += (f" no_nxn_hlo={no_nxn}"
                                f" dense_bytes_avoided={8 * n * n}")
            rows.append((name, us, derived))
            payload[f"{tname}_n{n}"] = {
                "us_per_iter": us, "n_iters": n_iters,
                "edges": g.n_undirected, "kl_vs_iters": kl.tolist(),
            }
    common.save("topology_scale_bench", payload)
    return rows
