"""Shared benchmark machinery for the paper-figure reproductions."""
from __future__ import annotations

import itertools
import json
import os
import time

import jax
import numpy as np

from repro.core import algorithms, expfam, gmm, network, refperm

OUTDIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "benchmarks")


def setup_gmm(data, K, D, *, seed=0, graph_seed=0, beta0=0.1, w0=10.0):
    expfam.enable_x64()
    prior = expfam.noninformative_prior(K, D, beta0=beta0, w0_scale=w0)
    n = data.x.shape[0]
    adj, _ = network.random_geometric_graph(n, seed=graph_seed)
    W = network.nearest_neighbor_weights(adj)
    x_all, labels_all = data.flat
    ref = gmm.ground_truth_posterior(x_all, labels_all, prior, K)
    ref_phis = (refperm.permuted_refs(ref) if K <= 6 else None)
    init_q = algorithms._perturbed_init(prior, data.x,
                                        jax.random.PRNGKey(seed))
    return dict(prior=prior, adj=adj, W=W, ref_phis=ref_phis, init_q=init_q)


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    return out, time.time() - t0


def us_per_iter(wall_s: float, n_iters: int, n_repeat: int = 1) -> float:
    return wall_s / (n_iters * n_repeat) * 1e6


def accuracy(data, phi_nodes, K, D) -> float:
    """Mean clustering accuracy over nodes, best label permutation."""
    x_all, labels = data.flat
    labels = np.asarray(labels)
    accs = []
    for i in range(phi_nodes.shape[0]):
        q = expfam.unpack_natural(phi_nodes[i], K, D)
        pred = np.asarray(gmm.predict_labels(x_all, q))
        best = max(np.mean(np.asarray([p[c] for c in pred]) == labels)
                   for p in itertools.permutations(range(K)))
        accs.append(best)
    return float(np.mean(accs))


def save(name: str, payload: dict):
    os.makedirs(OUTDIR, exist_ok=True)
    with open(os.path.join(OUTDIR, name + ".json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def load(name: str) -> dict | None:
    """Read back a prior `save` (cross-benchmark handoff), None if absent."""
    path = os.path.join(OUTDIR, name + ".json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)
