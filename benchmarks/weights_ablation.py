"""Combination-weight rule ablation (paper Sec. III-A lists nearest-
neighbour, Metropolis and Laplacian rules as valid choices for Eq. 27b).

Runs dSVB under nearest-neighbour (Eq. 47) vs Metropolis weights on the
Sec. V-A instance — both must converge; Metropolis (doubly stochastic)
typically mixes slightly faster on irregular graphs.
"""
from __future__ import annotations

from benchmarks import common
from repro.core import algorithms, network
from repro.data import synthetic

K, D = 3, 2


def run(full=False):
    data = synthetic.paper_synthetic(n_nodes=50 if full else 20,
                                     n_per_node=100 if full else 80, seed=1)
    s = common.setup_gmm(data, K, D, graph_seed=3)
    n_iters = 2000 if full else 600
    kw = dict(n_iters=n_iters, K=K, D=D, ref_phi=s["ref_phis"],
              init_q=s["init_q"])
    w_nn = s["W"]
    w_mh = network.metropolis_weights(s["adj"])
    nn, _ = common.timed(algorithms.run_dsvb, data.x, data.mask, w_nn,
                         s["prior"], tau=0.2, **kw)
    mh, wall = common.timed(algorithms.run_dsvb, data.x, data.mask, w_mh,
                            s["prior"], tau=0.2, **kw)
    res = {"nearest_neighbor": {"kl": float(nn.kl_mean[-1]),
                                "std": float(nn.kl_std[-1])},
           "metropolis": {"kl": float(mh.kl_mean[-1]),
                          "std": float(mh.kl_std[-1])}}
    common.save("weights_ablation", res)
    return [("weights_ablation", common.us_per_iter(wall, n_iters),
             f"kl nn={res['nearest_neighbor']['kl']:.2f} "
             f"metropolis={res['metropolis']['kl']:.2f}")]
