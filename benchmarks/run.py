"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` restores the
paper's exact experiment sizes (50 nodes, 2000-3000 iterations; the 300 MC
trials are NOT replicated — see README "Quickstart" / EXPERIMENTS.md);
default settings are reduced-but-faithful for the CPU container.

``--json PATH`` additionally emits a machine-readable snapshot:
``{name: {us_per_call, derived}}`` plus a ``failed`` list.  It DEFAULTS to
``BENCH_engine.json`` at the repo root — that file is committed, so the
perf trajectory accumulates in-tree across PRs instead of living only in
CI artifacts (pass ``--json /dev/null`` to opt out).  ``--only`` matches
comma-separated prefixes against either the benchmark name or its group
(``paper_fig`` selects every fig*/table* reproduction).
"""
import argparse
import json
import os
import sys
import traceback

# self-bootstrapping: runnable as `python benchmarks/run.py` without any
# PYTHONPATH setup (repo root for `benchmarks`, src/ for `repro`)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark-name or group prefixes")
    ap.add_argument("--json", metavar="PATH",
                    default=os.path.join(_ROOT, "BENCH_engine.json"),
                    help="also write {name: {us_per_call, derived}} JSON "
                         "(default: BENCH_engine.json at the repo root, "
                         "which is committed so the perf trajectory "
                         "accumulates across PRs)")
    args, _ = ap.parse_known_args()

    from benchmarks import consensus_bench, gmm_backend_bench, kernel_bench, \
        linreg_bench, minibatch_bench, paper_figures, roofline, \
        svrg_bench, topology_scale_bench, vb_service_bench, \
        weights_ablation
    # (group, name, fn) — group is an --only alias for a family of benches
    benches = ([("paper_fig", f.__name__, f) for f in paper_figures.ALL]
               + [("weights_ablation", "weights_ablation",
                   weights_ablation.run),
                  ("linreg_generality", "linreg_generality",
                   linreg_bench.run),
                  ("kernel_bench", "kernel_bench", kernel_bench.run),
                  ("gmm_backend", "gmm_backend", gmm_backend_bench.run),
                  ("minibatch_vb", "minibatch_vb", minibatch_bench.run),
                  ("svrg_vb", "svrg_vb", svrg_bench.run),
                  ("vb_service", "vb_service_throughput",
                   vb_service_bench.run),
                  ("vb_driver", "vb_driver_poisson",
                   vb_service_bench.run_poisson),
                  ("vb_mixed", "vb_service_mixed",
                   vb_service_bench.run_mixed_fleet),
                  ("consensus_lm", "consensus_lm", consensus_bench.run),
                  ("consensus_vb", "consensus_vb", consensus_bench.vb_run),
                  ("topology_scale", "topology_scale",
                   topology_scale_bench.run),
                  ("roofline", "roofline", roofline.run)])
    if args.only:
        pre = tuple(args.only.split(","))
        benches = [b for b in benches
                   if b[0].startswith(pre) or b[1].startswith(pre)]

    print("name,us_per_call,derived")
    results, failed = {}, []
    for _group, bname, bench in benches:
        try:
            for name, us, derived in bench(full=args.full):
                print(f"{name},{us:.1f},{derived}")
                sys.stdout.flush()
                results[name] = {"us_per_call": us, "derived": derived}
        except Exception:
            failed.append(bname)
            print(f"{bname},nan,FAILED")
            traceback.print_exc()
    if args.json and args.json != "/dev/null":
        # merge into an existing snapshot (partial --only runs must not
        # wipe the committed trajectory's other rows)
        merged = {}
        if os.path.exists(args.json):
            try:
                with open(args.json) as f:
                    merged = json.load(f).get("results", {})
            except (ValueError, OSError) as e:
                print(f"WARNING: could not parse existing {args.json} "
                      f"({e}); its rows will be lost", file=sys.stderr)
        merged.update(results)
        with open(args.json, "w") as f:
            json.dump({"results": merged, "failed": failed}, f, indent=1,
                      default=float)
    if failed:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
