"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` restores the
paper's exact experiment sizes (50 nodes, 2000-3000 iterations, 300 MC
trials are NOT replicated — see DESIGN.md §7); default settings are
reduced-but-faithful for the CPU container.
"""
import argparse
import os
import sys
import traceback

# self-bootstrapping: runnable as `python benchmarks/run.py` without any
# PYTHONPATH setup (repo root for `benchmarks`, src/ for `repro`)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark-name prefixes")
    args, _ = ap.parse_known_args()

    from benchmarks import consensus_bench, kernel_bench, linreg_bench, \
        paper_figures, roofline, weights_ablation
    benches = ([(f.__name__, f) for f in paper_figures.ALL]
               + [("weights_ablation", weights_ablation.run),
                  ("linreg_generality", linreg_bench.run),
                  ("kernel_bench", kernel_bench.run),
                  ("consensus_lm", consensus_bench.run),
                  ("roofline", roofline.run)])
    if args.only:
        pre = tuple(args.only.split(","))
        benches = [b for b in benches if b[0].startswith(pre)]

    print("name,us_per_call,derived")
    failed = 0
    for bname, bench in benches:
        try:
            for name, us, derived in bench(full=args.full):
                print(f"{name},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception:
            failed += 1
            print(f"{bname},nan,FAILED")
            traceback.print_exc()
    if failed:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
